"""Fig 7 (ours): sustained-traffic serving over a (replica, shard) mesh.

Closed-loop Poisson benchmark for the replicated serving tier
(``serving.router.ReplicatedSearchEngine``): S concurrent sessions each
replay a multi-turn conversation, submitting turn t+1 an exponential
think time after turn t's result arrives.  Per-replica pump threads run
the continuous-batching loop (launch wave N+1 while wave N runs on
device).  Reported per replica count: sustained QPS (turns/s), client
p50/p99 latency (submit → result), per-replica load balance, and slab
eviction counts.

What the replica axis buys — **session capacity**, not just parallel
devices: the ``SessionStore`` slab and ``ResultCache`` are per-replica
device state with a fixed slot count, so at R replicas a session
population of R·n_slots sits fully resident.  The benchmark holds
``n_slots`` per replica FIXED and sizes the population to S = 2·n_slots:
at ``replicas=1`` the LRU slab thrashes — nearly every turn evicts a
session (a full-slab zero-scatter dispatch per eviction, on top of the
wave's own scatter) and returns as a rebuilt first turn — while at
``replicas=2`` every session stays resident and steady-state turns pay
only the cached TopLoc step.  That cost gap is hardware-independent
(evictions are extra device dispatches on any platform), which is what
makes the smoke-mode QPS assertion meaningful on a CPU host where R
device groups time-share the same cores.

Bit-identity gate (smoke): the ``replicas=2`` run must reproduce the
single-replica *sequential* engine per session, bit for bit, with the
result cache off AND on — session pinning + per-drain wave splitting +
the sharded-scan identity contract compose end to end.  The thrashing
``replicas=1`` run is intentionally NOT bit-identical (evictions rebuild
sessions); its eviction count is reported instead.

  PYTHONPATH=src:. python benchmarks/fig7_serving.py
  PYTHONPATH=src:. python benchmarks/fig7_serving.py --smoke
"""
from __future__ import annotations

import os
import sys

if "--smoke" in sys.argv:
    os.environ.setdefault("BENCH_DOCS", "4000")
    os.environ.setdefault("BENCH_PARTITIONS", "512")
    os.environ.setdefault("BENCH_CONVS", "256")
    os.environ.setdefault("BENCH_TURNS", "4")

# must happen before jax import: give the host platform 8 devices
_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import heapq
import random
import threading
import time
from typing import Dict, Tuple

import numpy as np
import jax

from repro.serving import (ConversationalSearchEngine,
                           ReplicatedSearchEngine, ServingConfig)
from benchmarks import common as C

K = 10
NPROBE = 8
H = 384
SHARDS = 2
MAX_BATCH = 32
THINK_MEAN_S = 0.001          # mean exponential think time between turns
CACHE_THRESHOLD = 0.95
CACHE_DEPTH = 64
REPEATS = 3                   # timed runs per replica count (best-of QPS)


def config(*, cache: bool, shards: int = 0) -> ServingConfig:
    # the replicated runs shard the corpus (shards=SHARDS per replica);
    # the sequential oracle runs unsharded — the sharded-scan identity
    # contract (tests/test_sharded_retrieval.py) bridges the two
    return ServingConfig(
        backend="ivf", strategy="toploc+", k=K, nprobe=NPROBE, h=H,
        alpha=0.25, shards=shards,
        cache_threshold=CACHE_THRESHOLD if cache else 0.0,
        cache_depth=CACHE_DEPTH if cache else 0)


def closed_loop(eng: ReplicatedSearchEngine, wl, *, think_mean_s: float,
                seed: int) -> Dict:
    """Drive S sessions closed-loop: session j submits turn t+1 an
    Exp(think) after turn t resolves.  Returns QPS, latency percentiles,
    and every (session, turn) result for the identity gate."""
    S, T = wl.conversations.shape[0], wl.conversations.shape[1]
    rng = random.Random(seed)
    cond = threading.Condition()
    heap = []                             # (due, session, turn)
    lat = []
    results: Dict[Tuple[int, int], Tuple] = {}
    remaining = [S * T]

    def on_done(sid: int, turn: int, t_submit: float):
        def cb(fut):
            res = fut.result()            # propagate engine errors
            now = time.perf_counter()
            with cond:
                lat.append(now - t_submit)
                results[(sid, turn)] = res
                remaining[0] -= 1
                if turn + 1 < T:
                    heapq.heappush(
                        heap,
                        (now + rng.expovariate(1.0 / think_mean_s),
                         sid, turn + 1))
                cond.notify()
        return cb

    t0 = time.perf_counter()
    with cond:
        for sid in range(S):              # all sessions arrive at t=0
            heapq.heappush(heap, (t0, sid, 0))
    eng.start()
    while True:
        with cond:
            if remaining[0] == 0:
                break
            now = time.perf_counter()
            if not heap or heap[0][0] > now:
                timeout = (heap[0][0] - now) if heap else 0.05
                cond.wait(timeout)
                continue
            _, sid, turn = heapq.heappop(heap)
        # submit outside the condition: the future may resolve (and its
        # callback take cond) before submit returns
        fut = eng.submit(sid_name(sid), wl.conversations[sid, turn])
        fut.add_done_callback(on_done(sid, turn, time.perf_counter()))
    wall = time.perf_counter() - t0
    lat_ms = np.asarray(lat) * 1e3
    return {
        "qps": (S * T) / wall,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "results": results,
        "load": eng.load_stats(),
        "evictions": sum(e.store.evictions for e in eng.engines),
    }


def sid_name(sid: int) -> str:
    return f"s{sid}"


def sequential_reference(wl, *, cache: bool, ivf_idx
                         ) -> Dict[Tuple[int, int], Tuple]:
    """Per-session oracle: the single-replica sequential engine."""
    eng = ConversationalSearchEngine(config(cache=cache), ivf_index=ivf_idx)
    out = {}
    for sid in range(wl.conversations.shape[0]):
        for t in range(wl.conversations.shape[1]):
            out[(sid, t)] = eng.query(sid_name(sid),
                                      wl.conversations[sid, t])
    return out


def check_identity(got: Dict, want: Dict, label: str) -> None:
    assert got.keys() == want.keys(), f"{label}: turn sets differ"
    for key in want:
        gv, gi = got[key]
        wv, wi = want[key]
        if not (np.array_equal(np.asarray(gv), np.asarray(wv))
                and np.array_equal(np.asarray(gi), np.asarray(wi))):
            raise AssertionError(f"{label}: results differ at {key}")
    print(f"  identity OK ({label}: {len(want)} turns bit-identical to "
          "the sequential engine)")


def warmup(eng: ReplicatedSearchEngine, wl) -> None:
    """Compile every program the timed loop will hit (the single-bucket
    batcher keeps this to one batched step per engine), plus the
    acquire/release scatter paths, then reset the accounting."""
    d = wl.conversations.shape[-1]
    for e in eng.engines:
        for j in range(MAX_BATCH):
            e.submit(f"warm{j}", np.zeros(d, np.float32))
        e.drain()
        for j in range(MAX_BATCH):
            e.end_conversation(f"warm{j}")
        e.records.clear()
        e.turn_count.clear()
        e.store.evictions = 0
        if e._cache is not None:
            e._cache.hits = e._cache.misses = 0


def run(wl, ivf_idx, *, replicas: int, n_slots: int, cache: bool,
        seed: int) -> Dict:
    with ReplicatedSearchEngine(
            config(cache=cache, shards=SHARDS), replicas=replicas,
            ivf_index=ivf_idx, n_slots=n_slots, max_batch=MAX_BATCH,
            max_wait_s=0.003, buckets=(MAX_BATCH,)) as eng:
        warmup(eng, wl)
        out = closed_loop(eng, wl, think_mean_s=THINK_MEAN_S, seed=seed)
    return out


def main():
    smoke = "--smoke" in sys.argv
    wl = C.workload("cast20")
    idx = C.ivf_index("cast20")
    S, T = wl.conversations.shape[0], wl.conversations.shape[1]
    # fixed per-replica slab: R=1 holds half the population (LRU
    # thrash), R=2 holds all of it resident
    n_slots = max(MAX_BATCH, S // 2)
    print(f"corpus: {C.N_DOCS} docs, p={C.PARTITIONS}; traffic: {S} "
          f"sessions x {T} turns, think ~Exp({THINK_MEAN_S * 1e3:.0f}ms); "
          f"{n_slots} slots/replica, shards={SHARDS}, "
          f"devices={jax.device_count()}")

    # throughput runs serve the full production config (result cache
    # on): an eviction then costs TWO full-slab zero-scatters (session
    # slab + cache slab row), which is exactly what thrashing costs a
    # real deployment
    print(f"\n{'replicas':>8s} {'qps':>8s} {'p50 ms':>8s} {'p99 ms':>8s} "
          f"{'imbalance':>9s} {'evictions':>9s}")
    stats = {}
    for replicas in (1, 2):
        outs = [run(wl, idx, replicas=replicas, n_slots=n_slots,
                    cache=True, seed=7 + 10 * replicas + rep)
                for rep in range(REPEATS)]
        # best-of-N on both sides: sustained QPS under closed-loop load
        # is interference-noise-prone on a shared host, and the best run
        # is the least-perturbed estimate of what the engine sustains
        out = max(outs, key=lambda o: o["qps"])
        stats[replicas] = out
        print(f"{replicas:8d} {out['qps']:8.1f} {out['p50_ms']:8.2f} "
              f"{out['p99_ms']:8.2f} {out['load']['imbalance']:9.2f} "
              f"{out['evictions']:9d}")

    speedup = stats[2]["qps"] / stats[1]["qps"]
    print(f"\nsustained QPS: replicas=2 is {speedup:.2f}x replicas=1 "
          f"(fixed {n_slots}-slot slab per replica; "
          f"{stats[1]['evictions']} vs {stats[2]['evictions']} evictions)")

    # identity gate on the non-thrashing run, cache on (reusing the
    # timed run's results) and off (one extra replicas=2 run)
    check_identity(stats[2]["results"],
                   sequential_reference(wl, cache=True, ivf_idx=idx),
                   "cache on")
    uncached = run(wl, idx, replicas=2, n_slots=n_slots, cache=False,
                   seed=11)
    check_identity(uncached["results"],
                   sequential_reference(wl, cache=False, ivf_idx=idx),
                   "cache off")

    if smoke:
        assert jax.device_count() >= 2 * SHARDS, (
            "smoke needs a multi-device host platform")
        assert speedup >= 1.5, (
            f"replicas=2 QPS only {speedup:.2f}x replicas=1 (need 1.5x)")
        assert stats[2]["load"]["imbalance"] <= 1.3, (
            f"per-replica imbalance {stats[2]['load']['imbalance']:.2f} "
            "> 1.3")
        assert stats[2]["evictions"] == 0, (
            "replicas=2 run evicted sessions — capacity sizing is wrong")
        print(f"SMOKE OK: {speedup:.2f}x >= 1.5x, imbalance "
              f"{stats[2]['load']['imbalance']:.2f} <= 1.3, identity holds "
              "with cache on and off")


if __name__ == "__main__":
    main()
