"""Figure 1 reproduction: effectiveness/efficiency frontier vs nprobe.

Sweeps np over powers of two for IVF, TopLoc_IVF, TopLoc_IVF+ and
TopLoc_IVFPQ on both conversation sets — NDCG@10 vs per-turn time and
vs distance computations (the paper varies np exactly this way; the PQ
row shows how much of the frontier survives 4·d/m-compressed lists).

``--smoke`` shrinks the corpus and asserts the paper's frontier claim:
TopLoc_IVF does strictly less distance work than plain IVF at the same
nprobe while holding NDCG@10 within 0.9x.

  PYTHONPATH=src:. python benchmarks/fig1_ivf_sweep.py --smoke
"""
from __future__ import annotations

import sys
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import toploc as TL
from repro.core.backend import IVFBackend, IVFPQBackend
from benchmarks import common as C

NPROBES = (4, 8, 16, 32, 64)
H_FACTOR = 16         # h = 16·np (np/h ≈ 6%, paper-regime grid point)
ALPHA = 0.25
K = 10
RERANK = 64


def sweep(kind: str, csv: bool = True) -> List[Dict]:
    wl = C.workload(kind)
    index = C.ivf_index(kind)
    pq_index = C.ivf_pq_index(kind)
    convs = jnp.asarray(wl.conversations)
    n_conv, turns, _ = convs.shape
    rows = []
    for npb in NPROBES:
        h = min(H_FACTOR * npb, index.p)
        for method, mode, alpha in (
                ("IVF", "plain", -1.0),
                ("TopLoc_IVF", "toploc", -1.0),
                ("TopLoc_IVF+", "toploc", ALPHA),
                ("TopLoc_IVFPQ", "toploc", -1.0)):
            if method == "TopLoc_IVFPQ":
                bk = IVFPQBackend(h=h, nprobe=npb, alpha=alpha,
                                  rerank=RERANK)
                bidx = pq_index
            else:
                bk = IVFBackend(h=h, nprobe=npb, alpha=alpha)
                bidx = index

            def all_convs(cs, bk=bk, bidx=bidx, mode=mode):
                return jax.vmap(lambda conv: TL.conversation(
                    bk, bidx, conv, k=K, mode=mode))(cs)

            fn = jax.jit(all_convs)
            _, ids, stats = fn(convs)
            jax.block_until_ready(ids)
            wall = C.time_fn(fn, convs, repeat=2)
            metrics = C.eval_conversations(np.asarray(ids), wl)
            work = float((np.asarray(stats.centroid_dists)
                          + np.asarray(stats.list_dists)).mean())
            code_work = float(np.asarray(stats.code_dists).mean())
            row = dict(dataset=kind, method=method, nprobe=npb, h=h,
                       ndcg10=metrics["ndcg@10"], mrr10=metrics["mrr@10"],
                       ms_per_turn=1e3 * wall / (n_conv * turns),
                       work=work, code_work=code_work)
            rows.append(row)
            if csv:
                print(f"fig1,{kind},{method},{npb},{row['ndcg10']:.3f},"
                      f"{row['ms_per_turn']:.3f},{work:.0f},"
                      f"{code_work:.0f}")
    return rows


def _assert_smoke_floors(rows: List[Dict]) -> None:
    by = {(r["method"], r["nprobe"]): r for r in rows}
    for npb in NPROBES:
        plain, tl = by[("IVF", npb)], by[("TopLoc_IVF", npb)]
        assert tl["work"] < plain["work"], (
            f"np={npb}: TopLoc_IVF work {tl['work']:.0f} not below "
            f"IVF {plain['work']:.0f}")
        assert tl["ndcg10"] >= 0.9 * plain["ndcg10"], (
            f"np={npb}: TopLoc_IVF ndcg@10 {tl['ndcg10']:.3f} < "
            f"0.9 x IVF {plain['ndcg10']:.3f}")
    print("SMOKE OK: TopLoc_IVF under IVF work at every nprobe with "
          "ndcg@10 >= 0.9x")


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    if smoke:
        global NPROBES
        C.N_DOCS, C.PARTITIONS = 4000, 128
        C.CONVS, C.TURNS = 6, 6
        NPROBES = (2, 4)        # keep h = 16*np < p so pruning is live
    print("fig,dataset,method,nprobe,ndcg@10,ms_per_turn,work_dists,"
          "code_dists")
    rows = []
    for kind in (("cast19",) if smoke else ("cast19", "cast20")):
        rows += sweep(kind)
    if smoke:
        _assert_smoke_floors(rows)


if __name__ == "__main__":
    main()
