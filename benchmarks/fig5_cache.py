"""Fig 5 (ours): the session-level historical-embedding result cache.

Frieder et al., *Caching Historical Embeddings in Conversational
Search*, show that the topical locality TopLoc exploits for index
pruning also makes per-conversation result caches effective.  This
figure sweeps the cache's cosine threshold on both synthetic CAsT sets
and reports the operating curve:

  * **hit rate** — fraction of turns answered straight from the cached
    document embeddings (zero backend work: no centroid scoring, no
    list scan);
  * **recall@10 vs the uncached run** — how much of the exact TopLoc
    answer the cached answer retains;
  * **recall@10 vs exact search** and ndcg@10 — absolute effectiveness;
  * **mean backend work per turn** — the paper-style distance counters,
    shrinking with the hit rate.

``threshold = 0`` disables the cache (the uncached baseline — bit-
identical to a cache-absent engine, pinned by tests/test_result_cache).
Higher thresholds admit only nearer-duplicate queries: fewer hits, less
work saved, but near-perfect agreement with the uncached ranking.  The
cache stores ``DEPTH`` candidates per session (the engine over-fetches
the backend once per miss) so hits re-score a deeper pool than the k
returned — the knob Frieder et al. use to trade one miss's extra work
for many cheap hits.

``--smoke`` runs a tiny corpus and asserts the CI floors: at the
operating threshold the cache must actually hit (hit-rate > 0) while
keeping recall@10 ≥ 0.95x the uncached run's.

  PYTHONPATH=src:. python benchmarks/fig5_cache.py
  PYTHONPATH=src:. python benchmarks/fig5_cache.py --smoke
"""
from __future__ import annotations

import sys
from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from repro.core import ivf as IV
from repro.serving import ConversationalSearchEngine, ServingConfig
from benchmarks import common as C

NPROBE = 16
H = 256
ALPHA = 0.25
K = 10
DEPTH = 64                    # cached candidates per session (>= K)
THRESHOLDS = (0.0, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
SMOKE_THRESHOLD = 0.7         # CI floor operating point


def _recall_vs(ids: np.ndarray, ref_ids: np.ndarray) -> float:
    a = ids.reshape(-1, K)
    b = ref_ids.reshape(-1, K)
    return float(np.mean([len(set(a[j]) & set(b[j])) / K
                          for j in range(b.shape[0])]))


def _serve(kind: str, threshold: float):
    wl = C.workload(kind)
    index = C.ivf_index(kind)
    eng = ConversationalSearchEngine(
        ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                      h=min(H, index.p), alpha=ALPHA, k=K,
                      cache_threshold=threshold, cache_depth=DEPTH),
        ivf_index=index, doc_vecs=jnp.asarray(wl.doc_vecs))
    n_conv, turns, _ = wl.conversations.shape
    ids = np.empty((n_conv, turns, K), np.int64)
    for c in range(n_conv):
        for t in range(turns):
            _, i = eng.query(f"c{c}", jnp.asarray(wl.conversations[c, t]))
            ids[c, t] = i
        eng.end_conversation(f"c{c}")
    return eng, ids, wl


def sweep(kind: str, csv: bool = True) -> List[Dict]:
    wl = C.workload(kind)
    docs = jnp.asarray(wl.doc_vecs)
    flat_q = jnp.asarray(wl.conversations.reshape(-1,
                                                  wl.doc_vecs.shape[1]))
    _, exact_ids = IV.exact_search(docs, flat_q, K)
    exact_ids = np.asarray(exact_ids)
    rows, ref_ids = [], None
    for th in THRESHOLDS:
        eng, ids, _ = _serve(kind, th)
        if ref_ids is None:
            ref_ids = ids                     # th=0: the uncached run
        stats = eng.cache_stats() or {"hit_rate": 0.0}
        metrics = C.eval_conversations(ids, wl)
        work = (eng.summary()["mean_centroid_dists"]
                + eng.summary()["mean_list_dists"])
        row = dict(dataset=kind, threshold=th,
                   hit_rate=stats["hit_rate"],
                   recall_vs_uncached=_recall_vs(ids, ref_ids),
                   recall_vs_exact=_recall_vs(ids, exact_ids),
                   ndcg10=metrics["ndcg@10"], work=work)
        rows.append(row)
        if csv:
            print(f"fig5,{kind},{th:.2f},{row['hit_rate']:.3f},"
                  f"{row['recall_vs_uncached']:.3f},"
                  f"{row['recall_vs_exact']:.3f},{row['ndcg10']:.3f},"
                  f"{work:.0f}")
    return rows


def _assert_smoke_floors(rows: List[Dict]) -> None:
    by = {(r["dataset"], r["threshold"]): r for r in rows}
    for kind in ("cast19",):
        base = by[(kind, 0.0)]
        op = by[(kind, SMOKE_THRESHOLD)]
        assert op["hit_rate"] > 0.0, (
            f"{kind}: cache never hit at threshold {SMOKE_THRESHOLD}")
        assert op["recall_vs_exact"] >= 0.95 * base["recall_vs_exact"], (
            f"{kind}: cached recall@10 {op['recall_vs_exact']:.3f} < "
            f"0.95 x uncached {base['recall_vs_exact']:.3f}")
        assert op["work"] < base["work"], (
            f"{kind}: cache hits saved no backend work")
    print(f"SMOKE OK: threshold {SMOKE_THRESHOLD} hit-rate "
          f"{by[('cast19', SMOKE_THRESHOLD)]['hit_rate']:.2f} > 0 and "
          "recall@10 >= 0.95x uncached")


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    if smoke:
        global H
        C.N_DOCS, C.PARTITIONS = 4000, 128
        C.CONVS, C.TURNS = 6, 6
        H = 64                        # keep np << h < p at p=128
    print("fig,dataset,threshold,hit_rate,recall@10_vs_uncached,"
          "recall@10_vs_exact,ndcg@10,mean_work_per_turn")
    rows = []
    for kind in ("cast19", "cast20"):
        rows += sweep(kind)
    if smoke:
        _assert_smoke_floors(rows)
    return rows


if __name__ == "__main__":
    main()
