"""Render the §Roofline table from the dry-run log (artifacts/dryrun.jsonl).

Per (arch × shape × mesh): the three roofline terms in seconds, dominant
bottleneck, per-device memory fit, MODEL_FLOPS ratio, and a one-line
what-would-move-it note derived from the dominant term.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

MOVE_NOTES = {
    "compute": ("compute-bound: only faster matmul units / lower "
                "precision move this; already the roofline goal"),
    "memory": ("memory-bound: raise arithmetic intensity — fuse "
               "elementwise chains (TPU compile does), larger tiles, "
               "fewer remat recomputes, bf16 activations"),
    "collective": ("collective-bound: reshard to cut the largest "
                   "collective, overlap with compute, or compress "
                   "payloads (int8 grads)"),
}


def load(path: str) -> List[Dict]:
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    # keep last record per (arch, shape, mesh)
    dedup = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r.get("mesh", "-"))] = r
    return list(dedup.values())


def render(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | coll s | "
           "dominant | peak GB/dev | MODEL/HLO | note |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r.get("mesh", "-"))):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                       f"SKIP | - | - | {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                       f"- | - | ERROR | - | - | {r['error'][:60]} |")
            continue
        c, roof = r["cost"], r["roofline"]
        peak = c["peak_memory"] / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {roof['compute_s']:.3e} | {roof['memory_s']:.3e} "
            f"| {roof['collective_s']:.3e} | {roof['dominant']} "
            f"| {peak:.2f} | {roof['model_flops_ratio']:.3f} "
            f"| {MOVE_NOTES[roof['dominant']][:48]} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--log", default="artifacts/dryrun.jsonl")
    args = ap.parse_args()
    print(render(load(args.log)))


if __name__ == "__main__":
    main()
