"""Render the §Roofline table from the dry-run log (artifacts/dryrun.jsonl).

Per (arch × shape × mesh): the three roofline terms in seconds, dominant
bottleneck, per-device memory fit, MODEL_FLOPS ratio, and a one-line
what-would-move-it note derived from the dominant term.

``--autotune`` instead renders the fused-megakernel autotune cache
(``kernels.autotune``, artifacts/autotune/) and judges every entry:
tuned vs static-default predicted time, single-dispatch vs 3-dispatch,
and the measured time where validation ran.  Exits non-zero if any
cached "tuned" config predicts slower than the static default — the
sweep must never regress the default.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

MOVE_NOTES = {
    "compute": ("compute-bound: only faster matmul units / lower "
                "precision move this; already the roofline goal"),
    "memory": ("memory-bound: raise arithmetic intensity — fuse "
               "elementwise chains (TPU compile does), larger tiles, "
               "fewer remat recomputes, bf16 activations"),
    "collective": ("collective-bound: reshard to cut the largest "
                   "collective, overlap with compute, or compress "
                   "payloads (int8 grads)"),
}


def load(path: str) -> List[Dict]:
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    # keep last record per (arch, shape, mesh)
    dedup = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r.get("mesh", "-"))] = r
    return list(dedup.values())


def render(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | coll s | "
           "dominant | peak GB/dev | MODEL/HLO | note |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r.get("mesh", "-"))):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                       f"SKIP | - | - | {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                       f"- | - | ERROR | - | - | {r['error'][:60]} |")
            continue
        c, roof = r["cost"], r["roofline"]
        peak = c["peak_memory"] / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {roof['compute_s']:.3e} | {roof['memory_s']:.3e} "
            f"| {roof['collective_s']:.3e} | {roof['dominant']} "
            f"| {peak:.2f} | {roof['model_flops_ratio']:.3f} "
            f"| {MOVE_NOTES[roof['dominant']][:48]} |")
    return "\n".join(out)


def render_autotune(records: List[Dict]) -> str:
    """Judge table for the fused-kernel autotune cache; raises
    AssertionError if a cached winner predicts slower than the static
    default (the sweep includes the default, so that is a model bug)."""
    out = ["| shape | dev | config | vmem KiB | default s | tuned s | "
           "3-disp s | tuned/def | measured s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        cfg = r["config"]
        ratio = r["predicted_s"] / r["default_predicted_s"]
        assert r["predicted_s"] <= r["default_predicted_s"] * (1 + 1e-9), (
            f"{r['shape']}: tuned config predicts {r['predicted_s']:.3e}s"
            f" > default {r['default_predicted_s']:.3e}s")
        meas = ("-" if r.get("measured_s") is None
                else f"{r['measured_s']:.3e}")
        shape = r["shape"]
        sk = (f"{shape['family']} b{shape['b']} p{shape['p']} "
              f"L{shape['lmax']} d{shape['d']} np{shape['nprobe']} "
              f"k{shape['k']} {shape['precision']}")
        out.append(
            f"| {sk} | {r['device']} "
            f"| blk_p={cfg['blk_p']} max_tile={cfg['max_tile']} "
            f"over={cfg['over']} | {r['vmem_bytes'] / 1024:.0f} "
            f"| {r['default_predicted_s']:.3e} | {r['predicted_s']:.3e} "
            f"| {r['dispatch3_predicted_s']:.3e} | {ratio:.3f} "
            f"| {meas} |")
    if len(records) > 1:
        wins = sum(r["predicted_s"] < r["default_predicted_s"]
                   for r in records)
        out.append(f"\nautotune beats the static default on {wins}/"
                   f"{len(records)} cached shapes")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--log", default="artifacts/dryrun.jsonl")
    ap.add_argument("--autotune", action="store_true",
                    help="render + judge the fused-kernel autotune cache")
    ap.add_argument("--autotune-dir", default=None)
    args = ap.parse_args()
    if args.autotune:
        from repro.kernels import autotune as AT
        recs = AT.load_records(args.autotune_dir)
        if not recs:
            print("autotune cache empty — run benchmarks/fig8_fused.py "
                  "(or kernels.autotune.autotune) first")
            return
        print(render_autotune(recs))
        return
    print(render(load(args.log)))


if __name__ == "__main__":
    main()
