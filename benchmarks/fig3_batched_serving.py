"""Fig 3 (ours): batched multi-conversation serving throughput sweep.

Measures the tentpole claim of the batched serving path: draining the
MicroBatcher into one padded device batch per flush amortises dispatch
overhead across concurrent conversations, so turns/sec scales with the
micro-batch size while per-turn results stay bit-identical to the
sequential engine (tests/test_serving_batched.py pins the equivalence;
this file measures the speedup rather than asserting it).

Protocol: CONVS concurrent conversations × TURNS turns are replayed
through ``BatchedConversationalSearchEngine`` with ``max_batch`` ∈
BATCH_SIZES.  For each turn round every conversation submits one
request, then the engine drains — so a batch size of 1 is the
one-dispatch-per-turn baseline (the sequential engine's dispatch
pattern) and larger sizes serve whole cohorts per dispatch.  Reported:
turns/sec (wall), p95 request latency (enqueue → result, i.e. including
queueing), and mean per-turn work counters as a sanity check that the
strategy did not change under batching.

  PYTHONPATH=src python benchmarks/fig3_batched_serving.py
  BENCH_DOCS=20000 BENCH_CONVS=64 PYTHONPATH=src python benchmarks/fig3_batched_serving.py

``--smoke`` shrinks the corpus and asserts the figure's claim: the
largest micro-batch beats batch=1 throughput for every strategy.
"""
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(__file__))

SMOKE = "--smoke" in sys.argv
if SMOKE:
    os.environ.setdefault("BENCH_DOCS", "3000")
    os.environ.setdefault("BENCH_PARTITIONS", "128")
    os.environ.setdefault("BENCH_CONVS", "16")
    os.environ.setdefault("BENCH_TURNS", "4")

from repro.core import hnsw as HN
from repro.core import ivf as IV
from repro.data import synthetic as SY
from repro.serving.engine import (BatchedConversationalSearchEngine,
                                  ServingConfig)

N_DOCS = int(os.environ.get("BENCH_DOCS", 6000))
DIM = int(os.environ.get("BENCH_DIM", 64))
CONVS = int(os.environ.get("BENCH_CONVS", 32))
TURNS = int(os.environ.get("BENCH_TURNS", 6))
PARTITIONS = int(os.environ.get("BENCH_PARTITIONS", 256))
BATCH_SIZES = (1, 8, 32)
REPEAT = int(os.environ.get("BENCH_REPEAT", 2))

STRATEGIES = {
    "ivf_plain": ServingConfig(backend="ivf", strategy="plain", nprobe=8,
                               k=10),
    "ivf_toploc+": ServingConfig(backend="ivf", strategy="toploc+",
                                 nprobe=8, h=64, alpha=0.25, k=10),
    "hnsw_toploc": ServingConfig(backend="hnsw", strategy="toploc",
                                 ef_search=24, up=2, k=10),
}


def replay(cfg, ivf_idx, hnsw_idx, wl, batch_size):
    """One full traffic replay; returns (wall_s, p95_ms, mean_work)."""
    eng = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_idx if cfg.backend == "ivf" else None,
        hnsw_index=hnsw_idx if cfg.backend == "hnsw" else None,
        n_slots=max(CONVS, batch_size), max_batch=batch_size,
        max_wait_s=0.0,
        buckets=(1, 2, 4, 8, 16, 32))
    t0 = time.perf_counter()
    for t in range(TURNS):
        futs = [eng.submit(f"c{c}", jnp.asarray(wl.conversations[c, t]))
                for c in range(CONVS)]
        eng.drain()
        for f in futs:
            f.result()
    wall = time.perf_counter() - t0
    s = eng.summary()
    work = (s["mean_centroid_dists"] + s["mean_list_dists"]
            + s["mean_graph_dists"])
    # p95_request_ms = queue wait + service (latency_s alone is now
    # service time only) — keeps this column's documented
    # enqueue→result semantics
    return wall, s["p95_request_ms"], work


def main():
    print(f"corpus: {N_DOCS} docs, d={DIM}, p={PARTITIONS}; traffic: "
          f"{CONVS} conversations x {TURNS} turns")
    wl = SY.make_workload(SY.WorkloadConfig(
        n_docs=N_DOCS, d=DIM, n_topics=48, n_conversations=CONVS,
        turns_per_conversation=TURNS, query_drift=0.15, shift_prob=0.1,
        seed=3))
    print("building IVF index ...")
    ivf_idx = IV.build(jnp.asarray(wl.doc_vecs), p=PARTITIONS, iters=6,
                       key=jax.random.PRNGKey(0))
    print("building HNSW index ...")
    hnsw_idx = HN.build(wl.doc_vecs, m=12, ef_construction=32)

    turns = CONVS * TURNS
    print(f"\n{'strategy':12s} {'batch':>6s} {'turns/s':>9s} "
          f"{'p95 ms':>8s} {'work/turn':>10s}")
    speedups = {}
    for name, cfg in STRATEGIES.items():
        tps_by_bs = {}
        for bs in BATCH_SIZES:
            # warmup replay compiles every bucket this size uses, then
            # the timed replays measure steady-state serving
            replay(cfg, ivf_idx, hnsw_idx, wl, bs)
            walls, p95s, works = zip(*[
                replay(cfg, ivf_idx, hnsw_idx, wl, bs)
                for _ in range(REPEAT)])
            wall = float(np.median(walls))
            tps = turns / wall
            tps_by_bs[bs] = tps
            print(f"{name:12s} {bs:6d} {tps:9.1f} "
                  f"{float(np.median(p95s)):8.2f} "
                  f"{float(np.mean(works)):10.0f}")
        speedups[name] = tps_by_bs[BATCH_SIZES[-1]] / tps_by_bs[1]
        print(f"{name:12s}  batch={BATCH_SIZES[-1]} vs batch=1 speedup: "
              f"{speedups[name]:.2f}x")

    worst = min(speedups.values())
    print(f"\nworst-case batching speedup across strategies: {worst:.2f}x "
          f"({'OK: batch=32 beats batch=1' if worst > 1.0 else 'REGRESSION'})")
    if SMOKE:
        assert worst > 1.0, (
            f"smoke: batch={BATCH_SIZES[-1]} did not beat batch=1 "
            f"(worst speedup {worst:.2f}x)")
        print(f"SMOKE OK: batch={BATCH_SIZES[-1]} beats batch=1 for all "
              f"strategies (worst {worst:.2f}x)")


if __name__ == "__main__":
    main()
