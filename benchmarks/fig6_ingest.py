"""Fig 6 (ours): streaming ingest vs serving latency on a mutable corpus.

Closed-loop benchmark for the segmented mutable corpus
(``core.segment`` behind ``ServingConfig.segment_cap``): S concurrent
sessions replay multi-turn conversations through the batched engine
while an ingest loop appends document batches into the delta segment,
tombstones previously-served documents, and folds the delta into the
frozen base (``compact()``) whenever the segment fills.  Reported:
per-wave serving latency with ingest OFF vs ON (the delta-scan +
tombstone-mask overhead), sustained ingest throughput (docs/s through
``add_documents``), and compaction cost.

Two properties make the numbers meaningful:

  * adds and deletes are **shape-stable** — the delta buffer is a fixed
    ``(cap, d)`` slab and tombstones a fixed bool mask, so mutation
    never retraces the serving programs; only ``compact()`` (which
    grows the base) pays a retrace, and that cost is reported
    separately, not smeared into turn latency.
  * the smoke gate pins the **compaction contract**: after the run, the
    engine's compacted host index must be bit-identical to
    ``core.segment.rebuild`` — the from-scratch oracle over the pristine
    index plus the full add/delete history — and a turn served mid-run
    may never contain a document deleted before it was submitted.

  PYTHONPATH=src:. python benchmarks/fig6_ingest.py
  PYTHONPATH=src:. python benchmarks/fig6_ingest.py --smoke
"""
from __future__ import annotations

import os
import sys

if "--smoke" in sys.argv:
    os.environ.setdefault("BENCH_DOCS", "4000")
    os.environ.setdefault("BENCH_PARTITIONS", "512")
    os.environ.setdefault("BENCH_CONVS", "64")
    os.environ.setdefault("BENCH_TURNS", "4")
    os.environ.setdefault("BENCH_SEG_CAP", "256")

# must happen before jax import: give the host platform 8 devices (the
# CI job shares one env with the sharded fig7 step)
_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import time
from typing import Dict, List

import numpy as np
import jax

from repro.core import backend as B
from repro.core import segment as S
from repro.serving import BatchedConversationalSearchEngine, ServingConfig
from benchmarks import common as C

K = 10
NPROBE = 8
H = 384
ALPHA = 0.25
MAX_BATCH = 32
SEG_CAP = int(os.environ.get("BENCH_SEG_CAP", 2048))
# sized to force compactions inside the wave loop (~2.5 segment fills)
INGEST_BATCH = int(os.environ.get(
    "BENCH_INGEST_BATCH",
    max(32, (SEG_CAP * 5) // (2 * max(1, C.TURNS)))))


def config() -> ServingConfig:
    # result cache off: this figure isolates the mutation-path overhead
    # (delta scan + tombstone mask); the cache's interplay with deletes
    # is pinned by tests/test_result_cache.py instead
    return ServingConfig(backend="ivf", strategy="toploc+", k=K,
                         nprobe=NPROBE, h=H, alpha=ALPHA,
                         segment_cap=SEG_CAP)


def serve_wave(eng, wl, turn: int) -> tuple:
    """One closed-loop wave: every session submits its next turn, the
    driver flushes until all futures land.  Returns (ids per session,
    wall seconds)."""
    S_ = wl.conversations.shape[0]
    t0 = time.perf_counter()
    futs = [eng.submit(f"s{sid}", wl.conversations[sid, turn])
            for sid in range(S_)]
    while not all(f.done() for f in futs):
        if eng.flush() == 0:
            eng.sync()
    wall = time.perf_counter() - t0
    return [np.asarray(f.result()[1]) for f in futs], wall


def ingest_pool(n: int, d: int) -> np.ndarray:
    rng = np.random.default_rng(6)
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def drive(eng, wl, *, ingest: bool) -> Dict:
    """Wave loop; with ``ingest`` each wave is followed by one
    add_documents batch, one delete of a just-served doc, and a
    compact() whenever the next batch would overflow the segment."""
    T = wl.conversations.shape[1]
    pool = ingest_pool(INGEST_BATCH * T, wl.doc_vecs.shape[1])
    wave_s: List[float] = []
    add_s: List[float] = []
    compact_s: List[float] = []
    added: List[np.ndarray] = []
    deleted: List[int] = []
    fill = 0
    stale_served = 0
    for turn in range(T):
        ids_by_sid, wall = serve_wave(eng, wl, turn)
        wave_s.append(wall)
        dead = set(deleted)
        stale_served += sum(
            int(np.isin(ids, list(dead)).sum()) for ids in ids_by_sid
        ) if dead else 0
        if not ingest:
            continue
        batch = pool[turn * INGEST_BATCH:(turn + 1) * INGEST_BATCH]
        if fill + len(batch) > SEG_CAP:
            t0 = time.perf_counter()
            eng.compact()
            compact_s.append(time.perf_counter() - t0)
            fill = 0
        t0 = time.perf_counter()
        eng.add_documents(batch)
        add_s.append(time.perf_counter() - t0)
        added.append(batch)
        fill += len(batch)
        # tombstone a doc this wave actually served (base or delta)
        victim = int(ids_by_sid[turn % len(ids_by_sid)][0])
        if victim not in dead:
            eng.delete_documents([victim])
            deleted.append(victim)
    out = {
        "qps": (wl.conversations.shape[0] * T) / sum(wave_s),
        "p50_ms": float(np.percentile(np.asarray(wave_s) * 1e3, 50)),
        "p99_ms": float(np.percentile(np.asarray(wave_s) * 1e3, 99)),
        "stale_served": stale_served,
    }
    if ingest:
        n_added = sum(len(a) for a in added)
        out.update({
            "added": np.concatenate(added),
            "deleted": deleted,
            "docs_per_s": n_added / sum(add_s),
            "add_p50_ms": float(np.percentile(np.asarray(add_s) * 1e3,
                                              50)),
            "compactions": len(compact_s),
            "compact_ms": [round(t * 1e3, 1) for t in compact_s],
        })
    return out


def warmup(eng, wl) -> None:
    """Compile the wave programs, then reset accounting."""
    d = wl.conversations.shape[-1]
    for j in range(MAX_BATCH):
        eng.submit(f"warm{j}", np.zeros(d, np.float32))
    eng.drain()
    for j in range(MAX_BATCH):
        eng.end_conversation(f"warm{j}")
    eng.records.clear()
    eng.turn_count.clear()


def check_identity(eng, pristine_idx, added: np.ndarray,
                   deleted: List[int]) -> None:
    """The smoke gate's hard bar: fold the remaining delta and compare
    the engine's host index, leaf by leaf, against the from-scratch
    rebuild oracle over the same mutation history."""
    eng.compact()
    inner = B.make("ivf", h=H, nprobe=NPROBE, alpha=ALPHA)
    oracle = S.rebuild(inner, pristine_idx, added, deleted, cap=SEG_CAP)
    got = jax.tree.leaves(eng._seg_host, is_leaf=lambda x: x is None)
    want = jax.tree.leaves(oracle, is_leaf=lambda x: x is None)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        if g is None or w is None:
            assert g is None and w is None
            continue
        if not np.array_equal(np.asarray(g), np.asarray(w)):
            raise AssertionError(
                "post-compaction index differs from the from-scratch "
                "rebuild — the bit-identity contract is broken")
    print(f"  identity OK (compact == rebuild over {len(added)} adds, "
          f"{len(deleted)} deletes, bit-identical)")


def main():
    smoke = "--smoke" in sys.argv
    wl = C.workload("cast20")
    idx = C.ivf_index("cast20")
    S_, T = wl.conversations.shape[0], wl.conversations.shape[1]
    print(f"corpus: {C.N_DOCS} docs, p={C.PARTITIONS}; traffic: {S_} "
          f"sessions x {T} turns; segment cap={SEG_CAP}, "
          f"ingest {INGEST_BATCH} docs/wave")

    runs = {}
    for label, ingest in (("ingest off", False), ("ingest on", True)):
        eng = BatchedConversationalSearchEngine(
            config(), ivf_index=idx, n_slots=max(MAX_BATCH, S_),
            max_batch=MAX_BATCH, max_wait_s=1e-4, buckets=(MAX_BATCH,))
        warmup(eng, wl)
        runs[label] = drive(eng, wl, ingest=ingest)
        if ingest:
            check_identity(eng, idx, runs[label]["added"],
                           runs[label]["deleted"])
        eng.close()

    print(f"\n{'phase':>12s} {'qps':>8s} {'p50 ms':>8s} {'p99 ms':>8s}")
    for label, out in runs.items():
        print(f"{label:>12s} {out['qps']:8.1f} {out['p50_ms']:8.2f} "
              f"{out['p99_ms']:8.2f}")
    on = runs["ingest on"]
    print(f"\ningest: {on['docs_per_s']:.0f} docs/s sustained "
          f"(add p50 {on['add_p50_ms']:.2f} ms/batch), "
          f"{on['compactions']} compaction(s) at {on['compact_ms']} ms; "
          f"serving overhead p50 "
          f"{on['p50_ms'] - runs['ingest off']['p50_ms']:+.2f} ms/wave")

    if smoke:
        assert on["docs_per_s"] > 0, "ingest throughput is zero"
        assert on["stale_served"] == 0, (
            f"{on['stale_served']} result(s) contained a tombstoned doc")
        assert on["compactions"] >= 1, (
            "smoke sizing never filled the segment — compaction path "
            "untested")
        print(f"SMOKE OK: compact == rebuild bit-identical, "
              f"{on['docs_per_s']:.0f} docs/s ingest alongside "
              f"{on['qps']:.1f} qps serving, 0 tombstoned docs served")


if __name__ == "__main__":
    main()
