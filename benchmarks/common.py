"""Shared benchmark fixtures: workloads, indexes, timing helpers.

Two synthetic conversation sets mirror the paper's datasets:
  * "cast19-like" — low drift, no topic shifts (the easy set where the
    paper sees no effectiveness loss);
  * "cast20-like" — higher drift + mid-conversation topic shifts (the
    hard set where the refresh mechanism of TopLoc_IVF+ matters).

Index builds are cached on disk (artifacts/bench_cache) — HNSW
construction is the slow part.  The cache directory is gitignored:
every fixture regenerates *deterministically* on first use (fixed-seed
workloads, k-means keys, PQ codebooks, HNSW insertion order), so a
fresh checkout rebuilds byte-equivalent fixtures instead of shipping
binary blobs in the repo.
"""
from __future__ import annotations

import os
import pickle
import time
from typing import Callable, Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hnsw as HN
from repro.core import ivf as IV
from repro.core import pq as PQ
from repro.data import synthetic as SY

CACHE = os.environ.get("BENCH_CACHE", "artifacts/bench_cache")

# All knobs are env-overridable so the CI benchmark-smoke step (and any
# laptop run) can shrink the corpus without editing this file.
N_DOCS = int(os.environ.get("BENCH_DOCS", 20000))
DIM = 64
N_TOPICS = 64
# Paper regime: p is 5-40x above the sqrt(n) heuristic (2^15..2^18 for a
# 38.6M corpus) so the CENTROID SCAN dominates per-query cost — that is
# the term TopLoc eliminates. Scaled to 20k docs: p=2048 (~10 docs/list).
PARTITIONS = int(os.environ.get("BENCH_PARTITIONS", 2048))
CONVS = int(os.environ.get("BENCH_CONVS", 12))
TURNS = int(os.environ.get("BENCH_TURNS", 8))
PQ_M = int(os.environ.get("BENCH_PQ_M", 8))      # PQ subquantizers
HNSW_M = int(os.environ.get("BENCH_HNSW_M", 16))
HNSW_EFC = int(os.environ.get("BENCH_HNSW_EFC", 64))


def workload(kind: str) -> SY.Workload:
    # difficulty calibrated to the paper's sets: CAsT'19 — conversations
    # hold their topic (TopLoc loses ~nothing); CAsT'20 — moderate drift
    # + occasional topic shifts (static caches degrade, the |I0| refresh
    # recovers at a bounded refresh rate)
    if kind == "cast19":
        cfg = SY.WorkloadConfig(
            n_docs=N_DOCS, d=DIM, n_topics=N_TOPICS,
            n_conversations=CONVS, turns_per_conversation=TURNS,
            query_drift=0.10, walk_step=0.015, shift_prob=0.0, seed=19)
    elif kind == "cast20":
        cfg = SY.WorkloadConfig(
            n_docs=N_DOCS, d=DIM, n_topics=N_TOPICS,
            n_conversations=CONVS, turns_per_conversation=TURNS,
            query_drift=0.15, walk_step=0.05, shift_prob=0.10, seed=20)
    else:
        raise ValueError(kind)
    return _cached(f"workload_{kind}_{N_DOCS}_{CONVS}_{TURNS}",
                   lambda: SY.make_workload(cfg))


def _cached(name: str, build: Callable):
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, name + ".pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    obj = build()
    with open(path, "wb") as f:
        pickle.dump(jax.tree.map(np.asarray, obj), f)
    return obj


def ivf_index(kind: str) -> IV.IVFIndex:
    wl = workload(kind)
    raw = _cached(f"ivf_{kind}_{N_DOCS}_{PARTITIONS}",
                  lambda: IV.build(jnp.asarray(wl.doc_vecs), p=PARTITIONS,
                                   iters=8, key=jax.random.PRNGKey(0)))
    return IV.IVFIndex(*[jnp.asarray(x) for x in raw])


def ivf_pq_index(kind: str) -> PQ.IVFPQIndex:
    """IVF geometry of ``ivf_index`` + PQ-compressed posting lists."""
    idx = ivf_index(kind)
    wl = workload(kind)
    raw = _cached(
        f"ivfpq_{kind}_{N_DOCS}_{PARTITIONS}_{PQ_M}",
        lambda: PQ.build_ivf_pq(idx, jnp.asarray(wl.doc_vecs), m=PQ_M,
                                iters=8, key=jax.random.PRNGKey(0)))
    return PQ.IVFPQIndex(*[jnp.asarray(x) for x in raw])


def hnsw_index(kind: str) -> HN.HNSWIndex:
    wl = workload(kind)
    raw = _cached(f"hnsw_{kind}_{N_DOCS}_{HNSW_M}_{HNSW_EFC}",
                  lambda: HN.build(wl.doc_vecs, m=HNSW_M,
                                   ef_construction=HNSW_EFC, seed=0))
    # `deleted` is None on a pristine build — asarray would NaN it
    return HN.HNSWIndex(*[None if x is None else jnp.asarray(x)
                          for x in raw])


def time_fn(fn: Callable, *args, warmup: int = 1, repeat: int = 3
            ) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def eval_conversations(run_ids: np.ndarray, wl: SY.Workload
                       ) -> Dict[str, float]:
    return SY.evaluate_run(run_ids, wl, k=10)
