"""Figure 2 reproduction: effectiveness/efficiency frontier vs ef_search
for HNSW vs TopLoc_HNSW on both conversation sets.

``--smoke`` shrinks the corpus and asserts the figure's frontier claim:
TopLoc_HNSW does no more graph distance work than plain HNSW at the
same ef_search while holding NDCG@10 within 0.9x.

  PYTHONPATH=src:. python benchmarks/fig2_hnsw_sweep.py --smoke
"""
from __future__ import annotations

import sys
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import toploc as TL
from repro.core.backend import HNSWBackend
from benchmarks import common as C

EFS = (4, 8, 16, 32, 64)
UP = 2
K = 10


def sweep(kind: str, csv: bool = True) -> List[Dict]:
    wl = C.workload(kind)
    index = C.hnsw_index(kind)
    convs = jnp.asarray(wl.conversations)
    n_conv, turns, _ = convs.shape
    rows = []
    for ef in EFS:
        k = min(K, ef)
        for method, mode in (("HNSW", "plain"), ("TopLoc_HNSW", "toploc"),
                             ("TopLoc_HNSW_adaptive", "adaptive")):
            bk = HNSWBackend(ef=ef, up=UP, adaptive=mode == "adaptive")
            cmode = "plain" if mode == "plain" else "toploc"

            def all_convs(cs, bk=bk, cmode=cmode, k=k):
                return jax.vmap(lambda conv: TL.conversation(
                    bk, index, conv, k=k, mode=cmode))(cs)

            fn = jax.jit(all_convs)
            _, ids, stats = fn(convs)
            jax.block_until_ready(ids)
            wall = C.time_fn(fn, convs, repeat=2)
            pad = np.full((n_conv, turns, K - k), -1, np.int64)
            run_ids = np.concatenate([np.asarray(ids), pad], -1) \
                if k < K else np.asarray(ids)
            metrics = C.eval_conversations(run_ids, wl)
            work = float(np.asarray(stats.graph_dists).mean())
            row = dict(dataset=kind, method=method, ef=ef,
                       ndcg10=metrics["ndcg@10"], mrr10=metrics["mrr@10"],
                       ms_per_turn=1e3 * wall / (n_conv * turns),
                       work=work)
            rows.append(row)
            if csv:
                print(f"fig2,{kind},{method},{ef},{row['ndcg10']:.3f},"
                      f"{row['ms_per_turn']:.3f},{work:.0f}")
    return rows


def _assert_smoke_floors(rows: List[Dict]) -> None:
    by = {(r["method"], r["ef"]): r for r in rows}
    for ef in EFS:
        plain, tl = by[("HNSW", ef)], by[("TopLoc_HNSW", ef)]
        assert tl["work"] <= plain["work"], (
            f"ef={ef}: TopLoc_HNSW graph work {tl['work']:.0f} above "
            f"HNSW {plain['work']:.0f}")
        assert tl["ndcg10"] >= 0.9 * plain["ndcg10"], (
            f"ef={ef}: TopLoc_HNSW ndcg@10 {tl['ndcg10']:.3f} < "
            f"0.9 x HNSW {plain['ndcg10']:.3f}")
    print("SMOKE OK: TopLoc_HNSW graph work <= HNSW at every ef with "
          "ndcg@10 >= 0.9x")


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    if smoke:
        global EFS
        C.N_DOCS, C.PARTITIONS = 4000, 128
        C.CONVS, C.TURNS = 6, 6
        EFS = (8, 16)
    print("fig,dataset,method,ef_search,ndcg@10,ms_per_turn,work_dists")
    rows = []
    for kind in (("cast19",) if smoke else ("cast19", "cast20")):
        rows += sweep(kind)
    if smoke:
        _assert_smoke_floors(rows)


if __name__ == "__main__":
    main()
