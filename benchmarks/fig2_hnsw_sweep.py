"""Figure 2 reproduction: effectiveness/efficiency frontier vs ef_search
for HNSW vs TopLoc_HNSW on both conversation sets."""
from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import toploc as TL
from repro.core.backend import HNSWBackend
from benchmarks import common as C

EFS = (4, 8, 16, 32, 64)
UP = 2
K = 10


def sweep(kind: str, csv: bool = True) -> List[Dict]:
    wl = C.workload(kind)
    index = C.hnsw_index(kind)
    convs = jnp.asarray(wl.conversations)
    n_conv, turns, _ = convs.shape
    rows = []
    for ef in EFS:
        k = min(K, ef)
        for method, mode in (("HNSW", "plain"), ("TopLoc_HNSW", "toploc"),
                             ("TopLoc_HNSW_adaptive", "adaptive")):
            bk = HNSWBackend(ef=ef, up=UP, adaptive=mode == "adaptive")
            cmode = "plain" if mode == "plain" else "toploc"

            def all_convs(cs, bk=bk, cmode=cmode, k=k):
                return jax.vmap(lambda conv: TL.conversation(
                    bk, index, conv, k=k, mode=cmode))(cs)

            fn = jax.jit(all_convs)
            _, ids, stats = fn(convs)
            jax.block_until_ready(ids)
            wall = C.time_fn(fn, convs, repeat=2)
            pad = np.full((n_conv, turns, K - k), -1, np.int64)
            run_ids = np.concatenate([np.asarray(ids), pad], -1) \
                if k < K else np.asarray(ids)
            metrics = C.eval_conversations(run_ids, wl)
            work = float(np.asarray(stats.graph_dists).mean())
            row = dict(dataset=kind, method=method, ef=ef,
                       ndcg10=metrics["ndcg@10"], mrr10=metrics["mrr@10"],
                       ms_per_turn=1e3 * wall / (n_conv * turns),
                       work=work)
            rows.append(row)
            if csv:
                print(f"fig2,{kind},{method},{ef},{row['ndcg10']:.3f},"
                      f"{row['ms_per_turn']:.3f},{work:.0f}")
    return rows


def main():
    print("fig,dataset,method,ef_search,ndcg@10,ms_per_turn,work_dists")
    for kind in ("cast19", "cast20"):
        sweep(kind)


if __name__ == "__main__":
    main()
