"""Fig 4 (ours): device-sharded TopLoc retrieval over a corpus mesh.

Measures the tentpole claim of ``distributed/retrieval.py``: sharding
the IVF posting lists over a ``model`` mesh divides the *per-device
owned* list-scan work ~linearly in the shard count, while results stay
bit-identical to the single-device path (tests/test_sharded_retrieval.py
pins the equivalence; this file measures the work split and checks
identity as a sanity gate).

"Work" here is the real-distance counter: how many of the selected
lists' documents each device *owns* — the corpus-residency term that
caps single-device scale, and what a sparse (owner-routed) scheduler
would pay per device.  The dense SPMD scan dispatch still touches the
full selection on every shard with foreign probes masked (see the
module docstring of distributed/retrieval.py), so this figure is
memory-capacity / sparse-execution scaling evidence, not a dense
per-device FLOP measurement.

Protocol: CONVS conversations × TURNS turns replay through the real
``toploc.start/step`` registry drivers with the sharded scan plugged
in, for shards ∈ {1, 2, 4, 8} (host-platform devices — the script forces
``--xla_force_host_platform_device_count=8`` when unset, so it runs on
any machine).  Per-turn probe selections are recovered with the same
static-cache selection math the step performs (TopLoc strategy, α < 0 —
the cache never changes, so the selection is exactly reproducible from
the session), and ``retrieval.per_shard_list_work`` maps them onto the
contiguous-block partition ownership the sharded scans use.  Reported:
total list-scan work per turn, max/mean per-device work per turn (the
scaling claim), balance factor, and wall-clock per turn.

Host-platform wall-clock does NOT improve with shards (8 virtual devices
time-share one CPU and pay real collective overhead) — the per-device
work column is the hardware-independent scaling evidence, exactly like
the paper's distance counters.

  PYTHONPATH=src:. python benchmarks/fig4_sharded.py
  PYTHONPATH=src:. python benchmarks/fig4_sharded.py --smoke
"""
from __future__ import annotations

import os
import sys

if "--smoke" in sys.argv:
    os.environ.setdefault("BENCH_DOCS", "4000")
    os.environ.setdefault("BENCH_PARTITIONS", "256")
    os.environ.setdefault("BENCH_CONVS", "4")
    os.environ.setdefault("BENCH_TURNS", "8")

# must happen before jax import: give the host platform 8 devices
_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import toploc
from repro.core.backend import IVFBackend
from repro.distributed import retrieval as R
from benchmarks import common as C

NPROBE = 16
H = 128
K = 10


def replay(index, bk, wl):
    """All conversations through the registry start/step drivers
    (TopLoc, static cache).  Returns (ids (C,T,K), sels (C,T,NPROBE))
    as numpy."""
    ids, sels = [], []
    for c in range(wl.conversations.shape[0]):
        conv = jnp.asarray(wl.conversations[c])
        _, i, sess, _ = toploc.start(bk, index, conv[0], k=K)
        c_ids, c_sels = [np.asarray(i)], [np.asarray(sess.anchor_sel)]
        for t in range(1, conv.shape[0]):
            # static cache → the step's probe selection is exactly
            # top_np over the cached centroids (same math, same session)
            csims = sess.cache_vecs @ conv[t]
            _, loc = jax.lax.top_k(csims, NPROBE)
            c_sels.append(np.asarray(sess.cache_ids[loc]))
            _, i, sess, _ = toploc.step(bk, index, sess, conv[t], k=K)
            c_ids.append(np.asarray(i))
        ids.append(np.stack(c_ids))
        sels.append(np.stack(c_sels))
    return np.stack(ids), np.stack(sels)


def timed_replay(index, bk, wl) -> float:
    """Wall seconds for the pure step loop (no diagnostics)."""
    t0 = time.perf_counter()
    for c in range(wl.conversations.shape[0]):
        conv = jnp.asarray(wl.conversations[c])
        _, i, sess, _ = toploc.start(bk, index, conv[0], k=K)
        for t in range(1, conv.shape[0]):
            _, i, sess, _ = toploc.step(bk, index, sess, conv[t], k=K)
    jax.block_until_ready(i)
    return time.perf_counter() - t0


def main():
    smoke = "--smoke" in sys.argv
    wl = C.workload("cast20")
    idx = C.ivf_index("cast20")
    n_turns = wl.conversations.shape[0] * wl.conversations.shape[1]
    shard_counts = [s for s in (1, 2, 4, 8) if s <= jax.device_count()]
    print(f"corpus: {C.N_DOCS} docs, p={C.PARTITIONS}; traffic: "
          f"{C.CONVS} conversations x {C.TURNS} turns; "
          f"devices: {jax.device_count()}")
    print(f"\n{'shards':>6s} {'work/turn':>10s} {'max/dev':>9s} "
          f"{'mean/dev':>9s} {'balance':>8s} {'wall ms/turn':>13s}")

    sizes = np.asarray(idx.list_sizes)
    ref_ids = None
    max_dev_by_s = {}
    for s in shard_counts:
        mesh = R.retrieval_mesh(s)
        sbk, sidx = R.shard_backend(mesh, IVFBackend(h=H, nprobe=NPROBE),
                                    idx)
        ids, sels = replay(sidx, sbk, wl)
        timed_replay(sidx, sbk, wl)                   # warmup (compile)
        wall = timed_replay(sidx, sbk, wl)
        if ref_ids is None:
            ref_ids = ids
        elif not np.array_equal(ids, ref_ids):
            raise AssertionError(
                f"sharded ids at S={s} differ from S={shard_counts[0]}")
        work = R.per_shard_list_work(sizes, sels, s)
        total = work.sum() / n_turns
        max_dev = work.max() / n_turns
        mean_dev = work.mean() / n_turns
        max_dev_by_s[s] = max_dev
        print(f"{s:6d} {total:10.0f} {max_dev:9.0f} {mean_dev:9.0f} "
              f"{max_dev / mean_dev:8.2f} {1e3 * wall / n_turns:13.2f}")

    s_max = shard_counts[-1]
    shrink = max_dev_by_s[shard_counts[0]] / max_dev_by_s[s_max]
    print(f"\nper-device list-scan work: S={s_max} is {shrink:.1f}x below "
          f"S={shard_counts[0]} (linear would be {s_max}.0x); results "
          "bit-identical across all shard counts")
    if smoke:
        assert s_max >= 2, "smoke needs a multi-device host platform"
        # ~linear: within 2x of the perfectly balanced split
        assert shrink >= s_max / 2.0, (
            f"per-device work shrank only {shrink:.2f}x at S={s_max}")
        print(f"SMOKE OK: shrink {shrink:.2f}x >= {s_max / 2.0:.1f}x "
              "and sharded ids bit-identical")


if __name__ == "__main__":
    main()
