"""Fig 8 — fused retrieval megakernel: one dispatch vs the 3-stage turn.

What is measured, honestly:

  * **Wall clock** (CPU host): the fused turn runs as ONE jitted
    program; the staged baseline runs the same arithmetic as separate
    jitted programs with a device sync at every stage boundary —
    centroid top-nprobe, posting-list scan, (PQ) exact re-rank — i.e.
    the dispatch structure the classic path has as three Pallas kernel
    launches on real hardware.  The delta isolates exactly what fusion
    removes: launches and stage-boundary round trips.  Measured at
    batch 1 (dispatch-bound) and batch 32 (compute starts to amortise).
  * **Roofline model** (``kernels.autotune``): predicted single- vs
    3-dispatch time on the TPU device model for the same shapes, and a
    per-shape tile sweep — the autotuned config's predicted time must
    beat the static default on at least one shape (records land in
    ``artifacts/autotune/``; ``roofline_report.py --autotune`` is the
    judge).
  * **Recall floor**: the bf16/int8 fused paths (quantised stage-1/2
    scoring, float32 in-kernel re-rank) must hold recall@10 >= 0.95x
    the float path on the same probe set.

``--smoke`` shrinks the corpus and asserts all three gates:

  PYTHONPATH=src:. python benchmarks/fig8_fused.py --smoke
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import toploc
from repro.core.backend import IVFBackend, IVFPQBackend
from repro.kernels import autotune as AT
from repro.kernels import ops

SMOKE = "--smoke" in sys.argv

# corpus sizing (env-overridable like benchmarks/common.py).  The
# smoke corpus is deliberately small: what fusion removes is dispatch
# and stage-boundary sync, so the smoke gate needs that overhead to be
# a meaningful share of the turn — on the CPU CI host a large corpus
# drowns the (real, fixed-size) saving in scan compute and the gate
# becomes a noise race.
N_DOCS = int(os.environ.get("BENCH_DOCS", 1000 if SMOKE else 20000))
PARTITIONS = int(os.environ.get("BENCH_PARTITIONS",
                                256 if SMOKE else 2048))
DIM = 64
NPROBE = 8 if SMOKE else 16
K, RERANK, PQ_M = 10, 32 if SMOKE else 64, 8
BATCHES = (1, 32)
REPS = 50 if SMOKE else 100


def _paired_min_time(fn_a, fn_b, *args) -> dict:
    """Min-of-REPS wall time for two callables, *interleaved* rep by
    rep so slow host-load drift (CI co-tenancy, thermal throttling)
    biases both sides equally instead of whichever loop ran second."""
    fn_a(*args)                               # compile + warm
    fn_b(*args)
    best_a = best_b = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        best_b = min(best_b, time.perf_counter() - t0)
    return {"fused_s": best_a, "staged_s": best_b}


def build():
    from repro.core import ivf, pq
    from repro.data import synthetic as SY
    wl = SY.make_workload(SY.WorkloadConfig(
        n_docs=N_DOCS, d=DIM, n_topics=32, n_conversations=8,
        turns_per_conversation=8, seed=8))
    idx = ivf.build(jnp.asarray(wl.doc_vecs), p=PARTITIONS, iters=4,
                    key=jax.random.PRNGKey(0))
    pqi = pq.build_ivf_pq(idx, jnp.asarray(wl.doc_vecs), m=PQ_M,
                          iters=4, key=jax.random.PRNGKey(0))
    q = jnp.asarray(
        wl.conversations.reshape(-1, DIM)[:max(BATCHES)])
    return idx, pqi, q


def time_ivf(idx, q) -> dict:
    """Fused single-program turn vs staged dispatches, IVF f32.  The
    staged scan is ``ivf._scan_lists`` — the exact formulation the
    production 3-dispatch turn (``_ivf_family_plain_batch``) runs, and
    the one the fused f32 path is bit-identical to."""
    from repro.core import ivf as _iv
    fused = jax.jit(lambda q_: ops.fused_turn(
        q_, idx.centroids, idx.list_vecs, idx.list_ids,
        nprobe=NPROBE, k=K, mode="ref"))

    s1 = jax.jit(lambda q_: jax.lax.top_k(
        toploc._bcast_centroid_scores(idx.centroids, q_), NPROBE)[1])
    s2 = jax.jit(lambda q_, sel: _iv._scan_lists(idx, q_, sel, K)[:2])

    def staged(q_):
        sel = jax.block_until_ready(s1(q_))
        return s2(q_, sel)

    return _paired_min_time(fused, staged, q)


def time_pq(pqi, q) -> dict:
    """Fused vs the genuinely 3-dispatch PQ turn (centroid / ADC scan /
    exact re-rank)."""
    fused = jax.jit(lambda q_: ops.fused_turn_pq(
        q_, pqi.centroids, toploc._adc_tables(pqi, q_), pqi.list_codes,
        pqi.list_ids, pqi.doc_vecs, nprobe=NPROBE, k=K, rerank=RERANK,
        mode="ref"))

    r = max(K, min(RERANK, NPROBE * pqi.lmax))
    s1 = jax.jit(lambda q_: jax.lax.top_k(
        toploc._bcast_centroid_scores(pqi.centroids, q_), NPROBE)[1])
    s2 = jax.jit(lambda q_, sel: ops.pq_adc_scan(
        toploc._adc_tables(pqi, q_), pqi.list_codes, pqi.list_ids,
        sel, r, mode="ref"))

    @jax.jit
    def s3(q_, cand_v, cand_ids):
        safe = jnp.maximum(cand_ids, 0)
        exact = jnp.sum(pqi.doc_vecs[safe] * q_[:, None, :], axis=-1)
        exact = jnp.where(cand_ids >= 0, exact, -jnp.inf)
        v, pos = jax.lax.top_k(exact, K)
        return v, jnp.take_along_axis(cand_ids, pos, axis=-1)

    def staged(q_):
        sel = jax.block_until_ready(s1(q_))
        cv, ci = jax.block_until_ready(s2(q_, sel))
        return s3(q_, cv, ci)

    return _paired_min_time(fused, staged, q)


def recall_floor(idx, q) -> dict:
    """recall@10 of the quantised fused paths vs the float fused path."""
    base = ops.fused_turn(q, idx.centroids, idx.list_vecs, idx.list_ids,
                          nprobe=NPROBE, k=K, mode="ref")[1]
    out = {}
    for prec in ("bf16", "int8"):
        ids = ops.fused_turn(q, idx.centroids, idx.list_vecs,
                             idx.list_ids, nprobe=NPROBE, k=K,
                             precision=prec, mode="ref")[1]
        bi, qi = np.asarray(base), np.asarray(ids)
        out[prec] = float(np.mean(
            [len(set(bi[r]) & set(qi[r])) / K for r in range(len(bi))]))
    return out


def tune_shapes(idx, pqi) -> list:
    """Autotune the measured shapes; records land in artifacts/autotune
    for the roofline-report judge."""
    lmax = idx.lmax
    shapes = [AT.TurnShape(b=b, p=PARTITIONS, lmax=lmax, d=DIM,
                           nprobe=NPROBE, k=K) for b in BATCHES]
    shapes += [AT.TurnShape(b=32, p=PARTITIONS, lmax=lmax, d=DIM,
                            nprobe=NPROBE, k=K, precision="int8"),
               AT.TurnShape(b=32, p=PARTITIONS, lmax=pqi.lmax, d=DIM,
                            nprobe=NPROBE, k=K, family="pq", m=PQ_M,
                            rerank=RERANK)]
    rows = []
    for sh in shapes:
        cfg = AT.autotune(sh, refresh=True)
        rows.append((sh, cfg, AT.predict_fused_s(sh, cfg),
                     AT.predict_fused_s(sh, AT.DEFAULT),
                     AT.predict_3dispatch_s(sh)))
    return rows


def main():
    print(f"corpus: {N_DOCS} docs, d={DIM}, p={PARTITIONS}, "
          f"nprobe={NPROBE}, k={K}")
    idx, pqi, qall = build()

    print("fig,family,batch,us_fused,us_staged,speedup")
    wall = {}
    for fam, timer, index in (("ivf", time_ivf, idx),
                              ("pq", time_pq, pqi)):
        for b in BATCHES:
            t = timer(index, qall[:b])
            sp = t["staged_s"] / t["fused_s"]
            wall[(fam, b)] = sp
            print(f"fig8,{fam},{b},{1e6 * t['fused_s']:.1f},"
                  f"{1e6 * t['staged_s']:.1f},{sp:.2f}")

    rec = recall_floor(idx, qall[:32])
    for prec, r in rec.items():
        print(f"fig8,recall@10,{prec},{r:.3f},floor,0.95")

    rows = tune_shapes(idx, pqi)
    wins = 0
    print("fig8_autotune,shape,config,pred_tuned_s,pred_default_s,"
          "pred_3disp_s")
    for sh, cfg, tuned, default, d3 in rows:
        wins += tuned < default
        print(f"fig8_autotune,{sh.key()},bp{cfg.blk_p}/mt{cfg.max_tile}"
              f"/ov{cfg.over},{tuned:.3e},{default:.3e},{d3:.3e}")

    if SMOKE:
        for (fam, b), sp in wall.items():
            assert sp > 1.0, (
                f"single-dispatch {fam} at batch {b} is not faster: "
                f"speedup {sp:.2f}x")
        for prec, r in rec.items():
            assert r >= 0.95, f"{prec} recall@10 {r:.3f} < 0.95 floor"
        assert wins >= 1, "autotuned tiling beat the default on 0 shapes"
        print(f"SMOKE OK: fused beats staged at batches {BATCHES} "
              f"(ivf+pq), recall floors hold "
              f"(bf16={rec['bf16']:.3f}, int8={rec['int8']:.3f}), "
              f"autotune beats default on {wins}/{len(rows)} shapes")


if __name__ == "__main__":
    main()
