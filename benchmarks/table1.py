"""Table 1 reproduction: effectiveness + efficiency of every method on
both conversation sets.

Methods (paper rows + the PQ extension): Exact, IVF, TopLoc_IVF,
TopLoc_IVF+, IVF-PQ, TopLoc_IVFPQ, TopLoc_IVFPQ+, HNSW, TopLoc_HNSW.
Columns: MRR@10, NDCG@3, NDCG@10, recall@10 vs Exact, mean per-turn time
(jitted device path, batch-of-conversations), speedup vs the plain
counterpart, and the hardware-independent work counters (float distance
computations + PQ code distances — what the paper's speedups reduce to).

``--smoke`` runs the whole table on a tiny corpus and asserts the
quality floors (used by CI so the benchmark scripts cannot rot):
TopLoc_IVFPQ recall@10 must stay ≥ 0.9 of float TopLoc_IVF's.
"""
from __future__ import annotations

import sys
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ivf as IV
from repro.core import toploc as TL
from repro.core.backend import HNSWBackend, IVFBackend, IVFPQBackend
from benchmarks import common as C

NPROBE = 16
H = 256          # np/h ≈ 6%: the regime where the |I0| proxy
                 # discriminates (paper: np << h << p)
ALPHA = 0.25
EF = 32
UP = 2
K = 10
RERANK = 64      # IVF-PQ exact re-rank depth


def _recall_vs(ids: np.ndarray, exact_ids: np.ndarray) -> float:
    """Mean top-K overlap fraction against the exact run. (Q, K) each."""
    a = ids.reshape(-1, K)
    b = exact_ids.reshape(-1, K)
    return float(np.mean([len(set(a[j]) & set(b[j])) / K
                          for j in range(b.shape[0])]))


def _run_ivf(index, wl, mode: str, alpha: float, *,
             pq: bool = False) -> Dict:
    """One IVF-family run; ``pq=True`` routes through the PQ backend
    (same measurement scaffolding, ADC counters reported)."""
    convs = jnp.asarray(wl.conversations)           # (C, T, d)
    n_conv, turns, d = convs.shape

    bk = (IVFPQBackend(h=H, nprobe=NPROBE, alpha=alpha, rerank=RERANK)
          if pq else IVFBackend(h=H, nprobe=NPROBE, alpha=alpha))

    def one_conv(conv):
        return TL.conversation(bk, index, conv, k=K, mode=mode)

    fn = jax.jit(lambda cs: jax.vmap(one_conv)(cs))
    v, ids, stats = fn(convs)
    jax.block_until_ready(ids)
    wall = C.time_fn(fn, convs)
    metrics = C.eval_conversations(np.asarray(ids), wl)
    return dict(
        metrics=metrics,
        ids=np.asarray(ids),
        ms_per_turn=1e3 * wall / (n_conv * turns),
        centroid_work=float(np.asarray(stats.centroid_dists).mean()),
        list_work=float(np.asarray(stats.list_dists).mean()),
        graph_work=0.0,
        code_work=float(np.asarray(stats.code_dists).mean()),
        refresh_rate=float(np.asarray(stats.refreshed)[:, 1:].mean()),
    )


def _run_hnsw(index, wl, mode: str) -> Dict:
    convs = jnp.asarray(wl.conversations)
    n_conv, turns, d = convs.shape

    bk = HNSWBackend(ef=EF, up=UP)

    def all_convs(cs):
        return jax.vmap(
            lambda conv: TL.conversation(bk, index, conv, k=K,
                                         mode=mode))(cs)

    fn = jax.jit(all_convs)
    v, ids, stats = fn(convs)
    jax.block_until_ready(ids)
    wall = C.time_fn(fn, convs)
    metrics = C.eval_conversations(np.asarray(ids), wl)
    return dict(
        metrics=metrics,
        ids=np.asarray(ids),
        ms_per_turn=1e3 * wall / (n_conv * turns),
        centroid_work=0.0, list_work=0.0,
        graph_work=float(np.asarray(stats.graph_dists).mean()),
        code_work=0.0,
        refresh_rate=0.0,
    )


def _run_exact(wl) -> Dict:
    docs = jnp.asarray(wl.doc_vecs)
    convs = jnp.asarray(wl.conversations)
    n_conv, turns, d = convs.shape
    flat = convs.reshape(-1, d)
    fn = jax.jit(lambda q: IV.exact_search(docs, q, K))
    v, ids = fn(flat)
    jax.block_until_ready(ids)
    wall = C.time_fn(fn, flat)
    ids = np.asarray(ids).reshape(n_conv, turns, K)
    metrics = C.eval_conversations(ids, wl)
    return dict(metrics=metrics, ids=ids,
                ms_per_turn=1e3 * wall / flat.shape[0],
                centroid_work=0.0, list_work=float(docs.shape[0]),
                graph_work=0.0, code_work=0.0, refresh_rate=0.0)


def run(csv: bool = True) -> List[Dict]:
    rows = []
    for kind in ("cast19", "cast20"):
        wl = C.workload(kind)
        ivf_idx = C.ivf_index(kind)
        pq_idx = C.ivf_pq_index(kind)
        hnsw_idx = C.hnsw_index(kind)
        results = {
            "Exact": _run_exact(wl),
            "IVF": _run_ivf(ivf_idx, wl, "plain", -1.0),
            "TopLoc_IVF": _run_ivf(ivf_idx, wl, "toploc", -1.0),
            "TopLoc_IVF+": _run_ivf(ivf_idx, wl, "toploc", ALPHA),
            "IVF-PQ": _run_ivf(pq_idx, wl, "plain", -1.0, pq=True),
            "TopLoc_IVFPQ": _run_ivf(pq_idx, wl, "toploc", -1.0, pq=True),
            "TopLoc_IVFPQ+": _run_ivf(pq_idx, wl, "toploc", ALPHA,
                                      pq=True),
            "HNSW": _run_hnsw(hnsw_idx, wl, "plain"),
            "TopLoc_HNSW": _run_hnsw(hnsw_idx, wl, "toploc"),
        }
        exact_ids = results["Exact"]["ids"]
        base_ms = {"TopLoc_IVF": results["IVF"]["ms_per_turn"],
                   "TopLoc_IVF+": results["IVF"]["ms_per_turn"],
                   "TopLoc_IVFPQ": results["IVF-PQ"]["ms_per_turn"],
                   "TopLoc_IVFPQ+": results["IVF-PQ"]["ms_per_turn"],
                   "TopLoc_HNSW": results["HNSW"]["ms_per_turn"]}
        base_work = {
            "TopLoc_IVF": results["IVF"]["centroid_work"]
            + results["IVF"]["list_work"],
            "TopLoc_IVF+": results["IVF"]["centroid_work"]
            + results["IVF"]["list_work"],
            "TopLoc_IVFPQ": results["IVF-PQ"]["centroid_work"]
            + results["IVF-PQ"]["list_work"],
            "TopLoc_IVFPQ+": results["IVF-PQ"]["centroid_work"]
            + results["IVF-PQ"]["list_work"],
            "TopLoc_HNSW": results["HNSW"]["graph_work"]}
        for name, res in results.items():
            # float distances only; code_dists reported separately (an
            # ADC eval moves m bytes, a float distance moves 4·d)
            work = (res["centroid_work"] + res["list_work"]
                    + res["graph_work"])
            row = dict(dataset=kind, method=name, **res["metrics"],
                       recall10=round(_recall_vs(res["ids"], exact_ids), 3),
                       ms_per_turn=round(res["ms_per_turn"], 3),
                       work=round(work, 1),
                       code_work=round(res["code_work"], 1),
                       speedup_time=(round(base_ms[name]
                                           / res["ms_per_turn"], 2)
                                     if name in base_ms else None),
                       speedup_work=(round(base_work[name] / work, 2)
                                     if name in base_work else None),
                       refresh_rate=round(res["refresh_rate"], 3))
            rows.append(row)
            if csv:
                sp_t = row["speedup_time"] or "-"
                sp_w = row["speedup_work"] or "-"
                print(f"table1,{kind},{name},{row['mrr@10']:.3f},"
                      f"{row['ndcg@3']:.3f},{row['ndcg@10']:.3f},"
                      f"{row['recall10']:.3f},{row['ms_per_turn']},"
                      f"{row['work']},{row['code_work']},{sp_t},{sp_w}")
    return rows


def _assert_smoke_floors(rows: List[Dict]) -> None:
    """Quality floors pinned by the PR-3 acceptance criteria."""
    by = {(r["dataset"], r["method"]): r for r in rows}
    for kind in ("cast19", "cast20"):
        pq_rec = by[(kind, "TopLoc_IVFPQ")]["recall10"]
        fl_rec = by[(kind, "TopLoc_IVF")]["recall10"]
        assert pq_rec >= 0.9 * fl_rec, (
            f"{kind}: TopLoc_IVFPQ recall@10 {pq_rec} < 0.9 x "
            f"TopLoc_IVF {fl_rec}")
        # all three backends produced sane rankings
        for method in ("TopLoc_IVF", "TopLoc_IVFPQ", "TopLoc_HNSW"):
            assert by[(kind, method)]["recall10"] >= 0.3, (kind, method)
        # compression actually moved the float-distance counter
        assert (by[(kind, "TopLoc_IVFPQ")]["work"]
                < by[(kind, "TopLoc_IVF")]["work"]), kind
    print("smoke: all floors hold "
          f"(pq/float recall ratio >= 0.9 on both sets)")


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    if smoke:
        # tiny corpus so the full table runs in CI seconds; constants
        # are read at call time so mutating the modules is enough
        global H
        C.N_DOCS, C.PARTITIONS = 4000, 128
        C.CONVS, C.TURNS = 6, 6
        C.HNSW_M, C.HNSW_EFC = 8, 32
        H = 64                        # keep np << h < p at p=128
    print("table,dataset,method,mrr@10,ndcg@3,ndcg@10,recall@10,"
          "ms_per_turn,work_dists,code_dists,speedup_time,speedup_work")
    rows = run()
    if smoke:
        _assert_smoke_floors(rows)
    return rows


if __name__ == "__main__":
    main()
