"""Table 1 reproduction: effectiveness + efficiency of every method on
both conversation sets.

Methods (paper rows): Exact, IVF, TopLoc_IVF, TopLoc_IVF+, HNSW,
TopLoc_HNSW.  Columns: MRR@10, NDCG@3, NDCG@10, mean per-turn time
(jitted device path, batch-of-conversations), speedup vs the plain
counterpart, and the hardware-independent work counters (distance
computations — what the paper's speedups reduce to).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hnsw as HN
from repro.core import ivf as IV
from repro.core import toploc as TL
from benchmarks import common as C

NPROBE = 16
H = 256          # np/h ≈ 6%: the regime where the |I0| proxy
                 # discriminates (paper: np << h << p)
ALPHA = 0.25
EF = 32
UP = 2
K = 10


def _run_ivf(index, wl, mode: str, alpha: float) -> Dict:
    convs = jnp.asarray(wl.conversations)           # (C, T, d)
    n_conv, turns, d = convs.shape

    def all_convs(cs):
        return jax.vmap(
            lambda conv: TL.ivf_conversation(index, conv, h=H,
                                             nprobe=NPROBE, k=K,
                                             alpha=alpha, mode=mode))(cs)

    fn = jax.jit(all_convs)
    v, ids, stats = fn(convs)
    jax.block_until_ready(ids)
    wall = C.time_fn(fn, convs)
    metrics = C.eval_conversations(np.asarray(ids), wl)
    return dict(
        metrics=metrics,
        ms_per_turn=1e3 * wall / (n_conv * turns),
        centroid_work=float(np.asarray(stats.centroid_dists).mean()),
        list_work=float(np.asarray(stats.list_dists).mean()),
        graph_work=0.0,
        refresh_rate=float(np.asarray(stats.refreshed)[:, 1:].mean()),
    )


def _run_hnsw(index, wl, mode: str) -> Dict:
    convs = jnp.asarray(wl.conversations)
    n_conv, turns, d = convs.shape

    def all_convs(cs):
        return jax.vmap(
            lambda conv: TL.hnsw_conversation(index, conv, ef=EF, k=K,
                                              up=UP, mode=mode))(cs)

    fn = jax.jit(all_convs)
    v, ids, stats = fn(convs)
    jax.block_until_ready(ids)
    wall = C.time_fn(fn, convs)
    metrics = C.eval_conversations(np.asarray(ids), wl)
    return dict(
        metrics=metrics,
        ms_per_turn=1e3 * wall / (n_conv * turns),
        centroid_work=0.0, list_work=0.0,
        graph_work=float(np.asarray(stats.graph_dists).mean()),
        refresh_rate=0.0,
    )


def _run_exact(wl) -> Dict:
    docs = jnp.asarray(wl.doc_vecs)
    convs = jnp.asarray(wl.conversations)
    n_conv, turns, d = convs.shape
    flat = convs.reshape(-1, d)
    fn = jax.jit(lambda q: IV.exact_search(docs, q, K))
    v, ids = fn(flat)
    jax.block_until_ready(ids)
    wall = C.time_fn(fn, flat)
    metrics = C.eval_conversations(
        np.asarray(ids).reshape(n_conv, turns, K), wl)
    return dict(metrics=metrics, ms_per_turn=1e3 * wall / flat.shape[0],
                centroid_work=0.0, list_work=float(docs.shape[0]),
                graph_work=0.0, refresh_rate=0.0)


def run(csv: bool = True) -> List[Dict]:
    rows = []
    for kind in ("cast19", "cast20"):
        wl = C.workload(kind)
        ivf_idx = C.ivf_index(kind)
        hnsw_idx = C.hnsw_index(kind)
        results = {
            "Exact": _run_exact(wl),
            "IVF": _run_ivf(ivf_idx, wl, "plain", -1.0),
            "TopLoc_IVF": _run_ivf(ivf_idx, wl, "toploc", -1.0),
            "TopLoc_IVF+": _run_ivf(ivf_idx, wl, "toploc", ALPHA),
            "HNSW": _run_hnsw(hnsw_idx, wl, "plain"),
            "TopLoc_HNSW": _run_hnsw(hnsw_idx, wl, "toploc"),
        }
        base_ms = {"TopLoc_IVF": results["IVF"]["ms_per_turn"],
                   "TopLoc_IVF+": results["IVF"]["ms_per_turn"],
                   "TopLoc_HNSW": results["HNSW"]["ms_per_turn"]}
        base_work = {
            "TopLoc_IVF": results["IVF"]["centroid_work"]
            + results["IVF"]["list_work"],
            "TopLoc_IVF+": results["IVF"]["centroid_work"]
            + results["IVF"]["list_work"],
            "TopLoc_HNSW": results["HNSW"]["graph_work"]}
        for name, res in results.items():
            work = (res["centroid_work"] + res["list_work"]
                    + res["graph_work"])
            row = dict(dataset=kind, method=name, **res["metrics"],
                       ms_per_turn=round(res["ms_per_turn"], 3),
                       work=round(work, 1),
                       speedup_time=(round(base_ms[name]
                                           / res["ms_per_turn"], 2)
                                     if name in base_ms else None),
                       speedup_work=(round(base_work[name] / work, 2)
                                     if name in base_work else None),
                       refresh_rate=round(res["refresh_rate"], 3))
            rows.append(row)
            if csv:
                sp_t = row["speedup_time"] or "-"
                sp_w = row["speedup_work"] or "-"
                print(f"table1,{kind},{name},{row['mrr@10']:.3f},"
                      f"{row['ndcg@3']:.3f},{row['ndcg@10']:.3f},"
                      f"{row['ms_per_turn']},{row['work']},{sp_t},{sp_w}")
    return rows


def main():
    print("table,dataset,method,mrr@10,ndcg@3,ndcg@10,ms_per_turn,"
          "work_dists,speedup_time,speedup_work")
    run()


if __name__ == "__main__":
    main()
