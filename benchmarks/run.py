"""Benchmark entry point: kernel micro-benchmarks plus one harness per
paper table/figure, discovered automatically.

Every ``benchmarks/fig*.py`` and ``benchmarks/table*.py`` module is
picked up by glob — adding a new figure file makes it runnable here
with no registration step.  Each module owns a ``main()`` and honours
the uniform ``--smoke`` contract: shrink the workload, assert the
figure's headline claim, print a ``SMOKE OK`` line and exit zero.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run --only table1
  PYTHONPATH=src python -m benchmarks.run --only fig8
  PYTHONPATH=src python -m benchmarks.run --smoke      # all smokes

Modules run as subprocesses: several check ``--smoke`` at import time
to shrink env-derived constants, so in-process imports cannot apply
the contract uniformly.
"""
from __future__ import annotations

import argparse
import glob
import os
import subprocess
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(BENCH_DIR)


def discover() -> list:
    """All fig*/table* benchmark modules, sorted by name."""
    paths = (glob.glob(os.path.join(BENCH_DIR, "fig*.py"))
             + glob.glob(os.path.join(BENCH_DIR, "table*.py")))
    return sorted(os.path.splitext(os.path.basename(p))[0] for p in paths)


def _matches(stem: str, only: str) -> bool:
    """--only accepts a full stem (fig1_ivf_sweep) or its short prefix
    (fig1, table1)."""
    return stem == only or stem.split("_")[0] == only


def run_module(stem: str, smoke: bool) -> int:
    """Run one benchmark module as a subprocess; returns its exit code."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO_ROOT, "src"), REPO_ROOT,
                    env.get("PYTHONPATH")) if p)
    cmd = [sys.executable, os.path.join(BENCH_DIR, stem + ".py")]
    if smoke:
        cmd.append("--smoke")
    print(f"### {stem}{' --smoke' if smoke else ''}", flush=True)
    return subprocess.call(cmd, env=env, cwd=REPO_ROOT)


def bench_kernels() -> None:
    """Kernel micro-benchmarks (jnp ref path timing on CPU; the Pallas
    kernels themselves are TPU-target and validated via interpret)."""
    from repro.kernels import ops, ref
    from benchmarks.common import time_fn
    rng = np.random.default_rng(0)

    q = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(4096, 64)).astype(np.float32))
    f = jax.jit(lambda q, c: ref.centroid_topk(q, c, 64))
    t = time_fn(f, q, c)
    print(f"kernel.centroid_topk_ref,{1e6*t:.1f},p=4096 d=64 b=16")

    lv = jnp.asarray(rng.normal(size=(128, 256, 64)).astype(np.float32))
    li = jnp.asarray(rng.integers(0, 10**6, (128, 256)).astype(np.int32))
    sel = jnp.asarray(np.stack([rng.permutation(128)[:16]
                                for _ in range(16)]).astype(np.int32))
    f = jax.jit(lambda q, s: ref.ivf_scan_batch(q, lv, li, s, 10))
    t = time_fn(f, q, sel)
    print(f"kernel.ivf_scan_ref,{1e6*t:.1f},np=16 Lmax=256 b=16")

    cf = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    f = jax.jit(lambda q: ops.fused_turn(q, cf, lv, li, nprobe=16, k=10,
                                         mode="ref")[:2])
    t = time_fn(f, q)
    print(f"kernel.fused_turn_ref,{1e6*t:.1f},p=128 np=16 Lmax=256 b=16")

    qa = jnp.asarray(rng.normal(size=(2, 8, 1024, 64)).astype(np.float32))
    ka = jnp.asarray(rng.normal(size=(2, 2, 1024, 64)).astype(np.float32))
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True,
                                                    mode="ref"))
    t = time_fn(f, qa, ka, ka)
    print(f"kernel.attention_ref,{1e6*t:.1f},b2 h8 s1024 d64")

    table = jnp.asarray(rng.normal(size=(100000, 32)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, 100000, (4096, 20)).astype(np.int32))
    f = jax.jit(lambda t_, i_: ops.embedding_bag(t_, i_, mode="ref"))
    t = time_fn(f, table, ids)
    print(f"kernel.embedding_bag_ref,{1e6*t:.1f},V=1e5 b=4096 L=20")


def main() -> None:
    modules = discover()
    shorts = sorted({m.split("_")[0] for m in modules})
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="all, kernels, or a module name/prefix: "
                         + ", ".join(shorts))
    ap.add_argument("--smoke", action="store_true",
                    help="run each selected module with --smoke and "
                         "fail if any smoke gate fails")
    ap.add_argument("--list", action="store_true",
                    help="list discovered benchmark modules and exit")
    args, _ = ap.parse_known_args()

    if args.list:
        for m in modules:
            print(m)
        return

    selected = (modules if args.only in ("all", "kernels")
                else [m for m in modules if _matches(m, args.only)])
    if args.only not in ("all", "kernels") and not selected:
        ap.error(f"--only {args.only!r} matched no module "
                 f"(discovered: {', '.join(modules)})")

    t0 = time.time()
    if args.only in ("all", "kernels"):
        print("name,us_per_call,derived")
        bench_kernels()
    if args.only == "kernels":
        return

    failed = []
    for stem in selected:
        if run_module(stem, args.smoke) != 0:
            failed.append(stem)
            print(f"### {stem} FAILED", file=sys.stderr, flush=True)

    status = "ok" if not failed else f"FAILED: {', '.join(failed)}"
    print(f"# {len(selected)} benchmark modules in {time.time()-t0:.1f}s "
          f"({status})", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
