"""Benchmark entry point: one harness per paper table/figure + kernel
micro-benchmarks. Prints ``name,us_per_call,derived`` CSV per the
repository contract, then the detailed per-table CSVs.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only table1
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp


def bench_kernels() -> None:
    """Kernel micro-benchmarks (jnp ref path timing on CPU; the Pallas
    kernels themselves are TPU-target and validated via interpret)."""
    from repro.kernels import ops, ref
    from benchmarks.common import time_fn
    rng = np.random.default_rng(0)

    q = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(4096, 64)).astype(np.float32))
    f = jax.jit(lambda q, c: ref.centroid_topk(q, c, 64))
    t = time_fn(f, q, c)
    print(f"kernel.centroid_topk_ref,{1e6*t:.1f},p=4096 d=64 b=16")

    lv = jnp.asarray(rng.normal(size=(128, 256, 64)).astype(np.float32))
    li = jnp.asarray(rng.integers(0, 10**6, (128, 256)).astype(np.int32))
    sel = jnp.asarray(np.stack([rng.permutation(128)[:16]
                                for _ in range(16)]).astype(np.int32))
    f = jax.jit(lambda q, s: ref.ivf_scan_batch(q, lv, li, s, 10))
    t = time_fn(f, q, sel)
    print(f"kernel.ivf_scan_ref,{1e6*t:.1f},np=16 Lmax=256 b=16")

    qa = jnp.asarray(rng.normal(size=(2, 8, 1024, 64)).astype(np.float32))
    ka = jnp.asarray(rng.normal(size=(2, 2, 1024, 64)).astype(np.float32))
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True,
                                                    mode="ref"))
    t = time_fn(f, qa, ka, ka)
    print(f"kernel.attention_ref,{1e6*t:.1f},b2 h8 s1024 d64")

    table = jnp.asarray(rng.normal(size=(100000, 32)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, 100000, (4096, 20)).astype(np.int32))
    f = jax.jit(lambda t_, i_: ops.embedding_bag(t_, i_, mode="ref"))
    t = time_fn(f, table, ids)
    print(f"kernel.embedding_bag_ref,{1e6*t:.1f},V=1e5 b=4096 L=20")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    choices=["all", "table1", "fig1", "fig2", "kernels"])
    args, _ = ap.parse_known_args()

    t0 = time.time()
    print("name,us_per_call,derived")
    if args.only in ("all", "kernels"):
        bench_kernels()

    if args.only in ("all", "table1"):
        from benchmarks import table1
        rows = table1.run(csv=False)
        for r in rows:
            sp = r["speedup_time"] or 1.0
            spw = r["speedup_work"] or 1.0
            print(f"table1.{r['dataset']}.{r['method']},"
                  f"{1e3*r['ms_per_turn']:.1f},"
                  f"mrr={r['mrr@10']:.3f};ndcg10={r['ndcg@10']:.3f};"
                  f"speedup_t={sp};speedup_w={spw}")

    if args.only in ("all", "fig1"):
        from benchmarks import fig1_ivf_sweep
        for kind in ("cast19", "cast20"):
            for r in fig1_ivf_sweep.sweep(kind, csv=False):
                print(f"fig1.{kind}.{r['method']}.np{r['nprobe']},"
                      f"{1e3*r['ms_per_turn']:.1f},"
                      f"ndcg10={r['ndcg10']:.3f};work={r['work']:.0f}")

    if args.only in ("all", "fig2"):
        from benchmarks import fig2_hnsw_sweep
        for kind in ("cast19", "cast20"):
            for r in fig2_hnsw_sweep.sweep(kind, csv=False):
                print(f"fig2.{kind}.{r['method']}.ef{r['ef']},"
                      f"{1e3*r['ms_per_turn']:.1f},"
                      f"ndcg10={r['ndcg10']:.3f};work={r['work']:.0f}")

    print(f"# benchmarks completed in {time.time()-t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
