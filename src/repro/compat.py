"""Cross-version jax API shims.

The container pins jax 0.4.37, where ``shard_map`` lives in
``jax.experimental.shard_map`` (kwarg ``check_rep``) and ``jax.set_mesh``
does not exist.  Newer jax promotes ``jax.shard_map`` (kwarg
``check_vma``) and adds ``jax.set_mesh``.  Call sites import the two
names from here so the code runs unmodified on either side of the
rename.  (The Pallas-specific shim lives in ``repro.kernels.compat``.)
"""
from __future__ import annotations

import jax

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
if not _NEW_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _old_shard_map
    from jax._src.mesh import thread_resources as _thread_resources


def shard_map(f, *, mesh=None, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` signature, executable on jax 0.4.x.

    ``check_vma`` maps onto the old ``check_rep``; ``mesh=None`` resolves
    the active mesh context (``set_mesh`` below) as new jax does.
    """
    if _NEW_SHARD_MAP:
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    if mesh is None:
        mesh = _thread_resources.env.physical_mesh
        if mesh.empty:
            raise ValueError(
                "shard_map(mesh=None) needs an active mesh context "
                "(enter repro.compat.set_mesh(mesh) first)")
    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)


def set_mesh(mesh):
    """Context manager equivalent of ``jax.set_mesh``.

    Old jax: a ``Mesh`` is itself a context manager that installs the
    physical mesh our ``shard_map`` shim resolves against.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
