"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)
    return sched


def inverse_sqrt(peak: float, warmup_steps: int):
    def sched(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        warm = peak * s / max(warmup_steps, 1)
        decay = peak * (warmup_steps ** 0.5) / jnp.sqrt(s)
        return jnp.where(s < warmup_steps, warm, decay)
    return sched


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)
