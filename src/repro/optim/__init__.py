"""Optimizers, schedules, gradient utilities (clip/accum/compression)."""
from repro.optim import grad, optimizers, schedules  # noqa: F401
