"""Optimizers (pure JAX, no optax): AdamW, Adafactor, SGD+momentum.

Interface (optax-like but self-contained):
    opt = adamw(lr_schedule, ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

State pytrees mirror the param tree, so the distributed sharding rules
for params apply verbatim to optimizer state (FSDP shards moments the
same way it shards weights — DESIGN.md §5).

Adafactor (factored second moment, arXiv:1804.04235) is the default for
the 314B-class MoE configs: it keeps per-matrix row/col statistics
instead of full fp32 moments, cutting optimizer HBM by ~4x — the
difference between grok-1 fitting a 256-chip pod or not (EXPERIMENTS.md
§Dry-run memory table).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _to_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = m_new / bc1
            vhat = v_new / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u, m_new.astype(moment_dtype), v_new.astype(moment_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; memory-lean for huge models)
# ---------------------------------------------------------------------------

def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    sched = _to_schedule(lr)

    def _factored(shape) -> bool:
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(st, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(g, v):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, -1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, -2)
                denom = (vr[..., None] / jnp.mean(vr, -1, keepdims=True
                                                  )[..., None]) * vc[..., None, :]
                u = gf * jax.lax.rsqrt(denom + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(vv + eps)
                nv = {"v": vv}
            # update clipping (RMS ≤ clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, nv

        flat_g, tdef = jax.tree.flatten(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        pairs = [upd(g, v) for g, v in zip(flat_g, flat_v)]
        updates = tdef.unflatten([p[0] for p in pairs])
        new_v = tdef.unflatten([p[1] for p in pairs])
        return updates, {"v": new_v, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------

def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        if momentum == 0.0:
            return (jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32),
                                 grads), {"step": step})
        m = jax.tree.map(lambda mm, g: momentum * mm + g.astype(jnp.float32),
                         state["m"], grads)
        if nesterov:
            upd = jax.tree.map(
                lambda mm, g: -lr_t * (momentum * mm + g.astype(jnp.float32)),
                m, grads)
        else:
            upd = jax.tree.map(lambda mm: -lr_t * mm, m)
        return upd, {"m": m, "step": step}

    return Optimizer(init, update)


REGISTRY = {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}


def make(name: str, lr, **kw) -> Optimizer:
    return REGISTRY[name](lr, **kw)
