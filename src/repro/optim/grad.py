"""Gradient utilities: clipping, accumulation, int8 compressed all-reduce.

``compress_decompress`` implements error-feedback int8 gradient
compression (1-bit-Adam-family trick, arXiv:1811.03617): gradients are
quantised per-tensor to int8 before the data-parallel all-reduce (4x
less DP traffic — directly attacks the collective roofline term for
gradient reduction) and the quantisation residual is carried in an
error-feedback buffer so the bias cancels over steps.  Togglable per
config; the equivalence trend is tested in tests/test_optim.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# gradient accumulation
# ---------------------------------------------------------------------------

def accumulate(loss_fn, params, batches, *, has_aux: bool = True):
    """Average grads over leading microbatch axis with a scan.

    ``batches``: pytree whose leaves have a leading microbatch axis.
    Bounded-staleness note: the scan keeps one microbatch in flight, so a
    straggling data shard delays only its own microbatch, not the whole
    window (DESIGN.md §7).
    """
    n = jax.tree.leaves(batches)[0].shape[0]
    grad_fn = jax.grad(loss_fn, has_aux=has_aux)

    def body(carry, mb):
        acc, aux_acc = carry
        if has_aux:
            g, aux = grad_fn(params, mb)
            aux_acc = jax.tree.map(lambda a, b: a + b / n, aux_acc, aux)
        else:
            g = grad_fn(params, mb)
        acc = jax.tree.map(lambda a, b: a + b / n, acc, g)
        return (acc, aux_acc), None

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if has_aux:
        sample = jax.tree.map(lambda x: x[0], batches)
        _, aux0 = loss_fn(params, sample)
        zero_aux = jax.tree.map(lambda a: jnp.zeros_like(a), aux0)
    else:
        zero_aux = ()
    (grads, aux), _ = jax.lax.scan(body, (zero_g, zero_aux), batches)
    return (grads, aux) if has_aux else grads


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------

def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array, err: jax.Array
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """g + carried error → (int8 codes, scale, new error)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_mean(grads, err_state, axis_name: Optional[str] = None):
    """Quantise → (all-reduce) → dequantise, with error feedback.

    With ``axis_name`` (inside shard_map/pmap) the int8 codes are what
    crosses the interconnect; without, it models the same numerics for
    single-host tests.
    """
    def one(g, e):
        q, scale, new_e = compress(g, e)
        if axis_name is not None:
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            n = jax.lax.psum(1, axis_name)
            deq = qsum.astype(jnp.float32) * scale / n
        else:
            deq = decompress(q, scale)
        return deq, new_e

    out = jax.tree.map(one, grads, err_state)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return deq, err
