"""repro — TopLoc (SIGIR'25) as a production-grade JAX retrieval/serving
framework: core ANN library + TopLoc sessions, Pallas TPU kernels, model
zoo (LM/GNN/recsys/encoders), distributed runtime, serving engine."""
__version__ = "1.0.0"
