"""RetrievalBackend registry — one seam for every ANN backend.

The paper's TopLoc session logic (centroid cache, Eq. 1 ``|I0|`` drift
proxy, α·np refresh, privileged entry points) is backend-agnostic, yet
it used to be hand-copied into 12+ prefixed ``toploc.*`` entry points
(``ivf_start``, ``ivf_pq_step_batch``, ``hnsw_conversation``, …) with
every upper layer re-branching on backend strings.  This module
collapses the families behind one interface:

  * a backend is a **frozen, hashable dataclass** — it rides through
    ``jax.jit`` as a static argument, so the generic drivers
    (``toploc.start/step/plain(+_batch)/conversation``) compile one
    program per (backend, k) pair exactly as the prefixed clones did;
  * backend *knobs* (h, nprobe, alpha, rerank, ef, up, …) live on the
    dataclass; the *index* stays a pytree argument so sharded/device
    placement is orthogonal;
  * the IVF and IVF-PQ families share one implementation of the session
    machinery — only ``_list_scan`` differs (float posting lists vs
    ADC over PQ codes + exact re-rank), which is the whole point of the
    paper's backend-agnostic formulation;
  * ``session_template`` gives ``serving.sessions.SessionStore`` its
    slab layout; ``corpus_vectors`` gives the serving result cache its
    re-scoring source; ``index_kwarg``/``stateful`` let the engines
    stay entirely free of ``backend == "..."`` branches.

Registering a new backend:

    @register
    @dataclasses.dataclass(frozen=True)
    class MyBackend(RetrievalBackend):
        name: ClassVar[str] = "my"
        index_kwarg: ClassVar[str] = "my_index"
        ...knob fields...
        def start(self, index, q0, *, k): ...

and every layer — both serving engines, the session store, the result
cache, the benchmarks — picks it up through ``backend.make(...)``.

Bit-identity contract: the methods below are the *same formulations*
(same ops, same reduction shapes) as the legacy prefixed entry points,
which remain as deprecated aliases; ``tests/test_backend_registry.py``
pins registry == legacy bit for bit for all three backends across
sequential / batched / conversation drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core import hnsw as _hnsw
from repro.core import ivf as _ivf
from repro.core import toploc as _tl
from repro.core.topk import intersect_count


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["RetrievalBackend"]] = {}


def register(cls: Type["RetrievalBackend"]) -> Type["RetrievalBackend"]:
    """Class decorator: make ``cls`` resolvable by ``get``/``make``."""
    _REGISTRY[cls.name] = cls
    return cls


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Type["RetrievalBackend"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown retrieval backend {name!r}; registered: "
            f"{', '.join(names())}") from None


def _knob_fields(cls: Type["RetrievalBackend"]) -> set:
    return {f.name for f in dataclasses.fields(cls)}


def make(name: str, *, strict: bool = False,
         **knobs: Any) -> "RetrievalBackend":
    """Build a backend from a flat knob mapping (e.g. a ServingConfig's
    fields): knobs the backend does not declare are ignored, so one
    config dataclass can parameterise every backend.

    A knob that no *registered* backend declares is a typo, not a
    cross-backend knob, and raises even on the lenient path (a typo'd
    ``nprob=16`` used to yield a default-nprobe backend with no signal).
    ``strict=True`` (user-facing callers) additionally rejects knobs
    this backend doesn't declare itself.
    """
    cls = get(name)
    fields = _knob_fields(cls)
    if strict:
        unknown = sorted(set(knobs) - fields)
        if unknown:
            raise TypeError(
                f"make({name!r}, strict=True): unknown knob(s) "
                f"{', '.join(unknown)}; {cls.__name__} declares "
                f"{', '.join(sorted(fields))}")
        return cls(**knobs)
    union = set().union(*(_knob_fields(c) for c in _REGISTRY.values()))
    unknown = sorted(set(knobs) - union)
    if unknown:
        raise TypeError(
            f"make({name!r}): knob(s) {', '.join(unknown)} match no "
            f"registered backend's fields (likely a typo); known knobs: "
            f"{', '.join(sorted(union))}")
    return cls(**{k: v for k, v in knobs.items() if k in fields})


# ---------------------------------------------------------------------------
# shared IVF-family implementation (float and PQ lists)
#
# ``list_scan(index, q (B,d), sel (B,np), k)`` -> (top_v (B,k),
# top_i (B,k), list_dists (B,), code_dists (B,)) abstracts the only
# thing that differs between TopLoc_IVF and TopLoc_IVFPQ; everything
# session-shaped below is written once.
# ---------------------------------------------------------------------------


def _ivf_family_start(index, q0, *, h, nprobe, k, list_scan):
    """First utterance: full centroid scan, C0 = top_h(q0, C), answer."""
    cache_ids, cache_vecs = _ivf.make_cache(index, q0, h=h)
    # top_np(q0, C0) == top_np(q0, C) since C0 holds q0's h best centroids
    anchor_sel = cache_ids[:nprobe]
    top_v, top_i, list_d, code_d = list_scan(index, q0[None],
                                             anchor_sel[None], k)
    sess = _tl.IVFSession(cache_ids, cache_vecs, anchor_sel,
                          jnp.asarray(0, jnp.int32),
                          jnp.asarray(1, jnp.int32))
    stats = _tl.TurnStats(
        centroid_dists=jnp.asarray(index.p, jnp.int32),
        list_dists=list_d[0],
        graph_dists=jnp.asarray(0, jnp.int32),
        code_dists=code_d[0],
        i0=jnp.asarray(-1, jnp.int32),
        refreshed=jnp.asarray(True),
    )
    return top_v[0], top_i[0], sess, stats


def _ivf_family_step(index, sess, q, *, nprobe, k, alpha, list_scan):
    """Follow-up utterance: cached centroid selection, Eq. 1 drift check
    (``alpha < 0`` static cache, ``alpha >= 0`` refresh), one list scan.

    The drift check runs *before* any posting list is scanned, so a
    refreshed turn pays (h + p) centroid distances but only one scan.
    """
    h = sess.cache_ids.shape[0]
    # 1. centroid selection against the cached set C0  (cost: h)
    csims = sess.cache_vecs @ q                      # (h,)
    _, sel_local = jax.lax.top_k(csims, nprobe)
    sel_cached = sess.cache_ids[sel_local]           # (np,) global ids

    # 2. drift proxy |I0| = |top_np(qj, C0) ∩ top_np(q0, C0)|   (Eq. 1)
    i0 = intersect_count(sel_cached, sess.anchor_sel)
    need_refresh = (alpha >= 0.0) & (i0 < jnp.asarray(alpha * nprobe))

    # 3. optional refresh: rescan the full centroid set, re-anchor on qj
    def refreshed(_):
        cache_ids, cache_vecs = _ivf.make_cache(index, q, h=h)
        return cache_ids, cache_vecs, cache_ids[:nprobe], cache_ids[:nprobe]

    def kept(_):
        return sess.cache_ids, sess.cache_vecs, sess.anchor_sel, sel_cached

    cache_ids, cache_vecs, anchor_sel, sel = jax.lax.cond(
        need_refresh, refreshed, kept, None)

    # 4. one posting-list scan with the final selection
    top_v, top_i, list_d, code_d = list_scan(index, q[None], sel[None], k)

    new_sess = _tl.IVFSession(cache_ids, cache_vecs, anchor_sel,
                              sess.refreshes + need_refresh.astype(jnp.int32),
                              sess.turn + 1)
    stats = _tl.TurnStats(
        centroid_dists=jnp.asarray(h, jnp.int32)
        + need_refresh.astype(jnp.int32) * index.p,
        list_dists=list_d[0],
        graph_dists=jnp.asarray(0, jnp.int32),
        code_dists=code_d[0],
        i0=i0,
        refreshed=need_refresh,
    )
    return top_v[0], top_i[0], new_sess, stats


def _ivf_family_start_batch(index, q0, *, h, nprobe, k, list_scan):
    """Batched first utterances: B conversations in one dispatch."""
    b = q0.shape[0]
    cache_ids, cache_vecs = _tl.make_cache_batch(index, q0, h=h)
    anchor_sel = cache_ids[:, :nprobe]
    top_v, top_i, list_d, code_d = list_scan(index, q0, anchor_sel, k)
    sess = _tl.IVFSession(cache_ids, cache_vecs, anchor_sel,
                          jnp.zeros((b,), jnp.int32),
                          jnp.ones((b,), jnp.int32))
    stats = _tl.TurnStats(
        centroid_dists=jnp.full((b,), index.p, jnp.int32),
        list_dists=list_d,
        graph_dists=jnp.zeros((b,), jnp.int32),
        code_dists=code_d,
        i0=jnp.full((b,), -1, jnp.int32),
        refreshed=jnp.ones((b,), bool),
    )
    return top_v, top_i, sess, stats


def _ivf_family_step_batch(index, sess, q, *, nprobe, k, alpha, is_first,
                           list_scan):
    """Batched follow-ups over B concurrent conversations.

    ``is_first`` ((B,) bool) rows ignore the slot contents, pay a full
    centroid scan, and re-anchor — exactly first-turn semantics realised
    as a forced refresh so the whole batch stays one uniform program.
    Per-row logic is select-only (no per-row ``lax.cond``); the refresh
    scan itself is gated on the *batch-wide* predicate so steady-state
    follow-up flushes stay O(B·h) instead of O(B·p).
    """
    b, h = sess.cache_ids.shape
    csims = jnp.einsum("bhd,bd->bh", sess.cache_vecs, q)
    _, sel_local = jax.lax.top_k(csims, nprobe)
    sel_cached = jnp.take_along_axis(sess.cache_ids, sel_local, axis=1)

    i0 = jax.vmap(intersect_count)(sel_cached, sess.anchor_sel)
    drift = (alpha >= 0.0) & (i0 < jnp.asarray(alpha * nprobe))

    first = (jnp.zeros((b,), bool) if is_first is None else is_first)
    refresh = first | drift

    if is_first is not None or alpha >= 0.0:
        fresh_ids, fresh_vecs = jax.lax.cond(
            jnp.any(refresh),
            lambda: _tl.make_cache_batch(index, q, h=h),
            lambda: (jnp.zeros((b, h), jnp.int32),
                     jnp.zeros((b, h) + index.centroids.shape[1:],
                               index.centroids.dtype)))
        r1 = refresh[:, None]
        cache_ids = jnp.where(r1, fresh_ids, sess.cache_ids)
        cache_vecs = jnp.where(r1[..., None], fresh_vecs, sess.cache_vecs)
        anchor_sel = jnp.where(r1, fresh_ids[:, :nprobe], sess.anchor_sel)
        sel = jnp.where(r1, fresh_ids[:, :nprobe], sel_cached)
    else:
        cache_ids, cache_vecs = sess.cache_ids, sess.cache_vecs
        anchor_sel, sel = sess.anchor_sel, sel_cached

    top_v, top_i, list_d, code_d = list_scan(index, q, sel, k)

    step_refresh = drift & ~first      # first turns don't count as refreshes
    new_sess = _tl.IVFSession(
        cache_ids, cache_vecs, anchor_sel,
        jnp.where(first, 0, sess.refreshes + step_refresh.astype(jnp.int32)),
        jnp.where(first, 1, sess.turn + 1))
    stats = _tl.TurnStats(
        centroid_dists=jnp.where(
            first, index.p,
            h + step_refresh.astype(jnp.int32) * index.p).astype(jnp.int32),
        list_dists=list_d,
        graph_dists=jnp.zeros((b,), jnp.int32),
        code_dists=code_d,
        i0=jnp.where(first, -1, i0),
        refreshed=refresh,
    )
    return top_v, top_i, new_sess, stats


def _ivf_family_plain_batch(index, q, *, nprobe, k, list_scan):
    """Stateless baseline turn: full centroid scan, one list scan."""
    b = q.shape[0]
    cscores = _tl._bcast_centroid_scores(index.centroids, q)
    _, sel = jax.lax.top_k(cscores, nprobe)
    top_v, top_i, list_d, code_d = list_scan(index, q, sel, k)
    stats = _tl.TurnStats(
        centroid_dists=jnp.full((b,), index.p, jnp.int32),
        list_dists=list_d,
        graph_dists=jnp.zeros((b,), jnp.int32),
        code_dists=code_d,
        i0=jnp.full((b,), -1, jnp.int32),
        refreshed=jnp.zeros((b,), bool),
    )
    return top_v, top_i, stats


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


class RetrievalBackend:
    """Interface + shared glue for registered backends.

    Subclasses are frozen dataclasses (hashable ⇒ jit-static) exposing:
      ``start(index, q0, *, k)``               → (v, i, sess, stats)
      ``step(index, sess, q, *, k)``           → (v, i, sess, stats)
      ``plain(index, q, *, k)``                → (v, i, stats)
      ``start_batch / step_batch / plain_batch`` — leading batch dim;
        ``step_batch`` takes ``is_first`` ((B,) bool or None)
      ``session_template(index)``              → single-session pytree
        (None for stateless backends)
      ``corpus_vectors(index)``                → (n, d) float rows for
        result-cache re-scoring, or None if the index keeps no flat
        corpus
    ``stats`` are always ``toploc.TurnStats`` (the paper's cost model).
    """

    name: ClassVar[str] = "?"
    index_kwarg: ClassVar[str] = "?"       # engine kwarg holding the index
    stateful: ClassVar[bool] = True        # has per-conversation sessions

    def plain(self, index, q, *, k):
        """Single-query plain turn — B=1 through the (batch-size-stable)
        batched path, so sequential and batched serving stay
        bit-identical."""
        v, i, st = self.plain_batch(index, q[None], k=k)
        return v[0], i[0], jax.tree.map(lambda a: a[0], st)

    def start(self, index, q0, *, k):
        raise NotImplementedError(f"{self.name} backend is stateless")

    def step(self, index, sess, q, *, k):
        raise NotImplementedError(f"{self.name} backend is stateless")

    def start_batch(self, index, q0, *, k):
        raise NotImplementedError(f"{self.name} backend is stateless")

    def step_batch(self, index, sess, q, *, k, is_first=None):
        raise NotImplementedError(f"{self.name} backend is stateless")

    def session_template(self, index) -> Optional[Any]:
        return None

    def corpus_vectors(self, index) -> Optional[jax.Array]:
        return None

    def query_dim(self, index) -> int:
        """Embedding dimensionality queries against ``index`` must have."""
        raise NotImplementedError

    def fetch_limit(self, index) -> int:
        """Largest per-query result depth a turn can request while
        executing the *same* program a plain k-request would (same
        candidate pool, so the top-k prefix is unchanged).  The serving
        result cache clamps its over-fetch depth to this."""
        raise NotImplementedError


@register
@dataclasses.dataclass(frozen=True)
class IVFBackend(RetrievalBackend):
    """TopLoc_IVF / TopLoc_IVF+ over float posting lists.

    ``alpha < 0`` → static centroid cache (TopLoc_IVF); ``alpha >= 0`` →
    Eq. 1 refresh at ``|I0| < alpha·nprobe`` (TopLoc_IVF+).  ``scan``
    optionally replaces the posting-list scan (signature of
    ``ivf._scan_lists``; sharded: ``distributed.retrieval.ShardedIVFScan``).
    ``fused`` (a ``toploc.FusedTurn``) routes the scan — and, on the
    stateless plain path, the whole turn — through the single-dispatch
    Pallas megakernel; ``scan`` wins if both are set (the sharded scan
    carries its own fused plugin).
    """

    name: ClassVar[str] = "ivf"
    index_kwarg: ClassVar[str] = "ivf_index"

    h: int = 1024
    nprobe: int = 64
    alpha: float = -1.0
    scan: Any = None
    fused: Any = None

    def _list_scan(self, index, q, sel, k):
        if self.scan is not None:
            v, i, real = self.scan(index, q, sel, k)
        elif self.fused is not None:
            v, i, real = self.fused.list_scan_ivf(index, q, sel, k)
        else:
            v, i, real = _ivf._scan_lists(index, q, sel, k)
        return v, i, real, jnp.zeros_like(real)

    def start(self, index, q0, *, k):
        return _ivf_family_start(index, q0, h=self.h, nprobe=self.nprobe,
                                 k=k, list_scan=self._list_scan)

    def step(self, index, sess, q, *, k):
        return _ivf_family_step(index, sess, q, nprobe=self.nprobe, k=k,
                                alpha=self.alpha, list_scan=self._list_scan)

    def start_batch(self, index, q0, *, k):
        return _ivf_family_start_batch(index, q0, h=self.h,
                                       nprobe=self.nprobe, k=k,
                                       list_scan=self._list_scan)

    def step_batch(self, index, sess, q, *, k, is_first=None):
        return _ivf_family_step_batch(index, sess, q, nprobe=self.nprobe,
                                      k=k, alpha=self.alpha,
                                      is_first=is_first,
                                      list_scan=self._list_scan)

    def plain_batch(self, index, q, *, k):
        if self.fused is not None and self.scan is None:
            # whole turn in one kernel dispatch: centroid scoring, probe
            # selection, list scan/merge (and re-rank) never leave VMEM
            b = q.shape[0]
            v, i, _sel, real = self.fused.turn_ivf(index, q,
                                                   nprobe=self.nprobe, k=k)
            stats = _tl.TurnStats(
                centroid_dists=jnp.full((b,), index.p, jnp.int32),
                list_dists=real,
                graph_dists=jnp.zeros((b,), jnp.int32),
                code_dists=jnp.zeros((b,), jnp.int32),
                i0=jnp.full((b,), -1, jnp.int32),
                refreshed=jnp.zeros((b,), bool),
            )
            return v, i, stats
        return _ivf_family_plain_batch(index, q, nprobe=self.nprobe, k=k,
                                       list_scan=self._list_scan)

    def session_template(self, index):
        return _tl.IVFSession(
            cache_ids=jnp.zeros((self.h,), jnp.int32),
            cache_vecs=jnp.zeros((self.h, index.d), index.centroids.dtype),
            anchor_sel=jnp.zeros((self.nprobe,), jnp.int32),
            refreshes=jnp.zeros((), jnp.int32),
            turn=jnp.zeros((), jnp.int32))

    def query_dim(self, index) -> int:
        return index.d

    def fetch_limit(self, index) -> int:
        # the float scan's candidate pool: every slot of every probed list
        return self.nprobe * index.lmax


@register
@dataclasses.dataclass(frozen=True)
class IVFPQBackend(IVFBackend):
    """TopLoc_IVFPQ: identical session machinery, PQ-compressed lists.

    Lists are ADC-scanned (``kernels.ops.pq_adc_scan``) and the top-R
    candidates exact-re-ranked against the float corpus; ``list_dists``
    counts the R re-rank dots, ``code_dists`` the ADC table-sums.
    ``scan`` replaces the whole ADC-scan + re-rank stage (signature of
    ``toploc._scan_lists_pq``; sharded: ``ShardedPQScan``).
    """

    name: ClassVar[str] = "ivf_pq"
    index_kwarg: ClassVar[str] = "ivf_pq_index"

    rerank: int = 64

    def _list_scan(self, index, q, sel, k):
        if self.scan is not None:
            v, i, code_d, rerank_d = self.scan(index, q, sel, k, self.rerank)
        elif self.fused is not None:
            v, i, code_d, rerank_d = self.fused.list_scan_pq(
                index, q, sel, k, self.rerank)
        else:
            v, i, code_d, rerank_d = _tl._scan_lists_pq(
                index, q, sel, k, self.rerank)
        return v, i, rerank_d, code_d

    def plain_batch(self, index, q, *, k):
        if self.fused is not None and self.scan is None:
            b = q.shape[0]
            v, i, _sel, code_d, rerank_d = self.fused.turn_pq(
                index, q, nprobe=self.nprobe, k=k, rerank=self.rerank)
            stats = _tl.TurnStats(
                centroid_dists=jnp.full((b,), index.p, jnp.int32),
                list_dists=rerank_d,
                graph_dists=jnp.zeros((b,), jnp.int32),
                code_dists=code_d,
                i0=jnp.full((b,), -1, jnp.int32),
                refreshed=jnp.zeros((b,), bool),
            )
            return v, i, stats
        return _ivf_family_plain_batch(index, q, nprobe=self.nprobe, k=k,
                                       list_scan=self._list_scan)

    def corpus_vectors(self, index):
        return index.doc_vecs

    def fetch_limit(self, index) -> int:
        # asking for k beyond this would widen the exact re-rank pool
        # (``r = max(k, min(rerank, np·Lmax))`` in ``_scan_lists_pq``),
        # changing which candidates the top-k is drawn from
        return min(self.rerank, self.nprobe * index.lmax)


@register
@dataclasses.dataclass(frozen=True)
class HNSWBackend(RetrievalBackend):
    """TopLoc_HNSW: privileged entry point, first-turn ef upscaling.

    ``adaptive=True`` is the beyond-paper extension re-anchoring the
    entry point at every turn's top-1.  ``search`` optionally replaces
    ``hnsw.search`` (sharded: ``ShardedHNSWSearch``).
    """

    name: ClassVar[str] = "hnsw"
    index_kwarg: ClassVar[str] = "hnsw_index"

    ef: int = 64
    up: int = 2
    adaptive: bool = False
    search: Any = None

    def _search(self):
        return self.search or _hnsw.search

    def start(self, index, q0, *, k):
        v, i, nd = self._search()(index, q0[None], ef=self.up * self.ef,
                                  k=k)
        sess = _tl.HNSWSession(entry_point=i[0, 0].astype(jnp.int32),
                               turn=jnp.asarray(1, jnp.int32))
        stats = _tl._zero_stats()._replace(graph_dists=nd[0],
                                           refreshed=jnp.asarray(True))
        return v[0], i[0], sess, stats

    def step(self, index, sess, q, *, k):
        v, i, nd = self._search()(
            index, q[None], ef=self.ef, k=k,
            entry_override=sess.entry_point[None],
            use_entry_override=True)
        new_entry = (i[0, 0].astype(jnp.int32) if self.adaptive
                     else sess.entry_point)
        sess = _tl.HNSWSession(entry_point=new_entry, turn=sess.turn + 1)
        stats = _tl._zero_stats()._replace(graph_dists=nd[0])
        return v[0], i[0], sess, stats

    def start_batch(self, index, q0, *, k):
        b = q0.shape[0]
        v, i, nd = self._search()(index, q0, ef=self.up * self.ef, k=k)
        sess = _tl.HNSWSession(entry_point=i[:, 0].astype(jnp.int32),
                               turn=jnp.ones((b,), jnp.int32))
        z = jnp.zeros((b,), jnp.int32)
        stats = _tl.TurnStats(z, z, nd, z, jnp.full((b,), -1, jnp.int32),
                              jnp.ones((b,), bool))
        return v, i, sess, stats

    def step_batch(self, index, sess, q, *, k, is_first=None):
        b = q.shape[0]
        do_search = self._search()
        v, i, nd = do_search(index, q, ef=self.ef, k=k,
                             entry_override=sess.entry_point,
                             use_entry_override=True)
        if is_first is not None:
            # batch-wide gate: steady-state flushes (no first turns) skip
            # the full-descent upscaled search entirely
            v0, i_0, nd0 = jax.lax.cond(
                jnp.any(is_first),
                lambda: do_search(index, q, ef=self.up * self.ef, k=k),
                lambda: (jnp.zeros((b, k), index.vectors.dtype),
                         jnp.zeros((b, k), jnp.int32),
                         jnp.zeros((b,), jnp.int32)))
            f1 = is_first[:, None]
            v = jnp.where(f1, v0, v)
            i = jnp.where(f1, i_0, i)
            nd = jnp.where(is_first, nd0, nd)
            first = is_first
        else:
            first = jnp.zeros((b,), bool)

        top1 = i[:, 0].astype(jnp.int32)
        new_entry = top1 if self.adaptive else jnp.where(first, top1,
                                                         sess.entry_point)
        new_sess = _tl.HNSWSession(entry_point=new_entry,
                                   turn=jnp.where(first, 1, sess.turn + 1))
        z = jnp.zeros((b,), jnp.int32)
        stats = _tl.TurnStats(z, z, nd, z, jnp.full((b,), -1, jnp.int32),
                              first)
        return v, i, new_sess, stats

    def plain_batch(self, index, q, *, k):
        b = q.shape[0]
        v, i, nd = self._search()(index, q, ef=self.ef, k=k)
        z = jnp.zeros((b,), jnp.int32)
        stats = _tl.TurnStats(z, z, nd, z, jnp.full((b,), -1, jnp.int32),
                              jnp.zeros((b,), bool))
        return v, i, stats

    def session_template(self, index):
        return _tl.HNSWSession(entry_point=jnp.zeros((), jnp.int32),
                               turn=jnp.zeros((), jnp.int32))

    def corpus_vectors(self, index):
        return index.vectors

    def query_dim(self, index) -> int:
        return index.vectors.shape[1]

    def fetch_limit(self, index) -> int:
        # the level-0 beam holds ef candidates; top_k beyond that is
        # unsatisfiable (first turns search wider at up·ef, but every
        # follow-up is capped at ef)
        return self.ef


@register
@dataclasses.dataclass(frozen=True)
class ExactBackend(RetrievalBackend):
    """Brute-force top-k over the full collection (the paper's 'Exact'
    row).  Stateless: the engines route every strategy through
    ``plain``; its index is the raw ``(n, d)`` doc-vector array."""

    name: ClassVar[str] = "exact"
    index_kwarg: ClassVar[str] = "doc_vecs"
    stateful: ClassVar[bool] = False

    def plain_batch(self, index, q, *, k):
        b = q.shape[0]
        v, i = _ivf.exact_search(index, q, k)
        z = jnp.zeros((b,), jnp.int32)
        stats = _tl.TurnStats(z, z, z, z, jnp.full((b,), -1, jnp.int32),
                              jnp.zeros((b,), bool))
        return v, i, stats

    def corpus_vectors(self, index):
        return index

    def query_dim(self, index) -> int:
        return index.shape[1]

    def fetch_limit(self, index) -> int:
        return index.shape[0]
