"""TopLoc — the paper's contribution (§2), as a composable JAX module.

Three mechanisms, each a pure function over an explicit session pytree so
they vmap over concurrently-served conversations and jit into the serving
step:

  * ``ivf_start`` / ``ivf_step``   — TopLoc_IVF / TopLoc_IVF+ centroid
    caching with the |I0| drift proxy (Eq. 1) and α·np refresh trigger.
  * ``hnsw_start`` / ``hnsw_step`` — TopLoc_HNSW privileged entry point
    with the ``up`` first-turn ef upscaling.
  * ``conversation_scan``          — run a whole conversation under
    ``lax.scan`` (benchmark harness path).

Work accounting: every step returns a ``TurnStats`` whose fields mirror
the paper's cost model — centroid distances (p for a full scan, h for a
cached one), posting-list distances, graph distances.  Speedups in
benchmarks/ are computed from these counters *and* wall-clock.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hnsw as _hnsw
from repro.core import ivf as _ivf
from repro.core.topk import intersect_count, masked_topk


class IVFSession(NamedTuple):
    """Per-conversation TopLoc_IVF state (device resident)."""
    cache_ids: jax.Array    # (h,) int32 — global centroid ids of C0
    cache_vecs: jax.Array   # (h, d)     — gathered centroid vectors
    anchor_sel: jax.Array   # (np,) int32 — top_np(q0, C0), for Eq. 1
    refreshes: jax.Array    # () int32
    turn: jax.Array         # () int32


class HNSWSession(NamedTuple):
    """Per-conversation TopLoc_HNSW state."""
    entry_point: jax.Array  # () int32 — privileged entry node
    turn: jax.Array         # () int32


class TurnStats(NamedTuple):
    centroid_dists: jax.Array  # () int32
    list_dists: jax.Array      # () int32
    graph_dists: jax.Array     # () int32
    i0: jax.Array              # () int32 — |I0| (IVF+ only; -1 otherwise)
    refreshed: jax.Array       # () bool


def _zero_stats() -> TurnStats:
    z = jnp.asarray(0, jnp.int32)
    return TurnStats(z, z, z, jnp.asarray(-1, jnp.int32), jnp.asarray(False))


# ---------------------------------------------------------------------------
# TopLoc_IVF / TopLoc_IVF+
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("h", "nprobe", "k"))
def ivf_start(index: _ivf.IVFIndex, q0: jax.Array, *, h: int, nprobe: int,
              k: int) -> Tuple[jax.Array, jax.Array, IVFSession, TurnStats]:
    """First utterance: full centroid scan, build C0 = top_h(q0, C), answer.

    Returns (scores (k,), doc_ids (k,), session, stats).
    """
    cache_ids, cache_vecs = _ivf.make_cache(index, q0, h=h)
    # top_np(q0, C0) == top_np(q0, C) since C0 holds q0's h best centroids
    anchor_sel = cache_ids[:nprobe]
    top_v, top_i, real = _ivf._scan_lists(index, q0[None], anchor_sel[None], k)
    sess = IVFSession(cache_ids, cache_vecs, anchor_sel,
                      jnp.asarray(0, jnp.int32), jnp.asarray(1, jnp.int32))
    stats = TurnStats(
        centroid_dists=jnp.asarray(index.p, jnp.int32),
        list_dists=real[0],
        graph_dists=jnp.asarray(0, jnp.int32),
        i0=jnp.asarray(-1, jnp.int32),
        refreshed=jnp.asarray(True),
    )
    return top_v[0], top_i[0], sess, stats


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "alpha"))
def ivf_step(index: _ivf.IVFIndex, sess: IVFSession, q: jax.Array, *,
             nprobe: int, k: int, alpha: float = -1.0
             ) -> Tuple[jax.Array, jax.Array, IVFSession, TurnStats]:
    """Follow-up utterance.

    ``alpha < 0``  → TopLoc_IVF  (static cache, never refreshed)
    ``alpha >= 0`` → TopLoc_IVF+ (refresh when |I0| < α·np, Eq. 1)

    The drift check runs *before* any posting list is scanned, so a
    refreshed turn pays (h + p) centroid distances but only one list scan.
    """
    h = sess.cache_ids.shape[0]
    # 1. centroid selection against the cached set C0  (cost: h)
    csims = sess.cache_vecs @ q                      # (h,)
    _, sel_local = jax.lax.top_k(csims, nprobe)
    sel_cached = sess.cache_ids[sel_local]           # (np,) global ids

    # 2. drift proxy |I0| = |top_np(qj, C0) ∩ top_np(q0, C0)|   (Eq. 1)
    i0 = intersect_count(sel_cached, sess.anchor_sel)
    need_refresh = (alpha >= 0.0) & (i0 < jnp.asarray(alpha * nprobe))

    # 3. optional refresh: rescan the full centroid set, re-anchor on qj
    def refreshed(_):
        cache_ids, cache_vecs = _ivf.make_cache(index, q, h=h)
        return cache_ids, cache_vecs, cache_ids[:nprobe], cache_ids[:nprobe]

    def kept(_):
        return sess.cache_ids, sess.cache_vecs, sess.anchor_sel, sel_cached

    cache_ids, cache_vecs, anchor_sel, sel = jax.lax.cond(
        need_refresh, refreshed, kept, None)

    # 4. one posting-list scan with the final selection
    top_v, top_i, real = _ivf._scan_lists(index, q[None], sel[None], k)

    new_sess = IVFSession(cache_ids, cache_vecs, anchor_sel,
                          sess.refreshes + need_refresh.astype(jnp.int32),
                          sess.turn + 1)
    stats = TurnStats(
        centroid_dists=jnp.asarray(h, jnp.int32)
        + need_refresh.astype(jnp.int32) * index.p,
        list_dists=real[0],
        graph_dists=jnp.asarray(0, jnp.int32),
        i0=i0,
        refreshed=need_refresh,
    )
    return top_v[0], top_i[0], new_sess, stats


# ---------------------------------------------------------------------------
# TopLoc_HNSW
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("ef", "k", "up"))
def hnsw_start(index: _hnsw.HNSWIndex, q0: jax.Array, *, ef: int, k: int,
               up: int = 2) -> Tuple[jax.Array, jax.Array, HNSWSession, TurnStats]:
    """First utterance: plain HNSW with an upscaled candidate list
    (up · ef_search) so the privileged entry point is reliable."""
    v, i, nd = _hnsw.search(index, q0[None], ef=up * ef, k=k)
    sess = HNSWSession(entry_point=i[0, 0].astype(jnp.int32),
                       turn=jnp.asarray(1, jnp.int32))
    stats = _zero_stats()._replace(graph_dists=nd[0],
                                   refreshed=jnp.asarray(True))
    return v[0], i[0], sess, stats


@functools.partial(jax.jit, static_argnames=("ef", "k", "adaptive"))
def hnsw_step(index: _hnsw.HNSWIndex, sess: HNSWSession, q: jax.Array, *,
              ef: int, k: int, adaptive: bool = False
              ) -> Tuple[jax.Array, jax.Array, HNSWSession, TurnStats]:
    """Follow-up utterance: start the level-0 beam at the privileged entry
    point — no hierarchy descent (the paper's saving).

    ``adaptive=True`` is a beyond-paper extension: re-anchor the entry
    point at every turn's top-1 (the paper keeps q0's anchor for the whole
    conversation).
    """
    v, i, nd = _hnsw.search(index, q[None],
                            ef=ef, k=k,
                            entry_override=sess.entry_point[None],
                            use_entry_override=True)
    new_entry = i[0, 0].astype(jnp.int32) if adaptive else sess.entry_point
    sess = HNSWSession(entry_point=new_entry, turn=sess.turn + 1)
    stats = _zero_stats()._replace(graph_dists=nd[0])
    return v[0], i[0], sess, stats


# ---------------------------------------------------------------------------
# Whole-conversation scan (benchmark path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("h", "nprobe", "k", "alpha", "mode"))
def ivf_conversation(index: _ivf.IVFIndex, utterances: jax.Array, *, h: int,
                     nprobe: int, k: int, alpha: float = -1.0,
                     mode: str = "toploc"
                     ) -> Tuple[jax.Array, jax.Array, TurnStats]:
    """Run a (T, d) conversation through one IVF strategy.

    mode: 'toploc' (cache; alpha<0 static, alpha>=0 refresh) or 'plain'
    (full centroid scan every turn — the baseline).
    Returns (scores (T,k), ids (T,k), stats stacked over turns).
    """
    if mode == "plain":
        def body(carry, q):
            top_v, top_i, st = _ivf.search(index, q[None], nprobe=nprobe, k=k)
            stats = TurnStats(jnp.asarray(index.p, jnp.int32),
                              st.list_dists[0], jnp.asarray(0, jnp.int32),
                              jnp.asarray(-1, jnp.int32), jnp.asarray(False))
            return carry, (top_v[0], top_i[0], stats)
        _, (v, i, stats) = jax.lax.scan(body, 0, utterances)
        return v, i, stats

    q0, rest = utterances[0], utterances[1:]
    v0, i0_, sess, st0 = ivf_start(index, q0, h=h, nprobe=nprobe, k=k)

    def body(sess, q):
        v, i, sess, st = ivf_step(index, sess, q, nprobe=nprobe, k=k,
                                  alpha=alpha)
        return sess, (v, i, st)

    _, (v, i, st) = jax.lax.scan(body, sess, rest)
    v = jnp.concatenate([v0[None], v])
    i = jnp.concatenate([i0_[None], i])
    stats = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b]), st0, st)
    return v, i, stats


@functools.partial(jax.jit, static_argnames=("ef", "k", "up", "mode"))
def hnsw_conversation(index: _hnsw.HNSWIndex, utterances: jax.Array, *,
                      ef: int, k: int, up: int = 2, mode: str = "toploc"
                      ) -> Tuple[jax.Array, jax.Array, TurnStats]:
    """Run a (T, d) conversation through one HNSW strategy.

    mode: 'plain' | 'toploc' (paper: static q0 anchor) | 'adaptive'
    (beyond-paper: re-anchor the entry point at every turn's top-1).
    """
    if mode == "plain":
        v, i, nd = _hnsw.search(index, utterances, ef=ef, k=k)
        stats = TurnStats(
            jnp.zeros_like(nd), jnp.zeros_like(nd), nd,
            jnp.full_like(nd, -1), jnp.zeros(nd.shape, bool))
        return v, i, stats

    q0, rest = utterances[0], utterances[1:]
    v0, i0_, sess, st0 = hnsw_start(index, q0, ef=ef, k=k, up=up)
    adaptive = mode == "adaptive"

    def body(sess, q):
        v, i, sess, st = hnsw_step(index, sess, q, ef=ef, k=k,
                                   adaptive=adaptive)
        return sess, (v, i, st)

    _, (v, i, st) = jax.lax.scan(body, sess, rest)
    v = jnp.concatenate([v0[None], v])
    i = jnp.concatenate([i0_[None], i])
    stats = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b]), st0, st)
    return v, i, stats
