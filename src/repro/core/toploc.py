"""TopLoc — the paper's contribution (§2), as a composable JAX module.

The session logic (centroid cache, Eq. 1 ``|I0|`` drift proxy, α·np
refresh, privileged HNSW entry points) is backend-agnostic; the concrete
backends live in ``core.backend`` as registered, jit-static dataclasses
(``IVFBackend``, ``IVFPQBackend``, ``HNSWBackend``, ``ExactBackend``).
This module holds what is shared across all of them:

  * the session pytrees (``IVFSession``, ``HNSWSession``) and the
    ``TurnStats`` work counters mirroring the paper's cost model —
    centroid distances (p for a full scan, h for a cached one), float
    doc distances (lists/re-rank), graph distances, and PQ code
    distances (ADC table-sum evaluations);
  * the **generic jitted drivers** — one compiled program per
    (backend, k) pair, replacing the old per-prefix clones:

      ``start(backend, index, q0, k=…)``        first utterance
      ``step(backend, index, sess, q, k=…)``    follow-up utterance
      ``plain(backend, index, q, k=…)``         stateless baseline turn
      ``start_batch / step_batch / plain_batch`` batched serving path
      ``conversation(backend, index, utterances, k=…, mode=…)``
                                                whole-conversation scan

  * batch-size-stable numeric helpers (``_bcast_centroid_scores``,
    ``make_cache_batch``, ``_adc_tables``, ``_scan_lists_pq``) keeping
    sequential, batched and sharded paths bit-identical.

The legacy prefixed entry points (``ivf_start``, ``ivf_pq_step_batch``,
``hnsw_conversation``, …) remain as thin aliases that emit a
``DeprecationWarning`` and forward to the registry drivers;
``tests/test_backend_registry.py`` pins alias == driver bit for bit.
"""
from __future__ import annotations

import functools
import sys
import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ivf as _ivf
from repro.core import pq as _pq
from repro.kernels import ops as _kops


class IVFSession(NamedTuple):
    """Per-conversation TopLoc_IVF state (device resident)."""
    cache_ids: jax.Array    # (h,) int32 — global centroid ids of C0
    cache_vecs: jax.Array   # (h, d)     — gathered centroid vectors
    anchor_sel: jax.Array   # (np,) int32 — top_np(q0, C0), for Eq. 1
    refreshes: jax.Array    # () int32
    turn: jax.Array         # () int32


class HNSWSession(NamedTuple):
    """Per-conversation TopLoc_HNSW state."""
    entry_point: jax.Array  # () int32 — privileged entry node
    turn: jax.Array         # () int32


class TurnStats(NamedTuple):
    centroid_dists: jax.Array  # () int32
    list_dists: jax.Array      # () int32 — float doc distances (lists/rerank)
    graph_dists: jax.Array     # () int32
    code_dists: jax.Array      # () int32 — PQ ADC table-sum evaluations
    i0: jax.Array              # () int32 — |I0| (IVF+ only; -1 otherwise)
    refreshed: jax.Array       # () bool


def _zero_stats() -> TurnStats:
    z = jnp.asarray(0, jnp.int32)
    return TurnStats(z, z, z, z, jnp.asarray(-1, jnp.int32),
                     jnp.asarray(False))


# ---------------------------------------------------------------------------
# batch-size-stable numeric helpers
#
# The one subtlety of batched serving: a ``(B, d) @ (d, p)`` matmul
# lowers to a tiled reduction whose order differs from the sequential
# ``(p, d) @ (d,)`` matvec, so results would drift bitwise with batch
# size.  Broadcasting the static operand into the batch dim instead
# makes each row's dot_general reduce exactly like the matvec
# (tests/test_serving_batched.py pins this down).
# ---------------------------------------------------------------------------


def _bcast_centroid_scores(centroids: jax.Array, q: jax.Array) -> jax.Array:
    """(B, p) centroid scores, bit-identical per row to ``centroids @ q``."""
    b = q.shape[0]
    return jnp.einsum("bpd,bd->bp",
                      jnp.broadcast_to(centroids, (b,) + centroids.shape), q)


@functools.partial(jax.jit, static_argnames=("h",))
def make_cache_batch(index: _ivf.IVFIndex, q: jax.Array, *, h: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Batched ``ivf.make_cache``: C0 = top_h(q, C) per row. q: (B, d)."""
    cscores = _bcast_centroid_scores(index.centroids, q)
    _, ids = jax.lax.top_k(cscores, h)
    ids = ids.astype(jnp.int32)
    return ids, index.centroids[ids]


def _adc_tables(index: _pq.IVFPQIndex, q: jax.Array) -> jax.Array:
    """Per-query ADC lookup tables, (B, m, n_codes).

    Broadcasts the codewords into the batch dim (cf.
    ``_bcast_centroid_scores``) so each row's d_sub-length contractions
    are bit-identical at any batch size.
    """
    b = q.shape[0]
    m, n_codes, d_sub = index.codewords.shape
    qs = q.reshape(b, m, d_sub)
    cw = jnp.broadcast_to(index.codewords, (b,) + index.codewords.shape)
    return jnp.einsum("bmd,bmkd->bmk", qs, cw)


def _scan_lists_pq(index: _pq.IVFPQIndex, q: jax.Array, sel: jax.Array,
                   k: int, rerank: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """ADC-scan the selected PQ lists, exact-re-rank the top-R.

    q (B, d); sel (B, np).  Returns (top_v (B,k), top_i (B,k),
    code_dists (B,), rerank_dists (B,)).
    """
    nprobe = sel.shape[1]
    r = max(k, min(rerank, nprobe * index.lmax))
    tables = _adc_tables(index, q)
    cand_v, cand_ids = _kops.pq_adc_scan(tables, index.list_codes,
                                         index.list_ids, sel, r)
    # exact re-rank of the R survivors against the float corpus — the
    # only place uncompressed vectors are touched (R rows, not np·Lmax).
    # Explicit multiply-reduce, not a dot_general: XLA canonicalises the
    # unit batch dim away at B=1 and retiles the reduction (cf.
    # hnsw._dots), which would break sequential↔batched bit-identity.
    safe = jnp.maximum(cand_ids, 0)
    exact = jnp.sum(index.doc_vecs[safe] * q[:, None, :], axis=-1)
    exact = jnp.where(cand_ids >= 0, exact, -jnp.inf)
    top_v, pos = jax.lax.top_k(exact, k)
    top_i = jnp.take_along_axis(cand_ids, pos, axis=-1)
    code_d = jnp.sum(index.list_sizes[sel], axis=-1).astype(jnp.int32)
    rerank_d = jnp.sum((cand_ids >= 0), axis=-1).astype(jnp.int32)
    return top_v, top_i, code_d, rerank_d


# ---------------------------------------------------------------------------
# fused single-dispatch turn plugin (kernels.fused_turn)
# ---------------------------------------------------------------------------


import dataclasses as _dataclasses


@_dataclasses.dataclass(frozen=True)
class FusedTurn:
    """Opt-in plugin routing IVF-family turns through the fused Pallas
    megakernel (``kernels.fused_turn``): centroid scoring, probed-list
    scan/merge and (for quantized precisions or PQ) the exact re-rank run
    as ONE kernel dispatch instead of three.

    Precision contract (see ``kernels.fused_turn`` module docstring):
    ``precision="f32"`` is bit-identical to the 3-dispatch path — same
    ids, same scores, same ``TurnStats`` counters; ``"bf16"``/``"int8"``
    score stages 1–2 quantized but ALWAYS exact-re-rank the top
    ``k·over`` candidates in float32 inside the kernel, so returned
    scores are exact dots and recall@k is floored (fig8 pins ≥ 0.95×
    the float path).

    Frozen + hashable so it rides on the backend dataclass as a
    jit-static field.  ``mode=None`` follows ``kernels.ops`` dispatch
    (interpret on CPU, compiled on TPU); ``mode="ref"`` forces the pure
    XLA oracle in ``kernels.ref``.
    """

    precision: str = "f32"
    over: int = 2            # quantized candidate depth: r = k·over
    mode: Optional[str] = None

    # -- whole-turn entry points (stateless plain path) ---------------

    def turn_ivf(self, index: _ivf.IVFIndex, q: jax.Array, *,
                 nprobe: int, k: int):
        """Full single-dispatch turn: returns (v, i, sel, list_dists)."""
        v, i, sel = _kops.fused_turn(
            q, index.centroids, index.list_vecs, index.list_ids,
            nprobe=nprobe, k=k, over=self.over, precision=self.precision,
            mode=self.mode)
        real = jnp.sum(index.list_sizes[sel], axis=-1).astype(jnp.int32)
        return v, i, sel, real

    def turn_pq(self, index: _pq.IVFPQIndex, q: jax.Array, *,
                nprobe: int, k: int, rerank: int):
        """Full single-dispatch PQ turn: (v, i, sel, code_d, rerank_d)."""
        tables = _adc_tables(index, q)
        v, i, sel = _kops.fused_turn_pq(
            q, index.centroids, tables, index.list_codes, index.list_ids,
            index.doc_vecs, nprobe=nprobe, k=k, rerank=rerank,
            precision=self.precision, mode=self.mode)
        code_d = jnp.sum(index.list_sizes[sel], axis=-1).astype(jnp.int32)
        # every valid ADC candidate outranks the -inf pads, so the
        # re-ranked count is exactly min(r, candidates available)
        r = max(k, min(rerank, nprobe * index.lmax))
        rerank_d = jnp.minimum(r, code_d).astype(jnp.int32)
        return v, i, sel, code_d, rerank_d

    # -- list-scan entry points (cached/sessioned paths) --------------
    #
    # Stage 1 (centroid cache, Eq. 1 drift) stays in XLA on the
    # sessioned paths — only the scan+merge(+re-rank) stages fuse.

    def list_scan_ivf(self, index: _ivf.IVFIndex, q: jax.Array,
                      sel: jax.Array, k: int):
        """Drop-in for ``ivf._scan_lists``: (v, i, real_dists)."""
        v, i, _pos = _kops.fused_scan(
            q, index.list_vecs, index.list_ids, sel, k, over=self.over,
            precision=self.precision, mode=self.mode)
        real = jnp.sum(index.list_sizes[sel], axis=-1).astype(jnp.int32)
        return v, i, real

    def list_scan_pq(self, index: _pq.IVFPQIndex, q: jax.Array,
                     sel: jax.Array, k: int, rerank: int):
        """Drop-in for ``_scan_lists_pq``: (v, i, code_d, rerank_d)."""
        tables = _adc_tables(index, q)
        v, i, _pos = _kops.fused_scan_pq(
            tables, q, index.list_codes, index.list_ids, sel,
            index.doc_vecs, k, rerank=rerank, precision=self.precision,
            fuse_rerank=True, mode=self.mode)
        nprobe = sel.shape[1]
        code_d = jnp.sum(index.list_sizes[sel], axis=-1).astype(jnp.int32)
        r = max(k, min(rerank, nprobe * index.lmax))
        rerank_d = jnp.minimum(r, code_d).astype(jnp.int32)
        return v, i, code_d, rerank_d


# ---------------------------------------------------------------------------
# generic registry drivers — ONE jitted program per (backend, k) pair
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend", "k"))
def start(backend, index, q0: jax.Array, *, k: int):
    """First utterance through any registered backend.

    q0: (d,).  Returns (scores (k,), doc_ids (k,), session, stats).
    """
    return backend.start(index, q0, k=k)


@functools.partial(jax.jit, static_argnames=("backend", "k"))
def step(backend, index, sess, q: jax.Array, *, k: int):
    """Follow-up utterance. Returns (scores, doc_ids, session, stats)."""
    return backend.step(index, sess, q, k=k)


@functools.partial(jax.jit, static_argnames=("backend", "k"))
def plain(backend, index, q: jax.Array, *, k: int):
    """Stateless baseline turn. q: (d,). Returns (scores, doc_ids, stats)."""
    return backend.plain(index, q, k=k)


@functools.partial(jax.jit, static_argnames=("backend", "k"))
def start_batch(backend, index, q0: jax.Array, *, k: int):
    """Batched ``start``: B first utterances in one dispatch. q0: (B, d)."""
    return backend.start_batch(index, q0, k=k)


@functools.partial(jax.jit, static_argnames=("backend", "k"))
def step_batch(backend, index, sess, q: jax.Array, *, k: int,
               is_first: Optional[jax.Array] = None):
    """Batched ``step`` over B concurrent conversations.

    Session fields carry a leading batch dim (gathered from a
    ``serving.sessions.SessionStore`` slab); ``is_first`` ((B,) bool)
    marks rows whose slot is fresh — those run first-turn semantics as a
    forced refresh so the whole batch stays one uniform program.
    """
    return backend.step_batch(index, sess, q, k=k, is_first=is_first)


@functools.partial(jax.jit, static_argnames=("backend", "k"))
def plain_batch(backend, index, q: jax.Array, *, k: int):
    """Batched stateless baseline turn. q: (B, d)."""
    return backend.plain_batch(index, q, k=k)


@functools.partial(jax.jit, static_argnames=("backend", "k", "mode"))
def conversation(backend, index, utterances: jax.Array, *, k: int,
                 mode: str = "toploc"):
    """Run a (T, d) conversation through one strategy (benchmark path).

    mode: 'toploc' (sessioned; the backend's alpha/adaptive knobs pick
    the refresh flavour) or 'plain' (the stateless baseline every turn —
    turns run as one batch, which the batch-size-stable formulations
    keep bit-identical to per-turn dispatch).
    Returns (scores (T,k), ids (T,k), stats stacked over turns).
    """
    if mode == "plain":
        return backend.plain_batch(index, utterances, k=k)
    if mode != "toploc":
        raise ValueError(f"mode must be 'toploc' or 'plain', got {mode!r}")

    q0, rest = utterances[0], utterances[1:]
    v0, i0_, sess, st0 = backend.start(index, q0, k=k)

    def body(sess, q):
        v, i, sess, st = backend.step(index, sess, q, k=k)
        return sess, (v, i, st)

    _, (v, i, st) = jax.lax.scan(body, sess, rest)
    v = jnp.concatenate([v0[None], v])
    i = jnp.concatenate([i0_[None], i])
    stats = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b]), st0, st)
    return v, i, stats


# ---------------------------------------------------------------------------
# deprecated prefixed aliases (pre-registry API)
#
# Every alias forwards to the exact registry driver path — bit-identity
# is pinned by tests/test_backend_registry.py — and warns so downstream
# callers migrate.  New code should build a ``core.backend`` dataclass
# once and call the generic drivers above.
#
# Warning policy: once per *call site* (caller filename:lineno), with
# ``stacklevel=2`` so the warning points at the caller, not the alias.
# A serving loop hammering one legacy entry point logs a single line
# instead of one per request; distinct call sites each still get their
# warning.  The ``__deprecated_alias__`` marker is what the analyzer's
# deprecated-alias pass keys on (``repro.analysis.deprecation``).
# ---------------------------------------------------------------------------

_warned_sites: set = set()


def _deprecated_alias(repl: str):
    """Mark a legacy ``toploc.*`` entry point; warn once per call site."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            frame = sys._getframe(1)
            site = (frame.f_code.co_filename, frame.f_lineno)
            if site not in _warned_sites:
                _warned_sites.add(site)
                warnings.warn(
                    f"toploc.{fn.__name__} is deprecated; use the "
                    f"core.backend registry: {repl}",
                    DeprecationWarning, stacklevel=2)
            return fn(*args, **kwargs)
        wrapper.__deprecated_alias__ = True
        return wrapper
    return deco


def _ivf_backend(**knobs):
    from repro.core import backend as _backend
    return _backend.IVFBackend(**knobs)


def _pq_backend(**knobs):
    from repro.core import backend as _backend
    return _backend.IVFPQBackend(**knobs)


def _hnsw_backend(**knobs):
    from repro.core import backend as _backend
    return _backend.HNSWBackend(**knobs)


@_deprecated_alias("start(IVFBackend(h=…, nprobe=…), …)")
def ivf_start(index, q0, *, h, nprobe, k, scan=None):
    return start(_ivf_backend(h=h, nprobe=nprobe, scan=scan), index, q0,
                 k=k)


@_deprecated_alias("step(IVFBackend(…, alpha=…), …)")
def ivf_step(index, sess, q, *, nprobe, k, alpha=-1.0, scan=None):
    return step(_ivf_backend(h=sess.cache_ids.shape[0], nprobe=nprobe,
                             alpha=alpha, scan=scan), index, sess, q, k=k)


@_deprecated_alias("start_batch(IVFBackend(…), …)")
def ivf_start_batch(index, q0, *, h, nprobe, k, scan=None):
    return start_batch(_ivf_backend(h=h, nprobe=nprobe, scan=scan), index,
                       q0, k=k)


@_deprecated_alias("step_batch(IVFBackend(…), …)")
def ivf_step_batch(index, sess, q, *, nprobe, k, alpha=-1.0, is_first=None,
                   scan=None):
    return step_batch(_ivf_backend(h=sess.cache_ids.shape[1], nprobe=nprobe,
                                   alpha=alpha, scan=scan), index, sess, q,
                      k=k, is_first=is_first)


@_deprecated_alias("plain_batch(IVFBackend(…), …)")
def ivf_plain_batch(index, q, *, nprobe, k, scan=None):
    return plain_batch(_ivf_backend(nprobe=nprobe, scan=scan), index, q,
                       k=k)


@_deprecated_alias("conversation(IVFBackend(…), …)")
def ivf_conversation(index, utterances, *, h, nprobe, k, alpha=-1.0,
                     mode="toploc", scan=None):
    return conversation(_ivf_backend(h=h, nprobe=nprobe, alpha=alpha,
                                     scan=scan), index, utterances, k=k,
                        mode=mode)


@_deprecated_alias("start(IVFPQBackend(…), …)")
def ivf_pq_start(index, q0, *, h, nprobe, k, rerank=32, scan=None):
    return start(_pq_backend(h=h, nprobe=nprobe, rerank=rerank, scan=scan),
                 index, q0, k=k)


@_deprecated_alias("step(IVFPQBackend(…), …)")
def ivf_pq_step(index, sess, q, *, nprobe, k, alpha=-1.0, rerank=32,
                scan=None):
    return step(_pq_backend(h=sess.cache_ids.shape[0], nprobe=nprobe,
                            alpha=alpha, rerank=rerank, scan=scan), index,
                sess, q, k=k)


@_deprecated_alias("start_batch(IVFPQBackend(…), …)")
def ivf_pq_start_batch(index, q0, *, h, nprobe, k, rerank=32, scan=None):
    return start_batch(_pq_backend(h=h, nprobe=nprobe, rerank=rerank,
                                   scan=scan), index, q0, k=k)


@_deprecated_alias("step_batch(IVFPQBackend(…), …)")
def ivf_pq_step_batch(index, sess, q, *, nprobe, k, alpha=-1.0, rerank=32,
                      is_first=None, scan=None):
    return step_batch(_pq_backend(h=sess.cache_ids.shape[1], nprobe=nprobe,
                                  alpha=alpha, rerank=rerank, scan=scan),
                      index, sess, q, k=k, is_first=is_first)


@_deprecated_alias("plain_batch(IVFPQBackend(…), …)")
def ivf_pq_plain_batch(index, q, *, nprobe, k, rerank=32, scan=None):
    return plain_batch(_pq_backend(nprobe=nprobe, rerank=rerank, scan=scan),
                       index, q, k=k)


@_deprecated_alias("conversation(IVFPQBackend(…), …)")
def ivf_pq_conversation(index, utterances, *, h, nprobe, k, alpha=-1.0,
                        rerank=32, mode="toploc", scan=None):
    return conversation(_pq_backend(h=h, nprobe=nprobe, alpha=alpha,
                                    rerank=rerank, scan=scan), index,
                        utterances, k=k, mode=mode)


@_deprecated_alias("start(HNSWBackend(ef=…, up=…), …)")
def hnsw_start(index, q0, *, ef, k, up=2, search=None):
    return start(_hnsw_backend(ef=ef, up=up, search=search), index, q0,
                 k=k)


@_deprecated_alias("step(HNSWBackend(…), …)")
def hnsw_step(index, sess, q, *, ef, k, adaptive=False, search=None):
    return step(_hnsw_backend(ef=ef, adaptive=adaptive, search=search),
                index, sess, q, k=k)


@_deprecated_alias("start_batch(HNSWBackend(…), …)")
def hnsw_start_batch(index, q0, *, ef, k, up=2, search=None):
    return start_batch(_hnsw_backend(ef=ef, up=up, search=search), index,
                       q0, k=k)


@_deprecated_alias("step_batch(HNSWBackend(…), …)")
def hnsw_step_batch(index, sess, q, *, ef, k, up=2, adaptive=False,
                    is_first=None, search=None):
    return step_batch(_hnsw_backend(ef=ef, up=up, adaptive=adaptive,
                                    search=search), index, sess, q, k=k,
                      is_first=is_first)


@_deprecated_alias("plain_batch(HNSWBackend(…), …)")
def hnsw_plain_batch(index, q, *, ef, k, search=None):
    return plain_batch(_hnsw_backend(ef=ef, search=search), index, q, k=k)


@_deprecated_alias("conversation(HNSWBackend(…), …)")
def hnsw_conversation(index, utterances, *, ef, k, up=2, mode="toploc",
                      search=None):
    adaptive = mode == "adaptive"
    return conversation(
        _hnsw_backend(ef=ef, up=up, adaptive=adaptive, search=search),
        index, utterances, k=k, mode="plain" if mode == "plain" else
        "toploc")
