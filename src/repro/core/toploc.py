"""TopLoc — the paper's contribution (§2), as a composable JAX module.

Three mechanisms, each a pure function over an explicit session pytree so
they vmap over concurrently-served conversations and jit into the serving
step:

  * ``ivf_start`` / ``ivf_step``   — TopLoc_IVF / TopLoc_IVF+ centroid
    caching with the |I0| drift proxy (Eq. 1) and α·np refresh trigger.
  * ``ivf_pq_start`` / ``ivf_pq_step`` — TopLoc_IVFPQ: the same centroid
    cache + drift proxy, but posting lists are scanned *PQ-compressed*
    (asymmetric distance computation, ``kernels/pq_adc``) and the top-R
    ADC candidates are exact-re-ranked against the float corpus.  The
    first backend whose speedup comes from memory compression rather
    than search-space restriction — the two compose.
  * ``hnsw_start`` / ``hnsw_step`` — TopLoc_HNSW privileged entry point
    with the ``up`` first-turn ef upscaling.
  * ``*_conversation``             — run a whole conversation under
    ``lax.scan`` (benchmark harness path).

Work accounting: every step returns a ``TurnStats`` whose fields mirror
the paper's cost model — centroid distances (p for a full scan, h for a
cached one), posting-list float distances, graph distances, and PQ code
distances (ADC table-sum evaluations, each m table gathers + adds
instead of a d-dim dot).  Speedups in benchmarks/ are computed from
these counters *and* wall-clock.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hnsw as _hnsw
from repro.core import ivf as _ivf
from repro.core import pq as _pq
from repro.core.topk import intersect_count, masked_topk
from repro.kernels import ops as _kops


class IVFSession(NamedTuple):
    """Per-conversation TopLoc_IVF state (device resident)."""
    cache_ids: jax.Array    # (h,) int32 — global centroid ids of C0
    cache_vecs: jax.Array   # (h, d)     — gathered centroid vectors
    anchor_sel: jax.Array   # (np,) int32 — top_np(q0, C0), for Eq. 1
    refreshes: jax.Array    # () int32
    turn: jax.Array         # () int32


class HNSWSession(NamedTuple):
    """Per-conversation TopLoc_HNSW state."""
    entry_point: jax.Array  # () int32 — privileged entry node
    turn: jax.Array         # () int32


class TurnStats(NamedTuple):
    centroid_dists: jax.Array  # () int32
    list_dists: jax.Array      # () int32 — float doc distances (lists/rerank)
    graph_dists: jax.Array     # () int32
    code_dists: jax.Array      # () int32 — PQ ADC table-sum evaluations
    i0: jax.Array              # () int32 — |I0| (IVF+ only; -1 otherwise)
    refreshed: jax.Array       # () bool


def _zero_stats() -> TurnStats:
    z = jnp.asarray(0, jnp.int32)
    return TurnStats(z, z, z, z, jnp.asarray(-1, jnp.int32),
                     jnp.asarray(False))


# ---------------------------------------------------------------------------
# TopLoc_IVF / TopLoc_IVF+
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("h", "nprobe", "k", "scan"))
def ivf_start(index: _ivf.IVFIndex, q0: jax.Array, *, h: int, nprobe: int,
              k: int, scan=None
              ) -> Tuple[jax.Array, jax.Array, IVFSession, TurnStats]:
    """First utterance: full centroid scan, build C0 = top_h(q0, C), answer.

    ``scan`` optionally replaces the posting-list scan (signature of
    ``ivf._scan_lists``); the device-sharded retrieval path plugs in
    ``distributed.retrieval.ShardedIVFScan`` here while the centroid
    cache / session machinery stays replicated.
    Returns (scores (k,), doc_ids (k,), session, stats).
    """
    cache_ids, cache_vecs = _ivf.make_cache(index, q0, h=h)
    # top_np(q0, C0) == top_np(q0, C) since C0 holds q0's h best centroids
    anchor_sel = cache_ids[:nprobe]
    top_v, top_i, real = (scan or _ivf._scan_lists)(
        index, q0[None], anchor_sel[None], k)
    sess = IVFSession(cache_ids, cache_vecs, anchor_sel,
                      jnp.asarray(0, jnp.int32), jnp.asarray(1, jnp.int32))
    stats = TurnStats(
        centroid_dists=jnp.asarray(index.p, jnp.int32),
        list_dists=real[0],
        graph_dists=jnp.asarray(0, jnp.int32),
        code_dists=jnp.asarray(0, jnp.int32),
        i0=jnp.asarray(-1, jnp.int32),
        refreshed=jnp.asarray(True),
    )
    return top_v[0], top_i[0], sess, stats


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "alpha", "scan"))
def ivf_step(index: _ivf.IVFIndex, sess: IVFSession, q: jax.Array, *,
             nprobe: int, k: int, alpha: float = -1.0, scan=None
             ) -> Tuple[jax.Array, jax.Array, IVFSession, TurnStats]:
    """Follow-up utterance.

    ``alpha < 0``  → TopLoc_IVF  (static cache, never refreshed)
    ``alpha >= 0`` → TopLoc_IVF+ (refresh when |I0| < α·np, Eq. 1)

    The drift check runs *before* any posting list is scanned, so a
    refreshed turn pays (h + p) centroid distances but only one list scan.
    """
    h = sess.cache_ids.shape[0]
    # 1. centroid selection against the cached set C0  (cost: h)
    csims = sess.cache_vecs @ q                      # (h,)
    _, sel_local = jax.lax.top_k(csims, nprobe)
    sel_cached = sess.cache_ids[sel_local]           # (np,) global ids

    # 2. drift proxy |I0| = |top_np(qj, C0) ∩ top_np(q0, C0)|   (Eq. 1)
    i0 = intersect_count(sel_cached, sess.anchor_sel)
    need_refresh = (alpha >= 0.0) & (i0 < jnp.asarray(alpha * nprobe))

    # 3. optional refresh: rescan the full centroid set, re-anchor on qj
    def refreshed(_):
        cache_ids, cache_vecs = _ivf.make_cache(index, q, h=h)
        return cache_ids, cache_vecs, cache_ids[:nprobe], cache_ids[:nprobe]

    def kept(_):
        return sess.cache_ids, sess.cache_vecs, sess.anchor_sel, sel_cached

    cache_ids, cache_vecs, anchor_sel, sel = jax.lax.cond(
        need_refresh, refreshed, kept, None)

    # 4. one posting-list scan with the final selection
    top_v, top_i, real = (scan or _ivf._scan_lists)(index, q[None],
                                                    sel[None], k)

    new_sess = IVFSession(cache_ids, cache_vecs, anchor_sel,
                          sess.refreshes + need_refresh.astype(jnp.int32),
                          sess.turn + 1)
    stats = TurnStats(
        centroid_dists=jnp.asarray(h, jnp.int32)
        + need_refresh.astype(jnp.int32) * index.p,
        list_dists=real[0],
        graph_dists=jnp.asarray(0, jnp.int32),
        code_dists=jnp.asarray(0, jnp.int32),
        i0=i0,
        refreshed=need_refresh,
    )
    return top_v[0], top_i[0], new_sess, stats


# ---------------------------------------------------------------------------
# TopLoc_IVFPQ — centroid cache + PQ-compressed list scan + exact re-rank
# ---------------------------------------------------------------------------
#
# Identical session machinery to TopLoc_IVF (the ``IVFSession`` centroid
# cache, Eq. 1 drift proxy, α·np refresh) — only the posting-list scan
# changes: lists hold m-byte PQ codes, the hot loop is an asymmetric-
# distance scan (``kernels.ops.pq_adc_scan`` → Pallas on TPU, jnp ref on
# CPU), and the top-R ADC candidates are re-ranked with exact float dot
# products against ``index.doc_vecs``.  Work accounting: ``code_dists``
# counts ADC evaluations (m table gathers + adds each), ``list_dists``
# counts the exact re-rank dot products (R per turn) — so the float-
# distance counter drops from O(nprobe·L) to O(R).
#
# Numerics follow the batch-size-stability rule from the batched-serving
# section below: every reduction (LUT build, ADC sum, re-rank dots) is
# formulated so each row's reduction order is independent of the batch
# size, keeping sequential and batched engines bit-identical.


def _adc_tables(index: _pq.IVFPQIndex, q: jax.Array) -> jax.Array:
    """Per-query ADC lookup tables, (B, m, n_codes).

    Broadcasts the codewords into the batch dim (cf.
    ``_bcast_centroid_scores``) so each row's d_sub-length contractions
    are bit-identical at any batch size.
    """
    b = q.shape[0]
    m, n_codes, d_sub = index.codewords.shape
    qs = q.reshape(b, m, d_sub)
    cw = jnp.broadcast_to(index.codewords, (b,) + index.codewords.shape)
    return jnp.einsum("bmd,bmkd->bmk", qs, cw)


def _scan_lists_pq(index: _pq.IVFPQIndex, q: jax.Array, sel: jax.Array,
                   k: int, rerank: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """ADC-scan the selected PQ lists, exact-re-rank the top-R.

    q (B, d); sel (B, np).  Returns (top_v (B,k), top_i (B,k),
    code_dists (B,), rerank_dists (B,)).
    """
    nprobe = sel.shape[1]
    r = max(k, min(rerank, nprobe * index.lmax))
    tables = _adc_tables(index, q)
    cand_v, cand_ids = _kops.pq_adc_scan(tables, index.list_codes,
                                         index.list_ids, sel, r)
    # exact re-rank of the R survivors against the float corpus — the
    # only place uncompressed vectors are touched (R rows, not np·Lmax).
    # Explicit multiply-reduce, not a dot_general: XLA canonicalises the
    # unit batch dim away at B=1 and retiles the reduction (cf.
    # hnsw._dots), which would break sequential↔batched bit-identity.
    safe = jnp.maximum(cand_ids, 0)
    exact = jnp.sum(index.doc_vecs[safe] * q[:, None, :], axis=-1)
    exact = jnp.where(cand_ids >= 0, exact, -jnp.inf)
    top_v, pos = jax.lax.top_k(exact, k)
    top_i = jnp.take_along_axis(cand_ids, pos, axis=-1)
    code_d = jnp.sum(index.list_sizes[sel], axis=-1).astype(jnp.int32)
    rerank_d = jnp.sum((cand_ids >= 0), axis=-1).astype(jnp.int32)
    return top_v, top_i, code_d, rerank_d


@functools.partial(jax.jit, static_argnames=("h", "nprobe", "k", "rerank",
                                             "scan"))
def ivf_pq_start(index: _pq.IVFPQIndex, q0: jax.Array, *, h: int,
                 nprobe: int, k: int, rerank: int = 32, scan=None
                 ) -> Tuple[jax.Array, jax.Array, IVFSession, TurnStats]:
    """First utterance on the PQ backend: full centroid scan, build C0,
    ADC-scan + re-rank.  Session layout is exactly ``ivf_start``'s.
    ``scan`` optionally replaces the whole ADC-scan + re-rank stage
    (signature of ``_scan_lists_pq``; sharded:
    ``distributed.retrieval.ShardedPQScan``)."""
    cache_ids, cache_vecs = _ivf.make_cache(index, q0, h=h)
    anchor_sel = cache_ids[:nprobe]
    top_v, top_i, code_d, rerank_d = (scan or _scan_lists_pq)(
        index, q0[None], anchor_sel[None], k, rerank)
    sess = IVFSession(cache_ids, cache_vecs, anchor_sel,
                      jnp.asarray(0, jnp.int32), jnp.asarray(1, jnp.int32))
    stats = TurnStats(
        centroid_dists=jnp.asarray(index.p, jnp.int32),
        list_dists=rerank_d[0],
        graph_dists=jnp.asarray(0, jnp.int32),
        code_dists=code_d[0],
        i0=jnp.asarray(-1, jnp.int32),
        refreshed=jnp.asarray(True),
    )
    return top_v[0], top_i[0], sess, stats


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "alpha",
                                             "rerank", "scan"))
def ivf_pq_step(index: _pq.IVFPQIndex, sess: IVFSession, q: jax.Array, *,
                nprobe: int, k: int, alpha: float = -1.0, rerank: int = 32,
                scan=None
                ) -> Tuple[jax.Array, jax.Array, IVFSession, TurnStats]:
    """Follow-up utterance on the PQ backend.

    Same control flow as ``ivf_step`` (drift check before any scan;
    ``alpha < 0`` static cache, ``alpha >= 0`` refresh) with the PQ
    scan + re-rank in place of the float list scan.
    """
    h = sess.cache_ids.shape[0]
    csims = sess.cache_vecs @ q                      # (h,)
    _, sel_local = jax.lax.top_k(csims, nprobe)
    sel_cached = sess.cache_ids[sel_local]

    i0 = intersect_count(sel_cached, sess.anchor_sel)
    need_refresh = (alpha >= 0.0) & (i0 < jnp.asarray(alpha * nprobe))

    def refreshed(_):
        cache_ids, cache_vecs = _ivf.make_cache(index, q, h=h)
        return cache_ids, cache_vecs, cache_ids[:nprobe], cache_ids[:nprobe]

    def kept(_):
        return sess.cache_ids, sess.cache_vecs, sess.anchor_sel, sel_cached

    cache_ids, cache_vecs, anchor_sel, sel = jax.lax.cond(
        need_refresh, refreshed, kept, None)

    top_v, top_i, code_d, rerank_d = (scan or _scan_lists_pq)(
        index, q[None], sel[None], k, rerank)

    new_sess = IVFSession(cache_ids, cache_vecs, anchor_sel,
                          sess.refreshes + need_refresh.astype(jnp.int32),
                          sess.turn + 1)
    stats = TurnStats(
        centroid_dists=jnp.asarray(h, jnp.int32)
        + need_refresh.astype(jnp.int32) * index.p,
        list_dists=rerank_d[0],
        graph_dists=jnp.asarray(0, jnp.int32),
        code_dists=code_d[0],
        i0=i0,
        refreshed=need_refresh,
    )
    return top_v[0], top_i[0], new_sess, stats


# ---------------------------------------------------------------------------
# TopLoc_HNSW
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("ef", "k", "up", "search"))
def hnsw_start(index: _hnsw.HNSWIndex, q0: jax.Array, *, ef: int, k: int,
               up: int = 2, search=None
               ) -> Tuple[jax.Array, jax.Array, HNSWSession, TurnStats]:
    """First utterance: plain HNSW with an upscaled candidate list
    (up · ef_search) so the privileged entry point is reliable.
    ``search`` optionally replaces ``hnsw.search`` (sharded:
    ``distributed.retrieval.ShardedHNSWSearch``)."""
    v, i, nd = (search or _hnsw.search)(index, q0[None], ef=up * ef, k=k)
    sess = HNSWSession(entry_point=i[0, 0].astype(jnp.int32),
                       turn=jnp.asarray(1, jnp.int32))
    stats = _zero_stats()._replace(graph_dists=nd[0],
                                   refreshed=jnp.asarray(True))
    return v[0], i[0], sess, stats


@functools.partial(jax.jit, static_argnames=("ef", "k", "adaptive",
                                             "search"))
def hnsw_step(index: _hnsw.HNSWIndex, sess: HNSWSession, q: jax.Array, *,
              ef: int, k: int, adaptive: bool = False, search=None
              ) -> Tuple[jax.Array, jax.Array, HNSWSession, TurnStats]:
    """Follow-up utterance: start the level-0 beam at the privileged entry
    point — no hierarchy descent (the paper's saving).

    ``adaptive=True`` is a beyond-paper extension: re-anchor the entry
    point at every turn's top-1 (the paper keeps q0's anchor for the whole
    conversation).
    """
    v, i, nd = (search or _hnsw.search)(
        index, q[None], ef=ef, k=k,
        entry_override=sess.entry_point[None],
        use_entry_override=True)
    new_entry = i[0, 0].astype(jnp.int32) if adaptive else sess.entry_point
    sess = HNSWSession(entry_point=new_entry, turn=sess.turn + 1)
    stats = _zero_stats()._replace(graph_dists=nd[0])
    return v[0], i[0], sess, stats


# ---------------------------------------------------------------------------
# Batched multi-conversation entry points (serving path)
#
# One device dispatch serves a whole micro-batch of concurrent
# conversations: session pytrees carry a leading batch dim (gathered from
# a ``serving.sessions.SessionStore`` slab), and mixed first-turn /
# follow-up batches are handled with an ``is_first`` mask and pure
# ``jnp.where`` selects — no ``lax.cond`` — so every row runs the same
# program (TPU-friendly, no divergence).  The select logic means a batch
# always *executes* the refresh scan when any row might need it; the
# ``TurnStats`` counters keep reporting the paper's cost model (what a
# scalar implementation would pay), which is the documented semantics of
# the work accounting.
#
# Numerics: batched results are bit-identical to the sequential
# ``ivf_start``/``ivf_step``/``hnsw_*`` paths.  The one subtlety is the
# full centroid scan: ``(B, d) @ (d, p)`` lowers to a tiled matmul whose
# reduction order differs from the sequential ``(p, d) @ (d,)`` matvec,
# so ``_bcast_centroid_scores`` broadcasts the centroids into a batch
# dim instead — a batched dot_general reduces each row exactly like the
# matvec (tests/test_serving_batched.py pins this down).
# ---------------------------------------------------------------------------


def _bcast_centroid_scores(centroids: jax.Array, q: jax.Array) -> jax.Array:
    """(B, p) centroid scores, bit-identical per row to ``centroids @ q``."""
    b = q.shape[0]
    return jnp.einsum("bpd,bd->bp",
                      jnp.broadcast_to(centroids, (b,) + centroids.shape), q)


@functools.partial(jax.jit, static_argnames=("h",))
def make_cache_batch(index: _ivf.IVFIndex, q: jax.Array, *, h: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Batched ``ivf.make_cache``: C0 = top_h(q, C) per row. q: (B, d)."""
    cscores = _bcast_centroid_scores(index.centroids, q)
    _, ids = jax.lax.top_k(cscores, h)
    ids = ids.astype(jnp.int32)
    return ids, index.centroids[ids]


@functools.partial(jax.jit, static_argnames=("h", "nprobe", "k", "scan"))
def ivf_start_batch(index: _ivf.IVFIndex, q0: jax.Array, *, h: int,
                    nprobe: int, k: int, scan=None
                    ) -> Tuple[jax.Array, jax.Array, IVFSession, TurnStats]:
    """Batched ``ivf_start``: B first utterances in one dispatch.

    q0: (B, d).  Returns (scores (B,k), ids (B,k), session pytree with
    leading batch dim, stats with leading batch dim).
    """
    b = q0.shape[0]
    cache_ids, cache_vecs = make_cache_batch(index, q0, h=h)
    anchor_sel = cache_ids[:, :nprobe]
    top_v, top_i, real = (scan or _ivf._scan_lists)(index, q0, anchor_sel, k)
    sess = IVFSession(cache_ids, cache_vecs, anchor_sel,
                      jnp.zeros((b,), jnp.int32), jnp.ones((b,), jnp.int32))
    stats = TurnStats(
        centroid_dists=jnp.full((b,), index.p, jnp.int32),
        list_dists=real,
        graph_dists=jnp.zeros((b,), jnp.int32),
        code_dists=jnp.zeros((b,), jnp.int32),
        i0=jnp.full((b,), -1, jnp.int32),
        refreshed=jnp.ones((b,), bool),
    )
    return top_v, top_i, sess, stats


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "alpha",
                                             "scan"))
def ivf_step_batch(index: _ivf.IVFIndex, sess: IVFSession, q: jax.Array, *,
                   nprobe: int, k: int, alpha: float = -1.0,
                   is_first: Optional[jax.Array] = None, scan=None
                   ) -> Tuple[jax.Array, jax.Array, IVFSession, TurnStats]:
    """Batched ``ivf_step`` over B concurrent conversations.

    sess fields carry a leading batch dim; q: (B, d).  ``is_first``
    ((B,) bool) marks rows whose session slot is fresh (first utterance
    of a conversation, or a rebuild after eviction): those rows ignore
    the slot contents, pay a full centroid scan, and re-anchor — exactly
    ``ivf_start`` semantics, realised as a forced refresh so the whole
    batch stays one uniform program.
    """
    b, h = sess.cache_ids.shape
    # 1. centroid selection against each row's cached set C0  (cost: h)
    csims = jnp.einsum("bhd,bd->bh", sess.cache_vecs, q)
    _, sel_local = jax.lax.top_k(csims, nprobe)
    sel_cached = jnp.take_along_axis(sess.cache_ids, sel_local, axis=1)

    # 2. drift proxy per row (Eq. 1)
    i0 = jax.vmap(intersect_count)(sel_cached, sess.anchor_sel)
    drift = (alpha >= 0.0) & (i0 < jnp.asarray(alpha * nprobe))

    first = (jnp.zeros((b,), bool) if is_first is None else is_first)
    refresh = first | drift

    # 3. refresh path.  Per-row logic is select-only (no per-row
    # lax.cond — every row runs the same program), but the scan itself
    # is gated on the *batch-wide* predicate: a flush with no first
    # turns and no drift skips the full centroid scan entirely, which
    # is what keeps steady-state follow-up flushes at O(B·h) instead of
    # O(B·p).  When the trace can prove no row ever refreshes (pure
    # follow-up batch, static cache) the branch is dropped altogether.
    if is_first is not None or alpha >= 0.0:
        fresh_ids, fresh_vecs = jax.lax.cond(
            jnp.any(refresh),
            lambda: make_cache_batch(index, q, h=h),
            lambda: (jnp.zeros((b, h), jnp.int32),
                     jnp.zeros((b, h) + index.centroids.shape[1:],
                               index.centroids.dtype)))
        r1 = refresh[:, None]
        cache_ids = jnp.where(r1, fresh_ids, sess.cache_ids)
        cache_vecs = jnp.where(r1[..., None], fresh_vecs, sess.cache_vecs)
        anchor_sel = jnp.where(r1, fresh_ids[:, :nprobe], sess.anchor_sel)
        sel = jnp.where(r1, fresh_ids[:, :nprobe], sel_cached)
    else:
        cache_ids, cache_vecs = sess.cache_ids, sess.cache_vecs
        anchor_sel, sel = sess.anchor_sel, sel_cached

    # 4. one posting-list scan for the whole batch
    top_v, top_i, real = (scan or _ivf._scan_lists)(index, q, sel, k)

    step_refresh = drift & ~first      # first turns don't count as refreshes
    new_sess = IVFSession(
        cache_ids, cache_vecs, anchor_sel,
        jnp.where(first, 0, sess.refreshes + step_refresh.astype(jnp.int32)),
        jnp.where(first, 1, sess.turn + 1))
    stats = TurnStats(
        centroid_dists=jnp.where(
            first, index.p,
            h + step_refresh.astype(jnp.int32) * index.p).astype(jnp.int32),
        list_dists=real,
        graph_dists=jnp.zeros((b,), jnp.int32),
        code_dists=jnp.zeros((b,), jnp.int32),
        i0=jnp.where(first, -1, i0),
        refreshed=refresh,
    )
    return top_v, top_i, new_sess, stats


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "scan"))
def ivf_plain_batch(index: _ivf.IVFIndex, q: jax.Array, *, nprobe: int,
                    k: int, scan=None
                    ) -> Tuple[jax.Array, jax.Array, TurnStats]:
    """Batched plain-IVF baseline turn (stateless; engine parity path)."""
    b = q.shape[0]
    cscores = _bcast_centroid_scores(index.centroids, q)
    _, sel = jax.lax.top_k(cscores, nprobe)
    top_v, top_i, real = (scan or _ivf._scan_lists)(index, q, sel, k)
    stats = TurnStats(
        centroid_dists=jnp.full((b,), index.p, jnp.int32),
        list_dists=real,
        graph_dists=jnp.zeros((b,), jnp.int32),
        code_dists=jnp.zeros((b,), jnp.int32),
        i0=jnp.full((b,), -1, jnp.int32),
        refreshed=jnp.zeros((b,), bool),
    )
    return top_v, top_i, stats


@functools.partial(jax.jit, static_argnames=("h", "nprobe", "k", "rerank",
                                             "scan"))
def ivf_pq_start_batch(index: _pq.IVFPQIndex, q0: jax.Array, *, h: int,
                       nprobe: int, k: int, rerank: int = 32, scan=None
                       ) -> Tuple[jax.Array, jax.Array, IVFSession,
                                  TurnStats]:
    """Batched ``ivf_pq_start``: B first utterances in one dispatch."""
    b = q0.shape[0]
    cache_ids, cache_vecs = make_cache_batch(index, q0, h=h)
    anchor_sel = cache_ids[:, :nprobe]
    top_v, top_i, code_d, rerank_d = (scan or _scan_lists_pq)(
        index, q0, anchor_sel, k, rerank)
    sess = IVFSession(cache_ids, cache_vecs, anchor_sel,
                      jnp.zeros((b,), jnp.int32), jnp.ones((b,), jnp.int32))
    stats = TurnStats(
        centroid_dists=jnp.full((b,), index.p, jnp.int32),
        list_dists=rerank_d,
        graph_dists=jnp.zeros((b,), jnp.int32),
        code_dists=code_d,
        i0=jnp.full((b,), -1, jnp.int32),
        refreshed=jnp.ones((b,), bool),
    )
    return top_v, top_i, sess, stats


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "alpha",
                                             "rerank", "scan"))
def ivf_pq_step_batch(index: _pq.IVFPQIndex, sess: IVFSession,
                      q: jax.Array, *, nprobe: int, k: int,
                      alpha: float = -1.0, rerank: int = 32,
                      is_first: Optional[jax.Array] = None, scan=None
                      ) -> Tuple[jax.Array, jax.Array, IVFSession,
                                 TurnStats]:
    """Batched ``ivf_pq_step`` over B concurrent conversations.

    Mirrors ``ivf_step_batch`` — same ``is_first`` forced-refresh
    semantics, same batch-wide refresh gate — with the PQ scan +
    re-rank in place of the float list scan.
    """
    b, h = sess.cache_ids.shape
    csims = jnp.einsum("bhd,bd->bh", sess.cache_vecs, q)
    _, sel_local = jax.lax.top_k(csims, nprobe)
    sel_cached = jnp.take_along_axis(sess.cache_ids, sel_local, axis=1)

    i0 = jax.vmap(intersect_count)(sel_cached, sess.anchor_sel)
    drift = (alpha >= 0.0) & (i0 < jnp.asarray(alpha * nprobe))

    first = (jnp.zeros((b,), bool) if is_first is None else is_first)
    refresh = first | drift

    if is_first is not None or alpha >= 0.0:
        fresh_ids, fresh_vecs = jax.lax.cond(
            jnp.any(refresh),
            lambda: make_cache_batch(index, q, h=h),
            lambda: (jnp.zeros((b, h), jnp.int32),
                     jnp.zeros((b, h) + index.centroids.shape[1:],
                               index.centroids.dtype)))
        r1 = refresh[:, None]
        cache_ids = jnp.where(r1, fresh_ids, sess.cache_ids)
        cache_vecs = jnp.where(r1[..., None], fresh_vecs, sess.cache_vecs)
        anchor_sel = jnp.where(r1, fresh_ids[:, :nprobe], sess.anchor_sel)
        sel = jnp.where(r1, fresh_ids[:, :nprobe], sel_cached)
    else:
        cache_ids, cache_vecs = sess.cache_ids, sess.cache_vecs
        anchor_sel, sel = sess.anchor_sel, sel_cached

    top_v, top_i, code_d, rerank_d = (scan or _scan_lists_pq)(
        index, q, sel, k, rerank)

    step_refresh = drift & ~first
    new_sess = IVFSession(
        cache_ids, cache_vecs, anchor_sel,
        jnp.where(first, 0, sess.refreshes + step_refresh.astype(jnp.int32)),
        jnp.where(first, 1, sess.turn + 1))
    stats = TurnStats(
        centroid_dists=jnp.where(
            first, index.p,
            h + step_refresh.astype(jnp.int32) * index.p).astype(jnp.int32),
        list_dists=rerank_d,
        graph_dists=jnp.zeros((b,), jnp.int32),
        code_dists=code_d,
        i0=jnp.where(first, -1, i0),
        refreshed=refresh,
    )
    return top_v, top_i, new_sess, stats


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "rerank",
                                             "scan"))
def ivf_pq_plain_batch(index: _pq.IVFPQIndex, q: jax.Array, *, nprobe: int,
                       k: int, rerank: int = 32, scan=None
                       ) -> Tuple[jax.Array, jax.Array, TurnStats]:
    """Batched plain IVF-PQ baseline turn (stateless; full centroid scan
    every turn — what a sessionless IVFPQ deployment pays)."""
    b = q.shape[0]
    cscores = _bcast_centroid_scores(index.centroids, q)
    _, sel = jax.lax.top_k(cscores, nprobe)
    top_v, top_i, code_d, rerank_d = (scan or _scan_lists_pq)(
        index, q, sel, k, rerank)
    stats = TurnStats(
        centroid_dists=jnp.full((b,), index.p, jnp.int32),
        list_dists=rerank_d,
        graph_dists=jnp.zeros((b,), jnp.int32),
        code_dists=code_d,
        i0=jnp.full((b,), -1, jnp.int32),
        refreshed=jnp.zeros((b,), bool),
    )
    return top_v, top_i, stats


@functools.partial(jax.jit, static_argnames=("ef", "k", "up", "search"))
def hnsw_start_batch(index: _hnsw.HNSWIndex, q0: jax.Array, *, ef: int,
                     k: int, up: int = 2, search=None
                     ) -> Tuple[jax.Array, jax.Array, HNSWSession, TurnStats]:
    """Batched ``hnsw_start``: B first utterances, upscaled ef, one dispatch."""
    b = q0.shape[0]
    v, i, nd = (search or _hnsw.search)(index, q0, ef=up * ef, k=k)
    sess = HNSWSession(entry_point=i[:, 0].astype(jnp.int32),
                       turn=jnp.ones((b,), jnp.int32))
    z = jnp.zeros((b,), jnp.int32)
    stats = TurnStats(z, z, nd, z, jnp.full((b,), -1, jnp.int32),
                      jnp.ones((b,), bool))
    return v, i, sess, stats


@functools.partial(jax.jit, static_argnames=("ef", "k", "up", "adaptive",
                                             "search"))
def hnsw_step_batch(index: _hnsw.HNSWIndex, sess: HNSWSession, q: jax.Array,
                    *, ef: int, k: int, up: int = 2, adaptive: bool = False,
                    is_first: Optional[jax.Array] = None, search=None
                    ) -> Tuple[jax.Array, jax.Array, HNSWSession, TurnStats]:
    """Batched ``hnsw_step`` over B concurrent conversations.

    Follow-up rows start the level-0 beam at their privileged entry
    point.  With ``is_first``, first-turn rows additionally run the
    full-descent upscaled search (``up·ef``) and the per-row results are
    selected with ``jnp.where`` — the two beam widths are different
    static shapes, so a mixed batch executes both programs and selects,
    rather than diverging per row.
    """
    b = q.shape[0]
    do_search = search or _hnsw.search
    v, i, nd = do_search(index, q, ef=ef, k=k,
                         entry_override=sess.entry_point,
                         use_entry_override=True)
    if is_first is not None:
        # batch-wide gate: steady-state flushes (no first turns) skip
        # the full-descent upscaled search entirely
        v0, i_0, nd0 = jax.lax.cond(
            jnp.any(is_first),
            lambda: do_search(index, q, ef=up * ef, k=k),
            lambda: (jnp.zeros((b, k), index.vectors.dtype),
                     jnp.zeros((b, k), jnp.int32),
                     jnp.zeros((b,), jnp.int32)))
        f1 = is_first[:, None]
        v = jnp.where(f1, v0, v)
        i = jnp.where(f1, i_0, i)
        nd = jnp.where(is_first, nd0, nd)
        first = is_first
    else:
        first = jnp.zeros((b,), bool)

    top1 = i[:, 0].astype(jnp.int32)
    new_entry = top1 if adaptive else jnp.where(first, top1,
                                                sess.entry_point)
    new_sess = HNSWSession(entry_point=new_entry,
                           turn=jnp.where(first, 1, sess.turn + 1))
    z = jnp.zeros((b,), jnp.int32)
    stats = TurnStats(z, z, nd, z, jnp.full((b,), -1, jnp.int32), first)
    return v, i, new_sess, stats


@functools.partial(jax.jit, static_argnames=("ef", "k", "search"))
def hnsw_plain_batch(index: _hnsw.HNSWIndex, q: jax.Array, *, ef: int,
                     k: int, search=None
                     ) -> Tuple[jax.Array, jax.Array, TurnStats]:
    """Batched plain-HNSW baseline turn (stateless; engine parity path)."""
    b = q.shape[0]
    v, i, nd = (search or _hnsw.search)(index, q, ef=ef, k=k)
    z = jnp.zeros((b,), jnp.int32)
    stats = TurnStats(z, z, nd, z, jnp.full((b,), -1, jnp.int32),
                      jnp.zeros((b,), bool))
    return v, i, stats


# ---------------------------------------------------------------------------
# Whole-conversation scan (benchmark path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("h", "nprobe", "k", "alpha", "mode",
                                    "scan"))
def ivf_conversation(index: _ivf.IVFIndex, utterances: jax.Array, *, h: int,
                     nprobe: int, k: int, alpha: float = -1.0,
                     mode: str = "toploc", scan=None
                     ) -> Tuple[jax.Array, jax.Array, TurnStats]:
    """Run a (T, d) conversation through one IVF strategy.

    mode: 'toploc' (cache; alpha<0 static, alpha>=0 refresh) or 'plain'
    (full centroid scan every turn — the baseline).
    Returns (scores (T,k), ids (T,k), stats stacked over turns).
    """
    if mode == "plain":
        def body(carry, q):
            top_v, top_i, st = _ivf.search(index, q[None], nprobe=nprobe,
                                           k=k, scan=scan)
            stats = TurnStats(jnp.asarray(index.p, jnp.int32),
                              st.list_dists[0], jnp.asarray(0, jnp.int32),
                              jnp.asarray(0, jnp.int32),
                              jnp.asarray(-1, jnp.int32), jnp.asarray(False))
            return carry, (top_v[0], top_i[0], stats)
        _, (v, i, stats) = jax.lax.scan(body, 0, utterances)
        return v, i, stats

    q0, rest = utterances[0], utterances[1:]
    v0, i0_, sess, st0 = ivf_start(index, q0, h=h, nprobe=nprobe, k=k,
                                   scan=scan)

    def body(sess, q):
        v, i, sess, st = ivf_step(index, sess, q, nprobe=nprobe, k=k,
                                  alpha=alpha, scan=scan)
        return sess, (v, i, st)

    _, (v, i, st) = jax.lax.scan(body, sess, rest)
    v = jnp.concatenate([v0[None], v])
    i = jnp.concatenate([i0_[None], i])
    stats = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b]), st0, st)
    return v, i, stats


@functools.partial(jax.jit,
                   static_argnames=("h", "nprobe", "k", "alpha", "rerank",
                                    "mode", "scan"))
def ivf_pq_conversation(index: _pq.IVFPQIndex, utterances: jax.Array, *,
                        h: int, nprobe: int, k: int, alpha: float = -1.0,
                        rerank: int = 32, mode: str = "toploc", scan=None
                        ) -> Tuple[jax.Array, jax.Array, TurnStats]:
    """Run a (T, d) conversation through one IVF-PQ strategy.

    mode: 'toploc' (centroid cache; alpha<0 static, alpha>=0 refresh) or
    'plain' (full centroid scan every turn).
    """
    if mode == "plain":
        def body(carry, q):
            v, i, st = ivf_pq_plain_batch(index, q[None], nprobe=nprobe,
                                          k=k, rerank=rerank, scan=scan)
            return carry, (v[0], i[0], jax.tree.map(lambda a: a[0], st))
        _, (v, i, stats) = jax.lax.scan(body, 0, utterances)
        return v, i, stats

    q0, rest = utterances[0], utterances[1:]
    v0, i0_, sess, st0 = ivf_pq_start(index, q0, h=h, nprobe=nprobe, k=k,
                                      rerank=rerank, scan=scan)

    def body(sess, q):
        v, i, sess, st = ivf_pq_step(index, sess, q, nprobe=nprobe, k=k,
                                     alpha=alpha, rerank=rerank, scan=scan)
        return sess, (v, i, st)

    _, (v, i, st) = jax.lax.scan(body, sess, rest)
    v = jnp.concatenate([v0[None], v])
    i = jnp.concatenate([i0_[None], i])
    stats = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b]), st0, st)
    return v, i, stats


@functools.partial(jax.jit, static_argnames=("ef", "k", "up", "mode",
                                             "search"))
def hnsw_conversation(index: _hnsw.HNSWIndex, utterances: jax.Array, *,
                      ef: int, k: int, up: int = 2, mode: str = "toploc",
                      search=None
                      ) -> Tuple[jax.Array, jax.Array, TurnStats]:
    """Run a (T, d) conversation through one HNSW strategy.

    mode: 'plain' | 'toploc' (paper: static q0 anchor) | 'adaptive'
    (beyond-paper: re-anchor the entry point at every turn's top-1).
    """
    if mode == "plain":
        v, i, nd = (search or _hnsw.search)(index, utterances, ef=ef, k=k)
        stats = TurnStats(
            jnp.zeros_like(nd), jnp.zeros_like(nd), nd, jnp.zeros_like(nd),
            jnp.full_like(nd, -1), jnp.zeros(nd.shape, bool))
        return v, i, stats

    q0, rest = utterances[0], utterances[1:]
    v0, i0_, sess, st0 = hnsw_start(index, q0, ef=ef, k=k, up=up,
                                    search=search)
    adaptive = mode == "adaptive"

    def body(sess, q):
        v, i, sess, st = hnsw_step(index, sess, q, ef=ef, k=k,
                                   adaptive=adaptive, search=search)
        return sess, (v, i, st)

    _, (v, i, st) = jax.lax.scan(body, sess, rest)
    v = jnp.concatenate([v0[None], v])
    i = jnp.concatenate([i0_[None], i])
    stats = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b]), st0, st)
    return v, i, stats
