"""Distributed balanced k-means for IVF index construction.

The paper builds IVF partitions with K-Means (TopLoc §2, "IVF").  We
implement spherical Lloyd iterations as a pure-JAX program so index build
runs data-parallel under ``pjit`` on the production mesh: points sharded
over devices, centroid statistics reduced with (implicit SPMD) psums.

On TPU the posting lists must be *bucketed-padded* tensors (static shapes),
so we additionally balance the assignment: points whose cluster is over
capacity spill to their next-nearest centroid (the same trick ScaNN/SOAR
use).  This bounds the padding waste of the ``(p, Lmax, d)`` list tensor.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class KMeansResult(NamedTuple):
    centroids: jax.Array        # (p, d) float
    assignment: jax.Array       # (n,) int32 — balanced assignment
    sizes: jax.Array            # (p,) int32 — cluster sizes after balancing
    inertia: jax.Array          # () float — mean max-similarity at convergence


def _assign(points: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest-centroid assignment under dot-product similarity."""
    sims = points @ centroids.T                     # (n, p)
    return jnp.argmax(sims, axis=-1).astype(jnp.int32)


def _update(points: jax.Array, assign: jax.Array, p: int) -> Tuple[jax.Array, jax.Array]:
    """Centroid update: per-cluster mean (segment_sum / counts)."""
    sums = jax.ops.segment_sum(points, assign, num_segments=p)
    counts = jax.ops.segment_sum(jnp.ones_like(assign, jnp.float32), assign, num_segments=p)
    safe = jnp.maximum(counts, 1.0)[:, None]
    return sums / safe, counts


def _respawn_empty(centroids: jax.Array, counts: jax.Array, points: jax.Array,
                   key: jax.Array) -> jax.Array:
    """Re-seed empty clusters from random points (keeps p live partitions)."""
    n = points.shape[0]
    idx = jax.random.randint(key, (centroids.shape[0],), 0, n)
    repl = points[idx]
    empty = (counts < 0.5)[:, None]
    return jnp.where(empty, repl, centroids)


def _plusplus_init(points: jax.Array, p: int, key: jax.Array) -> jax.Array:
    """k-means++ seeding (Arthur & Vassilvitskii): each next seed is drawn
    with probability proportional to its squared distance from the nearest
    seed so far.  O(p·n) — used where codebook quality matters more than
    init cost (PQ subspace codebooks)."""
    n = points.shape[0]
    sq = jnp.sum(points ** 2, -1)
    k0, k_rest = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)

    def pick(carry, k):
        idx, d2 = carry
        # squared distance to the newest seed, folded into the running min
        c = points[idx]
        d2 = jnp.minimum(d2, sq + jnp.sum(c ** 2) - 2.0 * (points @ c))
        probs = jnp.maximum(d2, 0.0)
        probs = probs / jnp.maximum(probs.sum(), 1e-30)
        nxt = jax.random.choice(k, n, p=probs)
        return (nxt, d2), idx

    keys = jax.random.split(k_rest, p)
    _, seeds = jax.lax.scan(pick, (first, jnp.full((n,), jnp.inf)), keys)
    return points[seeds]


@functools.partial(jax.jit, static_argnames=("p", "iters", "block", "init"))
def kmeans_fit(points: jax.Array, p: int, *, iters: int = 10,
               key: Optional[jax.Array] = None, block: int = 0,
               init: str = "random") -> Tuple[jax.Array, jax.Array]:
    """Lloyd iterations; returns (centroids (p,d), assignment (n,)).

    Pure jnp — shard ``points`` over the data axis under pjit and the
    segment_sum/argmax pattern partitions automatically (the centroid
    statistics become an all-reduce).  ``block`` is unused here (kept for
    API parity with the kernelised assigner).  ``init``: 'random' (sample
    p points) or '++' (k-means++ seeding — better local optima, O(p·n)
    extra init work).
    """
    del block
    n = points.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    k_init, k_iter = jax.random.split(key)
    if init == "++":
        centroids0 = _plusplus_init(points, p, k_init)
    else:
        init_idx = jax.random.choice(k_init, n, (p,), replace=n < p)
        centroids0 = points[init_idx]

    def body(carry, k):
        centroids, _ = carry
        assign = _assign(points, centroids)
        centroids, counts = _update(points, assign, p)
        centroids = _respawn_empty(centroids, counts, points, k)
        return (centroids, assign), None

    keys = jax.random.split(k_iter, iters)
    (centroids, _), _ = jax.lax.scan(body, (centroids0, jnp.zeros(n, jnp.int32)), keys)
    assign = _assign(points, centroids)
    return centroids, assign


@functools.partial(jax.jit, static_argnames=("p", "capacity", "n_choices"))
def balance_assignment(points: jax.Array, centroids: jax.Array, p: int,
                       capacity: int, n_choices: int = 4) -> Tuple[jax.Array, jax.Array]:
    """Capacity-constrained assignment: greedy spill to next-nearest centroid.

    Deterministic, vectorised: points are processed in similarity-priority
    order per choice rank; a point that does not fit its rank-r centroid
    (cluster already at ``capacity``) is deferred to rank r+1.  After
    ``n_choices`` ranks any still-unplaced point lands in the globally
    least-loaded cluster (no capacity bound; in practice this bucket is
    empty for capacity ≥ 1.25·n/p).

    Returns (assignment (n,), sizes (p,)).
    """
    n = points.shape[0]
    n_choices = min(n_choices, p)
    sims = points @ centroids.T                                  # (n, p)
    choice_sims, choice_ids = jax.lax.top_k(sims, n_choices)     # (n, r)

    assignment = jnp.full((n,), -1, jnp.int32)
    sizes = jnp.zeros((p,), jnp.int32)

    def place_rank(carry, r):
        assignment, sizes = carry
        cand = choice_ids[:, r]                                   # (n,)
        want = assignment < 0                                     # unplaced
        # order unplaced points by similarity so the best-matching points
        # win the remaining capacity of each cluster
        order = jnp.argsort(jnp.where(want, -choice_sims[:, r], jnp.inf))
        cand_o = cand[order]
        want_o = want[order]
        # rank of each point within its candidate cluster, among this batch
        onehot_pos = jnp.cumsum(
            jax.nn.one_hot(jnp.where(want_o, cand_o, p), p + 1, dtype=jnp.int32),
            axis=0,
        )
        pos_in_cluster = jnp.take_along_axis(
            onehot_pos, jnp.where(want_o, cand_o, p)[:, None], axis=1
        )[:, 0] - 1                                               # 0-based
        room = capacity - sizes[jnp.where(want_o, cand_o, 0)]
        ok = want_o & (pos_in_cluster < room)
        new_assign_o = jnp.where(ok, cand_o, -1)
        # scatter back to original order
        new_assign = jnp.zeros((n,), jnp.int32).at[order].set(new_assign_o)
        placed_mask = jnp.zeros((n,), bool).at[order].set(ok)
        assignment = jnp.where(placed_mask, new_assign, assignment)
        sizes = sizes + jax.ops.segment_sum(
            placed_mask.astype(jnp.int32), jnp.where(placed_mask, assignment, p),
            num_segments=p + 1)[:p]
        return (assignment, sizes), None

    (assignment, sizes), _ = jax.lax.scan(
        place_rank, (assignment, sizes), jnp.arange(n_choices))

    # fallback: dump stragglers into the least-loaded cluster one by one
    def fallback(carry, i):
        assignment, sizes = carry
        unplaced = assignment[i] < 0
        tgt = jnp.argmin(sizes).astype(jnp.int32)
        assignment = assignment.at[i].set(jnp.where(unplaced, tgt, assignment[i]))
        sizes = sizes.at[tgt].add(jnp.where(unplaced, 1, 0))
        return (assignment, sizes), None

    (assignment, sizes), _ = jax.lax.scan(fallback, (assignment, sizes), jnp.arange(n))
    return assignment, sizes


def fit_balanced(points: jax.Array, p: int, *, iters: int = 10,
                 key: Optional[jax.Array] = None,
                 capacity_factor: float = 1.3) -> KMeansResult:
    """End-to-end: Lloyd fit + capacity-balanced final assignment."""
    n = points.shape[0]
    centroids, _ = kmeans_fit(points, p, iters=iters, key=key)
    capacity = max(1, int(capacity_factor * n / p + 0.9999))
    assignment, sizes = balance_assignment(points, centroids, p, capacity)
    sims = points @ centroids.T
    inertia = jnp.mean(jnp.max(sims, axis=-1))
    return KMeansResult(centroids, assignment, sizes, inertia)
