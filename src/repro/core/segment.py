"""Segmented mutable corpus: delta segment + tombstones + compaction.

Every index in the repo is build-once; real-time conversational search
needs a corpus that changes while sessions are live.  This module adds
the standard segmented design (the Lucene/FAISS ``IndexShards`` shape)
on top of any registered ``RetrievalBackend``:

  * **delta segment** — an append-only flat ``(cap, d)`` buffer scanned
    *exactly* (one masked multiply-reduce over ``cap`` rows).  New
    documents take monotonically increasing global ids, so delta row
    ``j`` always holds id ``n_base + j`` and ids are never renumbered —
    cache entries and tombstones stay valid across compactions.
  * **tombstone mask** — one bool per global id.  Deletes are masked out
    of both scans immediately: IVF/IVF-PQ posting-list entries flip to
    ``-1`` (the existing pad convention, so the scan kernels are
    untouched), HNSW nodes keep routing the beam but are masked from the
    result top-k (``hnsw.HNSWIndex.deleted``), and delta rows mask via
    ``tombstone[delta_ids]``.
  * **compaction** — ``compact()`` folds the delta into the base:
    IVF/IVF-PQ re-pack their posting lists with the live delta docs
    appended at their nearest coarse centroid (PQ re-encodes with the
    *frozen* codebook), HNSW inserts incrementally by continuing the
    build's level-RNG stream.  The hard contract — pinned by
    ``tests/test_segment.py`` — is that the compacted index is
    **bit-identical to ``rebuild()``**, the independent from-scratch
    construction over the same corpus and mutation set.

Determinism of the merged result order: base and delta top-k are merged
with the ``distributed_topk_ordered`` key scheme — ``jax.lax.sort`` on
``(-score, position)`` where base rank ``r`` carries position ``r < k``
and delta row ``j`` carries position ``k + j``.  Ties break base-first,
then by delta append order (= id order), so results are reproducible at
any delta fill level, and an empty delta reproduces the wrapped backend
bit for bit.

The coarse quantiser (IVF centroids) and the PQ codebooks are *frozen*
build artifacts — the standard streaming-index contract: delta docs are
assigned/encoded against them, never retrained.  A from-scratch rebuild
therefore means "re-derive every list/graph from the frozen quantisers
and the full mutation history", which is exactly what ``rebuild()``
does (for HNSW it is literally ``hnsw.build`` on the concatenated
corpus).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, NamedTuple, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hnsw as _hnsw
from repro.core import ivf as _ivf
from repro.core import pq as _pq
from repro.core.backend import IVFBackend, RetrievalBackend, register


class SegmentedIndex(NamedTuple):
    """Mutable corpus = frozen base + append-only delta + tombstones.

    ``tombstone`` covers the whole assignable id space
    ``n_base + cap`` (both static shapes), so ``n_base`` is derivable as
    ``tombstone.shape[0] - delta_ids.shape[0]`` and adds/deletes never
    change any array shape — the query path compiles once per
    compaction, not per mutation.
    """
    base: Any               # the wrapped backend's index (pytree)
    delta_vecs: jax.Array   # (cap, d) float32 — append-only buffer
    delta_ids: jax.Array    # (cap,) int32 — global doc ids, -1 = empty
    tombstone: jax.Array    # (n_base + cap,) bool — True = deleted


def n_base(index: SegmentedIndex) -> int:
    """Id-space size of the base segment (includes purged id holes)."""
    return index.tombstone.shape[0] - index.delta_ids.shape[0]


def delta_cap(index: SegmentedIndex) -> int:
    return index.delta_ids.shape[0]


def delta_fill(index: SegmentedIndex) -> int:
    """Occupied delta rows (appends are contiguous from row 0)."""
    return int(np.asarray(index.delta_ids >= 0).sum())


# ---------------------------------------------------------------------------
# delta scan + ordered merge (the jitted query path)
# ---------------------------------------------------------------------------

def _delta_scan(index: SegmentedIndex, q: jax.Array, kk: int):
    """Exact masked scan of the delta buffer.  q (B, d).

    Returns (scores (B,kk), rows (B,kk), ids (B,kk), live () int32).
    Explicit multiply-reduce (not a matvec) so the same delta doc scores
    bit-identically at any batch size — the repo-wide numeric doctrine.
    """
    live = (index.delta_ids >= 0) & \
        ~index.tombstone[jnp.maximum(index.delta_ids, 0)]
    scores = jnp.sum(index.delta_vecs[None, :, :] * q[:, None, :], axis=-1)
    scores = jnp.where(live[None, :], scores, -jnp.inf)
    v, rows = jax.lax.top_k(scores, kk)
    return v, rows.astype(jnp.int32), index.delta_ids[rows], \
        jnp.sum(live.astype(jnp.int32))


def _merge_ordered(base_v, base_i, delta_v, delta_rows, delta_i, k: int):
    """Deterministic base-vs-delta merge, ``distributed_topk_ordered``
    style: lexicographic ``lax.sort`` on (-score, position) with base
    rank r at position r (< k) and delta row j at position k + j.  Base
    wins score ties; delta ties break by append (= id) order; empty
    delta rows are -inf and sort behind every base entry — so the order
    is reproducible at any fill level and an empty delta returns the
    base top-k unchanged.
    """
    bpos = jnp.broadcast_to(
        jnp.arange(base_v.shape[-1], dtype=jnp.int32), base_v.shape)
    all_v = jnp.concatenate([base_v, delta_v], axis=-1)
    all_p = jnp.concatenate([bpos, k + delta_rows], axis=-1)
    all_i = jnp.concatenate([base_i, delta_i], axis=-1)
    _, _, top_i, top_v = jax.lax.sort(
        (-all_v, all_p, all_i, all_v), dimension=-1, num_keys=2)
    return top_v[..., :k], top_i[..., :k]


@register
@dataclasses.dataclass(frozen=True)
class SegmentedBackend(RetrievalBackend):
    """Any registered backend + a live delta segment + tombstones.

    Delegates every session/turn method to ``inner`` on ``index.base``
    (sessions — centroid caches, HNSW entry points — are derived from
    *base* results only, so session state never references a delta id
    that a compaction would move into the base graph), then merges the
    exactly-scanned delta top-k into the returned ranking.  The delta's
    live-row count is charged to ``TurnStats.list_dists`` — it is a real
    float scan, and the cost model must see it.
    """

    name: ClassVar[str] = "segmented"
    index_kwarg: ClassVar[str] = "segmented_index"

    inner: RetrievalBackend = IVFBackend()

    @property
    def stateful(self):  # type: ignore[override]
        return self.inner.stateful

    # ---- merge plumbing ---------------------------------------------------

    def _merge_batch(self, index, q, v, i, stats, k):
        kk = min(k, delta_cap(index))
        dv, drows, di, live = _delta_scan(index, q, kk)
        mv, mi = _merge_ordered(v, i, dv, drows, di, k)
        return mv, mi, stats._replace(list_dists=stats.list_dists + live)

    def _merge_one(self, index, q, v, i, stats, k):
        mv, mi, st = self._merge_batch(index, q[None], v[None], i[None],
                                       jax.tree.map(lambda a: a[None],
                                                    stats), k)
        return mv[0], mi[0], jax.tree.map(lambda a: a[0], st)

    # ---- driver surface ---------------------------------------------------

    def start(self, index, q0, *, k):
        v, i, sess, st = self.inner.start(index.base, q0, k=k)
        mv, mi, st = self._merge_one(index, q0, v, i, st, k)
        return mv, mi, sess, st

    def step(self, index, sess, q, *, k):
        v, i, sess, st = self.inner.step(index.base, sess, q, k=k)
        mv, mi, st = self._merge_one(index, q, v, i, st, k)
        return mv, mi, sess, st

    def start_batch(self, index, q0, *, k):
        v, i, sess, st = self.inner.start_batch(index.base, q0, k=k)
        mv, mi, st = self._merge_batch(index, q0, v, i, st, k)
        return mv, mi, sess, st

    def step_batch(self, index, sess, q, *, k, is_first=None):
        v, i, sess, st = self.inner.step_batch(index.base, sess, q, k=k,
                                               is_first=is_first)
        mv, mi, st = self._merge_batch(index, q, v, i, st, k)
        return mv, mi, sess, st

    def plain_batch(self, index, q, *, k):
        v, i, st = self.inner.plain_batch(index.base, q, k=k)
        return self._merge_batch(index, q, v, i, st, k)

    def session_template(self, index):
        return self.inner.session_template(index.base)

    def corpus_vectors(self, index):
        base = self.inner.corpus_vectors(index.base)
        if base is None:
            return None
        # delta row j holds global id n_base + j, so plain concatenation
        # keeps the id -> row mapping the result cache gathers by
        return jnp.concatenate([base, index.delta_vecs], axis=0)

    def query_dim(self, index) -> int:
        return self.inner.query_dim(index.base)

    def fetch_limit(self, index) -> int:
        return self.inner.fetch_limit(index.base)


# ---------------------------------------------------------------------------
# per-inner-backend compaction adapters (host-side; mutations are rare)
# ---------------------------------------------------------------------------

def _nearest_centroid(centroids: np.ndarray, v: np.ndarray) -> int:
    """Frozen-quantiser assignment for one delta doc.  Per-doc on host
    so the assignment is a function of the row alone — identical no
    matter how adds were batched (compact vs rebuild see different
    groupings of the same docs)."""
    return int(np.argmax(centroids @ v))


def _encode_one(book, v: np.ndarray) -> np.ndarray:
    """PQ-encode one doc with the frozen codebook.  Per-doc for the same
    reason as ``_nearest_centroid``: ``pq.encode``'s einsum may tile its
    reduction differently at different batch sizes, and codes must be a
    function of the row alone for compact == rebuild bit-identity."""
    return np.asarray(_pq.encode(book, jnp.asarray(v[None])))[0]


def _live_delta(delta_vecs, delta_ids, tombstone):
    """(id, vector) pairs of live delta docs, in id (= append) order."""
    out = []
    for row in np.flatnonzero(delta_ids >= 0):
        did = int(delta_ids[row])
        if not tombstone[did]:
            out.append((did, delta_vecs[row]))
    return out


def _masked_lists(list_ids: np.ndarray, tomb: np.ndarray):
    """Flip tombstoned posting-list entries to the -1 pad convention."""
    dead = (list_ids >= 0) & tomb[np.maximum(list_ids, 0)]
    ids = np.where(dead, -1, list_ids).astype(np.int32)
    return ids, (ids >= 0).sum(axis=1).astype(np.int32)


class _IVFAdapter:
    """IVF: delete = in-place -1 masking; compact = purge + append at
    the nearest frozen centroid, re-packed in id order (the same order
    ``ivf.build`` bucketises in)."""

    def size(self, base) -> int:
        return int(np.asarray(base.list_sizes).sum())

    def delete(self, base, tomb_base: np.ndarray):
        ids, sizes = _masked_lists(np.asarray(base.list_ids), tomb_base)
        return base._replace(list_ids=jnp.asarray(ids),
                             list_sizes=jnp.asarray(sizes))

    def _members(self, base, delta_vecs, delta_ids, tomb):
        """Per-list [(id, payload)] — survivors keep their stored order
        (ascending id, by induction from the build), live delta docs
        append in id order at their nearest centroid."""
        cent = np.asarray(base.centroids)
        li = np.asarray(base.list_ids)
        members = [[(int(i), self._payload(base, c, j))
                    for j, i in enumerate(li[c]) if i >= 0]
                   for c in range(li.shape[0])]
        for did, v in _live_delta(delta_vecs, delta_ids, tomb):
            members[_nearest_centroid(cent, v)].append(
                (did, self._delta_payload(base, v)))
        return members

    def _payload(self, base, c, j):
        return np.asarray(base.list_vecs)[c, j]

    def _delta_payload(self, base, v):
        return np.asarray(v, np.float32)

    def _pack(self, base, members, payload_shape, payload_dtype):
        p = len(members)
        lmax = max(1, max((len(mem) for mem in members), default=1))
        ids = np.full((p, lmax), -1, np.int32)
        payload = np.zeros((p, lmax) + payload_shape, payload_dtype)
        for c, mem in enumerate(members):
            for j, (did, pl) in enumerate(mem):
                ids[c, j] = did
                payload[c, j] = pl
        sizes = (ids >= 0).sum(axis=1).astype(np.int32)
        return ids, payload, sizes

    def compact(self, base, delta_vecs, delta_ids, tomb):
        members = self._members(base, delta_vecs, delta_ids, tomb)
        d = base.centroids.shape[1]
        ids, vecs, sizes = self._pack(base, members, (d,), np.float32)
        return _ivf.IVFIndex(base.centroids, jnp.asarray(vecs),
                             jnp.asarray(ids), jnp.asarray(sizes))

    def rebuild(self, pristine, added_vecs, tomb):
        n0 = self.size(pristine)
        added_ids = np.arange(n0, n0 + len(added_vecs), dtype=np.int32)
        return self.compact(self.delete(pristine, tomb[:n0]),
                            added_vecs, added_ids, tomb)


class _PQAdapter(_IVFAdapter):
    """IVF-PQ: same list machinery over uint8 code payloads; delta docs
    re-encode with the frozen codebook; ``doc_vecs`` grows by every
    added row (dead rows stay — ids index it directly)."""

    def size(self, base) -> int:
        return base.doc_vecs.shape[0]

    def _payload(self, base, c, j):
        return np.asarray(base.list_codes)[c, j]

    def _delta_payload(self, base, v):
        return _encode_one(base.book, np.asarray(v, np.float32))

    def compact(self, base, delta_vecs, delta_ids, tomb):
        members = self._members(base, delta_vecs, delta_ids, tomb)
        m = base.codewords.shape[0]
        ids, codes, sizes = self._pack(base, members, (m,), np.uint8)
        fill = int((np.asarray(delta_ids) >= 0).sum())
        doc_vecs = jnp.concatenate(
            [base.doc_vecs, jnp.asarray(delta_vecs[:fill], jnp.float32)],
            axis=0)
        return _pq.IVFPQIndex(base.centroids, base.codewords,
                              jnp.asarray(codes), jnp.asarray(ids),
                              jnp.asarray(sizes), doc_vecs)


class _HNSWAdapter:
    """HNSW: delete = result-mask only (nodes keep routing the beam);
    compact = incremental insertion continuing the build's RNG stream,
    so the compacted graph is the from-scratch graph."""

    def __init__(self, ef_construction: int = 64, seed: int = 0):
        self.ef_construction = ef_construction
        self.seed = seed

    def size(self, base) -> int:
        return base.vectors.shape[0]

    def delete(self, base, tomb_base: np.ndarray):
        return base._replace(deleted=jnp.asarray(tomb_base))

    def compact(self, base, delta_vecs, delta_ids, tomb):
        fill = int((np.asarray(delta_ids) >= 0).sum())
        # every added doc joins the graph, deleted ones included: the
        # from-scratch build inserts the full corpus sequence, and
        # deletions are a query-time mask, not a graph edit
        new = _hnsw.insert(base, delta_vecs[:fill],
                           ef_construction=self.ef_construction,
                           seed=self.seed)
        return new._replace(deleted=jnp.asarray(tomb[:new.n]))

    def rebuild(self, pristine, added_vecs, tomb):
        x = np.concatenate([np.asarray(pristine.vectors, np.float32),
                            np.asarray(added_vecs, np.float32)], axis=0)
        m = pristine.adj0.shape[1] // 2
        idx = _hnsw.build(x, m=m, ef_construction=self.ef_construction,
                          seed=self.seed)
        return idx._replace(deleted=jnp.asarray(tomb[:idx.n]))


def _adapter(inner: RetrievalBackend, **build_kw):
    name = type(inner).name
    makers: Dict[str, Any] = {
        "ivf": _IVFAdapter,
        "ivf_pq": _PQAdapter,
        "hnsw": _HNSWAdapter,
    }
    if name not in makers:
        raise NotImplementedError(
            f"segmented corpus does not support inner backend {name!r}; "
            f"supported: {', '.join(sorted(makers))}")
    if name != "hnsw" and build_kw:
        raise TypeError(
            f"build kwargs {sorted(build_kw)} only apply to hnsw "
            f"compaction (got inner backend {name!r})")
    return makers[name](**build_kw)


# ---------------------------------------------------------------------------
# public mutation API (host-side; returns new pytrees, never mutates)
# ---------------------------------------------------------------------------

def make_segmented(inner: RetrievalBackend, base_index, *, cap: int
                   ) -> SegmentedIndex:
    """Wrap a built base index with an empty ``cap``-row delta segment."""
    if cap < 1:
        raise ValueError(f"segment cap must be >= 1, got {cap}")
    ad = _adapter(inner)
    n0 = ad.size(base_index)
    d = inner.query_dim(base_index)
    return SegmentedIndex(
        base=base_index,
        delta_vecs=jnp.zeros((cap, d), jnp.float32),
        delta_ids=jnp.full((cap,), -1, jnp.int32),
        tombstone=jnp.zeros((n0 + cap,), bool))


def add_documents(index: SegmentedIndex, vectors
                  ) -> Tuple[SegmentedIndex, np.ndarray]:
    """Append documents to the delta segment.  Returns (index', ids) —
    ids are assigned monotonically and deterministically (``n_base +
    row``), which is what lets a replicated serving tier broadcast adds
    and stay bit-identical across replicas."""
    vecs = np.asarray(vectors, np.float32)
    if vecs.ndim == 1:
        vecs = vecs[None]
    fill, cap = delta_fill(index), delta_cap(index)
    b = vecs.shape[0]
    if fill + b > cap:
        raise ValueError(
            f"delta segment overflow: {fill} + {b} > cap {cap}; "
            f"compact() first")
    dv = np.asarray(index.delta_vecs).copy()
    di = np.asarray(index.delta_ids).copy()
    ids = np.arange(n_base(index) + fill, n_base(index) + fill + b,
                    dtype=np.int32)
    dv[fill:fill + b] = vecs
    di[fill:fill + b] = ids
    return index._replace(delta_vecs=jnp.asarray(dv),
                          delta_ids=jnp.asarray(di)), ids


def delete_documents(inner: RetrievalBackend, index: SegmentedIndex,
                     ids) -> SegmentedIndex:
    """Tombstone documents by global id (base or delta; idempotent)."""
    ids = np.atleast_1d(np.asarray(ids, np.int64))
    n0, fill = n_base(index), delta_fill(index)
    bad = ids[(ids < 0) | (ids >= n0 + fill)]
    if bad.size:
        raise ValueError(
            f"delete of unassigned doc id(s) {bad.tolist()} "
            f"(assigned id space: 0..{n0 + fill - 1})")
    tomb = np.asarray(index.tombstone).copy()
    tomb[ids] = True
    new_base = _adapter(inner).delete(index.base, tomb[:n0])
    return index._replace(base=new_base, tombstone=jnp.asarray(tomb))


def compact(inner: RetrievalBackend, index: SegmentedIndex,
            **build_kw) -> SegmentedIndex:
    """Fold the delta segment into the base and empty it.

    ``build_kw`` (hnsw only): ``ef_construction``/``seed`` must match
    the original ``hnsw.build`` call for the incremental insertion to
    continue its RNG stream (``hnsw.insert`` verifies and raises).
    Post-compaction results are bit-identical to ``rebuild()`` — dead
    ids stay tombstoned forever (ids are never reused), the delta
    resets to empty, and shapes change only here.
    """
    ad = _adapter(inner, **build_kw)
    tomb = np.asarray(index.tombstone)
    new_base = ad.compact(index.base, np.asarray(index.delta_vecs),
                          np.asarray(index.delta_ids), tomb)
    n_new = n_base(index) + delta_fill(index)
    cap = delta_cap(index)
    new_tomb = np.zeros((n_new + cap,), bool)
    new_tomb[:n_new] = tomb[:n_new]
    return SegmentedIndex(
        base=new_base,
        delta_vecs=jnp.zeros_like(index.delta_vecs),
        delta_ids=jnp.full((cap,), -1, jnp.int32),
        tombstone=jnp.asarray(new_tomb))


def rebuild(inner: RetrievalBackend, pristine_base, added_vecs,
            deleted_ids, *, cap: int, **build_kw) -> SegmentedIndex:
    """From-scratch reference construction — the compaction oracle.

    Independent path: given the pre-mutation base index, the full add
    history (in id order) and the set of deleted ids, re-derive the
    final segmented index directly.  ``compact()`` after any interleaved
    add/delete/compact sequence with the same net history must equal
    this bit for bit (``tests/test_segment.py`` pins it; for HNSW this
    is literally ``hnsw.build`` over the concatenated corpus).
    """
    ad = _adapter(inner, **build_kw)
    added = np.asarray(added_vecs, np.float32).reshape(
        (-1, int(inner.query_dim(pristine_base))))
    n0 = ad.size(pristine_base)
    n_new = n0 + added.shape[0]
    tomb = np.zeros((n_new + cap,), bool)
    dead = np.atleast_1d(np.asarray(deleted_ids, np.int64)) \
        if len(np.atleast_1d(deleted_ids)) else np.zeros(0, np.int64)
    if dead.size:
        tomb[dead] = True
    new_base = ad.rebuild(pristine_base, added, tomb)
    d = added.shape[1] if added.size else int(
        inner.query_dim(pristine_base))
    return SegmentedIndex(
        base=new_base,
        delta_vecs=jnp.zeros((cap, d), jnp.float32),
        delta_ids=jnp.full((cap,), -1, jnp.int32),
        tombstone=jnp.asarray(tomb))
