"""HNSW index — host-side (numpy) build, TPU-native (JAX) query path.

Build follows Malkov & Yashunin (the paper's [14]): probabilistic level
assignment (ml = 1/ln(M)), ef_construction beam insertion, bidirectional
links, degree cap 2M at level 0 / M above.  Index *construction* is an
offline pipeline step and runs on host; the *query* path — the part the
paper accelerates — is pure JAX.

TPU adaptation (DESIGN.md §2): the greedy candidate-list traversal is
re-expressed as a fixed-width beam over padded adjacency tensors:

  * adjacency: level 0 ``(n, 2M) int32`` (-1 pad), upper levels stacked
    ``(L, n, M) int32`` — regular gathers, no pointer chasing;
  * candidate heap → sorted ``(ef,)`` register tile, merged with top-k;
  * visited hash-set → dense ``(n,)`` bool bitmap;
  * the classic termination test ("best unexpanded candidate is worse
    than the worst result") is the ``while_loop`` predicate, so the
    data-dependent early exit — which TopLoc's privileged entry point
    makes fire sooner — is preserved.

Distance-computation counters are carried through the loop and returned
per query; they are the hardware-independent cost evidence for Table 1.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp


class HNSWIndex(NamedTuple):
    vectors: jax.Array      # (n, d) float32
    adj0: jax.Array         # (n, 2M) int32, -1 padded — level-0 graph
    upper_adj: jax.Array    # (L, n, M) int32, -1 padded — levels 1..L (bottom→top)
    entry_point: jax.Array  # () int32 — node at the top level
    node_level: jax.Array   # (n,) int32 — max level of each node
    # (n,) bool tombstones, or None when the corpus has no deletions.
    # Deleted nodes stay in the graph and keep routing the beam (the
    # standard HNSW tombstone scheme — removing edges would change every
    # survivor's traversal); they are masked out of the *result* top-k.
    deleted: Optional[jax.Array] = None

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def top_level(self) -> int:
        return self.upper_adj.shape[0]


# ---------------------------------------------------------------------------
# Host-side build (offline indexing step)
# ---------------------------------------------------------------------------

def _draw_levels(n: int, m: int, seed: int) -> np.ndarray:
    """Level assignments for nodes 0..n-1.  One sequential uniform draw,
    so ``_draw_levels(n)[:n0] == _draw_levels(n0)`` — the prefix property
    ``insert`` relies on to continue the stream."""
    rng = np.random.default_rng(seed)
    ml = 1.0 / np.log(max(m, 2))
    return np.minimum(
        (-np.log(rng.uniform(1e-12, 1.0, n)) * ml).astype(np.int64), 12)


def _insert_range(x, adj, deg, levels, entry, entry_level, lo, hi, m,
                  ef_construction):
    """Insert nodes ``lo..hi-1`` into the (mutable) adjacency state.

    This is the whole of the build loop; ``insert`` replays it starting
    from a stored graph, which is why incremental insertion reproduces
    ``build`` on the concatenated corpus bit for bit.
    """
    m0 = 2 * m

    def sims_to(q, ids):
        return x[ids] @ q

    def greedy(q, start, level):
        cur, cur_s = start, float(x[start] @ q)
        while True:
            nbrs = adj[level][cur]
            nbrs = nbrs[nbrs >= 0]
            if nbrs.size == 0:
                return cur, cur_s
            s = sims_to(q, nbrs)
            j = int(np.argmax(s))
            if s[j] > cur_s:
                cur, cur_s = int(nbrs[j]), float(s[j])
            else:
                return cur, cur_s

    def search_layer(q, start, level, ef):
        """Classic ef-beam search; returns (ids, sims) sorted desc."""
        visited = {start}
        s0 = float(x[start] @ q)
        cand = [(s0, start)]        # max-candidates (python list, small)
        result = [(s0, start)]
        while cand:
            cand.sort(key=lambda t: -t[0])
            c_s, c = cand.pop(0)
            w_s = min(r[0] for r in result)
            if c_s < w_s and len(result) >= ef:
                break
            nbrs = adj[level][c]
            nbrs = [int(v) for v in nbrs if v >= 0 and v not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            s = sims_to(q, np.asarray(nbrs, np.int64))
            for sv, nid in zip(s, nbrs):
                if len(result) < ef or sv > min(r[0] for r in result):
                    cand.append((float(sv), nid))
                    result.append((float(sv), nid))
                    if len(result) > ef:
                        result.remove(min(result))
        result.sort(key=lambda t: -t[0])
        return result

    def connect(src, dst_list, level):
        cap = m0 if level == 0 else m
        for dst in dst_list:
            for a, b in ((src, dst), (dst, src)):
                if deg[level][a] < cap:
                    adj[level][a, deg[level][a]] = b
                    deg[level][a] += 1
                else:  # shrink: keep the `cap` nearest neighbours
                    cur = adj[level][a][: deg[level][a]].tolist() + [b]
                    s = sims_to(x[a], np.asarray(cur, np.int64))
                    keep = np.argsort(-s)[:cap]
                    adj[level][a, :cap] = np.asarray(cur, np.int32)[keep]
                    deg[level][a] = cap

    for i in range(lo, hi):
        q = x[i]
        l_i = int(levels[i])
        cur = entry
        for level in range(entry_level, l_i, -1):
            cur, _ = greedy(q, cur, level)
        for level in range(min(l_i, entry_level), -1, -1):
            res = search_layer(q, cur, level, ef_construction)
            nbr = [nid for _, nid in res[: (m0 if level == 0 else m)]]
            connect(i, nbr, level)
            cur = res[0][1]
        if l_i > entry_level:
            entry, entry_level = i, l_i
    return entry, entry_level


def build(vectors, m: int = 16, ef_construction: int = 64,
          seed: int = 0) -> HNSWIndex:
    """Standard HNSW insertion, numpy. O(n·ef·M·hops) — offline."""
    x = np.asarray(vectors, np.float32)
    n, d = x.shape
    levels = _draw_levels(n, m, seed)
    top = int(levels.max()) if n else 0

    m0 = 2 * m
    adj = [np.full((n, m0 if l == 0 else m), -1, np.int32)
           for l in range(top + 1)]
    deg = [np.zeros(n, np.int32) for _ in range(top + 1)]
    entry, entry_level = _insert_range(
        x, adj, deg, levels, 0, int(levels[0]), 1, n, m, ef_construction)

    upper = (np.stack([a[:, :m] for a in adj[1:]], 0)
             if top >= 1 else np.zeros((0, n, m), np.int32))
    return HNSWIndex(
        vectors=jnp.asarray(x),
        adj0=jnp.asarray(adj[0]),
        upper_adj=jnp.asarray(upper),
        entry_point=jnp.asarray(entry, jnp.int32),
        node_level=jnp.asarray(levels, jnp.int32),
    )


def insert(index: HNSWIndex, new_vectors, *, ef_construction: int = 64,
           seed: int = 0) -> HNSWIndex:
    """Incrementally insert ``new_vectors`` as nodes ``n0..n-1``.

    The level draw continues ``build``'s RNG stream (one fresh draw of
    all ``n`` levels whose prefix reproduces the stored graph's), and the
    insertion loop is the same ``_insert_range`` — so
    ``insert(build(x[:n0], …), x[n0:])`` is bit-identical to
    ``build(x, …)`` for the same ``(m, ef_construction, seed)``.
    """
    xb = np.asarray(index.vectors, np.float32)
    xn = np.asarray(new_vectors, np.float32)
    n0, n = xb.shape[0], xb.shape[0] + xn.shape[0]
    x = np.concatenate([xb, xn], 0)
    m0 = index.adj0.shape[1]
    m = m0 // 2
    levels = _draw_levels(n, m, seed)
    if not np.array_equal(levels[:n0],
                          np.asarray(index.node_level, np.int64)):
        raise ValueError(
            "insert: level stream mismatch — the index was not built "
            f"with (m={m}, seed={seed}); incremental insertion would "
            "diverge from a from-scratch build")
    top = int(levels.max()) if n else 0

    adj = [np.full((n, m0 if l == 0 else m), -1, np.int32)
           for l in range(top + 1)]
    adj[0][:n0] = np.asarray(index.adj0)
    up = np.asarray(index.upper_adj)          # (L_old, n0, m)
    for l in range(1, index.top_level + 1):
        adj[l][:n0] = up[l - 1]
    # connect() fills each row as a contiguous prefix, so the stored
    # -1 padding encodes the degree state exactly
    deg = [np.sum(a >= 0, axis=1).astype(np.int32) for a in adj]

    entry = int(index.entry_point)
    entry, entry_level = _insert_range(
        x, adj, deg, levels, entry, int(levels[entry]), n0, n, m,
        ef_construction)

    upper = (np.stack([a[:, :m] for a in adj[1:]], 0)
             if top >= 1 else np.zeros((0, n, m), np.int32))
    deleted = index.deleted
    if deleted is not None:
        deleted = jnp.concatenate(
            [deleted, jnp.zeros((xn.shape[0],), bool)])
    return HNSWIndex(
        vectors=jnp.asarray(x),
        adj0=jnp.asarray(adj[0]),
        upper_adj=jnp.asarray(upper),
        entry_point=jnp.asarray(entry, jnp.int32),
        node_level=jnp.asarray(levels, jnp.int32),
        deleted=deleted,
    )


def save(index: HNSWIndex, path: str) -> None:
    np.savez(path, **{k: np.asarray(v)
                      for k, v in index._asdict().items() if v is not None})


def load(path: str) -> HNSWIndex:
    z = np.load(path)
    return HNSWIndex(**{k: jnp.asarray(z[k]) for k in z.files})


# ---------------------------------------------------------------------------
# JAX query path
# ---------------------------------------------------------------------------

def _dots(vecs: jax.Array, q: jax.Array) -> jax.Array:
    """Per-candidate dot products as an explicit multiply-reduce.

    A ``vecs @ q`` matvec lowers to a dot_general whose reduction tiling
    depends on the vmap batch size (XLA canonicalises unit batch dims
    away), so the same query scored inside a B=1 and a B=32 ``search``
    call could differ in the last ulp.  The elementwise-multiply +
    trailing-axis reduce keeps one reduction order per row regardless of
    batch size — this is what makes the batched serving path
    (``toploc.step_batch``) bit-identical to the sequential one.
    """
    return jnp.sum(vecs * q[None, :], axis=-1)


def _gather_dots(vectors):
    """Default ``dots_at`` factory: gather rows, explicit multiply-reduce.

    The search loops score candidates through an injected
    ``dots_at(ids) -> (len(ids),)`` closure rather than touching
    ``vectors`` directly, so the device-sharded path
    (``distributed.retrieval.ShardedHNSWSearch``) can swap in an
    owner-computes + ``psum`` scorer while reusing the exact traversal —
    the arithmetic per candidate is identical either way (one shard
    computes the same ``_dots`` row, the others contribute exact zeros),
    which keeps sharded and single-device searches bit-identical.
    """
    def factory(q):
        def dots_at(ids):
            return _dots(vectors[ids], q)
        return dots_at
    return factory


def _greedy_level(dots_at, adj, cur, cur_s, ndist):
    """Greedy hill-climb on one level (vectorised neighbour expansion)."""
    def cond(st):
        _, _, _, improved = st
        return improved

    def body(st):
        cur, cur_s, ndist, _ = st
        nbrs = adj[cur]                              # (deg,)
        valid = nbrs >= 0
        s = jnp.where(valid, dots_at(jnp.maximum(nbrs, 0)), -jnp.inf)
        j = jnp.argmax(s)
        better = s[j] > cur_s
        ndist = ndist + jnp.sum(valid.astype(jnp.int32))
        return (jnp.where(better, nbrs[j], cur),
                jnp.where(better, s[j], cur_s),
                ndist, better)

    cur, cur_s, ndist, _ = jax.lax.while_loop(
        cond, body, (cur, cur_s, ndist, jnp.asarray(True)))
    return cur, cur_s, ndist


def _search_layer0(dots_at, n, adj0, entry, ef: int, max_steps: int):
    """Fixed-width beam realisation of the ef-search candidate loop."""
    entry_s = dots_at(entry[None])[0]
    cand_v = jnp.full((ef,), -jnp.inf).at[0].set(entry_s)
    cand_i = jnp.full((ef,), -1, jnp.int32).at[0].set(entry)
    expanded = jnp.zeros((ef,), bool)
    visited = jnp.zeros((n,), bool).at[entry].set(True)
    ndist = jnp.asarray(1, jnp.int32)

    def cond(st):
        cand_v, cand_i, expanded, visited, ndist, step = st
        unexp = (~expanded) & (cand_i >= 0)
        any_unexp = jnp.any(unexp)
        best_unexp = jnp.max(jnp.where(unexp, cand_v, -jnp.inf))
        worst = jnp.min(jnp.where(cand_i >= 0, cand_v, jnp.inf))
        full = jnp.sum((cand_i >= 0).astype(jnp.int32)) >= ef
        # classic HNSW stop: nothing promising left to expand
        go = any_unexp & ~(full & (best_unexp < worst))
        return go & (step < max_steps)

    def body(st):
        cand_v, cand_i, expanded, visited, ndist, step = st
        unexp = (~expanded) & (cand_i >= 0)
        pick = jnp.argmax(jnp.where(unexp, cand_v, -jnp.inf))
        node = cand_i[pick]
        expanded = expanded.at[pick].set(True)
        nbrs = adj0[node]                            # (2M,)
        ok = (nbrs >= 0) & ~visited[jnp.maximum(nbrs, 0)]
        s = jnp.where(ok, dots_at(jnp.maximum(nbrs, 0)), -jnp.inf)
        ndist = ndist + jnp.sum(ok.astype(jnp.int32))
        visited = visited.at[jnp.maximum(nbrs, 0)].max(ok)
        # merge new candidates into the beam (expanded flag rides along)
        all_v = jnp.concatenate([cand_v, s])
        all_i = jnp.concatenate([cand_i, jnp.where(ok, nbrs, -1)])
        all_e = jnp.concatenate([expanded, jnp.zeros_like(ok)])
        top_v, pos = jax.lax.top_k(all_v, ef)
        return (top_v, all_i[pos], all_e[pos], visited, ndist, step + 1)

    cand_v, cand_i, expanded, visited, ndist, _ = jax.lax.while_loop(
        cond, body, (cand_v, cand_i, expanded, visited, ndist,
                     jnp.asarray(0, jnp.int32)))
    return cand_v, cand_i, ndist


def _search_impl(dots_factory, n, top_level, adj0, upper_adj, entry_point,
                 queries, entry_override, *, ef: int, k: int,
                 use_entry_override: bool,
                 deleted: Optional[jax.Array] = None,
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Traversal shared by the local and device-sharded search paths.

    ``dots_factory(q) -> dots_at(ids)`` supplies the candidate scorer;
    ``n`` sizes the visited bitmap (the *global* node count when vectors
    are sharded).  ``deleted`` (when set) masks tombstoned nodes out of
    the final top-k only — they still route the beam, so a compacted
    graph traverses identically to a from-scratch build over the same
    insertion sequence.  Everything else is exactly the public ``search``.
    """
    max_steps = 4 * ef + 16

    def one(q, override):
        dots_at = dots_factory(q)
        ndist = jnp.asarray(0, jnp.int32)
        if use_entry_override:
            start = override
        else:
            cur = entry_point
            cur_s = dots_at(cur[None])[0]
            ndist = ndist + 1
            for lvl in range(top_level - 1, -1, -1):  # top level → level 1
                cur, cur_s, ndist = _greedy_level(
                    dots_at, upper_adj[lvl], cur, cur_s, ndist)
            start = cur
        cand_v, cand_i, nd0 = _search_layer0(
            dots_at, n, adj0, start, ef, max_steps)
        if deleted is not None:
            dead = deleted[jnp.maximum(cand_i, 0)] & (cand_i >= 0)
            cand_v = jnp.where(dead, -jnp.inf, cand_v)
        top_v, pos = jax.lax.top_k(cand_v, k)
        return top_v, cand_i[pos], ndist + nd0

    if entry_override is None:
        entry_override = jnp.zeros((queries.shape[0],), jnp.int32)
    return jax.vmap(one)(queries, entry_override)


@functools.partial(jax.jit, static_argnames=("ef", "k", "use_entry_override"))
def search(index: HNSWIndex, queries: jax.Array, *, ef: int, k: int,
           entry_override: Optional[jax.Array] = None,
           use_entry_override: bool = False,
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batch HNSW search. queries: (B, d).

    Plain HNSW: hierarchy descent from the global entry point, then the
    level-0 ef-beam.  TopLoc_HNSW: ``use_entry_override=True`` starts the
    level-0 beam directly at ``entry_override`` (the conversation's
    privileged entry point), skipping the descent — the paper's saving.

    Returns (scores (B,k), ids (B,k), ndist (B,) int32).
    """
    return _search_impl(
        _gather_dots(index.vectors), index.n, index.top_level, index.adj0,
        index.upper_adj, index.entry_point, queries, entry_override,
        ef=ef, k=k, use_entry_override=use_entry_override,
        deleted=index.deleted)
