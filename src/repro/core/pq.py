"""Product quantisation for IVF posting lists (beyond-paper extension).

At the paper's scale (38.6M × 768-d passages) FAISS deployments store
posting lists PQ-compressed (IVF-PQ, the paper's reference [11]): each
vector is split into ``m`` subspaces, each encoded as one byte (256
k-means codewords per subspace) — 32–64× smaller lists, scanned via
asymmetric distance computation (ADC): the query precomputes a
``(m, 256)`` lookup table once, then every encoded doc costs ``m`` table
gathers + adds instead of a d-dim dot product.

TPU mapping: the LUT build is a tiny matmul; the ADC scan is a gather-
accumulate along the lanes — the same HBM→VMEM streaming shape as
``kernels/ivf_scan`` with 32× fewer bytes per document, which directly
divides the memory roofline term of list scanning.  TopLoc composes
orthogonally (it prunes *which* lists are scanned; PQ compresses *how*).

Pure-jnp here (build is offline).  The hot ADC scan lives in
``kernels/pq_adc.py`` (same PrefetchScalarGridSpec pattern as ivf_scan
with the (m, 256) LUT resident in VMEM); ``IVFPQIndex`` below packages
the compressed lists + re-rank source that ``backend.IVFPQBackend``
and the serving engines consume.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kmeans as _kmeans


class PQCodebook(NamedTuple):
    codewords: jax.Array     # (m, 256, d_sub)
    m: int = 0               # static copy of subspace count

    @property
    def d(self) -> int:
        return self.codewords.shape[0] * self.codewords.shape[2]


def train(vectors: jax.Array, m: int, *, iters: int = 8,
          key: Optional[jax.Array] = None, n_codes: int = 256
          ) -> PQCodebook:
    """Per-subspace k-means codebooks. vectors (n, d), d % m == 0."""
    n, d = vectors.shape
    assert d % m == 0, (d, m)
    d_sub = d // m
    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, m)
    subs = vectors.reshape(n, m, d_sub)
    books = []
    for j in range(m):
        # ++ seeding: random init leaves enough near-duplicate codewords
        # in the low-dim subspaces to visibly hurt ADC fidelity
        c, _ = _kmeans.kmeans_fit(subs[:, j], n_codes, iters=iters,
                                  key=keys[j], init="++")
        books.append(c)
    return PQCodebook(jnp.stack(books), m)


@jax.jit
def encode(book: PQCodebook, vectors: jax.Array) -> jax.Array:
    """→ codes (n, m) uint8: nearest codeword per subspace (L2)."""
    n, d = vectors.shape
    m, n_codes, d_sub = book.codewords.shape
    subs = vectors.reshape(n, m, d_sub)
    # ||x - c||² = ||x||² - 2<x,c> + ||c||²; argmin over codewords
    dots = jnp.einsum("nmd,mkd->nmk", subs, book.codewords)
    c_sq = jnp.sum(book.codewords ** 2, -1)                 # (m, k)
    return jnp.argmin(c_sq[None] - 2 * dots, axis=-1).astype(jnp.uint8)


@jax.jit
def decode(book: PQCodebook, codes: jax.Array) -> jax.Array:
    """Reconstruct (n, d) from (n, m) codes."""
    n, m = codes.shape
    rows = jnp.take_along_axis(
        book.codewords[None], codes[:, :, None, None].astype(jnp.int32),
        axis=2)[:, :, 0]                                    # (n, m, d_sub)
    return rows.reshape(n, -1)


@jax.jit
def adc_table(book: PQCodebook, query: jax.Array) -> jax.Array:
    """Query → (m, 256) inner-product lookup table (built once/query)."""
    m, n_codes, d_sub = book.codewords.shape
    q = query.reshape(m, d_sub)
    return jnp.einsum("md,mkd->mk", q, book.codewords)      # (m, 256)


@jax.jit
def adc_scores(table: jax.Array, codes: jax.Array) -> jax.Array:
    """ADC: approximate <q, x> for encoded docs. codes (n, m) → (n,)."""
    n, m = codes.shape
    gathered = jnp.take_along_axis(
        jnp.broadcast_to(table, (n, m, table.shape[1])),
        codes.astype(jnp.int32)[:, :, None], axis=2)[:, :, 0]
    return jnp.sum(gathered, axis=-1)


# ---------------------------------------------------------------------------
# IVF-PQ index: IVF geometry + PQ-compressed posting lists
# ---------------------------------------------------------------------------

class IVFPQIndex(NamedTuple):
    """IVF index whose posting lists store PQ codes instead of floats.

    Same bucketed-padded layout as ``ivf.IVFIndex`` but each list entry
    is ``m`` uint8 codes (m bytes/doc vs 4·d), cutting the
    bytes-from-HBM of a list scan by 4·d/m (16x at d=128, m=32... and
    64x at the paper's d=768, m=48).  ``doc_vecs`` keeps the
    uncompressed collection for exact re-ranking of the top-R ADC
    candidates — the standard IVFPQ+refine design: only R rows per
    query ever touch the float corpus.

    All fields are device arrays so the index is a pytree (jit/vmap
    friendly); static shape properties mirror ``IVFIndex``.
    """
    centroids: jax.Array    # (p, d)  float32 — IVF coarse quantiser
    codewords: jax.Array    # (m, n_codes, d_sub) — PQ codebooks
    list_codes: jax.Array   # (p, Lmax, m) uint8 — PQ-encoded lists
    list_ids: jax.Array     # (p, Lmax) int32 — doc ids, -1 = pad
    list_sizes: jax.Array   # (p,) int32 — real sizes
    doc_vecs: jax.Array     # (n, d) float32 — re-rank source

    @property
    def p(self) -> int:
        return self.centroids.shape[0]

    @property
    def d(self) -> int:
        return self.centroids.shape[1]

    @property
    def m(self) -> int:
        return self.codewords.shape[0]

    @property
    def lmax(self) -> int:
        return self.list_ids.shape[1]

    @property
    def n_docs(self) -> int:
        return int(self.list_sizes.sum())

    @property
    def book(self) -> PQCodebook:
        return PQCodebook(self.codewords, self.codewords.shape[0])

    @property
    def bytes_per_doc(self) -> int:
        """Posting-list payload per document (codes only)."""
        return self.codewords.shape[0]


def build_ivf_pq(index, vectors: jax.Array, m: int, *, iters: int = 8,
                 key: Optional[jax.Array] = None, n_codes: int = 256
                 ) -> IVFPQIndex:
    """PQ-compress the posting lists of a built ``ivf.IVFIndex``.

    Trains per-subspace codebooks on the full collection, encodes every
    doc, and gathers the codes into the index's bucketed layout (pad
    rows encode as code 0 but stay masked by ``list_ids == -1``).
    """
    book = train(vectors, m, iters=iters, key=key, n_codes=n_codes)
    codes = encode(book, vectors)                   # (n, m) uint8
    gather = jnp.maximum(index.list_ids, 0)
    list_codes = jnp.where((index.list_ids >= 0)[..., None],
                           codes[gather], jnp.asarray(0, jnp.uint8))
    return IVFPQIndex(index.centroids, book.codewords, list_codes,
                      index.list_ids, index.list_sizes.astype(jnp.int32),
                      jnp.asarray(vectors))


def adc_scores_masked(tables: jax.Array, codes: jax.Array,
                      ids: jax.Array) -> jax.Array:
    """ADC scores for pre-gathered candidate blocks, batched + masked.

    tables (B, m, n_codes) f32; codes (B, N, m) int32; ids (B, N) int32
    (-1 = pad/foreign → score -inf).  Returns (B, N) f32.

    The per-candidate compute — an m-row LUT gather transposed to
    (m, N) and reduced over the m axis — is formulated *exactly* like
    ``kernels.ref.pq_adc_scan`` so each candidate's reduction order (and
    therefore its last-ulp value) matches the single-device scan.  The
    device-sharded scan (``distributed.retrieval.ShardedPQScan``) relies
    on this to stay bit-identical to the unsharded backend.
    """
    def one(table, codes_q, ids_q):
        gathered = jnp.take_along_axis(table, codes_q.T, axis=1)  # (m, N)
        scores = jnp.sum(gathered, axis=0)
        return jnp.where(ids_q >= 0, scores, -jnp.inf)

    return jax.vmap(one)(tables, codes, ids)


@functools.partial(jax.jit, static_argnames=("k",))
def adc_search_lists(book: PQCodebook, query: jax.Array,
                     list_codes: jax.Array, list_ids: jax.Array,
                     sel: jax.Array, k: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """TopLoc+PQ composed: scan the selected PQ-encoded posting lists.

    query (d,); list_codes (p, Lmax, m) uint8; list_ids (p, Lmax);
    sel (np,) — e.g. from the TopLoc centroid cache.
    """
    table = adc_table(book, query)                          # (m, 256)
    codes = list_codes[sel]                                 # (np, L, m)
    ids = list_ids[sel]
    npb, lmax, m = codes.shape
    flat = codes.reshape(-1, m)
    scores = adc_scores(table, flat).reshape(npb, lmax)
    scores = jnp.where(ids >= 0, scores, -jnp.inf)
    v, pos = jax.lax.top_k(scores.reshape(-1), k)
    return v, ids.reshape(-1)[pos]
