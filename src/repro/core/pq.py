"""Product quantisation for IVF posting lists (beyond-paper extension).

At the paper's scale (38.6M × 768-d passages) FAISS deployments store
posting lists PQ-compressed (IVF-PQ, the paper's reference [11]): each
vector is split into ``m`` subspaces, each encoded as one byte (256
k-means codewords per subspace) — 32–64× smaller lists, scanned via
asymmetric distance computation (ADC): the query precomputes a
``(m, 256)`` lookup table once, then every encoded doc costs ``m`` table
gathers + adds instead of a d-dim dot product.

TPU mapping: the LUT build is a tiny matmul; the ADC scan is a gather-
accumulate along the lanes — the same HBM→VMEM streaming shape as
``kernels/ivf_scan`` with 32× fewer bytes per document, which directly
divides the memory roofline term of list scanning.  TopLoc composes
orthogonally (it prunes *which* lists are scanned; PQ compresses *how*).

Pure-jnp here (build is offline; the scan is the documented follow-up
Pallas kernel — same PrefetchScalarGridSpec pattern as ivf_scan with a
(m, 256) LUT resident in VMEM).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kmeans as _kmeans


class PQCodebook(NamedTuple):
    codewords: jax.Array     # (m, 256, d_sub)
    m: int = 0               # static copy of subspace count

    @property
    def d(self) -> int:
        return self.codewords.shape[0] * self.codewords.shape[2]


def train(vectors: jax.Array, m: int, *, iters: int = 8,
          key: Optional[jax.Array] = None, n_codes: int = 256
          ) -> PQCodebook:
    """Per-subspace k-means codebooks. vectors (n, d), d % m == 0."""
    n, d = vectors.shape
    assert d % m == 0, (d, m)
    d_sub = d // m
    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, m)
    subs = vectors.reshape(n, m, d_sub)
    books = []
    for j in range(m):
        # ++ seeding: random init leaves enough near-duplicate codewords
        # in the low-dim subspaces to visibly hurt ADC fidelity
        c, _ = _kmeans.kmeans_fit(subs[:, j], n_codes, iters=iters,
                                  key=keys[j], init="++")
        books.append(c)
    return PQCodebook(jnp.stack(books), m)


@jax.jit
def encode(book: PQCodebook, vectors: jax.Array) -> jax.Array:
    """→ codes (n, m) uint8: nearest codeword per subspace (L2)."""
    n, d = vectors.shape
    m, n_codes, d_sub = book.codewords.shape
    subs = vectors.reshape(n, m, d_sub)
    # ||x - c||² = ||x||² - 2<x,c> + ||c||²; argmin over codewords
    dots = jnp.einsum("nmd,mkd->nmk", subs, book.codewords)
    c_sq = jnp.sum(book.codewords ** 2, -1)                 # (m, k)
    return jnp.argmin(c_sq[None] - 2 * dots, axis=-1).astype(jnp.uint8)


@jax.jit
def decode(book: PQCodebook, codes: jax.Array) -> jax.Array:
    """Reconstruct (n, d) from (n, m) codes."""
    n, m = codes.shape
    rows = jnp.take_along_axis(
        book.codewords[None], codes[:, :, None, None].astype(jnp.int32),
        axis=2)[:, :, 0]                                    # (n, m, d_sub)
    return rows.reshape(n, -1)


@jax.jit
def adc_table(book: PQCodebook, query: jax.Array) -> jax.Array:
    """Query → (m, 256) inner-product lookup table (built once/query)."""
    m, n_codes, d_sub = book.codewords.shape
    q = query.reshape(m, d_sub)
    return jnp.einsum("md,mkd->mk", q, book.codewords)      # (m, 256)


@jax.jit
def adc_scores(table: jax.Array, codes: jax.Array) -> jax.Array:
    """ADC: approximate <q, x> for encoded docs. codes (n, m) → (n,)."""
    n, m = codes.shape
    gathered = jnp.take_along_axis(
        jnp.broadcast_to(table, (n, m, table.shape[1])),
        codes.astype(jnp.int32)[:, :, None], axis=2)[:, :, 0]
    return jnp.sum(gathered, axis=-1)


@functools.partial(jax.jit, static_argnames=("k",))
def adc_search_lists(book: PQCodebook, query: jax.Array,
                     list_codes: jax.Array, list_ids: jax.Array,
                     sel: jax.Array, k: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """TopLoc+PQ composed: scan the selected PQ-encoded posting lists.

    query (d,); list_codes (p, Lmax, m) uint8; list_ids (p, Lmax);
    sel (np,) — e.g. from the TopLoc centroid cache.
    """
    table = adc_table(book, query)                          # (m, 256)
    codes = list_codes[sel]                                 # (np, L, m)
    ids = list_ids[sel]
    npb, lmax, m = codes.shape
    flat = codes.reshape(-1, m)
    scores = adc_scores(table, flat).reshape(npb, lmax)
    scores = jnp.where(ids >= 0, scores, -jnp.inf)
    v, pos = jax.lax.top_k(scores.reshape(-1), k)
    return v, ids.reshape(-1)[pos]
