"""IVF (Inverted File) index — TPU-native bucketed-padded realisation.

Semantics follow FAISS IVF as described in the paper (TopLoc §2):

  * ``p`` centroids from (balanced) k-means; each data point lives in the
    posting list of its nearest centroid (modulo capacity spill, see
    ``core.kmeans.balance_assignment``).
  * A query scores all ``p`` centroids, selects the top-``nprobe`` lists,
    scans them exhaustively and returns the global top-k by dot product.

TPU adaptation (DESIGN.md §2): posting lists are stored as a dense
``(p, Lmax, d)`` tensor (+ id / mask tensors) so list scans are regular
gathers + matmuls.  Work counters report *real* (unpadded) distance
computations so efficiency numbers are not flattered by padding.

The pure-jnp search here is also the oracle for the Pallas ``ivf_scan``
kernel (kernels/ref.py re-exports pieces of it).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kmeans as _kmeans
from repro.core.topk import masked_topk


class IVFIndex(NamedTuple):
    """Bucketed-padded IVF index. All fields are device arrays (a pytree)."""
    centroids: jax.Array    # (p, d)  float32
    list_vecs: jax.Array    # (p, Lmax, d) float32 — padded posting lists
    list_ids: jax.Array     # (p, Lmax) int32 — original doc ids, -1 = pad
    list_sizes: jax.Array   # (p,) int32 — real sizes

    @property
    def p(self) -> int:
        return self.centroids.shape[0]

    @property
    def d(self) -> int:
        return self.centroids.shape[1]

    @property
    def lmax(self) -> int:
        return self.list_ids.shape[1]

    @property
    def n_docs(self) -> int:
        return int(self.list_sizes.sum())


class SearchStats(NamedTuple):
    """Per-query work counters (the hardware-independent cost model)."""
    centroid_dists: jax.Array   # (B,) int32 — centroid scoring work
    list_dists: jax.Array       # (B,) int32 — real doc distances computed
    padded_list_dists: jax.Array  # (B,) int32 — incl. padding (TPU lanes)


def build(vectors: jax.Array, p: int, *, iters: int = 10,
          key: Optional[jax.Array] = None,
          capacity_factor: float = 1.3) -> IVFIndex:
    """Build the index: balanced k-means + bucketed posting-list layout."""
    n, d = vectors.shape
    res = _kmeans.fit_balanced(vectors, p, iters=iters, key=key,
                               capacity_factor=capacity_factor)
    lmax = int(jax.device_get(res.sizes.max()))
    lmax = max(lmax, 1)
    assign = jax.device_get(res.assignment)
    # host-side bucketisation (index build is offline)
    import numpy as np
    ids = np.full((p, lmax), -1, np.int32)
    fill = np.zeros(p, np.int64)
    for doc, c in enumerate(assign):
        ids[c, fill[c]] = doc
        fill[c] += 1
    list_ids = jnp.asarray(ids)
    gather_idx = jnp.maximum(list_ids, 0)
    list_vecs = jnp.where((list_ids >= 0)[..., None],
                          vectors[gather_idx], 0.0)
    return IVFIndex(res.centroids, list_vecs, list_ids,
                    res.sizes.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Search paths
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k",))
def exact_search(vectors: jax.Array, queries: jax.Array, k: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Brute-force top-k over the full collection (paper's 'Exact' row)."""
    scores = queries @ vectors.T          # (B, n)
    return jax.lax.top_k(scores, k)


def _scan_lists(index: IVFIndex, queries: jax.Array, sel: jax.Array,
                k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Scan the selected posting lists; returns (top_v, top_ids, real_dists).

    queries: (B, d); sel: (B, np) selected centroid indices.
    """
    lv = index.list_vecs[sel]                       # (B, np, Lmax, d)
    li = index.list_ids[sel]                        # (B, np, Lmax)
    scores = jnp.einsum("bd,bnld->bnl", queries, lv)
    mask = li >= 0
    b = queries.shape[0]
    flat_scores = scores.reshape(b, -1)
    flat_mask = mask.reshape(b, -1)
    flat_ids = li.reshape(b, -1)
    top_v, pos = masked_topk(flat_scores, flat_mask, k)
    top_i = jnp.take_along_axis(flat_ids, pos, axis=-1)
    real = jnp.sum(index.list_sizes[sel], axis=-1).astype(jnp.int32)
    return top_v, top_i, real


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "scan"))
def search(index: IVFIndex, queries: jax.Array, *, nprobe: int, k: int,
           scan=None) -> Tuple[jax.Array, jax.Array, SearchStats]:
    """Plain IVF search (the paper's baseline).

    ``scan`` optionally replaces the posting-list scan (same signature as
    ``_scan_lists``) — the device-sharded retrieval path
    (``distributed.retrieval.ShardedIVFScan``) plugs in here.
    Returns (scores (B,k), doc_ids (B,k), stats).
    """
    b = queries.shape[0]
    cscores = queries @ index.centroids.T           # (B, p)
    _, sel = jax.lax.top_k(cscores, nprobe)          # (B, np)
    top_v, top_i, real = (scan or _scan_lists)(index, queries, sel, k)
    stats = SearchStats(
        centroid_dists=jnp.full((b,), index.p, jnp.int32),
        list_dists=real,
        padded_list_dists=jnp.full((b,), nprobe * index.lmax, jnp.int32),
    )
    return top_v, top_i, stats


@functools.partial(jax.jit, static_argnames=("nprobe", "k"))
def search_cached(index: IVFIndex, cache_ids: jax.Array, cache_vecs: jax.Array,
                  queries: jax.Array, *, nprobe: int, k: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, SearchStats]:
    """TopLoc_IVF search against a cached centroid subset ``C0``.

    cache_ids:  (h,) int32 — global centroid indices in the cache
    cache_vecs: (h, d)     — the cached centroid vectors (gathered once at
                              conversation start; resident per session)

    Returns (scores, doc_ids, sel_global (B,np) — the *global* centroid ids
    the query probed, needed by the ``I0`` drift proxy — and stats).
    """
    b = queries.shape[0]
    h = cache_ids.shape[0]
    cscores = queries @ cache_vecs.T                # (B, h)
    _, sel_local = jax.lax.top_k(cscores, nprobe)   # (B, np) into cache
    sel_global = cache_ids[sel_local]               # (B, np) global ids
    top_v, top_i, real = _scan_lists(index, queries, sel_global, k)
    stats = SearchStats(
        centroid_dists=jnp.full((b,), h, jnp.int32),
        list_dists=real,
        padded_list_dists=jnp.full((b,), nprobe * index.lmax, jnp.int32),
    )
    return top_v, top_i, sel_global, stats


@functools.partial(jax.jit, static_argnames=("h",))
def make_cache(index: IVFIndex, q0: jax.Array, *, h: int
               ) -> Tuple[jax.Array, jax.Array]:
    """Build the hot-centroid cache from the first utterance:
    ``C0 = top_h(q0, C)`` (TopLoc §2). q0: (d,).

    Returns (cache_ids (h,), cache_vecs (h,d)).
    """
    cscores = index.centroids @ q0                  # (p,)
    _, ids = jax.lax.top_k(cscores, h)
    return ids.astype(jnp.int32), index.centroids[ids]
