"""Top-k selection and merge utilities.

Everything here operates on *similarity scores* (higher is better), matching
the paper's use of the dot product as the similarity measure (TopLoc §2,
footnote 1).  All functions are jit-safe and differentiable-free (top-k has
no gradient; these are serving-path ops).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)


def topk(scores: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Top-k over the last axis. Returns (values, indices), sorted desc."""
    return jax.lax.top_k(scores, k)


def masked_topk(scores: jax.Array, mask: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Top-k over the last axis ignoring positions where ``mask`` is False."""
    neg = jnp.asarray(-jnp.inf, scores.dtype)
    return jax.lax.top_k(jnp.where(mask, scores, neg), k)


def merge_topk(
    values_a: jax.Array,
    ids_a: jax.Array,
    values_b: jax.Array,
    ids_b: jax.Array,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Merge two (values, ids) top-k lists into a single top-k list.

    Works on the last axis; leading axes broadcast. Ties are broken by
    whichever side sorts first in lax.top_k (stable enough for our use —
    ids are unique across sides by construction in the ivf/hnsw callers).
    """
    v = jnp.concatenate([values_a, values_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    top_v, pos = jax.lax.top_k(v, k)
    top_i = jnp.take_along_axis(i, pos, axis=-1)
    return top_v, top_i


def distributed_topk(
    local_values: jax.Array,
    local_ids: jax.Array,
    k: int,
    axis_name: str,
) -> Tuple[jax.Array, jax.Array]:
    """Global top-k across a mesh axis from per-shard top-k lists.

    Inside ``shard_map``: each shard passes its local top-k (already reduced
    to k entries — so the all_gather moves only ``k * axis_size`` entries,
    not the full candidate set). Returns identical (values, ids) on every
    shard.
    """
    all_v = jax.lax.all_gather(local_values, axis_name, axis=-1, tiled=True)
    all_i = jax.lax.all_gather(local_ids, axis_name, axis=-1, tiled=True)
    top_v, pos = jax.lax.top_k(all_v, k)
    top_i = jnp.take_along_axis(all_i, pos, axis=-1)
    return top_v, top_i


def distributed_topk_ordered(
    local_values: jax.Array,
    local_pos: jax.Array,
    local_ids: jax.Array,
    k: int,
    axis_name: str,
) -> Tuple[jax.Array, jax.Array]:
    """Global top-k across a mesh axis with *single-device tie-breaking*.

    ``distributed_topk`` concatenates shards in mesh order before the
    final ``top_k``, so candidates with equal scores resolve shard-major —
    but a single-device ``masked_topk`` over the flat candidate array
    resolves ties by flat position.  Here every shard passes, alongside
    its local top-k, each candidate's *global flat position* (``pos`` as
    returned by a local ``lax.top_k`` over the full-shape masked scan —
    the same flat index the single-device scan would use), and the merge
    sorts lexicographically by (score desc, position asc).  The result is
    bit-identical to the single-device selection even when duplicate
    documents produce exact score ties — the invariant the
    sharded-vs-single-device equivalence tests pin down.
    """
    all_v = jax.lax.all_gather(local_values, axis_name, axis=-1, tiled=True)
    all_p = jax.lax.all_gather(local_pos, axis_name, axis=-1, tiled=True)
    all_i = jax.lax.all_gather(local_ids, axis_name, axis=-1, tiled=True)
    # lax.sort is ascending: negate scores; positions break ties ascending
    _, _, top_i, top_v = jax.lax.sort(
        (-all_v, all_p, all_i, all_v), dimension=-1, num_keys=2)
    return top_v[..., :k], top_i[..., :k]


def intersect_count(ids_a: jax.Array, ids_b: jax.Array) -> jax.Array:
    """|set(ids_a) ∩ set(ids_b)| for 1-D id vectors (entries assumed unique
    within each vector; -1 entries are treated as padding and ignored).

    This is the paper's ``|I0|`` computation (Eq. 1). Cost is
    O(|a|·|b|) elementwise on the VPU — with np ≤ 4096 this is trivia
    compared to a single centroid scan, which is the point of the proxy.
    """
    a = ids_a[:, None]
    b = ids_b[None, :]
    eq = (a == b) & (a >= 0)
    return jnp.sum(jnp.any(eq, axis=1).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("k", "block"))
def streaming_topk(scores: jax.Array, k: int, block: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """Block-streaming top-k over a long last axis.

    Equivalent to ``jax.lax.top_k(scores, k)`` but processes ``scores`` in
    blocks, carrying a running (k,) register tile — the same schedule the
    Pallas ``centroid_topk`` kernel uses, exposed as a pure-jnp op so the
    host path and the kernel share a reference.
    """
    n = scores.shape[-1]
    pad = (-n) % block
    if pad:
        neg = jnp.full(scores.shape[:-1] + (pad,), -jnp.inf, scores.dtype)
        scores = jnp.concatenate([scores, neg], axis=-1)
    nblk = scores.shape[-1] // block
    blocks = scores.reshape(scores.shape[:-1] + (nblk, block))

    def body(carry, xs):
        run_v, run_i = carry
        blk_scores, blk_start = xs
        v, i = jax.lax.top_k(blk_scores, min(k, block))
        i = i + blk_start
        if k > block:  # pad the block's partial list up to k
            padv = jnp.full(blk_scores.shape[:-1] + (k - block,), -jnp.inf, blk_scores.dtype)
            padi = jnp.full(blk_scores.shape[:-1] + (k - block,), -1, i.dtype)
            v = jnp.concatenate([v, padv], axis=-1)
            i = jnp.concatenate([i, padi], axis=-1)
        mv, mi = merge_topk(run_v, run_i, v, i, k)
        return (mv, mi), None

    init_v = jnp.full(scores.shape[:-2] + (k,), -jnp.inf, scores.dtype)
    init_i = jnp.full(scores.shape[:-2] + (k,), -1, jnp.int32)
    blk_axis = -2 if scores.ndim > 1 else 0
    blocks_first = jnp.moveaxis(blocks, blk_axis, 0)
    starts = jnp.arange(nblk, dtype=jnp.int32) * block
    (v, i), _ = jax.lax.scan(body, (init_v, init_i), (blocks_first, starts))
    return v, i
