"""Core library: TopLoc (the paper's contribution) + the ANN substrate.

Public API:
  backend  — RetrievalBackend registry (IVF / IVF-PQ / HNSW / Exact
             dataclasses; the single seam every layer dispatches through)
  ivf      — bucketed-padded IVF index (build / search / search_cached)
  hnsw     — HNSW index (host build, JAX beam-query)
  toploc   — TopLoc sessions + the generic registry drivers
             (start/step/plain/… over any registered backend)
  kmeans   — distributed balanced k-means (index build substrate)
  topk     — top-k select/merge utilities incl. distributed merge
  pq       — product-quantised posting lists (IVF-PQ, beyond-paper)
  segment  — mutable corpus: delta segment + tombstones + compaction
             (SegmentedBackend wraps any registered backend)
"""
from repro.core import backend, hnsw, ivf, kmeans, pq, segment, topk, toploc  # noqa: F401,E501
from repro.core.backend import (  # noqa: F401
    ExactBackend, HNSWBackend, IVFBackend, IVFPQBackend, RetrievalBackend)
from repro.core.pq import (  # noqa: F401
    IVFPQIndex, PQCodebook, build_ivf_pq)
from repro.core.segment import (  # noqa: F401
    SegmentedBackend, SegmentedIndex)
