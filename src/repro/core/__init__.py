"""Core library: TopLoc (the paper's contribution) + the ANN substrate.

Public API:
  ivf      — bucketed-padded IVF index (build / search / search_cached)
  hnsw     — HNSW index (host build, JAX beam-query)
  toploc   — TopLoc sessions: centroid cache, |I0| refresh, entry points
  kmeans   — distributed balanced k-means (index build substrate)
  topk     — top-k select/merge utilities incl. distributed merge
  pq       — product-quantised posting lists (IVF-PQ, beyond-paper)
"""
from repro.core import hnsw, ivf, kmeans, pq, topk, toploc  # noqa: F401
from repro.core.pq import (  # noqa: F401
    IVFPQIndex, PQCodebook, build_ivf_pq)
