"""Synthetic CAsT-like conversational search workload.

No TREC data ships in this offline container, so the reproduction runs on
a *controlled* synthetic workload whose generative structure mirrors what
TopLoc exploits and what its benchmarks vary:

  * a topic-clustered corpus — documents concentrate around topic
    centroids on the unit sphere (mixture of von-Mises-Fisher-like
    gaussians, normalised);
  * conversations — a sequence of utterances around a start topic with
    per-turn *drift* and optional mid-conversation *topic shifts*
    ("easy" ≈ CAsT'19: low drift, no shifts; "hard" ≈ CAsT'20: higher
    drift + shifts — matching the paper's observation that CAsT'20
    queries are harder and centroid refresh matters there);
  * graded qrels — per query, the exhaustive-search top-20 docs with
    grades 3/2/1 by rank band (so Exact is the effectiveness upper bound
    exactly as in the paper's Table 1).

A parallel *text* view (topic-conditioned token sequences) feeds the
bi-encoder training example so the full paper pipeline — encode corpus,
build index, serve conversations — runs end to end on learned embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_docs: int = 100_000
    d: int = 64
    n_topics: int = 256
    doc_spread: float = 0.35       # doc noise around topic centre
    n_conversations: int = 25
    turns_per_conversation: int = 10
    query_drift: float = 0.15      # per-turn query noise
    walk_step: float = 0.05        # slow within-topic topic walk
    shift_prob: float = 0.0        # prob. of a hard topic shift per turn
    seed: int = 0


class Workload(NamedTuple):
    doc_vecs: np.ndarray           # (n_docs, d) float32, unit norm
    doc_topic: np.ndarray          # (n_docs,) int32
    topic_centers: np.ndarray      # (n_topics, d)
    conversations: np.ndarray      # (n_conv, turns, d) float32 queries
    conv_topics: np.ndarray        # (n_conv, turns) int32
    qrels: Dict[Tuple[int, int], Dict[int, int]]  # (conv, turn) → {doc: grade}


def _normalize(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def make_workload(cfg: WorkloadConfig) -> Workload:
    rng = np.random.default_rng(cfg.seed)
    centers = _normalize(rng.normal(size=(cfg.n_topics, cfg.d))
                         ).astype(np.float32)

    # corpus: zipf-ish topic popularity (real collections are skewed)
    pop = 1.0 / np.arange(1, cfg.n_topics + 1) ** 0.7
    pop /= pop.sum()
    doc_topic = rng.choice(cfg.n_topics, size=cfg.n_docs, p=pop
                           ).astype(np.int32)
    docs = _normalize(centers[doc_topic]
                      + cfg.doc_spread * rng.normal(
                          size=(cfg.n_docs, cfg.d))).astype(np.float32)

    # conversations
    convs = np.zeros((cfg.n_conversations, cfg.turns_per_conversation,
                      cfg.d), np.float32)
    conv_topics = np.zeros((cfg.n_conversations,
                            cfg.turns_per_conversation), np.int32)
    for c in range(cfg.n_conversations):
        topic = int(rng.integers(cfg.n_topics))
        anchor = centers[topic].copy()
        for t in range(cfg.turns_per_conversation):
            if t > 0 and rng.uniform() < cfg.shift_prob:
                topic = int(rng.integers(cfg.n_topics))
                anchor = centers[topic].copy()
            anchor = _normalize(anchor + cfg.walk_step *
                                rng.normal(size=cfg.d)).astype(np.float32)
            q = _normalize(anchor + cfg.query_drift *
                           rng.normal(size=cfg.d)).astype(np.float32)
            convs[c, t] = q
            conv_topics[c, t] = topic

    # graded qrels from exhaustive search (grade bands 3 / 2 / 1)
    qrels: Dict[Tuple[int, int], Dict[int, int]] = {}
    flat_q = convs.reshape(-1, cfg.d)
    scores = flat_q @ docs.T                       # (Q, n_docs)
    top20 = np.argsort(-scores, axis=-1)[:, :20]
    for qi in range(flat_q.shape[0]):
        c, t = divmod(qi, cfg.turns_per_conversation)
        grades: Dict[int, int] = {}
        for r, doc in enumerate(top20[qi]):
            grades[int(doc)] = 3 if r < 3 else (2 if r < 10 else 1)
        qrels[(c, t)] = grades
    return Workload(docs, doc_topic, centers, convs, conv_topics, qrels)


# ---------------------------------------------------------------------------
# IR metrics (MRR@k, NDCG@k — the paper's Table 1 metrics)
# ---------------------------------------------------------------------------

def mrr_at_k(ranked: np.ndarray, grades: Dict[int, int], k: int = 10,
             min_grade: int = 2) -> float:
    for r, doc in enumerate(ranked[:k]):
        if grades.get(int(doc), 0) >= min_grade:
            return 1.0 / (r + 1)
    return 0.0


def ndcg_at_k(ranked: np.ndarray, grades: Dict[int, int], k: int = 10
              ) -> float:
    dcg = sum((2 ** grades.get(int(doc), 0) - 1) / np.log2(r + 2)
              for r, doc in enumerate(ranked[:k]))
    ideal = sorted(grades.values(), reverse=True)[:k]
    idcg = sum((2 ** g - 1) / np.log2(r + 2) for r, g in enumerate(ideal))
    return float(dcg / idcg) if idcg > 0 else 0.0


def evaluate_run(run: np.ndarray, workload: Workload, k: int = 10
                 ) -> Dict[str, float]:
    """run: (n_conv, turns, ≥k) ranked doc ids → averaged metrics."""
    n_conv, turns, _ = run.shape
    mrr, n3, n10 = [], [], []
    for c in range(n_conv):
        for t in range(turns):
            g = workload.qrels[(c, t)]
            mrr.append(mrr_at_k(run[c, t], g, 10))
            n3.append(ndcg_at_k(run[c, t], g, 3))
            n10.append(ndcg_at_k(run[c, t], g, 10))
    return {"mrr@10": float(np.mean(mrr)), "ndcg@3": float(np.mean(n3)),
            "ndcg@10": float(np.mean(n10))}


# ---------------------------------------------------------------------------
# text view (for the bi-encoder pipeline)
# ---------------------------------------------------------------------------

def topic_text(rng: np.random.Generator, topic: int, n_topics: int,
               vocab: int, length: int, signal: float = 0.7) -> np.ndarray:
    """Token sequence: topic-specific band of the vocab + common noise."""
    band = vocab // (2 * n_topics)
    lo = vocab // 2 + topic * band
    topical = rng.integers(lo, lo + band, size=length)
    common = rng.integers(2, vocab // 2, size=length)
    use = rng.uniform(size=length) < signal
    toks = np.where(use, topical, common)
    toks[0] = 1                                    # CLS
    return toks.astype(np.int32)


def make_text_corpus(workload: Workload, vocab: int = 32768,
                     doc_len: int = 64, query_len: int = 16,
                     seed: int = 1):
    """Token views of docs + conversation queries (same topic structure)."""
    rng = np.random.default_rng(seed)
    n_topics = workload.topic_centers.shape[0]
    docs = np.stack([
        topic_text(rng, int(t), n_topics, vocab, doc_len)
        for t in workload.doc_topic])
    queries = np.stack([
        np.stack([topic_text(rng, int(workload.conv_topics[c, t]),
                             n_topics, vocab, query_len)
                  for t in range(workload.conv_topics.shape[1])])
        for c in range(workload.conv_topics.shape[0])])
    return docs, queries
