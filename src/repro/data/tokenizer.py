"""Deterministic hash tokenizer (no external vocab files).

Feature-hash words into a fixed id space — standard trick when a learned
subword vocab cannot ship. Ids: 0 = PAD, 1 = CLS, 2 = UNK, 3+ = hashed.
"""
from __future__ import annotations

import hashlib
from typing import Sequence, Tuple

import numpy as np

PAD, CLS, UNK = 0, 1, 2
_RESERVED = 3


def _hash_word(word: str, vocab: int) -> int:
    h = hashlib.blake2b(word.lower().encode("utf-8"), digest_size=8)
    return _RESERVED + int.from_bytes(h.digest(), "little") % (vocab - _RESERVED)


def encode(text: str, vocab: int, max_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """→ (ids (max_len,) int32, mask (max_len,) bool); CLS prepended."""
    words = text.split()
    ids = [CLS] + [_hash_word(w, vocab) for w in words][: max_len - 1]
    mask = np.zeros(max_len, bool)
    mask[: len(ids)] = True
    out = np.full(max_len, PAD, np.int32)
    out[: len(ids)] = ids
    return out, mask


def encode_batch(texts: Sequence[str], vocab: int, max_len: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    pairs = [encode(t, vocab, max_len) for t in texts]
    return (np.stack([p[0] for p in pairs]),
            np.stack([p[1] for p in pairs]))
