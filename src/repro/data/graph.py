"""Graph data substrate: generators, CSR, and a real neighbour sampler.

The ``minibatch_lg`` shape requires genuine fanout sampling (15-10 over a
114M-edge graph at full scale); ``NeighborSampler`` implements uniform
fanout sampling over CSR on the host — the standard GraphSAGE input
pipeline — emitting fixed-shape padded subgraphs for the JAX step.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Tuple

import numpy as np


class CSRGraph(NamedTuple):
    indptr: np.ndarray      # (N+1,) int64
    indices: np.ndarray     # (E,) int32 — in-neighbours of each node
    n_nodes: int


def edges_to_csr(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSRGraph:
    """CSR over incoming edges: row i lists sources j of edges j→i."""
    order = np.argsort(dst, kind="stable")
    s, d = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, d + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(indptr, s.astype(np.int32), n_nodes)


def sbm_graph(n_nodes: int, n_edges: int, n_blocks: int, p_in: float = 0.9,
              d_feat: int = 64, seed: int = 0
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Stochastic-block-model-ish graph with block-informative features.

    Returns (src, dst, features (N, d_feat), labels (N,)).
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_blocks, n_nodes).astype(np.int32)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    # with prob p_in rewire dst into the same block as src
    same = rng.uniform(size=n_edges) < p_in
    by_block: List[np.ndarray] = [np.where(labels == b)[0]
                                  for b in range(n_blocks)]
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    for b in range(n_blocks):
        m = same & (labels[src] == b)
        if by_block[b].size and m.any():
            dst[m] = rng.choice(by_block[b], size=int(m.sum()))
    proto = rng.normal(size=(n_blocks, d_feat)).astype(np.float32)
    feats = (proto[labels] +
             0.8 * rng.normal(size=(n_nodes, d_feat))).astype(np.float32)
    return src, dst, feats, labels


def molecule_batch(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                   n_classes: int, seed: int = 0):
    """Batched random 'molecules': label = parity of triangle-ish motif."""
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(batch, n_nodes, d_feat)).astype(np.float32)
    src = rng.integers(0, n_nodes, (batch, n_edges)).astype(np.int32)
    dst = rng.integers(0, n_nodes, (batch, n_edges)).astype(np.int32)
    node_mask = np.ones((batch, n_nodes), bool)
    edge_mask = np.ones((batch, n_edges), bool)
    deg = np.zeros((batch, n_nodes), np.int32)
    for b in range(batch):
        np.add.at(deg[b], dst[b], 1)
    labels = (deg.max(axis=1) % n_classes).astype(np.int32)
    return xs, src, dst, node_mask, edge_mask, labels


class SampledSubgraph(NamedTuple):
    """Fixed-shape padded k-hop subgraph (JAX-step ready)."""
    node_ids: np.ndarray    # (N_sub,) int32 global ids (-1 pad)
    feats: np.ndarray       # (N_sub, d)
    edge_src: np.ndarray    # (E_sub,) int32 local ids
    edge_dst: np.ndarray    # (E_sub,) int32 local ids
    edge_mask: np.ndarray   # (E_sub,) bool
    seed_mask: np.ndarray   # (N_sub,) bool — the labelled seed nodes
    labels: np.ndarray      # (N_sub,) int32 (-1 where not seed)


@dataclasses.dataclass
class NeighborSampler:
    """Uniform fanout sampler over CSR (GraphSAGE-style)."""
    graph: CSRGraph
    feats: np.ndarray
    labels: np.ndarray
    fanouts: Tuple[int, ...] = (15, 10)
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def max_nodes(self, batch_nodes: int) -> int:
        n = batch_nodes
        total = batch_nodes
        for f in self.fanouts:
            n = n * f
            total += n
        return total

    def max_edges(self, batch_nodes: int) -> int:
        n, total = batch_nodes, 0
        for f in self.fanouts:
            total += n * f
            n = n * f
        return total

    def sample(self, seeds: np.ndarray) -> SampledSubgraph:
        g, rng = self.graph, self._rng
        b = seeds.shape[0]
        nodes: List[np.ndarray] = [seeds.astype(np.int32)]
        srcs: List[np.ndarray] = []
        dsts: List[np.ndarray] = []
        masks: List[np.ndarray] = []
        frontier = seeds.astype(np.int64)
        fvalid = np.ones(frontier.size, bool)
        for f in self.fanouts:
            deg = g.indptr[frontier + 1] - g.indptr[frontier]
            can = fvalid & (deg > 0)
            pick = rng.integers(0, 2 ** 62, size=(frontier.size, f))
            off = pick % np.maximum(deg, 1)[:, None]
            nbr = g.indices[(g.indptr[frontier][:, None] + off).clip(
                0, max(g.indices.size - 1, 0))]
            nbr = np.where(can[:, None], nbr, -1).astype(np.int32)
            srcs.append(nbr.reshape(-1))
            dsts.append(np.repeat(frontier.astype(np.int32), f))
            masks.append(np.repeat(can, f))
            nodes.append(nbr.reshape(-1))
            frontier = np.maximum(nbr.reshape(-1), 0).astype(np.int64)
            fvalid = nbr.reshape(-1) >= 0

        n_max, e_max = self.max_nodes(b), self.max_edges(b)
        all_nodes = np.concatenate(nodes)
        all_src = np.concatenate(srcs)
        all_dst = np.concatenate(dsts)
        all_mask = np.concatenate(masks) & (all_src >= 0)

        uniq = np.unique(np.concatenate(
            [seeds.astype(np.int32),
             all_nodes[all_nodes >= 0].astype(np.int32)]))
        # local remap: seeds first (stable order), then the rest
        rest = uniq[~np.isin(uniq, seeds.astype(np.int32))]
        local_ids = np.concatenate([seeds.astype(np.int32), rest])
        sort_order = np.argsort(local_ids)
        sorted_ids = local_ids[sort_order]

        def to_local(a):
            a = np.asarray(a, np.int32)
            pos = np.clip(np.searchsorted(sorted_ids, a), 0,
                          sorted_ids.size - 1)
            found = sorted_ids[pos] == a
            return np.where(found, sort_order[pos], -1).astype(np.int32)

        src_l = to_local(np.where(all_mask, all_src, -1))
        dst_l = to_local(np.where(all_mask, all_dst, -1))
        emask = all_mask & (src_l >= 0) & (dst_l >= 0)

        n_sub = max(n_max, local_ids.size)
        node_ids = np.full(n_sub, -1, np.int32)
        node_ids[: local_ids.size] = local_ids
        feats = np.zeros((n_sub, self.feats.shape[1]), np.float32)
        feats[: local_ids.size] = self.feats[local_ids]
        labels = np.full(n_sub, -1, np.int32)
        labels[: b] = self.labels[seeds]
        seed_mask = np.zeros(n_sub, bool)
        seed_mask[: b] = True

        e_sub = max(e_max, src_l.size)
        es = np.zeros(e_sub, np.int32)
        ed = np.zeros(e_sub, np.int32)
        em = np.zeros(e_sub, bool)
        es[: src_l.size] = np.where(emask, src_l, 0)
        ed[: dst_l.size] = np.where(emask, dst_l, 0)
        em[: emask.size] = emask
        return SampledSubgraph(node_ids, feats, es, ed, em, seed_mask, labels)

    def sample_trees(self, seeds: np.ndarray):
        """Per-seed sampling-tree format (the ``minibatch_lg`` input):
        each seed gets its own padded tree — node 0 is the seed, then
        hop-1 neighbours, then hop-2, …; edges point child → parent.
        Trees are disjoint by construction, so the batch dim shards over
        data axes with zero cross-shard edges (DESIGN.md §5).

        Returns dict(x (B, Tn, d), edge_src/edge_dst/edge_mask (B, Te),
        labels (B,)) with Tn = 1+f1+f1·f2+…, Te = Tn-1.
        """
        g, rng = self.graph, self._rng
        b = seeds.shape[0]
        tn = self.max_nodes(1)
        te = tn - 1
        d = self.feats.shape[1]
        x = np.zeros((b, tn, d), np.float32)
        es = np.zeros((b, te), np.int32)
        ed = np.zeros((b, te), np.int32)
        em = np.zeros((b, te), bool)
        labels = self.labels[seeds].astype(np.int32)

        for bi, seed in enumerate(seeds):
            nodes = [int(seed)]
            valid = [True]
            frontier = [(0, int(seed), True)]       # (local id, gid, valid)
            e = 0
            for f in self.fanouts:
                nxt = []
                for (pl, pg, pv) in frontier:
                    lo, hi = g.indptr[pg], g.indptr[pg + 1]
                    deg = hi - lo
                    for _ in range(f):
                        ok = pv and deg > 0
                        gid = int(g.indices[lo + rng.integers(deg)]
                                  ) if ok else 0
                        cl = len(nodes)
                        nodes.append(gid)
                        valid.append(ok)
                        es[bi, e] = cl
                        ed[bi, e] = pl
                        em[bi, e] = ok
                        e += 1
                        nxt.append((cl, gid, ok))
                frontier = nxt
            ids = np.asarray(nodes, np.int64)
            x[bi] = np.where(np.asarray(valid)[:, None],
                             self.feats[ids], 0.0)
        return {"x": x, "edge_src": es, "edge_dst": ed, "edge_mask": em,
                "labels": labels}
