"""Host→device input pipeline: batching, shuffling, shard-aware feeding.

Deliberately simple and deterministic (seeded) — the point is a real
pipeline boundary (host numpy → sharded device arrays) with double
buffering, not a dataset framework.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_iterator(arrays: Dict[str, np.ndarray], batch_size: int, *,
                   shuffle: bool = True, seed: int = 0,
                   drop_remainder: bool = True
                   ) -> Iterator[Dict[str, np.ndarray]]:
    """Epoch-looping iterator over equally-indexed host arrays."""
    n = len(next(iter(arrays.values())))
    rng = np.random.default_rng(seed)
    while True:
        idx = rng.permutation(n) if shuffle else np.arange(n)
        stop = n - (n % batch_size) if drop_remainder else n
        for s in range(0, stop, batch_size):
            take = idx[s: s + batch_size]
            yield {k: v[take] for k, v in arrays.items()}


def shard_batch(batch: Dict[str, np.ndarray], mesh: Optional[Mesh],
                spec_fn: Optional[Callable[[str, np.ndarray], P]] = None
                ) -> Dict[str, jax.Array]:
    """Place a host batch on device(s). Default spec: batch dim over all
    data-like mesh axes (('pod',) if present, then 'data')."""
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def default_spec(name: str, arr: np.ndarray) -> P:
        return P(data_axes) if arr.ndim >= 1 else P()

    spec_fn = spec_fn or default_spec
    return {
        k: jax.device_put(v, NamedSharding(mesh, spec_fn(k, v)))
        for k, v in batch.items()
    }


class Prefetcher:
    """One-deep background prefetch (overlaps host batch prep with step)."""

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._it = it
        self._q: "collections.deque[Any]" = collections.deque()
        self._depth = depth
        self._lock = threading.Lock()
        self._stop = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._sema = threading.Semaphore(0)
        self._thread.start()

    def _fill(self):
        for item in self._it:
            while True:
                with self._lock:
                    if len(self._q) < self._depth:
                        self._q.append(item)
                        self._sema.release()
                        break
                if self._stop:
                    return
                threading.Event().wait(0.001)
            if self._stop:
                return

    def __iter__(self):
        return self

    def __next__(self):
        self._sema.acquire()
        with self._lock:
            return self._q.popleft()

    def close(self):
        self._stop = True
