"""Input pipelines: synthetic CAsT-like workload, tokenizer, graphs."""
from repro.data import graph, pipeline, synthetic, tokenizer  # noqa: F401
