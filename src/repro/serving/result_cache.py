"""Session-level historical-embedding result cache.

Frieder et al., *Caching Historical Embeddings in Conversational
Search*, observe that the same topical locality TopLoc exploits for
index pruning also makes per-conversation *result* caches effective:
within a conversation, consecutive utterances are near-duplicates in
embedding space, so the documents retrieved for turn j-1 usually contain
the answer for turn j.  This module caches, per session, the previous
answering turn:

    q_vec      (d,)    — the query embedding the entry is anchored to
    doc_ids    (k,)    — the turn's top-k document ids (-1 = empty)
    doc_scores (k,)    — their scores under ``q_vec``
    doc_vecs   (k, d)  — the *historical embeddings* of those documents
    valid      ()      — entry holds real state

A new turn first probes the cache: when ``cos(q_new, q_vec) >=
threshold`` the turn is answered **without touching the backend** by
re-scoring the cached document embeddings under the new query (or, when
the backend keeps no flat corpus, by replaying the cached ranking);
otherwise the backend runs and the entry is refreshed with the new
turn's results.  ``threshold <= 0`` disables the cache entirely — the
engines then execute the exact uncached program, bit for bit
(tests/test_result_cache.py pins cache-off == cache-absent and
threshold-0 == uncached).

Numerics follow the repo's batch-size-stability rule: the cosine
similarity and the re-scoring are explicit multiply-reduce contractions,
so the sequential engine (B=1 probes) and the batched engine (slab
gather → one fused probe per wave) stay bit-identical with the cache
enabled.

Storage reuses ``sessions.SessionStore`` as the slab container: the
batched engine keys cache rows by the *same* slot ids as the session
slab and registers a slot-freed listener so an evicted/released
conversation can never leak its entries to the slot's next occupant.
"""
from __future__ import annotations

import functools
import threading
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.concurrency import guarded_by
from repro.serving import sessions as _sessions


class CacheEntry(NamedTuple):
    """One session's cached turn (device resident; a pytree)."""
    q_vec: jax.Array       # (d,) float — anchor query embedding
    doc_ids: jax.Array     # (k,) int32 — cached top-k ids, -1 = empty
    doc_scores: jax.Array  # (k,) float — scores under q_vec
    doc_vecs: jax.Array    # (k, d) float — historical doc embeddings
    valid: jax.Array       # () bool


def entry_template(d: int, k: int, dtype=jnp.float32) -> CacheEntry:
    return CacheEntry(
        q_vec=jnp.zeros((d,), dtype),
        doc_ids=jnp.full((k,), -1, jnp.int32),
        doc_scores=jnp.zeros((k,), dtype),
        doc_vecs=jnp.zeros((k, d), dtype),
        valid=jnp.zeros((), bool))


@functools.partial(jax.jit, static_argnames=("out_k", "threshold",
                                             "rescore"))
def probe(entries: CacheEntry, q: jax.Array, *, out_k: int,
          threshold: float, rescore: bool
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Probe a batch of cache entries. entries: leading (B,); q: (B, d).

    Returns (hit (B,) bool, scores (B, out_k), ids (B, out_k)).  A hit
    requires a valid entry whose anchor query's cosine similarity to the
    new query reaches ``threshold``.  With ``rescore`` the cached
    document embeddings (``depth`` per entry, depth >= out_k) are
    re-scored under the new query (exact dot products — the same
    multiply-reduce shape as the IVF-PQ re-rank) and the best out_k
    returned; without, the cached ranking is replayed as-is.
    """
    qq = jnp.sum(q * q, axis=-1)
    cc = jnp.sum(entries.q_vec * entries.q_vec, axis=-1)
    dot = jnp.sum(entries.q_vec * q, axis=-1)
    sim = dot * jax.lax.rsqrt(jnp.maximum(qq * cc, 1e-30))
    hit = entries.valid & (sim >= jnp.asarray(threshold, sim.dtype))
    if rescore:
        scores = jnp.sum(entries.doc_vecs * q[:, None, :], axis=-1)
        scores = jnp.where(entries.doc_ids >= 0, scores, -jnp.inf)
        v, pos = jax.lax.top_k(scores, out_k)
        ids = jnp.take_along_axis(entries.doc_ids, pos, axis=-1)
    else:
        # cached scores are already sorted — the prefix is the top-out_k
        v = entries.doc_scores[..., :out_k]
        ids = entries.doc_ids[..., :out_k]
    return hit, v, ids


@jax.jit
def _make_entries_rescore(q: jax.Array, v: jax.Array, ids: jax.Array,
                          corpus: jax.Array) -> CacheEntry:
    vecs = corpus[jnp.maximum(ids, 0)]
    vecs = jnp.where((ids >= 0)[..., None], vecs, 0.0)
    return CacheEntry(q, ids.astype(jnp.int32), v, vecs,
                      jnp.ones(q.shape[:-1], bool))


@jax.jit
def _make_entries_static(q: jax.Array, v: jax.Array, ids: jax.Array
                         ) -> CacheEntry:
    b, k = ids.shape
    d = q.shape[-1]
    return CacheEntry(q, ids.astype(jnp.int32), v,
                      jnp.zeros((b, k, d), q.dtype),
                      jnp.ones((b,), bool))


@functools.partial(jax.jit, static_argnames=("out_k", "threshold",
                                             "rescore"))
def fuse_wave(entries: CacheEntry, q: jax.Array, v: jax.Array,
              i: jax.Array, sess_old: Any, sess_new: Any, stats: Any,
              corpus: Optional[jax.Array], *, out_k: int, threshold: float,
              rescore: bool):
    """One fused cache pass for a batched wave.

    ``v``/``i`` are the backend's depth-wide results (depth >= out_k).
    Probes the gathered ``entries`` against the wave queries and, per
    hit row, substitutes the cached answer, zeroes the work counters
    (a hit pays no backend work — the documented scalar-cost semantics
    of ``TurnStats``), keeps the *old* session state (the sequential
    engine never steps a session on a hit), and keeps the old cache
    entry; miss rows adopt the backend results (returned sliced to
    out_k) and a refreshed depth-wide entry.

    Returns (v (B, out_k), i (B, out_k), sess, stats, entries, hit).
    """
    hit, cv, ci = probe(entries, q, out_k=out_k, threshold=threshold,
                        rescore=rescore)
    fresh = (_make_entries_rescore(q, v, i, corpus) if rescore
             else _make_entries_static(q, v, i))
    h1 = hit[:, None]
    v = jnp.where(h1, cv, v[..., :out_k])
    i = jnp.where(h1, ci, i[..., :out_k])
    b = q.shape[0]
    z = jnp.zeros((b,), jnp.int32)
    zero_stats = type(stats)(z, z, z, z, jnp.full((b,), -1, jnp.int32),
                             jnp.zeros((b,), bool))
    stats = jax.tree.map(lambda zs, s: jnp.where(hit, zs, s),
                         zero_stats, stats)

    def row_sel(old, new):
        mask = hit.reshape((b,) + (1,) * (new.ndim - 1))
        return jnp.where(mask, old, new)

    sess = jax.tree.map(row_sel, sess_old, sess_new)
    entries = jax.tree.map(row_sel, entries, fresh)
    return v, i, sess, stats, entries, hit


@guarded_by("_lock", "hits", "misses", "_entries")
class ResultCache:
    """Per-session result cache for both serving engines.

    Sequential mode (``n_slots=None``): entries keyed by conversation id
    in a host dict (one device row each).  Batched mode: a slab of
    ``n_slots`` rows + trash slot, addressed by the engine's session
    slot ids (``gather``/``fuse``/``scatter``); ``clear_slot`` is the
    ``SessionStore`` slot-freed listener.

    ``corpus`` (n, d) enables historical-embedding re-scoring on hits;
    without it the cache replays the stored ranking (scores stale by one
    turn's drift).  ``depth >= k`` rows are cached per session (the
    engines over-fetch the backend to depth and serve/record only the
    top-k), so a hit rescoring a deeper candidate pool loses less
    recall — the Frieder et al. design.  ``threshold <= 0`` never hits
    (``enabled`` False) — the engines skip the cache path entirely,
    keeping disabled runs bit-identical to cache-absent ones.

    Thread safety: the hit/miss counters and the sequential-mode entry
    dict are guarded by an internal lock — in batched serving,
    ``count_hits`` runs on the pump thread at wave retirement while
    ``invalidate_docs`` arrives on client threads through
    ``delete_documents``.  Device work (``probe``/``fuse_wave``) runs
    outside the lock; slab-mode row state is guarded by the underlying
    ``SessionStore``'s own lock.
    """

    def __init__(self, *, d: int, k: int, threshold: float,
                 depth: Optional[int] = None,
                 corpus: Optional[jax.Array] = None,
                 n_slots: Optional[int] = None, mesh: Any = None,
                 dtype=jnp.float32):
        self.threshold = float(threshold)
        self.k = int(k)
        self.depth = max(int(depth or k), int(k))
        self.corpus = corpus
        self.rescore = corpus is not None
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self._template = entry_template(d, self.depth, dtype)
        self._entries: Dict[str, CacheEntry] = {}
        self._slab: Optional[_sessions.SessionStore] = None
        if n_slots is not None:
            self._slab = _sessions.SessionStore(self._template, n_slots,
                                                mesh=mesh)

    @property
    def enabled(self) -> bool:
        return self.threshold > 0.0

    def stats(self) -> Dict[str, float]:
        with self._lock:
            total = self.hits + self.misses
            return {"hits": self.hits, "misses": self.misses,
                    "hit_rate": (self.hits / total) if total else 0.0}

    def count_hits(self, hit: np.ndarray, b: int) -> None:
        """Fold a wave's materialized hit mask (first ``b`` rows are
        real requests) into the counters.  Separate from ``fuse`` so the
        continuous-batching engine can defer the blocking ``device_get``
        of the mask to wave retirement instead of the launch path."""
        n_hit = int(np.asarray(hit)[:b].sum())
        with self._lock:
            self.hits += n_hit
            self.misses += b - n_hit

    # -- sequential (dict) mode ---------------------------------------

    def lookup(self, conv_id: str, q: jax.Array
               ) -> Optional[Tuple[jax.Array, jax.Array]]:
        """Probe ``conv_id``'s entry with q (d,); (scores (k,), ids
        (k,)) on a hit, None (counted as a miss) otherwise."""
        with self._lock:
            entry = self._entries.get(conv_id)
        if entry is None:
            with self._lock:
                self.misses += 1
            return None
        batched = jax.tree.map(lambda a: a[None], entry)
        hit, v, ids = probe(batched, q[None], out_k=self.k,
                            threshold=self.threshold,
                            rescore=self.rescore)
        if bool(jax.device_get(hit[0])):
            with self._lock:
                self.hits += 1
            return v[0], ids[0]
        with self._lock:
            self.misses += 1
        return None

    def update(self, conv_id: str, q: jax.Array, v: jax.Array,
               ids: jax.Array) -> None:
        """Refresh ``conv_id``'s entry with the turn's backend answer
        (``v``/``ids`` depth-wide)."""
        fresh = (_make_entries_rescore(q[None], v[None], ids[None],
                                       self.corpus) if self.rescore
                 else _make_entries_static(q[None], v[None], ids[None]))
        row = jax.tree.map(lambda a: a[0], fresh)
        with self._lock:
            self._entries[conv_id] = row

    def invalidate(self, conv_id: str) -> None:
        with self._lock:
            self._entries.pop(conv_id, None)

    def invalidate_docs(self, doc_ids) -> int:
        """Corpus-tombstone sweep: drop every entry whose cached
        candidate pool intersects ``doc_ids``, in both storage modes —
        after this, no later hit can serve or re-score a deleted
        document.  The engines call it on every ``delete_documents``
        (each corpus-epoch bump); returns entries/rows dropped.
        """
        dead = np.atleast_1d(np.asarray(doc_ids, np.int64))
        if dead.size == 0:
            return 0
        n = 0
        with self._lock:                               # sequential mode
            drop = [cid for cid, e in self._entries.items()
                    if np.isin(np.asarray(e.doc_ids), dead).any()]
            for cid in drop:
                del self._entries[cid]
            n += len(drop)
        if self._slab is not None:                     # slab mode
            slab = self._slab.slab
            ids = np.asarray(jax.device_get(slab.doc_ids))
            valid = np.asarray(jax.device_get(slab.valid))
            rows = np.flatnonzero(valid & np.isin(ids, dead).any(axis=-1))
            if rows.size:
                self._slab.clear(rows.tolist())
            n += int(rows.size)
        return n

    # -- batched (slab) mode ------------------------------------------

    def gather(self, slots: Sequence[int]) -> CacheEntry:
        return self._slab.gather(slots)

    def scatter(self, slots: Sequence[int], entries: CacheEntry) -> None:
        self._slab.scatter(slots, entries)

    def clear_slot(self, slot: int) -> None:
        """Slot-freed listener: wipe the slot's cache row."""
        self._slab.clear([slot])

    def fuse(self, slots: Sequence[int], q, v, i, sess_old, sess_new,
             stats):
        """Batched-wave cache pass (see ``fuse_wave``); scatters the
        selected entries back and returns (v (B,k), i (B,k), sess,
        stats, hit (B,) ndarray)."""
        entries = self.gather(slots)
        v, i, sess, stats, entries, hit = fuse_wave(
            entries, q, v, i, sess_old, sess_new, stats, self.corpus,
            out_k=self.k, threshold=self.threshold, rescore=self.rescore)
        self.scatter(slots, entries)
        return v, i, sess, stats, hit
