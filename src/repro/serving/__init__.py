"""Conversational serving runtime: session engine + scheduler."""
from repro.serving import engine, scheduler  # noqa: F401
