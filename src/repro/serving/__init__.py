"""Conversational serving runtime: session engines + scheduler + router.

Sequential path: ``engine.ConversationalSearchEngine`` (one turn per
dispatch).  Batched path: ``engine.BatchedConversationalSearchEngine``
(continuously micro-batched flushes over a device-resident
``sessions.SessionStore`` slab).  Replicated path:
``router.ReplicatedSearchEngine`` (session-affine routing over the
replica axis of a 2-D corpus mesh, with cross-replica hedging for
stateless traffic).  ``scheduler`` supplies the batching/hedging
front door.
"""
from repro.serving import (  # noqa: F401
    engine, result_cache, router, scheduler, sessions)
from repro.serving.engine import (  # noqa: F401
    BatchedConversationalSearchEngine, ConversationalSearchEngine,
    ServingConfig, TurnRecord)
from repro.serving.result_cache import (  # noqa: F401
    CacheEntry, ResultCache)
from repro.serving.router import ReplicatedSearchEngine  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    HedgedExecutor, MicroBatcher, Request)
from repro.serving.sessions import (  # noqa: F401
    SessionStore, hnsw_session_store, ivf_pq_session_store,
    ivf_session_store, store_for_backend)
