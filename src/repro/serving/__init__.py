"""Conversational serving runtime: session engines + scheduler.

Sequential path: ``engine.ConversationalSearchEngine`` (one turn per
dispatch).  Batched path: ``engine.BatchedConversationalSearchEngine``
(micro-batched flushes over a device-resident ``sessions.SessionStore``
slab).  ``scheduler`` supplies the batching/hedging front door.
"""
from repro.serving import engine, result_cache, scheduler, sessions  # noqa: F401,E501
from repro.serving.engine import (  # noqa: F401
    BatchedConversationalSearchEngine, ConversationalSearchEngine,
    ServingConfig, TurnRecord)
from repro.serving.result_cache import (  # noqa: F401
    CacheEntry, ResultCache)
from repro.serving.scheduler import (  # noqa: F401
    HedgedExecutor, MicroBatcher, Request)
from repro.serving.sessions import (  # noqa: F401
    SessionStore, hnsw_session_store, ivf_pq_session_store,
    ivf_session_store, store_for_backend)
