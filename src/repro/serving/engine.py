"""Conversational serving engine — TopLoc as a first-class feature.

Python-side session orchestration around the jitted core:
  * per-conversation TopLoc state (IVF centroid cache / HNSW entry
    point) held device-resident between turns;
  * strategy selected per deployment config (plain / toploc / exact,
    IVF / IVF-PQ / HNSW backend — IVF-PQ scans PQ-compressed lists via
    ADC and exact-re-ranks the top-R candidates);
  * work + latency accounting per turn (feeds benchmarks/table1.py);
  * optional query encoder in front (full paper pipeline), and an item
    corpus front-end for the two-tower ``retrieval_cand`` serving shape.

Two engines share the accounting:

``ConversationalSearchEngine`` — one turn per dispatch, sessions in a
Python dict.  The reference implementation and the oracle the batched
path is tested against.

``BatchedConversationalSearchEngine`` — the serving-scale path: requests
enter a ``scheduler.MicroBatcher``; each flush drains up to ``max_batch``
requests, pads to the next shape bucket, gathers the sessions from a
device-resident ``sessions.SessionStore`` slab, runs ONE jitted batched
TopLoc step (``toploc.ivf_step_batch`` / ``hnsw_step_batch``) with an
``is_first`` mask for rows whose conversation has no cached state, and
scatters the updated sessions back.  A flush containing several turns of
the same conversation is split into consecutive waves (a later turn must
observe the earlier turn's updated cache), so one device batch never
holds a conversation twice.  Per-turn ``TurnStats`` are recorded exactly
as the sequential engine records them; batched results are bit-identical
to the sequential path (tests/test_serving_batched.py).

Sessions are sticky: at multi-host scale the router pins a conversation
to one data-parallel group so its cache stays local (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hnsw as _hnsw
from repro.core import ivf as _ivf
from repro.core import pq as _pq
from repro.core import toploc
from repro.distributed import retrieval as _retrieval
from repro.serving import sessions as _sessions
from repro.serving.scheduler import MicroBatcher, Request


@dataclasses.dataclass
class ServingConfig:
    backend: str = "ivf"          # "ivf" | "ivf_pq" | "hnsw" | "exact"
    strategy: str = "toploc"      # "toploc" | "toploc+" | "plain"
    k: int = 10
    # IVF / IVF-PQ
    nprobe: int = 64
    h: int = 1024                 # cached centroids (TopLoc_IVF)
    alpha: float = 0.1            # refresh threshold (TopLoc_IVF+)
    rerank: int = 64              # exact re-rank depth (IVF-PQ)
    # HNSW
    ef_search: int = 64
    up: int = 2                   # first-turn ef upscaling
    # corpus sharding (distributed.retrieval): shards > 1 partitions the
    # posting lists / vector corpus over a device mesh; results stay
    # bit-identical to single-device (tests/test_sharded_retrieval.py)
    shards: int = 0               # 0/1 = single device
    mesh: Any = None              # prebuilt jax Mesh (overrides shards)
    shard_axis: str = "model"


@dataclasses.dataclass
class TurnRecord:
    conv_id: str
    turn: int
    latency_s: float
    centroid_dists: int
    list_dists: int
    graph_dists: int
    refreshed: bool
    i0: int
    code_dists: int = 0           # PQ ADC evaluations (ivf_pq backend)


class _EngineAccounting:
    """Shared per-turn records + summary (sequential and batched engines)."""

    records: List[TurnRecord]

    def summary(self) -> Dict[str, float]:
        if not self.records:
            return {}
        lat = np.asarray([r.latency_s for r in self.records])
        return {
            "turns": len(self.records),
            "mean_latency_ms": float(lat.mean() * 1e3),
            "p95_latency_ms": float(np.percentile(lat, 95) * 1e3),
            "mean_centroid_dists": float(np.mean(
                [r.centroid_dists for r in self.records])),
            "mean_list_dists": float(np.mean(
                [r.list_dists for r in self.records])),
            "mean_graph_dists": float(np.mean(
                [r.graph_dists for r in self.records])),
            "mean_code_dists": float(np.mean(
                [r.code_dists for r in self.records])),
            "refresh_rate": float(np.mean(
                [r.refreshed for r in self.records[1:]] or [0.0])),
        }


def _check_indexes(config: ServingConfig, ivf_index, hnsw_index, doc_vecs,
                   ivf_pq_index=None):
    if config.backend == "ivf" and ivf_index is None:
        raise ValueError("ivf backend needs ivf_index")
    if config.backend == "ivf_pq" and ivf_pq_index is None:
        raise ValueError("ivf_pq backend needs ivf_pq_index")
    if config.backend == "hnsw" and hnsw_index is None:
        raise ValueError("hnsw backend needs hnsw_index")
    if config.backend == "exact" and doc_vecs is None:
        raise ValueError("exact backend needs doc_vecs")


class _ShardedRetrievalMixin:
    """Corpus-mesh wiring shared by both engines.

    ``_setup_sharding`` resolves the ``ServingConfig`` mesh/shards knob,
    re-places the active backend's index on the mesh (posting lists /
    vector corpus sharded, centroids + session math replicated) and
    builds the scan callables the strategy paths inject into
    ``core.toploc``.  With no mesh configured every ``self._*scan``
    stays ``None`` and the toploc entry points fall back to their local
    scans — the single-device behaviour is untouched.
    """

    def _setup_sharding(self, config: ServingConfig) -> None:
        mesh = config.mesh
        if mesh is None and config.shards and config.shards > 1:
            mesh = _retrieval.retrieval_mesh(config.shards,
                                             axis=config.shard_axis)
        self.mesh = mesh
        self._ivf_scan = self._pq_scan = self._hnsw_search = None
        if mesh is None or config.backend == "exact":
            return
        ax = config.shard_axis
        if config.backend == "ivf":
            self.ivf = _retrieval.shard_ivf_index(mesh, self.ivf, axis=ax)
            self._ivf_scan = _retrieval.ShardedIVFScan(mesh, ax)
        elif config.backend == "ivf_pq":
            self.ivf_pq = _retrieval.shard_ivf_pq_index(mesh, self.ivf_pq,
                                                        axis=ax)
            self._pq_scan = _retrieval.ShardedPQScan(mesh, ax)
        elif config.backend == "hnsw":
            self.hnsw = _retrieval.shard_hnsw_index(mesh, self.hnsw,
                                                    axis=ax)
            self._hnsw_search = _retrieval.ShardedHNSWSearch(mesh, ax)


class ConversationalSearchEngine(_EngineAccounting, _ShardedRetrievalMixin):
    def __init__(self, config: ServingConfig, *,
                 ivf_index: Optional[_ivf.IVFIndex] = None,
                 hnsw_index: Optional[_hnsw.HNSWIndex] = None,
                 ivf_pq_index: Optional[_pq.IVFPQIndex] = None,
                 doc_vecs: Optional[jax.Array] = None):
        self.cfg = config
        self.ivf = ivf_index
        self.hnsw = hnsw_index
        self.ivf_pq = ivf_pq_index
        self.doc_vecs = doc_vecs
        _check_indexes(config, ivf_index, hnsw_index, doc_vecs,
                       ivf_pq_index)
        self._setup_sharding(config)
        self.sessions: Dict[str, Any] = {}
        self.turn_count: Dict[str, int] = {}
        self.records: List[TurnRecord] = []

    # -- public API ---------------------------------------------------

    def query(self, conv_id: str, qvec: jax.Array
              ) -> Tuple[np.ndarray, np.ndarray]:
        """One conversational turn. qvec (d,). Returns (scores, doc_ids)."""
        t0 = time.perf_counter()
        cfg = self.cfg
        turn = self.turn_count.get(conv_id, 0)

        if cfg.backend == "exact":
            v, i = _ivf.exact_search(self.doc_vecs, qvec[None], cfg.k)
            v, i = v[0], i[0]
            stats = None
        elif cfg.backend == "ivf":
            v, i, stats = self._ivf_turn(conv_id, qvec, turn)
        elif cfg.backend == "ivf_pq":
            v, i, stats = self._ivf_pq_turn(conv_id, qvec, turn)
        else:
            v, i, stats = self._hnsw_turn(conv_id, qvec, turn)

        v = np.asarray(jax.device_get(v))
        i = np.asarray(jax.device_get(i))
        dt = time.perf_counter() - t0
        self.turn_count[conv_id] = turn + 1
        if stats is not None:
            self.records.append(TurnRecord(
                conv_id, turn, dt,
                int(stats.centroid_dists), int(stats.list_dists),
                int(stats.graph_dists), bool(stats.refreshed),
                int(stats.i0), int(stats.code_dists)))
        else:
            self.records.append(TurnRecord(conv_id, turn, dt,
                                           0, 0, 0, False, -1))
        return v, i

    def end_conversation(self, conv_id: str) -> None:
        self.sessions.pop(conv_id, None)
        self.turn_count.pop(conv_id, None)

    # -- strategy paths -------------------------------------------------

    def _ivf_turn(self, conv_id, qvec, turn):
        cfg = self.cfg
        if cfg.strategy == "plain":
            v, i, st = _ivf.search(self.ivf, qvec[None],
                                   nprobe=cfg.nprobe, k=cfg.k,
                                   scan=self._ivf_scan)
            stats = toploc.TurnStats(
                jnp.asarray(self.ivf.p, jnp.int32), st.list_dists[0],
                jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
                jnp.asarray(-1, jnp.int32), jnp.asarray(False))
            return v[0], i[0], stats
        if turn == 0 or conv_id not in self.sessions:
            v, i, sess, stats = toploc.ivf_start(
                self.ivf, qvec, h=cfg.h, nprobe=cfg.nprobe, k=cfg.k,
                scan=self._ivf_scan)
            self.sessions[conv_id] = sess
            return v, i, stats
        alpha = cfg.alpha if cfg.strategy == "toploc+" else -1.0
        v, i, sess, stats = toploc.ivf_step(
            self.ivf, self.sessions[conv_id], qvec,
            nprobe=cfg.nprobe, k=cfg.k, alpha=alpha, scan=self._ivf_scan)
        self.sessions[conv_id] = sess
        return v, i, stats

    def _ivf_pq_turn(self, conv_id, qvec, turn):
        cfg = self.cfg
        if cfg.strategy == "plain":
            # B=1 call into the (batch-size-stable) batched path keeps
            # sequential and batched plain serving bit-identical
            v, i, st = toploc.ivf_pq_plain_batch(
                self.ivf_pq, qvec[None], nprobe=cfg.nprobe, k=cfg.k,
                rerank=cfg.rerank, scan=self._pq_scan)
            return v[0], i[0], jax.tree.map(lambda a: a[0], st)
        if turn == 0 or conv_id not in self.sessions:
            v, i, sess, stats = toploc.ivf_pq_start(
                self.ivf_pq, qvec, h=cfg.h, nprobe=cfg.nprobe, k=cfg.k,
                rerank=cfg.rerank, scan=self._pq_scan)
            self.sessions[conv_id] = sess
            return v, i, stats
        alpha = cfg.alpha if cfg.strategy == "toploc+" else -1.0
        v, i, sess, stats = toploc.ivf_pq_step(
            self.ivf_pq, self.sessions[conv_id], qvec,
            nprobe=cfg.nprobe, k=cfg.k, alpha=alpha, rerank=cfg.rerank,
            scan=self._pq_scan)
        self.sessions[conv_id] = sess
        return v, i, stats

    def _hnsw_turn(self, conv_id, qvec, turn):
        cfg = self.cfg
        if cfg.strategy == "plain":
            v, i, nd = (self._hnsw_search or _hnsw.search)(
                self.hnsw, qvec[None], ef=cfg.ef_search, k=cfg.k)
            stats = toploc.TurnStats(
                jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
                nd[0], jnp.asarray(0, jnp.int32),
                jnp.asarray(-1, jnp.int32), jnp.asarray(False))
            return v[0], i[0], stats
        if turn == 0 or conv_id not in self.sessions:
            v, i, sess, stats = toploc.hnsw_start(
                self.hnsw, qvec, ef=cfg.ef_search, k=cfg.k, up=cfg.up,
                search=self._hnsw_search)
            self.sessions[conv_id] = sess
            return v, i, stats
        v, i, sess, stats = toploc.hnsw_step(
            self.hnsw, self.sessions[conv_id], qvec,
            ef=cfg.ef_search, k=cfg.k, search=self._hnsw_search)
        self.sessions[conv_id] = sess
        return v, i, stats

class BatchedConversationalSearchEngine(_EngineAccounting,
                                        _ShardedRetrievalMixin):
    """Micro-batched multi-conversation serving front door.

    Requests flow ``submit() → MicroBatcher queue → flush → one padded
    device batch → scatter sessions → resolve futures``.  See the module
    docstring for the flush/wave semantics.

    ``n_slots`` bounds resident conversations; the LRU conversation is
    evicted when a new one arrives at full occupancy and is rebuilt
    (first-turn semantics) if it ever returns.
    """

    def __init__(self, config: ServingConfig, *,
                 ivf_index: Optional[_ivf.IVFIndex] = None,
                 hnsw_index: Optional[_hnsw.HNSWIndex] = None,
                 ivf_pq_index: Optional[_pq.IVFPQIndex] = None,
                 doc_vecs: Optional[jax.Array] = None,
                 n_slots: int = 256, max_batch: int = 32,
                 max_wait_s: float = 0.002,
                 buckets: Sequence[int] = (1, 2, 4, 8, 16, 32)):
        self.cfg = config
        self.ivf = ivf_index
        self.hnsw = hnsw_index
        self.ivf_pq = ivf_pq_index
        self.doc_vecs = doc_vecs
        _check_indexes(config, ivf_index, hnsw_index, doc_vecs,
                       ivf_pq_index)
        self._setup_sharding(config)
        # a wave holds up to max_batch distinct conversations, each
        # needing its own live slot — fewer slots would make acquire()
        # evict a conversation acquired earlier in the SAME wave and
        # scatter two rows into one slot (silent session corruption)
        if config.backend != "exact" and n_slots < max_batch:
            raise ValueError(
                f"n_slots ({n_slots}) must be >= max_batch ({max_batch})")
        # ensure the bucket table covers max_batch so a full wave never
        # pads to a bucket smaller than itself
        buckets = tuple(sorted(set(buckets) | {max_batch}))
        # session slabs replicate over the corpus mesh (sessions are the
        # replicated TopLoc state; only the corpus shards)
        if config.backend == "ivf":
            self.store = _sessions.ivf_session_store(
                self.ivf, h=config.h, nprobe=config.nprobe,
                n_slots=n_slots, mesh=self.mesh)
        elif config.backend == "ivf_pq":
            self.store = _sessions.ivf_pq_session_store(
                self.ivf_pq, h=config.h, nprobe=config.nprobe,
                n_slots=n_slots, mesh=self.mesh)
        elif config.backend == "hnsw":
            self.store = _sessions.hnsw_session_store(
                self.hnsw, n_slots=n_slots, mesh=self.mesh)
        else:
            self.store = None            # exact backend is stateless
        self.batcher = MicroBatcher(self._process_batch,
                                    max_batch=max_batch,
                                    max_wait_s=max_wait_s, buckets=buckets)
        self.turn_count: Dict[str, int] = {}
        self.records: List[TurnRecord] = []

    # -- public API ---------------------------------------------------

    def submit(self, conv_id: str, qvec: jax.Array):
        """Enqueue one conversational turn; resolves at the next flush.

        Returns a ``concurrent.futures.Future`` of (scores, doc_ids).
        """
        return self.batcher.submit(Request(conv_id, qvec))

    def flush(self) -> int:
        """Drain one micro-batch from the queue (serving-loop tick)."""
        return self.batcher.flush_loop_once()

    def drain(self) -> int:
        """Flush until the queue is empty; returns turns served."""
        served = 0
        while True:
            n = self.batcher.flush_loop_once()
            if n == 0:
                return served
            served += n

    def query(self, conv_id: str, qvec: jax.Array
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Synchronous single-turn convenience (submit + flush)."""
        fut = self.submit(conv_id, qvec)
        while not fut.done():
            self.batcher.flush_loop_once()
        return fut.result()

    def end_conversation(self, conv_id: str) -> None:
        if self.store is not None:
            self.store.release(conv_id)
        self.turn_count.pop(conv_id, None)

    # -- batch execution ----------------------------------------------

    def _process_batch(self, reqs: List[Request]) -> List[Any]:
        """MicroBatcher callback: serve a drained micro-batch.

        Splits the batch into waves holding at most one turn per
        conversation (turn t+1 must gather the session state turn t
        scattered), each wave being one padded device dispatch.  The
        batcher's trailing pad requests are dropped here — each wave
        re-pads itself to its own bucket with trash-slot rows, so pad
        rows never acquire a session slot or emit a ``TurnRecord``.
        """
        results: List[Any] = [None] * len(reqs)
        remaining = [(j, r) for j, r in enumerate(reqs)
                     if r.conv_id != MicroBatcher.PAD_ID]
        while remaining:
            seen, wave, deferred = set(), [], []
            for item in remaining:
                if item[1].conv_id in seen:
                    deferred.append(item)
                else:
                    seen.add(item[1].conv_id)
                    wave.append(item)
            self._process_wave(wave, results)
            remaining = deferred
        return results

    def _process_wave(self, wave, results) -> None:
        cfg = self.cfg
        b = len(wave)
        bb = self.batcher.bucket(b)          # padded (bucketed) batch size
        qs = [np.asarray(r.payload, np.float32) for _, r in wave]
        q = jnp.asarray(np.stack(qs + [np.zeros_like(qs[0])] * (bb - b)))

        if cfg.backend == "exact":
            v, i = _ivf.exact_search(self.doc_vecs, q, cfg.k)
            stats = None
        else:
            # padded rows run against the trash slot with
            # is_first=False: their zeroed trash session never trips the
            # drift check, so the batch-wide refresh/first-turn gates
            # stay closed on steady-state flushes (marking them first
            # would force the full scan on every non-bucket-exact
            # flush); the scatter writes them back to the trash row,
            # never a live session
            slots = np.full((bb,), self.store.trash_slot, np.int32)
            is_first = np.zeros((bb,), bool)
            for row, (_, r) in enumerate(wave):
                slots[row], is_first[row] = self.store.acquire(r.conv_id)
            if cfg.backend == "ivf":
                v, i, stats = self._ivf_wave(q, slots, is_first)
            elif cfg.backend == "ivf_pq":
                v, i, stats = self._ivf_pq_wave(q, slots, is_first)
            else:
                v, i, stats = self._hnsw_wave(q, slots, is_first)

        v = np.asarray(jax.device_get(v))
        i = np.asarray(jax.device_get(i))
        stats = (None if stats is None else
                 jax.tree.map(lambda a: np.asarray(jax.device_get(a)), stats))
        now = time.perf_counter()
        for row, (j, r) in enumerate(wave):
            turn = self.turn_count.get(r.conv_id, 0)
            self.turn_count[r.conv_id] = turn + 1
            if stats is None:
                rec = TurnRecord(r.conv_id, turn, now - r.enqueue_t,
                                 0, 0, 0, False, -1)
            else:
                rec = TurnRecord(
                    r.conv_id, turn, now - r.enqueue_t,
                    int(stats.centroid_dists[row]),
                    int(stats.list_dists[row]),
                    int(stats.graph_dists[row]),
                    bool(stats.refreshed[row]), int(stats.i0[row]),
                    int(stats.code_dists[row]))
            self.records.append(rec)
            results[j] = (v[row], i[row])

    def _ivf_wave(self, q, slots, is_first):
        cfg = self.cfg
        if cfg.strategy == "plain":
            return toploc.ivf_plain_batch(self.ivf, q, nprobe=cfg.nprobe,
                                          k=cfg.k, scan=self._ivf_scan)
        alpha = cfg.alpha if cfg.strategy == "toploc+" else -1.0
        sess = self.store.gather(slots)
        v, i, new_sess, stats = toploc.ivf_step_batch(
            self.ivf, sess, q, nprobe=cfg.nprobe, k=cfg.k, alpha=alpha,
            is_first=jnp.asarray(is_first), scan=self._ivf_scan)
        self.store.scatter(slots, new_sess)
        return v, i, stats

    def _ivf_pq_wave(self, q, slots, is_first):
        cfg = self.cfg
        if cfg.strategy == "plain":
            return toploc.ivf_pq_plain_batch(self.ivf_pq, q,
                                             nprobe=cfg.nprobe, k=cfg.k,
                                             rerank=cfg.rerank,
                                             scan=self._pq_scan)
        alpha = cfg.alpha if cfg.strategy == "toploc+" else -1.0
        sess = self.store.gather(slots)
        v, i, new_sess, stats = toploc.ivf_pq_step_batch(
            self.ivf_pq, sess, q, nprobe=cfg.nprobe, k=cfg.k, alpha=alpha,
            rerank=cfg.rerank, is_first=jnp.asarray(is_first),
            scan=self._pq_scan)
        self.store.scatter(slots, new_sess)
        return v, i, stats

    def _hnsw_wave(self, q, slots, is_first):
        cfg = self.cfg
        if cfg.strategy == "plain":
            return toploc.hnsw_plain_batch(self.hnsw, q, ef=cfg.ef_search,
                                           k=cfg.k,
                                           search=self._hnsw_search)
        sess = self.store.gather(slots)
        v, i, new_sess, stats = toploc.hnsw_step_batch(
            self.hnsw, sess, q, ef=cfg.ef_search, k=cfg.k, up=cfg.up,
            is_first=jnp.asarray(is_first), search=self._hnsw_search)
        self.store.scatter(slots, new_sess)
        return v, i, stats
