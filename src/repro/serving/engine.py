"""Conversational serving engine — TopLoc as a first-class feature.

Python-side session orchestration around the jitted core:
  * per-conversation TopLoc state (IVF centroid cache / HNSW entry
    point) held device-resident between turns;
  * strategy selected per deployment config (plain / toploc / exact,
    IVF / HNSW backend);
  * work + latency accounting per turn (feeds benchmarks/table1.py);
  * optional query encoder in front (full paper pipeline), and an item
    corpus front-end for the two-tower ``retrieval_cand`` serving shape.

Sessions are sticky: at multi-host scale the router pins a conversation
to one data-parallel group so its cache stays local (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hnsw as _hnsw
from repro.core import ivf as _ivf
from repro.core import toploc


@dataclasses.dataclass
class ServingConfig:
    backend: str = "ivf"          # "ivf" | "hnsw" | "exact"
    strategy: str = "toploc"      # "toploc" | "toploc+" | "plain"
    k: int = 10
    # IVF
    nprobe: int = 64
    h: int = 1024                 # cached centroids (TopLoc_IVF)
    alpha: float = 0.1            # refresh threshold (TopLoc_IVF+)
    # HNSW
    ef_search: int = 64
    up: int = 2                   # first-turn ef upscaling


@dataclasses.dataclass
class TurnRecord:
    conv_id: str
    turn: int
    latency_s: float
    centroid_dists: int
    list_dists: int
    graph_dists: int
    refreshed: bool
    i0: int


class ConversationalSearchEngine:
    def __init__(self, config: ServingConfig, *,
                 ivf_index: Optional[_ivf.IVFIndex] = None,
                 hnsw_index: Optional[_hnsw.HNSWIndex] = None,
                 doc_vecs: Optional[jax.Array] = None):
        self.cfg = config
        self.ivf = ivf_index
        self.hnsw = hnsw_index
        self.doc_vecs = doc_vecs
        if config.backend == "ivf" and ivf_index is None:
            raise ValueError("ivf backend needs ivf_index")
        if config.backend == "hnsw" and hnsw_index is None:
            raise ValueError("hnsw backend needs hnsw_index")
        if config.backend == "exact" and doc_vecs is None:
            raise ValueError("exact backend needs doc_vecs")
        self.sessions: Dict[str, Any] = {}
        self.turn_count: Dict[str, int] = {}
        self.records: list[TurnRecord] = []

    # -- public API ---------------------------------------------------

    def query(self, conv_id: str, qvec: jax.Array
              ) -> Tuple[np.ndarray, np.ndarray]:
        """One conversational turn. qvec (d,). Returns (scores, doc_ids)."""
        t0 = time.perf_counter()
        cfg = self.cfg
        turn = self.turn_count.get(conv_id, 0)

        if cfg.backend == "exact":
            v, i = _ivf.exact_search(self.doc_vecs, qvec[None], cfg.k)
            v, i = v[0], i[0]
            stats = None
        elif cfg.backend == "ivf":
            v, i, stats = self._ivf_turn(conv_id, qvec, turn)
        else:
            v, i, stats = self._hnsw_turn(conv_id, qvec, turn)

        v = np.asarray(jax.device_get(v))
        i = np.asarray(jax.device_get(i))
        dt = time.perf_counter() - t0
        self.turn_count[conv_id] = turn + 1
        if stats is not None:
            self.records.append(TurnRecord(
                conv_id, turn, dt,
                int(stats.centroid_dists), int(stats.list_dists),
                int(stats.graph_dists), bool(stats.refreshed),
                int(stats.i0)))
        else:
            self.records.append(TurnRecord(conv_id, turn, dt,
                                           0, 0, 0, False, -1))
        return v, i

    def end_conversation(self, conv_id: str) -> None:
        self.sessions.pop(conv_id, None)
        self.turn_count.pop(conv_id, None)

    # -- strategy paths -------------------------------------------------

    def _ivf_turn(self, conv_id, qvec, turn):
        cfg = self.cfg
        if cfg.strategy == "plain":
            v, i, st = _ivf.search(self.ivf, qvec[None],
                                   nprobe=cfg.nprobe, k=cfg.k)
            stats = toploc.TurnStats(
                jnp.asarray(self.ivf.p, jnp.int32), st.list_dists[0],
                jnp.asarray(0, jnp.int32), jnp.asarray(-1, jnp.int32),
                jnp.asarray(False))
            return v[0], i[0], stats
        if turn == 0 or conv_id not in self.sessions:
            v, i, sess, stats = toploc.ivf_start(
                self.ivf, qvec, h=cfg.h, nprobe=cfg.nprobe, k=cfg.k)
            self.sessions[conv_id] = sess
            return v, i, stats
        alpha = cfg.alpha if cfg.strategy == "toploc+" else -1.0
        v, i, sess, stats = toploc.ivf_step(
            self.ivf, self.sessions[conv_id], qvec,
            nprobe=cfg.nprobe, k=cfg.k, alpha=alpha)
        self.sessions[conv_id] = sess
        return v, i, stats

    def _hnsw_turn(self, conv_id, qvec, turn):
        cfg = self.cfg
        if cfg.strategy == "plain":
            v, i, nd = _hnsw.search(self.hnsw, qvec[None],
                                    ef=cfg.ef_search, k=cfg.k)
            stats = toploc.TurnStats(
                jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
                nd[0], jnp.asarray(-1, jnp.int32), jnp.asarray(False))
            return v[0], i[0], stats
        if turn == 0 or conv_id not in self.sessions:
            v, i, sess, stats = toploc.hnsw_start(
                self.hnsw, qvec, ef=cfg.ef_search, k=cfg.k, up=cfg.up)
            self.sessions[conv_id] = sess
            return v, i, stats
        v, i, sess, stats = toploc.hnsw_step(
            self.hnsw, self.sessions[conv_id], qvec,
            ef=cfg.ef_search, k=cfg.k)
        self.sessions[conv_id] = sess
        return v, i, stats

    # -- accounting ------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        if not self.records:
            return {}
        lat = np.asarray([r.latency_s for r in self.records])
        return {
            "turns": len(self.records),
            "mean_latency_ms": float(lat.mean() * 1e3),
            "p95_latency_ms": float(np.percentile(lat, 95) * 1e3),
            "mean_centroid_dists": float(np.mean(
                [r.centroid_dists for r in self.records])),
            "mean_list_dists": float(np.mean(
                [r.list_dists for r in self.records])),
            "mean_graph_dists": float(np.mean(
                [r.graph_dists for r in self.records])),
            "refresh_rate": float(np.mean(
                [r.refreshed for r in self.records[1:]] or [0.0])),
        }
