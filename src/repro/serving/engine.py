"""Conversational serving engine — TopLoc as a first-class feature.

Python-side session orchestration around the jitted core:
  * per-conversation TopLoc state (IVF centroid cache / HNSW entry
    point) held device-resident between turns;
  * the retrieval backend resolved ONCE from the ``core.backend``
    registry (``ServingConfig.backend`` is just the registry name) —
    both engines drive it exclusively through the generic
    ``toploc.start/step/plain(+_batch)`` drivers, so adding a backend
    to the registry adds it to serving with zero engine edits and zero
    ``backend == "..."`` branches;
  * an optional session-level historical-embedding **result cache**
    (``serving.result_cache``, Frieder et al.): when a turn's query is
    cosine-close to the session's cached query, the turn is answered
    from the cached documents without touching the backend;
  * work + latency accounting per turn (feeds benchmarks/table1.py).

Two engines share the accounting:

``ConversationalSearchEngine`` — one turn per dispatch, sessions in a
Python dict.  The reference implementation and the oracle the batched
path is tested against.

``BatchedConversationalSearchEngine`` — the serving-scale path: requests
enter a ``scheduler.MicroBatcher``; each flush drains up to ``max_batch``
requests, pads to the next shape bucket, gathers the sessions from a
device-resident ``sessions.SessionStore`` slab, runs ONE jitted batched
TopLoc step (``toploc.step_batch``) with an ``is_first`` mask for rows
whose conversation has no cached state, and scatters the updated
sessions back.  A flush containing several turns of the same
conversation is split into consecutive waves (a later turn must observe
the earlier turn's updated cache), so one device batch never holds a
conversation twice.  With the result cache enabled, each wave adds one
fused probe over the cache slab (same slot ids as the session slab);
hit rows take the cached answer, keep their session untouched, and
report zero backend work — exactly what the sequential engine does when
it skips the dispatch, so the two engines stay bit-identical with the
cache on as well as off.  Per-turn ``TurnStats`` are recorded exactly
as the sequential engine records them (tests/test_serving_batched.py).

Sessions are sticky: at multi-host scale the router pins a conversation
to one data-parallel group so its cache stays local (DESIGN.md §2).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import backend as _backend
from repro.core import hnsw as _hnsw
from repro.core import ivf as _ivf
from repro.core import pq as _pq
from repro.core import segment as _segment
from repro.core import toploc
from repro.distributed import retrieval as _retrieval
from repro.serving import result_cache as _result_cache
from repro.serving import sessions as _sessions
from repro.serving.scheduler import MicroBatcher, Request


@dataclasses.dataclass
class ServingConfig:
    backend: str = "ivf"          # any core.backend registry name
    strategy: str = "toploc"      # "toploc" | "toploc+" | "plain"
    k: int = 10
    # IVF / IVF-PQ
    nprobe: int = 64
    h: int = 1024                 # cached centroids (TopLoc_IVF)
    alpha: float = 0.1            # refresh threshold (TopLoc_IVF+)
    rerank: int = 64              # exact re-rank depth (IVF-PQ)
    # fused single-dispatch turn (core.toploc.FusedTurn over
    # kernels.fused_turn): opt-in Pallas megakernel for the IVF family —
    # centroid scoring, probe selection, list scan/merge and re-rank in
    # ONE kernel dispatch.  ``precision`` picks the stage-1/2 scoring
    # dtype: "f32" is bit-identical to the 3-dispatch path; "bf16"/
    # "int8" score quantised but always exact-re-rank in float32
    # in-kernel (recall@k floored, benchmarks/fig8_fused.py).  Ignored
    # by backends that don't declare the knob (hnsw, exact).
    fused: bool = False
    precision: str = "f32"
    # HNSW
    ef_search: int = 64
    up: int = 2                   # first-turn ef upscaling
    # corpus sharding (distributed.retrieval): shards > 1 partitions the
    # posting lists / vector corpus over a device mesh; results stay
    # bit-identical to single-device (tests/test_sharded_retrieval.py)
    shards: int = 0               # 0/1 = single device
    mesh: Any = None              # prebuilt jax Mesh (overrides shards)
    shard_axis: str = "model"
    # session-level historical-embedding result cache
    # (serving/result_cache.py): a turn whose query reaches this cosine
    # similarity to the session's cached query is answered from the
    # cached documents without touching the backend.  <= 0 disables the
    # cache — runs are then bit-identical to a cache-absent engine.
    # cache_depth > k over-fetches the backend to that depth and caches
    # the deeper candidate pool (hits rescore it; only the top-k is ever
    # served/recorded); 0 caches exactly the top-k.  The depth is
    # clamped to the backend's fetch limit — the largest request that
    # still executes the plain-k program (nprobe·Lmax for IVF, the
    # re-rank depth for IVF-PQ, ef for HNSW) — so miss turns always
    # serve exactly the uncached top-k.
    cache_threshold: float = 0.0
    cache_depth: int = 0
    # mutable corpus (core.segment): > 0 wraps the backend in a
    # SegmentedBackend with a `segment_cap`-row delta segment, enabling
    # add_documents / delete_documents / compact() on the engine while
    # sessions are live.  0 (default) serves the frozen index exactly as
    # before — no wrapper, byte-identical programs.
    segment_cap: int = 0


@dataclasses.dataclass
class TurnRecord:
    conv_id: str
    turn: int
    latency_s: float              # service time (dispatch -> result) only
    centroid_dists: int
    list_dists: int
    graph_dists: int
    refreshed: bool
    i0: int
    code_dists: int = 0           # PQ ADC evaluations (ivf_pq backend)
    cache_hit: bool = False       # answered from the result cache
    # time spent queued before dispatch (batched engine; 0 for the
    # sequential engine, which has no queue).  latency_s + queue_wait_s
    # is the client-observed enqueue->result request latency — kept as a
    # separate field so sequential-vs-batched latency comparisons
    # (table1/fig3) compare service time to service time
    queue_wait_s: float = 0.0


class _EngineAccounting:
    """Shared per-turn records + summary (sequential and batched engines)."""

    records: List[TurnRecord]

    def summary(self) -> Dict[str, float]:
        if not self.records:
            return {}
        lat = np.asarray([r.latency_s for r in self.records])
        wait = np.asarray([r.queue_wait_s for r in self.records])
        return {
            "turns": len(self.records),
            "mean_latency_ms": float(lat.mean() * 1e3),
            "p95_latency_ms": float(np.percentile(lat, 95) * 1e3),
            "mean_queue_wait_ms": float(wait.mean() * 1e3),
            # client-observed request latency: queue wait + service time
            "p95_request_ms": float(np.percentile(lat + wait, 95) * 1e3),
            "mean_centroid_dists": float(np.mean(
                [r.centroid_dists for r in self.records])),
            "mean_list_dists": float(np.mean(
                [r.list_dists for r in self.records])),
            "mean_graph_dists": float(np.mean(
                [r.graph_dists for r in self.records])),
            "mean_code_dists": float(np.mean(
                [r.code_dists for r in self.records])),
            # refresh is only defined from each conversation's second
            # turn on (turn 0 always runs the full scan) — exclude every
            # conversation's first turn, not just records[0]
            "refresh_rate": float(np.mean(
                [r.refreshed for r in self.records if r.turn > 0]
                or [0.0])),
            "cache_hit_rate": float(np.mean(
                [r.cache_hit for r in self.records])),
        }


class _EngineBase(_EngineAccounting):
    """Backend/index/mesh/cache resolution shared by both engines."""

    def _setup(self, config: ServingConfig, *, ivf_index, hnsw_index,
               ivf_pq_index, doc_vecs) -> None:
        self.cfg = config
        alpha = config.alpha if config.strategy == "toploc+" else -1.0
        fused = (toploc.FusedTurn(precision=config.precision)
                 if config.fused else None)
        self.backend = _backend.make(
            config.backend, h=config.h, nprobe=config.nprobe, alpha=alpha,
            rerank=config.rerank, ef=config.ef_search, up=config.up,
            fused=fused)
        provided = {"ivf_index": ivf_index, "hnsw_index": hnsw_index,
                    "ivf_pq_index": ivf_pq_index, "doc_vecs": doc_vecs}
        self.index = provided.get(self.backend.index_kwarg)
        if self.index is None:
            raise ValueError(f"{config.backend} backend needs "
                             f"{self.backend.index_kwarg}")
        self.doc_vecs = doc_vecs
        # corpus mesh: place the index, plug the sharded scan into the
        # backend; with no mesh both pass through untouched
        mesh = config.mesh
        if mesh is None and config.shards and config.shards > 1:
            mesh = _retrieval.retrieval_mesh(config.shards,
                                             axis=config.shard_axis)
        self.mesh = mesh
        # host-authoritative copies for the mutable-corpus path: segment
        # mutations and compaction run on the unsharded index, then the
        # result is re-placed on the mesh
        inner_plain, index_plain = self.backend, self.index
        if mesh is not None:
            self.backend, self.index = _retrieval.shard_backend(
                mesh, self.backend, self.index, axis=config.shard_axis)
        # corpus epoch: bumped on every successful mutation (add /
        # delete / compact); cache invalidation and corpus refresh key
        # off it, and readers can use it to detect staleness
        self.corpus_epoch = 0
        self._seg_inner: Optional[_backend.RetrievalBackend] = None
        self._seg_host: Optional[_segment.SegmentedIndex] = None
        if config.segment_cap and config.segment_cap > 0:
            self._seg_inner = inner_plain
            self._seg_host = _segment.make_segmented(
                inner_plain, index_plain, cap=config.segment_cap)
            self.backend = _segment.SegmentedBackend(inner=self.backend)
            self.index = self._placed_segment(
                self._seg_host, base_dev=self.index)
        self.turn_count: Dict[str, int] = {}
        self.records: List[TurnRecord] = []

    @property
    def _sessioned(self) -> bool:
        """Per-conversation state in play this deployment?"""
        return self.backend.stateful and self.cfg.strategy != "plain"

    # -- mutable corpus (core.segment) --------------------------------

    def _placed_segment(self, seg: "_segment.SegmentedIndex", *,
                        base_dev: Any) -> "_segment.SegmentedIndex":
        """Device view of the host-authoritative segment state: the
        (possibly sharded) base plus mesh-replicated delta/tombstone
        arrays."""
        if self.mesh is None:
            return seg._replace(base=base_dev)
        placed = _retrieval.place_segmented(self.mesh,
                                            seg._replace(base=base_dev))
        return placed._replace(base=base_dev)

    def _require_segmented(self) -> None:
        if self._seg_host is None:
            raise RuntimeError(
                "corpus mutation needs ServingConfig.segment_cap > 0 "
                "(the engine is serving a frozen index)")

    def _mutation_scope(self):
        """Engine hook: context under which a corpus mutation swaps the
        index.  The sequential engine needs none (one thread, no
        in-flight work); the batched engine overrides with
        ``batcher.paused()``, which retires in-flight waves AND holds
        the drain lock for the whole swap — a bare sync would leave a
        window where a concurrent flush launches a wave against the
        pre-mutation index, whose futures then resolve (and can serve a
        tombstoned doc) after the mutation returned."""
        return contextlib.nullcontext()

    def _after_mutation(self, *, base_changed: bool) -> None:
        """Re-place the mutated host state on the device/mesh, refresh
        the cache's historical-embedding corpus, and bump the epoch."""
        seg = self._seg_host
        base_dev = self.index.base
        if base_changed:
            base_dev = seg.base
            if self.mesh is not None:
                # re-place through the sharding registry (same plugin,
                # new arrays); the returned backend is discarded — the
                # serving backend already carries the sharded scan
                _, base_dev = _retrieval.shard_backend(
                    self.mesh, self._seg_inner, seg.base,
                    axis=self.cfg.shard_axis)
        self.index = self._placed_segment(seg, base_dev=base_dev)
        self.corpus_epoch += 1
        if self._cache is not None:
            self._cache.corpus = self._cache_corpus()

    def add_documents(self, vectors) -> np.ndarray:
        """Ingest new documents into the delta segment (shape-stable:
        no recompilation); returns their assigned global ids."""
        self._require_segmented()
        with self._mutation_scope():
            self._seg_host, ids = _segment.add_documents(self._seg_host,
                                                         vectors)
            # existing cache entries stay valid: their candidate pools
            # simply predate the new docs (documented staleness, same as
            # a miss turn served just before the add)
            self._after_mutation(base_changed=False)
        return ids

    def delete_documents(self, ids) -> None:
        """Tombstone documents by global id; a cache hit can never
        serve them again (intersecting entries are invalidated)."""
        self._require_segmented()
        with self._mutation_scope():
            self._seg_host = _segment.delete_documents(self._seg_inner,
                                                       self._seg_host,
                                                       ids)
            self._after_mutation(base_changed=True)
            # the tombstone sweep must land inside the scope too: a wave
            # launched between the index swap and the sweep could
            # refresh a cache entry that still holds the dead doc
            if self._cache is not None:
                self._cache.invalidate_docs(ids)

    def compact(self, **build_kw) -> None:
        """Fold the delta segment into the base index (background
        maintenance; the one mutation that changes array shapes and so
        costs one retrace).  Results afterwards are bit-identical to a
        from-scratch rebuild (core.segment contract)."""
        self._require_segmented()
        with self._mutation_scope():
            self._compact_locked(**build_kw)

    def _compact_locked(self, **build_kw) -> None:
        if self.doc_vecs is not None:
            # compaction folds delta rows into the base id range; the
            # engine-provided flat corpus must grow with it so cache
            # re-scoring keeps covering ids 0..n_base-1
            fill = _segment.delta_fill(self._seg_host)
            self.doc_vecs = jnp.concatenate(
                [jnp.asarray(self.doc_vecs),
                 self._seg_host.delta_vecs[:fill]], axis=0)
        self._seg_host = _segment.compact(self._seg_inner,
                                          self._seg_host, **build_kw)
        self._after_mutation(base_changed=True)

    def _cache_corpus(self) -> Optional[jax.Array]:
        """Flat (n, d) corpus for historical-embedding re-scoring.

        The segmented path concatenates from the *host* mirror (the
        sharded base pads its row count, which would shift delta ids off
        their rows); delta rows sit at exactly ids n_base..n_base+cap-1.
        """
        if self._seg_host is not None:
            base = (self.doc_vecs if self.doc_vecs is not None
                    else self._seg_inner.corpus_vectors(
                        self._seg_host.base))
            if base is None:
                return None
            return jnp.concatenate(
                [jnp.asarray(base), self._seg_host.delta_vecs], axis=0)
        return (self.doc_vecs if self.doc_vecs is not None
                else self.backend.corpus_vectors(self.index))

    def _make_cache(self, n_slots: Optional[int] = None
                    ) -> Optional[_result_cache.ResultCache]:
        """Result cache iff enabled and the deployment is sessioned
        (the cache is session-level state — plain/stateless serving has
        no session to anchor an entry to)."""
        cfg = self.cfg
        if cfg.cache_threshold <= 0.0 or not self._sessioned:
            return None
        corpus = self._cache_corpus()
        # clamp the over-fetch to the backend's candidate pool: a wider
        # request would either be unsatisfiable (HNSW: top_k over an
        # ef-wide beam) or change which candidates the top-k is drawn
        # from (IVF-PQ: the re-rank pool widens with k)
        depth = min(max(cfg.cache_depth or cfg.k, cfg.k),
                    self.backend.fetch_limit(self.index))
        return _result_cache.ResultCache(
            d=self.backend.query_dim(self.index), k=cfg.k,
            threshold=cfg.cache_threshold, depth=depth,
            corpus=corpus, n_slots=n_slots, mesh=self.mesh)

    @property
    def _k_fetch(self) -> int:
        """Result depth requested from the backend: the cache depth when
        the cache is on (the entry stores the deeper pool; only the
        top-k is served), plain k otherwise — so disabled-cache runs
        execute the exact uncached program."""
        return self._cache.depth if self._cache is not None else self.cfg.k

    def cache_stats(self) -> Dict[str, float]:
        """Result-cache hit/miss counters ({} when the cache is off)."""
        return self._cache.stats() if self._cache is not None else {}


class ConversationalSearchEngine(_EngineBase):
    def __init__(self, config: ServingConfig, *,
                 ivf_index: Optional[_ivf.IVFIndex] = None,
                 hnsw_index: Optional[_hnsw.HNSWIndex] = None,
                 ivf_pq_index: Optional[_pq.IVFPQIndex] = None,
                 doc_vecs: Optional[jax.Array] = None):
        self._setup(config, ivf_index=ivf_index, hnsw_index=hnsw_index,
                    ivf_pq_index=ivf_pq_index, doc_vecs=doc_vecs)
        self.sessions: Dict[str, Any] = {}
        self._cache = self._make_cache()

    # -- public API ---------------------------------------------------

    def query(self, conv_id: str, qvec: jax.Array
              ) -> Tuple[np.ndarray, np.ndarray]:
        """One conversational turn. qvec (d,). Returns (scores, doc_ids)."""
        t0 = time.perf_counter()
        cfg = self.cfg
        turn = self.turn_count.get(conv_id, 0)

        cached = (self._cache.lookup(conv_id, qvec)
                  if self._cache is not None else None)
        if cached is not None:
            v, i = cached
            stats = toploc._zero_stats()
        elif not self._sessioned:
            v, i, stats = toploc.plain(self.backend, self.index, qvec,
                                       k=self._k_fetch)
        elif turn == 0 or conv_id not in self.sessions:
            v, i, sess, stats = toploc.start(self.backend, self.index,
                                             qvec, k=self._k_fetch)
            self.sessions[conv_id] = sess
        else:
            v, i, sess, stats = toploc.step(self.backend, self.index,
                                            self.sessions[conv_id], qvec,
                                            k=self._k_fetch)
            self.sessions[conv_id] = sess
        if cached is None and self._cache is not None:
            self._cache.update(conv_id, qvec, v, i)
            v, i = v[:cfg.k], i[:cfg.k]

        v = np.asarray(jax.device_get(v))
        i = np.asarray(jax.device_get(i))
        dt = time.perf_counter() - t0
        self.turn_count[conv_id] = turn + 1
        self.records.append(TurnRecord(
            conv_id, turn, dt,
            int(stats.centroid_dists), int(stats.list_dists),
            int(stats.graph_dists), bool(stats.refreshed),
            int(stats.i0), int(stats.code_dists),
            cache_hit=cached is not None))
        return v, i

    def end_conversation(self, conv_id: str) -> None:
        self.sessions.pop(conv_id, None)
        self.turn_count.pop(conv_id, None)
        if self._cache is not None:
            self._cache.invalidate(conv_id)


class BatchedConversationalSearchEngine(_EngineBase):
    """Continuously micro-batched multi-conversation serving front door.

    Requests flow ``submit() → MicroBatcher queue → flush → one padded
    device batch → scatter sessions → resolve futures``.  See the module
    docstring for the flush/wave semantics.

    Batches run as a **continuous-batching loop**: ``flush`` only
    *launches* the device work (jax async dispatch — every op in
    ``_launch_wave`` returns before the device finishes) and hands the
    MicroBatcher a completion thunk; with ``max_inflight=2`` the host
    drains, pads, and launches wave N+1 while wave N is still running on
    device, and wave N's futures/records are resolved when the batcher
    retires it.  Correctness under overlap comes from device-stream
    ordering through the session slab: wave N's scatter is enqueued
    before wave N+1's gather, so a conversation appearing in consecutive
    launches still observes its own updated state, and the wave
    invariant (one device batch never holds a conversation twice) is
    enforced per drain exactly as before.

    ``n_slots`` bounds resident conversations; the LRU conversation is
    evicted when a new one arrives at full occupancy and is rebuilt
    (first-turn semantics) if it ever returns.
    """

    def __init__(self, config: ServingConfig, *,
                 ivf_index: Optional[_ivf.IVFIndex] = None,
                 hnsw_index: Optional[_hnsw.HNSWIndex] = None,
                 ivf_pq_index: Optional[_pq.IVFPQIndex] = None,
                 doc_vecs: Optional[jax.Array] = None,
                 n_slots: int = 256, max_batch: int = 32,
                 max_wait_s: float = 0.002,
                 buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
                 max_inflight: int = 2):
        self._setup(config, ivf_index=ivf_index, hnsw_index=hnsw_index,
                    ivf_pq_index=ivf_pq_index, doc_vecs=doc_vecs)
        # a wave holds up to max_batch distinct conversations, each
        # needing its own live slot — fewer slots would make acquire()
        # evict a conversation acquired earlier in the SAME wave and
        # scatter two rows into one slot (silent session corruption)
        if self.backend.stateful and n_slots < max_batch:
            raise ValueError(
                f"n_slots ({n_slots}) must be >= max_batch ({max_batch})")
        # ensure the bucket table covers max_batch so a full wave never
        # pads to a bucket smaller than itself
        buckets = tuple(sorted(set(buckets) | {max_batch}))
        # session slab replicates over the corpus mesh (sessions are the
        # replicated TopLoc state; only the corpus shards); stateless
        # backends get no store
        self.store = _sessions.store_for_backend(
            self.backend, self.index, n_slots=n_slots, mesh=self.mesh)
        self._cache = self._make_cache(n_slots=n_slots)
        if self._cache is not None:
            # a freed session slot must also drop its cache row, or the
            # slot's next conversation could hit another user's entry
            self.store.add_slot_freed_listener(self._cache.clear_slot)
        self.batcher = MicroBatcher(dispatch_batch=self._dispatch_batch,
                                    max_batch=max_batch,
                                    max_wait_s=max_wait_s, buckets=buckets,
                                    max_inflight=max_inflight)

    # -- public API ---------------------------------------------------

    def submit(self, conv_id: str, qvec: jax.Array):
        """Enqueue one conversational turn; resolves at the next flush.

        Returns a ``concurrent.futures.Future`` of (scores, doc_ids).
        """
        return self.batcher.submit(Request(conv_id, qvec))

    def flush(self) -> int:
        """Launch one micro-batch from the queue (serving-loop tick).

        Returns the number of requests launched; their futures resolve
        once the batch is retired (after ``max_inflight`` later
        launches, or at ``sync``/``drain``).
        """
        return self.batcher.flush_loop_once()

    def sync(self) -> None:
        """Retire every in-flight batch (resolves outstanding futures)."""
        self.batcher.sync()

    def drain(self) -> int:
        """Flush until the queue is empty and all launches retired;
        returns turns served."""
        served = 0
        while True:
            n = self.batcher.flush_loop_once()
            if n == 0:
                self.batcher.sync()
                if self.batcher.flush_loop_once() == 0:
                    return served
                continue
            served += n

    def query(self, conv_id: str, qvec: jax.Array
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Synchronous single-turn convenience (submit + flush + sync)."""
        fut = self.submit(conv_id, qvec)
        while not fut.done():
            if self.batcher.flush_loop_once() == 0:
                self.batcher.sync()
        return fut.result()

    def close(self) -> None:
        """Quiesce: retire in-flight launches so no future is left
        pending.  Idempotent; also reachable as a context manager."""
        self.batcher.sync()

    def __enter__(self) -> "BatchedConversationalSearchEngine":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def _mutation_scope(self):
        # a corpus mutation swaps self.index; paused() retires in-flight
        # waves and holds the drain lock for the whole swap, so no wave
        # is launched against the pre-mutation index while the swap (and
        # the cache's tombstone sweep) is mid-flight — a launched batch
        # never straddles two corpus epochs
        return self.batcher.paused()

    def end_conversation(self, conv_id: str) -> None:
        # release under the paused batcher: a launched wave's scatter
        # still targets this conversation's slot (freeing the slot now
        # could hand it to a conversation in the *next* launch before
        # the scatter executes), and turn_count is otherwise only
        # touched by launches under the drain lock
        with self.batcher.paused():
            if self.store is not None:
                self.store.release(conv_id)
            self.turn_count.pop(conv_id, None)

    # -- batch execution ----------------------------------------------

    def _dispatch_batch(self, reqs: List[Request]
                        ) -> Any:
        """MicroBatcher dispatch callback: launch a drained micro-batch.

        Splits the batch into waves holding at most one turn per
        conversation (turn t+1 must gather the session state turn t
        scattered), launches each wave's device work without blocking,
        and returns a completion thunk that device_gets the results and
        writes the ``TurnRecord``s.  The batcher's trailing pad requests
        are dropped here — each wave re-pads itself to its own bucket
        with trash-slot rows, so pad rows never acquire a session slot
        or emit a ``TurnRecord``.
        """
        remaining = [(j, r) for j, r in enumerate(reqs)
                     if r.conv_id != MicroBatcher.PAD_ID]
        finishers = []
        while remaining:
            seen, wave, deferred = set(), [], []
            for item in remaining:
                if item[1].conv_id in seen:
                    deferred.append(item)
                else:
                    seen.add(item[1].conv_id)
                    wave.append(item)
            finishers.append(self._launch_wave(wave))
            remaining = deferred

        def complete() -> List[Any]:
            results: List[Any] = [None] * len(reqs)
            for finish in finishers:
                finish(results)
            return results
        return complete

    def _launch_wave(self, wave):
        """Enqueue one wave's device work (no host-side blocking) and
        return a ``finish(results)`` closure that materializes it.

        Everything up to the returned closure is async dispatch: gather,
        step_batch, cache fuse, and scatter all enqueue onto the device
        stream and return immediately.  The closure's ``device_get``
        calls are the only blocking point — deferred until the batcher
        retires this launch, by which time the next wave's host assembly
        has already overlapped this wave's device execution.
        """
        cfg = self.cfg
        b = len(wave)
        bb = self.batcher.bucket(b)          # padded (bucketed) batch size
        qs = [np.asarray(r.payload, np.float32) for _, r in wave]
        q = jnp.asarray(np.stack(qs + [np.zeros_like(qs[0])] * (bb - b)))

        hit = None
        if not self._sessioned:
            v, i, stats = toploc.plain_batch(self.backend, self.index, q,
                                             k=cfg.k)
        else:
            # padded rows run against the trash slot with
            # is_first=False: their zeroed trash session never trips the
            # drift check, so the batch-wide refresh/first-turn gates
            # stay closed on steady-state flushes (marking them first
            # would force the full scan on every non-bucket-exact
            # flush); the scatter writes them back to the trash row,
            # never a live session
            slots = np.full((bb,), self.store.trash_slot, np.int32)
            is_first = np.zeros((bb,), bool)
            for row, (_, r) in enumerate(wave):
                slots[row], is_first[row] = self.store.acquire(r.conv_id)
            sess = self.store.gather(slots)
            v, i, new_sess, stats = toploc.step_batch(
                self.backend, self.index, sess, q, k=self._k_fetch,
                is_first=jnp.asarray(is_first))
            if self._cache is not None:
                # fused probe over the cache slab: hit rows take the
                # cached answer, zero their work counters, and keep the
                # pre-step session (the sequential engine skips the
                # dispatch entirely on a hit — same observable state)
                v, i, new_sess, stats, hit = self._cache.fuse(
                    slots, q, v, i, sess, new_sess, stats)
            self.store.scatter(slots, new_sess)

        # turn numbers are claimed at LAUNCH: a later launch holding the
        # same conversation must see this wave's increment even though
        # its records are written at retirement
        turns = []
        for _, r in wave:
            t = self.turn_count.get(r.conv_id, 0)
            self.turn_count[r.conv_id] = t + 1
            turns.append(t)
        t_dispatch = time.perf_counter()

        def finish(results) -> None:
            vh = np.asarray(jax.device_get(v))
            ih = np.asarray(jax.device_get(i))
            st = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                              stats)
            hh = None
            if hit is not None:
                hh = np.asarray(jax.device_get(hit))
                self._cache.count_hits(hh, b)
            now = time.perf_counter()
            for row, ((j, r), turn) in enumerate(zip(wave, turns)):
                rec = TurnRecord(
                    r.conv_id, turn, now - t_dispatch,
                    int(st.centroid_dists[row]),
                    int(st.list_dists[row]),
                    int(st.graph_dists[row]),
                    bool(st.refreshed[row]), int(st.i0[row]),
                    int(st.code_dists[row]),
                    cache_hit=bool(hh[row]) if hh is not None else False,
                    queue_wait_s=t_dispatch - r.enqueue_t)
                self.records.append(rec)
                results[j] = (vh[row], ih[row])
        return finish
