"""Replica router: session-affine serving over a (replica, shard) mesh.

The serving tier's second dimension (DESIGN.md §2): where
``distributed.retrieval`` shards the *corpus* over the ``model`` axis of
a mesh, this module scales *throughput* by running R independent
``BatchedConversationalSearchEngine`` replicas, each on its own
per-replica submesh (``distributed.retrieval.replica_submeshes``) —
every replica group holds a full sharded corpus, its own
``SessionStore`` slab, and its own ``ResultCache``.

Routing rule — stateful vs. stateless:

  * **Stateful** deployments (TopLoc strategies on stateful backends):
    the session slab and cache rows are per-replica device state, so a
    conversation is **pinned** to one replica for its lifetime
    (least-loaded assignment at first turn, sticky until
    ``end_conversation``).  Turn t's scatter and turn t+1's gather must
    hit the same slab; migrating mid-conversation would orphan the C0
    cache.  An eviction *inside* a replica's LRU slab does NOT unpin —
    the conversation rebuilds first-turn state on the same replica,
    exactly like the single-engine eviction path, so routed results
    stay bit-identical to a single engine serving that conversation.
  * **Stateless** deployments (``strategy="plain"`` or a stateless
    backend): no session anchors the request, so any replica can serve
    it and duplicate dispatch is *safe* — requests route through a
    ``scheduler.HedgedExecutor`` (Dean & Barroso): the p95-adaptive
    hedge re-issues a straggling request on the next replica and the
    first successful result wins.  Results are bit-identical regardless
    of the winning replica (each replica runs the identical jitted
    program on an identical full corpus), which is precisely why
    hedging is restricted to stateless traffic: a hedged *stateful*
    turn would step two divergent session copies.

Pinning + per-drain wave splitting compose into the global wave
invariant: a conversation's turns all flow through one replica's
batcher, which never puts two of them in one device batch.

Hedged calls block on the target engine's futures, so hedged traffic
needs the per-replica pump threads running (``start()`` — called
lazily on first hedged submit).  Pinned traffic works either threaded
(``start()``/``close()``) or single-threaded via ``drain()``.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.concurrency import guarded_by
from repro.distributed import retrieval as _retrieval
from repro.serving.engine import (BatchedConversationalSearchEngine,
                                  ServingConfig, _EngineAccounting)
from repro.serving.scheduler import HedgedExecutor


@guarded_by("_route_lock", "_replica_of", "_load", "_rr",
            "_pumps", "_closed")
class ReplicatedSearchEngine:
    """R replica ``BatchedConversationalSearchEngine``s behind one
    session-affine front door (module docstring has the routing rule).

    Thread safety: the routing table, load counters, pump-thread list,
    and the closed flag are guarded by ``_route_lock`` — submits arrive
    on arbitrary client threads while pumps run and ``close()`` may race
    a lazy ``start()``.  After ``close()`` every ``submit``/``query``/
    mutation raises ``RuntimeError`` instead of dispatching to dead pump
    threads; ``close()`` itself is idempotent.

    ``config.mesh`` may be a prebuilt 2-D ``(replica, shard)`` mesh
    (split into per-replica submeshes; its replica count must match
    ``replicas``); with ``config.shards > 1`` and no mesh the 2-D mesh
    is built from the local devices; otherwise each replica runs
    unsharded on the default device.  Engine kwargs (slots, batching)
    apply per replica — total session capacity is ``replicas *
    n_slots``, which is the capacity story behind fig7: a session
    population that thrashes one replica's LRU slab sits fully resident
    across two.
    """

    def __init__(self, config: ServingConfig, *, replicas: int = 1,
                 ivf_index: Any = None, hnsw_index: Any = None,
                 ivf_pq_index: Any = None, doc_vecs: Any = None,
                 n_slots: int = 256, max_batch: int = 32,
                 max_wait_s: float = 0.002,
                 buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32),
                 max_inflight: int = 2,
                 hedge_quantile: float = 0.95,
                 hedge_floor_s: float = 0.005):
        if replicas < 1:
            raise ValueError(f"replicas={replicas} must be >= 1")
        submeshes = self._resolve_submeshes(config, replicas)
        self.replicas = replicas
        self.engines: List[BatchedConversationalSearchEngine] = []
        for sm in submeshes:
            # shards=0: the submesh (when any) already encodes the shard
            # count; a per-replica engine must never rebuild its own mesh
            cfg_r = dataclasses.replace(config, mesh=sm, shards=0)
            self.engines.append(BatchedConversationalSearchEngine(
                cfg_r, ivf_index=ivf_index, hnsw_index=hnsw_index,
                ivf_pq_index=ivf_pq_index, doc_vecs=doc_vecs,
                n_slots=n_slots, max_batch=max_batch,
                max_wait_s=max_wait_s, buckets=buckets,
                max_inflight=max_inflight))
        self.stateful = self.engines[0]._sessioned
        self._route_lock = threading.Lock()
        self._replica_of: Dict[str, int] = {}
        self._load = [0] * replicas            # pinned sessions / replica
        self._rr = 0                           # round-robin tie-break
        self._hedge: Optional[HedgedExecutor] = None
        self._hedge_pool: Optional[ThreadPoolExecutor] = None
        if not self.stateful:
            self._hedge = HedgedExecutor(
                [self._replica_call(r) for r in range(replicas)],
                hedge_quantile=hedge_quantile, hedge_floor_s=hedge_floor_s)
            # hedge.call blocks; this pool turns it back into a Future
            self._hedge_pool = ThreadPoolExecutor(
                max_workers=2 * replicas,
                thread_name_prefix="hedge-front")
        self._pumps: List[threading.Thread] = []
        self._stop = threading.Event()
        self._closed = False

    # -- construction helpers -----------------------------------------

    @staticmethod
    def _resolve_submeshes(config: ServingConfig, replicas: int) -> List:
        mesh = config.mesh
        if mesh is not None:
            subs = _retrieval.replica_submeshes(mesh)
            if len(subs) != replicas:
                raise ValueError(
                    f"config.mesh has {len(subs)} replica group(s) but "
                    f"replicas={replicas}")
            return subs
        if config.shards and config.shards > 1:
            mesh = _retrieval.retrieval_mesh(
                config.shards, axis=config.shard_axis, replicas=replicas)
            return _retrieval.replica_submeshes(mesh)
        return [None] * replicas

    def _replica_call(self, r: int):
        """Hedge-target callable: run one stateless turn on replica r
        end to end (submit + block on the future)."""
        def call(payload: Tuple[str, Any]):
            conv_id, qvec = payload
            return self.engines[r].submit(conv_id, qvec).result()
        return call

    # -- routing -------------------------------------------------------

    def replica_of(self, conv_id: str) -> Optional[int]:
        """The replica a conversation is pinned to (None if unseen)."""
        with self._route_lock:
            return self._replica_of.get(conv_id)

    def _acquire_replica(self, conv_id: str) -> int:
        with self._route_lock:
            r = self._replica_of.get(conv_id)
            if r is None:
                # least-loaded pinning, round-robin among ties so a cold
                # start spreads sessions instead of piling on replica 0
                order = [(self._load[i], (i - self._rr) % self.replicas, i)
                         for i in range(self.replicas)]
                r = min(order)[2]
                self._rr = (r + 1) % self.replicas
                self._replica_of[conv_id] = r
                self._load[r] += 1
            return r

    # -- public API ----------------------------------------------------

    def _ensure_open(self) -> None:
        with self._route_lock:
            if self._closed:
                raise RuntimeError(
                    "ReplicatedSearchEngine is closed; build a new "
                    "router to serve further traffic")

    def _pumps_running(self) -> bool:
        with self._route_lock:
            return bool(self._pumps)

    def submit(self, conv_id: str, qvec) -> Future:
        """Enqueue one turn; Future of (scores, doc_ids).

        Stateful traffic goes to the conversation's pinned replica;
        stateless traffic is hedged across replicas.  Raises
        ``RuntimeError`` after ``close()``.
        """
        self._ensure_open()
        if self.stateful:
            r = self._acquire_replica(conv_id)
            return self.engines[r].submit(conv_id, qvec)
        # no-op once running; atomically spawns the pumps on first use
        # (two concurrent first submits must not double-spawn)
        self.start()
        return self._hedge_pool.submit(self._hedge.call, (conv_id, qvec))

    def query(self, conv_id: str, qvec) -> Tuple[np.ndarray, np.ndarray]:
        """Synchronous single-turn convenience."""
        fut = self.submit(conv_id, qvec)
        if self.stateful and not self._pumps_running():
            # read the pin under the route lock (replica_of); a racing
            # end_conversation may have already dropped it between
            # submit() and here, in which case the turn was enqueued on
            # whichever replica held the pin at submit time — drain all
            # replicas until the future lands instead of KeyError-ing
            r = self.replica_of(conv_id)
            engines = [self.engines[r]] if r is not None else self.engines
            while not fut.done():
                if sum(eng.flush() for eng in engines) == 0:
                    for eng in engines:
                        eng.sync()
        return fut.result()

    def end_conversation(self, conv_id: str) -> None:
        with self._route_lock:
            r = self._replica_of.pop(conv_id, None)
            if r is not None:
                self._load[r] -= 1
        if r is not None:
            self.engines[r].end_conversation(conv_id)

    # -- mutable corpus (core.segment) ---------------------------------

    def add_documents(self, vectors) -> np.ndarray:
        """Broadcast an ingest batch to every replica.  Full-corpus
        replicas must stay identical for pinning (and stateless
        hedging) to be safe; id assignment is deterministic (``n_base +
        delta row``), so every replica assigns the same ids — asserted
        here.  Returns the assigned global ids.
        """
        self._ensure_open()
        ids: Optional[np.ndarray] = None
        for eng in self.engines:
            got = eng.add_documents(vectors)
            if ids is not None and not np.array_equal(ids, got):
                raise RuntimeError(
                    "replica divergence: add_documents assigned "
                    f"{got.tolist()} vs {ids.tolist()}")
            ids = got
        return ids

    def delete_documents(self, ids) -> None:
        """Broadcast tombstones to every replica (each invalidates its
        own result-cache entries intersecting the deleted ids)."""
        self._ensure_open()
        for eng in self.engines:
            eng.delete_documents(ids)

    def compact(self, **build_kw) -> None:
        """Compact the delta segment on every replica (replicas fold
        the identical delta into the identical base, so they remain
        bit-identical afterwards — the core.segment rebuild contract)."""
        self._ensure_open()
        for eng in self.engines:
            eng.compact(**build_kw)

    @property
    def corpus_epoch(self) -> int:
        """Corpus mutation epoch (identical across replicas — every
        mutation broadcasts)."""
        return self.engines[0].corpus_epoch

    def drain(self) -> int:
        """Single-threaded serving: drain every replica's queue and
        retire all launches; returns turns served."""
        served = 0
        while True:
            n = sum(e.drain() for e in self.engines)
            if n == 0:
                return served
            served += n

    # -- serving-loop threads ------------------------------------------

    def start(self) -> "ReplicatedSearchEngine":
        """Spawn one pump (serving-loop) thread per replica.  No-op when
        already running or closed; safe to call concurrently (the pump
        list is built under the route lock, so two racing first submits
        can never double-spawn)."""
        with self._route_lock:
            if self._pumps or self._closed:
                return self
            self._stop.clear()
            for r, eng in enumerate(self.engines):
                t = threading.Thread(target=self._pump_loop, args=(eng,),
                                     name=f"replica-pump-{r}", daemon=True)
                t.start()
                self._pumps.append(t)
        return self

    def _pump_loop(self, eng: BatchedConversationalSearchEngine) -> None:
        while not self._stop.is_set():
            # flush blocks on the batcher condvar up to max_wait_s, so
            # an idle pump parks instead of spinning; an empty tick
            # retires in-flight launches so tail futures resolve even
            # when no new traffic pushes them out
            if eng.flush() == 0:
                eng.sync()

    def close(self) -> None:
        """Quiesce and tear down.  Order matters: the hedge front pool
        drains first (its calls need live pumps to resolve), then the
        hedge executor's replica pool, then the pumps, then the engines.
        Idempotent — the closed flag flips exactly once under the route
        lock, so a second (or concurrent) close returns immediately."""
        with self._route_lock:
            if self._closed:
                return
            self._closed = True
        if self._hedge_pool is not None:
            self._hedge_pool.shutdown(wait=True)
        if self._hedge is not None:
            self._hedge.close()
        self._stop.set()
        with self._route_lock:
            pumps, self._pumps = list(self._pumps), []
        for t in pumps:
            t.join(timeout=10.0)
        for eng in self.engines:
            eng.close()

    def __enter__(self) -> "ReplicatedSearchEngine":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- merged accounting ---------------------------------------------

    @property
    def records(self) -> List:
        """All replicas' TurnRecords (hedged duplicates included — a
        hedge that loses still did the work)."""
        return [rec for eng in self.engines for rec in eng.records]

    def summary(self) -> Dict[str, float]:
        acc = _EngineAccounting()
        acc.records = self.records
        return acc.summary()

    def cache_stats(self) -> Dict[str, float]:
        merged: Dict[str, float] = {"hits": 0, "misses": 0}
        for eng in self.engines:
            s = eng.cache_stats()
            merged["hits"] += s.get("hits", 0)
            merged["misses"] += s.get("misses", 0)
        total = merged["hits"] + merged["misses"]
        merged["hit_rate"] = (merged["hits"] / total) if total else 0.0
        return merged

    def hedge_stats(self) -> Dict[str, float]:
        return self._hedge.stats() if self._hedge is not None else {}

    def load_stats(self) -> Dict[str, Any]:
        """Per-replica load + imbalance (max/mean served turns)."""
        turns = [len(eng.records) for eng in self.engines]
        with self._route_lock:
            sessions = list(self._load)
        mean = float(np.mean(turns)) if any(turns) else 0.0
        return {
            "per_replica_turns": turns,
            "per_replica_sessions": sessions,
            "imbalance": (max(turns) / mean) if mean else 1.0,
        }
