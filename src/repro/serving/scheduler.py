"""Serving scheduler: micro-batching + hedged (straggler-proof) dispatch.

``MicroBatcher`` — classic continuous-batching front door: requests
accumulate until ``max_batch`` or ``max_wait_s`` (deadline-based flush),
then execute as one device batch.  Padding to the next bucket keeps jit
cache hits high (static shapes).

``HedgedExecutor`` — tail-latency mitigation for multi-replica serving:
after an adaptive p95-based deadline, the slowest in-flight call is
re-issued on a second replica and the first result wins (Dean &
Barroso, "The Tail at Scale").  At 1000-node scale this is what keeps
p99 flat when a host degrades; tests/test_serving.py exercises it with
a deliberately slow replica.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    conv_id: str
    payload: Any
    enqueue_t: float = dataclasses.field(default_factory=time.perf_counter)


class MicroBatcher:
    """Deadline-based micro-batching with shape bucketing."""

    def __init__(self, process_batch: Callable[[List[Request]], List[Any]],
                 *, max_batch: int = 32, max_wait_s: float = 0.002,
                 buckets: Sequence[int] = (1, 2, 4, 8, 16, 32)):
        self._process = process_batch
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.buckets = sorted(buckets)
        self._queue: "collections.deque[Tuple[Request, Future]]" = \
            collections.deque()
        self._lock = threading.Lock()
        self.batch_sizes: List[int] = []

    def submit(self, req: Request) -> Future:
        fut: Future = Future()
        with self._lock:
            self._queue.append((req, fut))
        return fut

    def bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def flush_loop_once(self) -> int:
        """Drain one micro-batch (call from the serving loop)."""
        deadline = time.perf_counter() + self.max_wait_s
        while time.perf_counter() < deadline:
            with self._lock:
                if len(self._queue) >= self.max_batch:
                    break
            time.sleep(self.max_wait_s / 10)
        with self._lock:
            take = min(len(self._queue), self.max_batch)
            items = [self._queue.popleft() for _ in range(take)]
        if not items:
            return 0
        reqs = [r for r, _ in items]
        self.batch_sizes.append(len(reqs))
        try:
            results = self._process(reqs)
            for (_, fut), res in zip(items, results):
                fut.set_result(res)
        except BaseException as e:
            for _, fut in items:
                fut.set_exception(e)
        return len(items)


class HedgedExecutor:
    """First-result-wins duplicate dispatch across replicas."""

    def __init__(self, replicas: Sequence[Callable[[Any], Any]], *,
                 hedge_quantile: float = 0.95, min_history: int = 8,
                 hedge_floor_s: float = 0.005):
        assert len(replicas) >= 1
        self.replicas = list(replicas)
        self.hedge_quantile = hedge_quantile
        self.hedge_floor_s = hedge_floor_s
        self.min_history = min_history
        self._lat: List[float] = []
        self._pool = ThreadPoolExecutor(max_workers=2 * len(replicas))
        self._rr = 0
        self.hedges_issued = 0
        self.hedges_won = 0

    def _deadline(self) -> float:
        if len(self._lat) < self.min_history:
            return self.hedge_floor_s
        return max(self.hedge_floor_s,
                   float(np.percentile(self._lat, 100 * self.hedge_quantile)))

    def call(self, payload: Any) -> Any:
        t0 = time.perf_counter()
        primary_idx = self._rr % len(self.replicas)
        self._rr += 1
        primary = self._pool.submit(self.replicas[primary_idx], payload)
        done, _ = wait([primary], timeout=self._deadline())
        futures = [primary]
        hedged: Optional[Future] = None
        if not done and len(self.replicas) > 1:
            backup_idx = (primary_idx + 1) % len(self.replicas)
            hedged = self._pool.submit(self.replicas[backup_idx], payload)
            futures.append(hedged)
            self.hedges_issued += 1
        done, _ = wait(futures, return_when=FIRST_COMPLETED)
        winner = next(iter(done))
        if hedged is not None and winner is hedged:
            self.hedges_won += 1
        result = winner.result()
        self._lat.append(time.perf_counter() - t0)
        return result

    def stats(self) -> Dict[str, float]:
        lat = np.asarray(self._lat) if self._lat else np.zeros(1)
        return {"calls": len(self._lat),
                "hedges_issued": self.hedges_issued,
                "hedges_won": self.hedges_won,
                "mean_ms": float(lat.mean() * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3)}
