"""Serving scheduler: continuous micro-batching + hedged dispatch.

``MicroBatcher`` — the serving front door: requests accumulate until
``max_batch`` or ``max_wait_s`` (deadline-based flush), then execute as
one device batch.  Padding to the next bucket keeps jit cache hits high
(static shapes).  Two execution modes share the drain/pad logic:

  * **synchronous** (``process_batch``): the callback computes the
    results before the flush returns — the original one-wave-at-a-time
    loop, still used by tests and simple tools.
  * **continuous** (``dispatch_batch``): the callback only *launches*
    the device work (jax async dispatch) and returns a completion
    thunk; the batcher keeps up to ``max_inflight`` launched batches
    outstanding and resolves their futures when it retires them.  The
    host therefore assembles wave N+1 while wave N runs on device —
    the device never idles waiting for host-side scheduling, which is
    what turns per-wave speedups into sustained QPS.

All queue and stats state is guarded by one lock (``submit`` may be
called from any number of client threads); the drain/retire path is
single-owner (``_drain_lock``), so two serving-loop threads calling
``flush_loop_once`` concurrently serialize instead of interleaving a
drain mid-pad.  Waiting for work uses a condition variable — a submit
wakes the flusher immediately, and an idle flusher sleeps instead of
hot-spinning the deadline poll.

``HedgedExecutor`` — tail-latency mitigation for multi-replica serving:
after an adaptive p95-based deadline, the slowest in-flight call is
re-issued on a second replica and the first result wins (Dean &
Barroso, "The Tail at Scale").  At 1000-node scale this is what keeps
p99 flat when a host degrades; tests/test_serving.py exercises it with
a deliberately slow replica.  It owns a thread pool, so it is a context
manager — call ``close()`` (or use ``with``) when tearing an engine or
benchmark down, or every rebuild leaks 2x``len(replicas)`` threads.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.concurrency import guarded_by, holds


@dataclasses.dataclass
class Request:
    conv_id: str
    payload: Any
    enqueue_t: float = dataclasses.field(default_factory=time.perf_counter)


@guarded_by("_lock", "_queue", "batch_sizes", "padded_sizes")
@guarded_by("_drain_lock", "_inflight")
class MicroBatcher:
    """Deadline-based micro-batching with shape bucketing.

    Every flush is padded up to ``bucket(n)`` with trailing **pad
    requests** (``conv_id == PAD_ID``, payload cloned from the first real
    request) before reaching the batch callback — so the callback only
    ever sees batch sizes from the bucket table and the jitted device
    program compiles once per bucket instead of once per distinct raw
    size.  Pad results are discarded (no futures exist for them);
    batch-aware callbacks such as the batched engine route pad rows to
    the session store's trash slot.  ``batch_sizes`` records the raw
    drained sizes, ``padded_sizes`` the dispatched (bucketed) sizes —
    both appended under the lock, so concurrent flusher threads cannot
    interleave the two lists out of step.

    Exactly one of ``process_batch`` (synchronous) and
    ``dispatch_batch`` (continuous) must be given.  ``dispatch_batch``
    receives the padded request list, launches the device work without
    blocking, and returns a zero-argument completion thunk yielding the
    per-request results; the batcher retires the oldest outstanding
    launch whenever ``max_inflight`` would be exceeded, and ``sync()``
    retires everything (serving-loop quiesce / ``drain``).
    """

    PAD_ID = "__pad__"   # reserved conv_id marking padding requests

    def __init__(self, process_batch: Optional[
                     Callable[[List[Request]], List[Any]]] = None,
                 *, dispatch_batch: Optional[
                     Callable[[List[Request]], Callable[[], List[Any]]]] = None,
                 max_batch: int = 32, max_wait_s: float = 0.002,
                 buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
                 max_inflight: int = 2):
        if (process_batch is None) == (dispatch_batch is None):
            raise ValueError(
                "exactly one of process_batch / dispatch_batch required")
        self._process = process_batch
        self._dispatch = dispatch_batch
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_inflight = max(1, int(max_inflight))
        # the table must cover max_batch, else a drain larger than the
        # top bucket would dispatch ragged (bucket() would return a
        # bucket *smaller* than n and the pad range would be empty)
        self.buckets = sorted(set(buckets) | {max_batch})
        self._queue: "collections.deque[Tuple[Request, Future]]" = \
            collections.deque()
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        # single-owner drain/retire: two flusher threads serialize here
        self._drain_lock = threading.Lock()
        self._inflight: "collections.deque[Tuple[List, Callable]]" = \
            collections.deque()
        self.batch_sizes: List[int] = []
        self.padded_sizes: List[int] = []

    def submit(self, req: Request) -> Future:
        fut: Future = Future()
        with self._work:
            self._queue.append((req, fut))
            self._work.notify()
        return fut

    def bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    @property
    def inflight(self) -> int:
        """Launched-but-unretired batches (continuous mode)."""
        with self._drain_lock:
            return len(self._inflight)

    def _wait_and_drain(self) -> List[Tuple[Request, Future]]:
        """Wait (condvar, not poll) until max_batch or the deadline,
        then pop up to max_batch items."""
        deadline = time.perf_counter() + self.max_wait_s
        with self._work:
            while len(self._queue) < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                self._work.wait(timeout=remaining)
            take = min(len(self._queue), self.max_batch)
            return [self._queue.popleft() for _ in range(take)]

    @holds("_drain_lock")
    def _retire_oldest_locked(self) -> None:
        """Complete the oldest in-flight launch and resolve its futures.
        Caller holds ``_drain_lock``."""
        items, complete = self._inflight.popleft()
        try:
            results = complete()
            # pads are trailing: zip over items covers exactly the real
            # requests and drops pad results
            for (_, fut), res in zip(items, results):
                fut.set_result(res)
        except BaseException as e:
            for _, fut in items:
                fut.set_exception(e)

    def flush_loop_once(self) -> int:
        """Drain one micro-batch (call from the serving loop).

        Continuous mode returns once the batch is *launched* (futures
        resolve when the launch is retired — after ``max_inflight``
        later launches, or at ``sync()``); synchronous mode returns with
        the futures already resolved.  Returns the number of real
        requests drained.
        """
        with self._drain_lock:
            items = self._wait_and_drain()
            if not items:
                return 0
            reqs = [r for r, _ in items]
            # pad to the bucket so the batch callback always dispatches
            # a bucketed (jit-cache-stable) batch; pad payloads clone a
            # real request so any payload-shape assumptions hold
            bb = self.bucket(len(reqs))
            padded = reqs + [Request(self.PAD_ID, reqs[0].payload)
                             for _ in range(bb - len(reqs))]
            with self._lock:
                self.batch_sizes.append(len(reqs))
                self.padded_sizes.append(len(padded))
            if self._dispatch is None:
                try:
                    results = self._process(padded)
                    for (_, fut), res in zip(items, results):
                        fut.set_result(res)
                except BaseException as e:
                    for _, fut in items:
                        fut.set_exception(e)
                return len(items)
            try:
                complete = self._dispatch(padded)
            except BaseException as e:
                for _, fut in items:
                    fut.set_exception(e)
                return len(items)
            self._inflight.append((items, complete))
            # two-in-flight steady state: launching wave N+1 retires
            # wave N — its device work overlapped this launch's host
            # assembly, so the (blocking) completion is cheap by now
            while len(self._inflight) >= self.max_inflight:
                self._retire_oldest_locked()
            return len(items)

    def sync(self) -> None:
        """Retire every outstanding launch (continuous mode quiesce)."""
        with self._drain_lock:
            while self._inflight:
                self._retire_oldest_locked()

    @contextlib.contextmanager
    def paused(self) -> Iterator[None]:
        """Quiesce AND hold the drain path closed for the scope.

        ``sync()`` alone is not enough for a caller about to mutate
        state a wave reads (the corpus index, the session slab): between
        ``sync()`` returning and the mutation landing, a concurrent
        ``flush_loop_once`` can drain the queue and *launch* a wave
        against the pre-mutation state — whose futures then resolve
        after the mutation call returned (the delete-vs-wave race the
        schedule explorer replays).  ``paused()`` retires every
        outstanding launch and keeps ``_drain_lock`` held until the
        scope exits, so no wave can launch while the caller swaps state
        underneath the batcher.  Queued requests are untouched — they
        dispatch on the first flush after resume, observing the mutated
        state.
        """
        with self._drain_lock:
            while self._inflight:
                self._retire_oldest_locked()
            yield


@guarded_by("_lock", "_lat", "_rr", "calls", "hedges_issued",
            "hedges_won", "failovers")
class HedgedExecutor:
    """First-*successful*-result-wins duplicate dispatch across replicas.

    Winner selection is deterministic: among completed futures the
    primary is considered before the hedge (``wait`` returns an
    unordered set, so ``next(iter(done))`` would make ``hedges_won`` —
    and, worse, *which exception propagates* — depend on set iteration
    order).  A failed completion never wins while another replica is
    still running or succeeded: a primary that fails *before* the hedge
    deadline triggers an immediate failover dispatch to the backup
    (counted in ``failovers``, not ``hedges_issued``), and the call
    raises only when every issued replica failed (then the primary's
    exception propagates).  ``hedges_won`` counts only hedges that
    strictly beat a still-pending primary — a hedge or failover that
    merely rescued a failed primary is not a latency win.

    The latency history backing the adaptive p95 deadline is a bounded
    deque (``lat_window``), so ``_deadline()`` stays O(window) instead
    of percentile-over-all-time-calls, and the deadline tracks the
    *recent* latency distribution at sustained traffic.

    Owns a ``ThreadPoolExecutor`` — ``close()`` (idempotent; also via
    ``with``) shuts it down, or every engine/benchmark rebuild leaks
    2x``len(replicas)`` threads.  ``call`` after ``close`` raises
    ``RuntimeError`` immediately — nothing is ever queued on the
    shut-down pool.

    ``call`` may be invoked from any number of threads concurrently
    (the replica router fronts it with a 2R-worker pool), so the
    round-robin cursor, the counters, and the latency window are
    guarded by ``_lock``; the replica dispatch and the wait loop run
    outside it (holding a lock across a cross-replica RPC would
    serialize the hedging this class exists to provide).
    """

    def __init__(self, replicas: Sequence[Callable[[Any], Any]], *,
                 hedge_quantile: float = 0.95, min_history: int = 8,
                 hedge_floor_s: float = 0.005, lat_window: int = 1024):
        assert len(replicas) >= 1
        self.replicas = list(replicas)
        self.hedge_quantile = hedge_quantile
        self.hedge_floor_s = hedge_floor_s
        self.min_history = min_history
        self._lat: "collections.deque[float]" = collections.deque(
            maxlen=lat_window)
        self._pool = ThreadPoolExecutor(max_workers=2 * len(replicas),
                                        thread_name_prefix="hedge")
        self._lock = threading.Lock()
        self._closed = False
        self._rr = 0
        self.calls = 0
        self.hedges_issued = 0
        self.hedges_won = 0
        self.failovers = 0

    def close(self) -> None:
        """Shut the replica thread pool down (waits for in-flight
        calls).  Idempotent."""
        if not self._closed:
            self._closed = True
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "HedgedExecutor":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def _deadline(self) -> float:
        with self._lock:
            if len(self._lat) < self.min_history:
                return self.hedge_floor_s
            lat = list(self._lat)
        return max(self.hedge_floor_s,
                   float(np.percentile(lat, 100 * self.hedge_quantile)))

    def call(self, payload: Any) -> Any:
        if self._closed:
            raise RuntimeError("HedgedExecutor is closed")
        t0 = time.perf_counter()
        with self._lock:
            self.calls += 1
            primary_idx = self._rr % len(self.replicas)
            self._rr += 1
        primary = self._pool.submit(self.replicas[primary_idx], payload)
        done, _ = wait([primary], timeout=self._deadline())
        futures = [primary]
        backup_idx = (primary_idx + 1) % len(self.replicas)
        hedged: Optional[Future] = None
        if not done and len(self.replicas) > 1:
            hedged = self._pool.submit(self.replicas[backup_idx], payload)
            futures.append(hedged)
            with self._lock:
                self.hedges_issued += 1
        elif (done and len(self.replicas) > 1
              and primary.exception() is not None):
            # primary failed before the hedge deadline: fail over to the
            # backup immediately rather than raising with a healthy
            # replica untried
            hedged = self._pool.submit(self.replicas[backup_idx], payload)
            futures.append(hedged)
            with self._lock:
                self.failovers += 1
        winner: Optional[Future] = None
        pending = set(futures)
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            # deterministic preference: primary before hedge
            ok = [f for f in futures if f in done and f.exception() is None]
            if ok:
                winner = ok[0]
                if winner is hedged and primary in pending:
                    with self._lock:
                        self.hedges_won += 1
                break
        if winner is None:       # every issued replica failed
            winner = primary
        result = winner.result()
        with self._lock:
            self._lat.append(time.perf_counter() - t0)
        return result

    def stats(self) -> Dict[str, float]:
        with self._lock:
            lat = np.asarray(self._lat) if self._lat else np.zeros(1)
            return {"calls": self.calls,
                    "hedges_issued": self.hedges_issued,
                    "hedges_won": self.hedges_won,
                    "failovers": self.failovers,
                    "mean_ms": float(lat.mean() * 1e3),
                    "p99_ms": float(np.percentile(lat, 99) * 1e3)}
