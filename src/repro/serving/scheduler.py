"""Serving scheduler: micro-batching + hedged (straggler-proof) dispatch.

``MicroBatcher`` — classic continuous-batching front door: requests
accumulate until ``max_batch`` or ``max_wait_s`` (deadline-based flush),
then execute as one device batch.  Padding to the next bucket keeps jit
cache hits high (static shapes).

``HedgedExecutor`` — tail-latency mitigation for multi-replica serving:
after an adaptive p95-based deadline, the slowest in-flight call is
re-issued on a second replica and the first result wins (Dean &
Barroso, "The Tail at Scale").  At 1000-node scale this is what keeps
p99 flat when a host degrades; tests/test_serving.py exercises it with
a deliberately slow replica.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    conv_id: str
    payload: Any
    enqueue_t: float = dataclasses.field(default_factory=time.perf_counter)


class MicroBatcher:
    """Deadline-based micro-batching with shape bucketing.

    Every flush is padded up to ``bucket(n)`` with trailing **pad
    requests** (``conv_id == PAD_ID``, payload cloned from the first real
    request) before reaching ``process_batch`` — so the callback only
    ever sees batch sizes from the bucket table and the jitted device
    program compiles once per bucket instead of once per distinct raw
    size.  Pad results are discarded (no futures exist for them);
    batch-aware callbacks such as the batched engine route pad rows to
    the session store's trash slot.  ``batch_sizes`` records the raw
    drained sizes, ``padded_sizes`` the dispatched (bucketed) sizes.
    """

    PAD_ID = "__pad__"   # reserved conv_id marking padding requests

    def __init__(self, process_batch: Callable[[List[Request]], List[Any]],
                 *, max_batch: int = 32, max_wait_s: float = 0.002,
                 buckets: Sequence[int] = (1, 2, 4, 8, 16, 32)):
        self._process = process_batch
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        # the table must cover max_batch, else a drain larger than the
        # top bucket would dispatch ragged (bucket() would return a
        # bucket *smaller* than n and the pad range would be empty)
        self.buckets = sorted(set(buckets) | {max_batch})
        self._queue: "collections.deque[Tuple[Request, Future]]" = \
            collections.deque()
        self._lock = threading.Lock()
        self.batch_sizes: List[int] = []
        self.padded_sizes: List[int] = []

    def submit(self, req: Request) -> Future:
        fut: Future = Future()
        with self._lock:
            self._queue.append((req, fut))
        return fut

    def bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def flush_loop_once(self) -> int:
        """Drain one micro-batch (call from the serving loop)."""
        deadline = time.perf_counter() + self.max_wait_s
        while time.perf_counter() < deadline:
            with self._lock:
                if len(self._queue) >= self.max_batch:
                    break
            time.sleep(self.max_wait_s / 10)
        with self._lock:
            take = min(len(self._queue), self.max_batch)
            items = [self._queue.popleft() for _ in range(take)]
        if not items:
            return 0
        reqs = [r for r, _ in items]
        self.batch_sizes.append(len(reqs))
        # pad to the bucket so the process callback always dispatches a
        # bucketed (jit-cache-stable) batch; pad payloads clone a real
        # request so any payload-shape assumptions hold
        bb = self.bucket(len(reqs))
        reqs = reqs + [Request(self.PAD_ID, reqs[0].payload)
                       for _ in range(bb - len(reqs))]
        self.padded_sizes.append(len(reqs))
        try:
            results = self._process(reqs)
            # pads are trailing: zip over items covers exactly the real
            # requests and drops pad results
            for (_, fut), res in zip(items, results):
                fut.set_result(res)
        except BaseException as e:
            for _, fut in items:
                fut.set_exception(e)
        return len(items)


class HedgedExecutor:
    """First-*successful*-result-wins duplicate dispatch across replicas.

    Winner selection is deterministic: among completed futures the
    primary is considered before the hedge (``wait`` returns an
    unordered set, so ``next(iter(done))`` would make ``hedges_won`` —
    and, worse, *which exception propagates* — depend on set iteration
    order).  A failed completion never wins while another replica is
    still running or succeeded: a primary that fails *before* the hedge
    deadline triggers an immediate failover dispatch to the backup
    (counted in ``failovers``, not ``hedges_issued``), and the call
    raises only when every issued replica failed (then the primary's
    exception propagates).  ``hedges_won`` counts only hedges that
    strictly beat a still-pending primary — a hedge or failover that
    merely rescued a failed primary is not a latency win.

    The latency history backing the adaptive p95 deadline is a bounded
    deque (``lat_window``), so ``_deadline()`` stays O(window) instead
    of percentile-over-all-time-calls, and the deadline tracks the
    *recent* latency distribution at sustained traffic.
    """

    def __init__(self, replicas: Sequence[Callable[[Any], Any]], *,
                 hedge_quantile: float = 0.95, min_history: int = 8,
                 hedge_floor_s: float = 0.005, lat_window: int = 1024):
        assert len(replicas) >= 1
        self.replicas = list(replicas)
        self.hedge_quantile = hedge_quantile
        self.hedge_floor_s = hedge_floor_s
        self.min_history = min_history
        self._lat: "collections.deque[float]" = collections.deque(
            maxlen=lat_window)
        self._pool = ThreadPoolExecutor(max_workers=2 * len(replicas))
        self._rr = 0
        self.calls = 0
        self.hedges_issued = 0
        self.hedges_won = 0
        self.failovers = 0

    def _deadline(self) -> float:
        if len(self._lat) < self.min_history:
            return self.hedge_floor_s
        return max(self.hedge_floor_s,
                   float(np.percentile(self._lat, 100 * self.hedge_quantile)))

    def call(self, payload: Any) -> Any:
        t0 = time.perf_counter()
        self.calls += 1
        primary_idx = self._rr % len(self.replicas)
        self._rr += 1
        primary = self._pool.submit(self.replicas[primary_idx], payload)
        done, _ = wait([primary], timeout=self._deadline())
        futures = [primary]
        backup_idx = (primary_idx + 1) % len(self.replicas)
        hedged: Optional[Future] = None
        if not done and len(self.replicas) > 1:
            hedged = self._pool.submit(self.replicas[backup_idx], payload)
            futures.append(hedged)
            self.hedges_issued += 1
        elif (done and len(self.replicas) > 1
              and primary.exception() is not None):
            # primary failed before the hedge deadline: fail over to the
            # backup immediately rather than raising with a healthy
            # replica untried
            hedged = self._pool.submit(self.replicas[backup_idx], payload)
            futures.append(hedged)
            self.failovers += 1
        winner: Optional[Future] = None
        pending = set(futures)
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            # deterministic preference: primary before hedge
            ok = [f for f in futures if f in done and f.exception() is None]
            if ok:
                winner = ok[0]
                if winner is hedged and primary in pending:
                    self.hedges_won += 1
                break
        if winner is None:       # every issued replica failed
            winner = primary
        result = winner.result()
        self._lat.append(time.perf_counter() - t0)
        return result

    def stats(self) -> Dict[str, float]:
        lat = np.asarray(self._lat) if self._lat else np.zeros(1)
        return {"calls": self.calls,
                "hedges_issued": self.hedges_issued,
                "hedges_won": self.hedges_won,
                "failovers": self.failovers,
                "mean_ms": float(lat.mean() * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3)}
