"""Device-resident session store for batched conversational serving.

The sequential engine keeps one ``IVFSession`` / ``HNSWSession`` pytree
per conversation in a Python dict — fine for one turn at a time, useless
for batched dispatch (B separate pytrees would need B gathers anyway).
``SessionStore`` instead keeps *one* struct-of-arrays slab per field with
a fixed slot count:

    cache_ids  (S+1, h)      int32      entry_point (S+1,) int32
    cache_vecs (S+1, h, d)   float32    turn        (S+1,) int32
    anchor_sel (S+1, np)     int32
    refreshes  (S+1,)        int32
    turn       (S+1,)        int32

so serving a micro-batch is: gather B rows → one jitted batched step →
scatter B rows back.  Slot bookkeeping (conv_id → slot, free list, LRU
eviction) is host-side Python — it is O(B) dict work per flush and never
touches device memory.

Slot model
  * ``n_slots`` live slots are allocated from a free list; slot ids are
    stable for the lifetime of a conversation (sticky sessions).
  * One extra **trash slot** (index ``n_slots``) absorbs the padded rows
    of a partially-filled device batch: padded rows gather/scatter the
    trash row, so they can run the full batched program without ever
    corrupting a live session.
  * When the store is full, the least-recently-served conversation is
    evicted.  An evicted conversation that returns is treated as a first
    turn again (its C0 cache / entry point is rebuilt from the current
    utterance) — the same semantics as a TopLoc_IVF+ refresh, so
    effectiveness degrades gracefully rather than failing.

The store is **per-replica state**: on a 2-D ``(replica, shard)``
serving mesh each replica engine owns its own slab on its own device
group, and ``serving.router.ReplicatedSearchEngine`` pins a
conversation to one replica for its lifetime — a session gathered on
replica r must be scattered back to the same slab, and cross-replica
migration would lose the C0 cache (DESIGN.md §2).  When the *corpus*
is sharded over a device mesh (``distributed.retrieval``) the slab
replicates over that mesh — sessions are the replicated TopLoc state;
only posting lists / vector rows shard.

Continuous batching note: the engine launches wave N+1 before wave N's
results are fetched.  This is safe *because* every wave chains through
the slab on one device stream — wave N's ``scatter`` (which consumes
the donated slab) is enqueued before wave N+1's ``gather``, so in-order
stream execution gives wave N+1 the updated rows and donation never
frees a buffer a pending gather still reads.
"""
from __future__ import annotations

import functools
import threading
import warnings
from collections import OrderedDict
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as _P

from repro.concurrency import guarded_by
from repro.core import hnsw as _hnsw
from repro.core import ivf as _ivf
from repro.core import pq as _pq


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_slab(slab: Any, idx: jax.Array, updates: Any) -> Any:
    """Scatter batched session rows into the slab.

    The old slab is donated: on TPU the row writes happen in place, so a
    flush costs O(B · row) instead of an O(S · row) slab copy.  (CPU jax
    ignores the donation and copies — correct either way.)
    """
    return jax.tree.map(lambda a, u: a.at[idx].set(u), slab, updates)


@guarded_by("_lock", "_slab", "_slot_of", "_free",
            "allocs", "evictions", "hits")
class SessionStore:
    """Fixed-capacity struct-of-arrays slab of per-conversation state.

    Thread safety: slot bookkeeping and the slab reference are guarded
    by an internal ``RLock`` (reentrant because ``acquire``/``release``
    scatter through ``self.scatter`` while already holding it).  The
    batched engine serializes its wave path through the MicroBatcher's
    drain lock, but ``release`` arrives on *client* threads
    (``end_conversation``) — without the store lock, a release racing a
    wave's ``acquire`` could interleave the free-list append with an
    LRU eviction and hand one slot to two conversations.  Lock
    acquisition order is always batcher drain lock → store lock, never
    the reverse (the store calls nothing that flushes).
    """

    def __init__(self, template: Any, n_slots: int, *, mesh: Any = None):
        """``template``: a single-session pytree (no leading batch dim)
        whose leaf shapes/dtypes define the slab layout.

        ``mesh``: optional corpus mesh (distributed.retrieval) — the slab
        is *replicated* over it, matching the replicated TopLoc session
        state of the sharded scan paths (only the corpus shards).
        """
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.n_slots = n_slots
        self._slab = jax.tree.map(
            lambda a: jnp.zeros((n_slots + 1,) + jnp.shape(a),
                                jnp.asarray(a).dtype), template)
        # the all-zero row scattered over released slots (one row batch)
        self._zero_row = jax.tree.map(
            lambda a: jnp.zeros((1,) + jnp.shape(a), jnp.asarray(a).dtype),
            template)
        if mesh is not None:
            rep = lambda a: jax.device_put(a, NamedSharding(mesh, _P()))
            self._slab = jax.tree.map(rep, self._slab)
            self._zero_row = jax.tree.map(rep, self._zero_row)
        self._lock = threading.RLock()
        self._free = list(range(n_slots - 1, -1, -1))   # pop() → slot 0 first
        self._slot_of: "OrderedDict[str, int]" = OrderedDict()  # LRU order
        self._slot_freed_listeners: list = []
        self.allocs = 0
        self.evictions = 0
        self.hits = 0

    # -- slot bookkeeping (host) --------------------------------------

    @property
    def trash_slot(self) -> int:
        """Slot absorbing padded batch rows; never mapped to a conv."""
        return self.n_slots

    def add_slot_freed_listener(self, fn) -> None:
        """Register ``fn(slot)`` to run whenever a slot leaves its
        conversation (release or LRU eviction), *after* the slab row has
        been zeroed.  Companion per-slot state — e.g. the serving
        result cache's slab (``serving.result_cache``) — hooks in here
        so a recycled slot can never leak another conversation's
        entries."""
        self._slot_freed_listeners.append(fn)

    def _notify_slot_freed(self, slot: int) -> None:
        for fn in self._slot_freed_listeners:
            fn(slot)

    @property
    def occupancy(self) -> int:
        with self._lock:
            return len(self._slot_of)

    def lookup(self, conv_id: str) -> Optional[int]:
        with self._lock:
            return self._slot_of.get(conv_id)

    def acquire(self, conv_id: str) -> Tuple[int, bool]:
        """Slot for ``conv_id``; allocates (evicting LRU if full).

        Returns (slot, is_new) — ``is_new`` means the slot holds no state
        for this conversation and the caller must treat the turn as a
        first turn (full cache build).
        """
        with self._lock:
            slot = self._slot_of.get(conv_id)
            if slot is not None:
                self._slot_of.move_to_end(conv_id)
                self.hits += 1
                return slot, False
            if not self._free:
                lru_id, lru_slot = next(iter(self._slot_of.items()))
                del self._slot_of[lru_id]
                self._free.append(lru_slot)
                self.evictions += 1
                # same leak protection as release(): the evicted row is
                # wiped before the slot changes hands, so the new
                # occupant can never read the evicted conversation's
                # cache
                self.scatter([lru_slot], self._zero_row)
                self._notify_slot_freed(lru_slot)
            slot = self._free.pop()
            self._slot_of[conv_id] = slot
            self.allocs += 1
            return slot, True

    def release(self, conv_id: str) -> Optional[int]:
        """End a conversation; its slot returns to the free list.

        The released slab row is zeroed (the template row is scattered
        over it) so a freed slot can never leak the prior conversation's
        centroid cache / entry point to a later occupant — a misbehaving
        caller that skips the ``is_first`` rebuild reads zeros, not
        another user's state.  Idempotent: releasing an unknown or
        already-released ``conv_id`` is a no-op returning ``None`` (in
        particular the slot is never double-appended to the free list,
        which would hand one slot to two conversations).
        """
        with self._lock:
            slot = self._slot_of.pop(conv_id, None)
            if slot is not None:
                self._free.append(slot)
                self.scatter([slot], self._zero_row)
                self._notify_slot_freed(slot)
            return slot

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"n_slots": self.n_slots, "occupancy": self.occupancy,
                    "allocs": self.allocs, "evictions": self.evictions,
                    "hits": self.hits}

    # -- device slab access -------------------------------------------

    def gather(self, slots: Sequence[int]) -> Any:
        """Session pytree batch for ``slots`` (leading dim len(slots))."""
        idx = jnp.asarray(np.asarray(slots, np.int32))
        with self._lock:
            return jax.tree.map(lambda a: a[idx], self._slab)

    def scatter(self, slots: Sequence[int], sessions: Any) -> None:
        """Write a batched session pytree back into the slab rows.

        ``slots`` may repeat only on the trash slot (padded rows);
        live-slot rows must be unique within one call — the batched
        engine guarantees one turn per conversation per device batch.
        """
        idx = jnp.asarray(np.asarray(slots, np.int32))
        with self._lock, warnings.catch_warnings():
            # CPU backends warn that the donated slab was not consumed
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            self._slab = _scatter_slab(self._slab, idx, sessions)

    def clear(self, slots: Sequence[int]) -> None:
        """Zero the given slab rows in one scatter (the template row is
        tiled to the batch, not dispatched once per slot)."""
        slots = list(slots)
        if not slots:
            return
        tiled = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (len(slots),) + a.shape[1:]),
            self._zero_row)
        self.scatter(slots, tiled)

    @property
    def slab(self) -> Any:
        """The raw slab pytree (leading dim ``n_slots + 1``).  Read-only
        view for bulk inspection (e.g. the result cache's tombstone
        sweep); mutate only through ``scatter``/``clear``."""
        with self._lock:
            return self._slab


def store_for_backend(backend: Any, index: Any, *, n_slots: int,
                      mesh: Any = None) -> Optional[SessionStore]:
    """Slab sized by ``backend.session_template(index)`` — the generic
    constructor both engines use (``core.backend`` registry).  Returns
    None for stateless backends (no per-conversation state)."""
    template = backend.session_template(index)
    if template is None:
        return None
    return SessionStore(template, n_slots, mesh=mesh)


def ivf_session_store(index: "_ivf.IVFIndex | _pq.IVFPQIndex", *, h: int,
                      nprobe: int, n_slots: int,
                      mesh: Any = None) -> SessionStore:
    """Slab of ``toploc.IVFSession`` rows sized for ``index`` (reads
    only the ``.d``/``.centroids`` fields both index types share)."""
    from repro.core import backend as _backend
    return store_for_backend(_backend.IVFBackend(h=h, nprobe=nprobe),
                             index, n_slots=n_slots, mesh=mesh)


def ivf_pq_session_store(index: _pq.IVFPQIndex, *, h: int, nprobe: int,
                         n_slots: int, mesh: Any = None) -> SessionStore:
    """Slab for the IVF-PQ backend.

    TopLoc_IVFPQ reuses the ``IVFSession`` layout unchanged (the
    centroid cache is identical — only the list scan differs), so this
    delegates to the float-IVF store builder, which only reads the
    ``.d``/``.centroids`` fields both index types share.
    """
    return ivf_session_store(index, h=h, nprobe=nprobe, n_slots=n_slots,
                             mesh=mesh)


def hnsw_session_store(index: _hnsw.HNSWIndex, *, n_slots: int,
                       mesh: Any = None) -> SessionStore:
    """Slab of ``toploc.HNSWSession`` rows."""
    from repro.core import backend as _backend
    return store_for_backend(_backend.HNSWBackend(), index,
                             n_slots=n_slots, mesh=mesh)
