"""Pallas TPU kernel: fused posting-list gather + dot + masked top-k.

TopLoc hot spot #2 (DESIGN.md §2): after centroid selection, IVF scans the
``nprobe`` selected posting lists exhaustively.  The naive XLA path
materialises the gathered ``(B, np, Lmax, d)`` list tensor in HBM (a full
extra round trip).  Here the *scalar-prefetched* selection indices drive
the BlockSpec index_map directly, so each selected list tile is DMA'd
HBM→VMEM exactly once, scored on the MXU against the query, masked
(padding lanes → -inf) and folded into a running per-query top-k register
tile via the bitonic merge network.  This is the classic
``PrefetchScalarGridSpec`` data-dependent-gather pattern.

Grid: ``(B, nprobe)`` — the nprobe axis is sequential ("arbitrary") so
the running tile carries across a query's lists; the batch axis is
parallel.

VMEM per step (Lmax≤2048, d≤1024, f32): list tile ≤ 8 MB — for larger
(Lmax·d) the ops wrapper splits lists into sub-tiles by lowering blk_l.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

from repro.kernels import sorting


def _kernel(sel_ref, q_ref, lv_ref, li_ref, out_v_ref, out_i_ref,
            run_v, run_i, *, k: int, nprobe: int, nsub: int):
    j = pl.program_id(1)          # probe-tile index (sequential)

    @pl.when(j == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, -jnp.inf)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)                    # (1, d)
    lv = lv_ref[...].astype(jnp.float32)                  # (1, blk_l, d)
    li = li_ref[...]                                      # (1, blk_l)
    scores = jax.lax.dot_general(
        lv[0], q[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (blk_l,)
    scores = jnp.where(li[0] >= 0, scores, -jnp.inf)[None]  # (1, blk_l)

    blk_v, blk_i = sorting.block_topk_desc(scores, li, k)
    mv, mi = sorting.merge_topk_desc(run_v[...], run_i[...], blk_v, blk_i)
    run_v[...] = mv
    run_i[...] = mi

    @pl.when(j == nprobe * nsub - 1)
    def _finalize():
        out_v_ref[...] = run_v[...]
        out_i_ref[...] = run_i[...]


@functools.partial(jax.jit, static_argnames=("k", "blk_l", "interpret"))
def ivf_scan(queries: jax.Array, list_vecs: jax.Array, list_ids: jax.Array,
             sel: jax.Array, k: int, *, blk_l: int = 0,
             interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Fused IVF list scan.

    queries (B, d); list_vecs (p, Lmax, d); list_ids (p, Lmax) int32
    (-1 pad); sel (B, nprobe) int32 — per-query selected partitions.

    Returns (values (B, k) f32 desc, doc_ids (B, k) int32).
    Padding contract (ops.py): Lmax multiple of blk_l, blk_l & k pow2,
    k ≤ blk_l.
    """
    b, d = queries.shape
    p, lmax, _ = list_vecs.shape
    nprobe = sel.shape[1]
    if blk_l == 0:
        blk_l = lmax
    assert lmax % blk_l == 0, (lmax, blk_l)
    nsub = lmax // blk_l
    assert sorting._is_pow2(k) and sorting._is_pow2(blk_l) and k <= blk_l

    kern = functools.partial(_kernel, k=k, nprobe=nprobe, nsub=nsub)
    grid = (b, nprobe * nsub)

    def lv_map(bi, j, sel_ref):
        return (sel_ref[bi, j // nsub], j % nsub, 0)

    def li_map(bi, j, sel_ref):
        return (sel_ref[bi, j // nsub], j % nsub)

    out_v, out_i = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d), lambda bi, j, sel_ref: (bi, 0)),
                pl.BlockSpec((1, blk_l, d), lv_map),
                pl.BlockSpec((1, blk_l), li_map),
            ],
            out_specs=[
                pl.BlockSpec((1, k), lambda bi, j, sel_ref: (bi, 0)),
                pl.BlockSpec((1, k), lambda bi, j, sel_ref: (bi, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((1, k), jnp.float32),
                pltpu.VMEM((1, k), jnp.int32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(sel, queries, list_vecs, list_ids)
    return out_v, out_i
