"""Pallas TPU kernel: fused EmbeddingBag (gather + weighted segment-sum).

RecSys hot path (kernel_taxonomy §B.6 / §B.11): JAX has no native
EmbeddingBag, and the jnp path (``table[ids]`` then einsum) materialises
the gathered ``(B, L, d)`` rows in HBM.  This kernel drives the row
gather from *scalar-prefetched* bag ids through the BlockSpec index_map —
each table row streams HBM→VMEM once and is accumulated directly into
the output tile, so the op runs at gather-bandwidth with zero
intermediate traffic.

Grid: ``(B, L)`` — L sequential (running accumulation per bag).
Production note: one row per step keeps the index_map exact for
arbitrary vocab sizes; rows are d ≤ 256 floats, and the MXU is idle here
anyway (pure bandwidth op).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _kernel(ids_ref, w_ref, table_ref, o_ref, acc, *, bag: int):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    idx = ids_ref[b, l]
    w = w_ref[b, l]
    valid = (idx >= 0).astype(jnp.float32) * w
    row = table_ref[...].astype(jnp.float32)        # (1, d)
    acc[...] = acc[...] + row * valid

    @pl.when(l == bag - 1)
    def _finalize():
        o_ref[...] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: Optional[jax.Array] = None, *,
                  interpret: bool = False) -> jax.Array:
    """Sum-mode bag lookup. table (V, d), ids (B, L) int32 (-1 pad),
    weights (B, L) or None. Returns (B, d) in table dtype.

    Mean mode / normalisation is applied by ``ops.embedding_bag``.
    """
    v, d = table.shape
    b, bag = ids.shape
    if weights is None:
        weights = jnp.ones((b, bag), jnp.float32)

    kern = functools.partial(_kernel, bag=bag)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, bag),
            in_specs=[
                pl.BlockSpec(
                    (1, d),
                    lambda bi, l, ids_ref, w_ref: (
                        jnp.maximum(ids_ref[bi, l], 0), 0)),
            ],
            out_specs=pl.BlockSpec((1, d),
                                   lambda bi, l, ids_ref, w_ref: (bi, 0)),
            scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ids, weights.astype(jnp.float32), table)
    return out
