"""Tile autotuner for the fused retrieval megakernel.

The fused turn (``kernels.fused_turn``) exposes three tiling knobs —
``blk_p`` (centroid tile rows), ``max_tile`` (posting-list tile cap fed
to ``tiling.list_tile``) and ``over`` (quantised candidate depth
``r = k·over``).  The right setting depends on the turn *shape*
(batch, p, Lmax, d, nprobe, k, precision, family): small batches want
wide list tiles to amortise per-step overhead, large d hits the VMEM
byte cap first, quantised paths trade re-rank rows against recall.

This module sweeps the knob grid for a shape, scores every candidate
with a roofline model (compute vs HBM vs per-step overhead, mirroring
the dry-run's cost accounting), optionally validates the top candidates
empirically against the live op, and caches the winner as JSON under
``artifacts/autotune/`` keyed by shape + device kind.  The cache is an
artifact, not source — it is gitignored and regenerates
deterministically (the model is pure arithmetic; validation re-times).

``benchmarks/roofline_report.py --autotune`` renders the cached entries
and judges autotuned vs static-default predicted times.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import jax

from repro.kernels import tiling

CACHE_DIR = os.environ.get("AUTOTUNE_CACHE", "artifacts/autotune")

_ITEM = {"f32": 4, "bf16": 2, "int8": 1}


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One point of the fused-kernel tuning grid (hashable, jit-static).

    ``blk_p``/``max_tile`` are *requests* — the binding tile split is
    whatever ``tiling.centroid_tile``/``list_tile`` resolve them to, so
    two configs that clamp to the same tiles are the same program.
    """
    blk_p: int = 512
    max_tile: int = 2048
    over: int = 2


DEFAULT = TileConfig()


@dataclasses.dataclass(frozen=True)
class TurnShape:
    """Static shape of one fused turn — the autotune cache key."""
    b: int
    p: int
    lmax: int
    d: int
    nprobe: int
    k: int
    precision: str = "f32"
    family: str = "ivf"          # "ivf" | "pq"
    m: int = 0                   # PQ subquantizers (family == "pq")
    rerank: int = 0              # PQ exact re-rank depth (backend knob)

    def key(self) -> str:
        return (f"{self.family}_b{self.b}_p{self.p}_L{self.lmax}"
                f"_d{self.d}_np{self.nprobe}_k{self.k}_m{self.m}"
                f"_r{self.rerank}_{self.precision}")


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Coarse roofline terms for the target device.

    Absolute numbers only need to be the right order of magnitude — the
    autotuner ranks *relative* candidate times on one device, and the
    fig8 judge compares predictions made with the same model.
    """
    name: str
    flops: float                 # peak f32 FLOP/s
    hbm_bw: float                # HBM bytes/s
    dispatch_s: float            # per-kernel-launch host overhead
    step_s: float                # per-grid-step sequencing overhead
    sort_flop: float = 4.0       # compare-exchange cost multiplier


TPU_MODEL = DeviceModel(name="tpu", flops=2.75e13, hbm_bw=1.2e12,
                        dispatch_s=5e-6, step_s=1.5e-7)
CPU_MODEL = DeviceModel(name="cpu", flops=5e10, hbm_bw=2e10,
                        dispatch_s=3e-5, step_s=1e-6)


def device_model() -> DeviceModel:
    return TPU_MODEL if jax.default_backend() == "tpu" else CPU_MODEL


# ---------------------------------------------------------------------------
# roofline model
# ---------------------------------------------------------------------------


def _log2(n: int) -> int:
    return max(int(n).bit_length() - 1, 0)


def resolve(shape: TurnShape, cfg: TileConfig
            ) -> Dict[str, int]:
    """The binding tile split for (shape, cfg) — exactly the numbers
    ``ops.fused_turn``/``fused_turn_pq`` derive before calling the
    kernel, so predictions and the live program can never disagree."""
    s, c = shape, cfg
    np_pad = tiling.next_pow2(s.nprobe)
    if s.family == "pq":
        want = s.rerank or s.k
    elif s.precision == "f32":
        want = s.k
    else:
        want = s.k * c.over
    r = max(s.k, min(want, s.nprobe * s.lmax))
    r_pad = tiling.next_pow2(r)
    blk, p_pad = tiling.centroid_tile(s.p, np_pad, blk_p=c.blk_p)
    row_bytes = s.m if s.family == "pq" else s.d * _ITEM["f32"]
    blk_l, lpad = tiling.list_tile(s.lmax, row_bytes, kp=r_pad,
                                   max_tile=c.max_tile)
    return dict(np_pad=np_pad, r=r, r_pad=r_pad, blk=blk, p_pad=p_pad,
                blk_l=blk_l, lpad=lpad, row_bytes=row_bytes,
                kp=tiling.next_pow2(s.k))


def vmem_bytes(shape: TurnShape, cfg: TileConfig) -> int:
    """Fused-kernel VMEM residency under (shape, cfg): blocked operands
    + scratch, with the streamed list tile double-buffered.  Mirrors the
    scratch list of ``fused_turn._turn_call`` (the ``kernel_budget``
    pass audits the same accounting against the traced kernel)."""
    s = shape
    t = resolve(shape, cfg)
    by = 0
    by += s.b * s.d * 4                       # q block
    by += t["blk"] * s.d * 4                  # centroid tile
    if s.family == "pq":
        by += s.b * s.m * 256 * 4             # ADC tables (≤256 codes)
    by += 2 * s.b * t["np_pad"] * 4           # run_pv/run_pi
    by += 3 * s.b * t["r_pad"] * 4            # run_cv/ci/cp
    by += 2 * t["blk_l"] * t["row_bytes"]     # lbuf, double-buffered
    by += 2 * t["blk_l"] * 4                  # ibuf
    if s.precision != "f32" or s.family == "pq":
        by += t["r_pad"] * s.d * 4            # re-rank row gather
    by += 2 * s.b * t["kp"] * 4               # out_v/out_i blocks
    by += s.b * t["np_pad"] * 4               # out_sel block
    return by


def feasible(shape: TurnShape, cfg: TileConfig) -> bool:
    t = resolve(shape, cfg)
    if t["np_pad"] > t["blk"] or t["r_pad"] > t["blk_l"]:
        return False
    return vmem_bytes(shape, cfg) <= tiling.VMEM_BUDGET_BYTES


def predict_fused_s(shape: TurnShape, cfg: TileConfig,
                    hw: Optional[DeviceModel] = None) -> float:
    """Modeled wall time of ONE fused dispatch for (shape, cfg)."""
    hw = hw or device_model()
    s = shape
    t = resolve(shape, cfg)
    rerank = s.precision != "f32" or s.family == "pq"

    # stage 1 — centroid tiles: MXU dots + one (blk → np_pad) tie-merge
    steps1 = t["p_pad"] // t["blk"]
    fl1 = 2.0 * s.b * t["p_pad"] * s.d
    fl1 += hw.sort_flop * s.b * t["p_pad"] * _log2(t["blk"]) ** 2
    mem1 = t["p_pad"] * s.d * 4.0             # centroid stream

    # stage 2 — probed list tiles: DMA'd (double-buffered), scored,
    # tie-merged into the running (r_pad) candidate set
    steps2 = s.b * s.nprobe * (t["lpad"] // t["blk_l"])
    rows2 = float(s.b * s.nprobe * t["lpad"])
    if s.family == "pq":
        fl2 = rows2 * s.m                     # ADC table-sums
    else:
        fl2 = 2.0 * rows2 * s.d
    fl2 += hw.sort_flop * rows2 * _log2(t["blk_l"]) ** 2
    mem2 = rows2 * (t["row_bytes"] + 4.0)     # codes/vecs + ids

    # stage 3 — in-kernel exact re-rank of the r survivors
    fl3 = mem3 = 0.0
    if rerank:
        fl3 = 2.0 * s.b * t["r"] * s.d
        fl3 += hw.sort_flop * s.b * t["r_pad"] * _log2(t["r_pad"]) ** 2
        mem3 = s.b * t["r_pad"] * s.d * 4.0   # candidate row gathers

    compute = (fl1 + fl2 + fl3) / hw.flops
    memory = (mem1 + mem2 + mem3) / hw.hbm_bw
    steps = steps1 + steps2 + (s.b if rerank else 0)
    return hw.dispatch_s + steps * hw.step_s + max(compute, memory)


def predict_3dispatch_s(shape: TurnShape,
                        hw: Optional[DeviceModel] = None) -> float:
    """Modeled wall time of the classic 3-dispatch turn at the static
    default tiling: the same stage arithmetic, but three kernel
    launches and the stage-boundary intermediates (probe ids, ADC
    candidates) round-tripping through HBM."""
    hw = hw or device_model()
    s = shape
    t = resolve(shape, DEFAULT)
    rerank = s.precision != "f32" or s.family == "pq"
    one = predict_fused_s(shape, DEFAULT, hw)
    # extra launches: centroid top-k, list scan, (re-rank or merge)
    extra = 2 * hw.dispatch_s
    # stage-boundary traffic: sel (B, np) write+read, candidate ids +
    # scores (B, r) write+read, re-rank gather issued from a cold kernel
    boundary = 2.0 * s.b * (t["np_pad"] + (2 * t["r_pad"] if rerank
                                           else 0)) * 4.0
    return one + extra + 2.0 * boundary / hw.hbm_bw


# ---------------------------------------------------------------------------
# sweep + cache
# ---------------------------------------------------------------------------

BLK_P_GRID = (128, 256, 512, 1024)
MAX_TILE_GRID = (256, 512, 1024, 2048, 4096, 8192)
OVER_GRID = (1, 2, 4)


def candidates(shape: TurnShape) -> List[TileConfig]:
    """Feasible, program-distinct configs for a shape (deduped by the
    binding tile split — requests past the clamps collapse)."""
    overs = OVER_GRID if (shape.precision != "f32"
                          and shape.family == "ivf") else (DEFAULT.over,)
    seen, out = set(), []
    for bp in BLK_P_GRID:
        for mt in MAX_TILE_GRID:
            for ov in overs:
                cfg = TileConfig(blk_p=bp, max_tile=mt, over=ov)
                if not feasible(shape, cfg):
                    continue
                t = resolve(shape, cfg)
                key = (t["blk"], t["blk_l"], t["r"])
                if key in seen:
                    continue
                seen.add(key)
                out.append(cfg)
    return out


def _cache_path(shape: TurnShape, hw: DeviceModel,
                cache_dir: Optional[str] = None) -> str:
    return os.path.join(cache_dir or CACHE_DIR,
                        f"{hw.name}_{shape.key()}.json")


def autotune(shape: TurnShape, *, hw: Optional[DeviceModel] = None,
             cache_dir: Optional[str] = None, validate: bool = False,
             measure=None, top: int = 3,
             refresh: bool = False) -> TileConfig:
    """Best TileConfig for ``shape`` on this device (cached).

    Every feasible candidate is scored with the roofline model; with
    ``validate=True`` the ``top`` model picks are additionally timed
    through ``measure(cfg) -> seconds`` (e.g. the live fused op) and
    the measured best wins.  The result is cached as JSON keyed by
    shape + device kind; ``refresh=True`` re-sweeps.
    """
    hw = hw or device_model()
    path = _cache_path(shape, hw, cache_dir)
    if not refresh and os.path.exists(path):
        with open(path) as f:
            saved = json.load(f)
        return TileConfig(**saved["config"])

    cand = candidates(shape)
    if not cand:
        raise ValueError(f"no feasible tile config for {shape}")
    scored = sorted(cand, key=lambda c: predict_fused_s(shape, c, hw))
    best, measured = scored[0], None
    if validate and measure is not None:
        timed = []
        for cfg in scored[:top]:
            timed.append((measure(cfg), cfg))
        measured, best = min(timed, key=lambda t: t[0])

    record = {
        "shape": dataclasses.asdict(shape),
        "device": hw.name,
        "config": dataclasses.asdict(best),
        "predicted_s": predict_fused_s(shape, best, hw),
        "default_predicted_s": predict_fused_s(shape, DEFAULT, hw),
        "dispatch3_predicted_s": predict_3dispatch_s(shape, hw),
        "measured_s": measured,
        "vmem_bytes": vmem_bytes(shape, best),
        "n_candidates": len(cand),
        "timestamp": time.time(),
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return best


def load_records(cache_dir: Optional[str] = None) -> List[Dict]:
    """All cached autotune records (for the roofline-report judge)."""
    d = cache_dir or CACHE_DIR
    if not os.path.isdir(d):
        return []
    out = []
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                out.append(json.load(f))
    return out
