"""Version-compat shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(jax >= 0.5); this container pins jax 0.4.37 which only has the old
name.  Kernels import ``CompilerParams`` from here so both spellings
work without touching every call site again on the next upgrade.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
