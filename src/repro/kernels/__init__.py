"""Pallas TPU kernels for the perf-critical compute layers.

  centroid_topk   fused QxC matmul + streaming exact top-k   [TopLoc #1]
  ivf_scan        fused list gather + dot + masked top-k     [TopLoc #2]
  pq_adc          fused PQ code gather + ADC LUT scan        [IVF-PQ]
  flash_attention prefill/train flash attn + flash decode    [LM archs]
  embedding_bag   fused gather + weighted bag reduction      [recsys]

Call through ``repro.kernels.ops`` — it owns padding contracts and the
TPU-kernel / CPU-reference dispatch. ``repro.kernels.ref`` holds the
pure-jnp oracles; ``sorting`` the bitonic top-k networks the kernels use.
"""
from repro.kernels import ops, ref, sorting  # noqa: F401
