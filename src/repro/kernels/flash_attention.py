"""Pallas TPU kernels: flash attention (prefill/train) and flash decode.

The LM architectures in the zoo (grok-1, deepseek-v2-lite, qwen, yi) are
attention-dominated at the assigned shapes (train_4k, prefill_32k,
decode_32k).  These kernels are the standard IO-aware formulation adapted
to TPU: KV tiles stream HBM→VMEM, the (m, l, acc) online-softmax state
lives in VMEM scratch, and the MXU sees (blk_q, d)x(d, blk_kv) /
(blk_q, blk_kv)x(blk_kv, d) matmuls.  GQA is handled in the index_map
(query-head → kv-head division) so KV tiles are fetched once per group,
not per head.

Backward pass: ``ops.flash_attention`` wraps this forward in a
``jax.custom_vjp`` whose backward runs the pure-jnp reference (exact same
math, recompute-based) — the honest CPU-container trade-off; a fused bwd
kernel is a listed future optimisation in EXPERIMENTS.md §Perf.

Grids:
  prefill: (B·H, nq, nkv)  — nkv sequential, causal tiles skipped.
  decode:  (B·Hkv, nkv)    — one query row per kv head group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               blk_q: int, blk_kv: int, nkv: int, causal: bool,
               q_offset: int, scale: float):
    """One (q-tile, kv-tile) step of online-softmax attention."""
    i = pl.program_id(1)          # q tile
    j = pl.program_id(2)          # kv tile (sequential)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_first = i * blk_q + q_offset              # absolute q positions
    kv_first = j * blk_kv
    q = q_ref[0].astype(jnp.float32) * scale    # (blk_q, d)
    k = k_ref[0].astype(jnp.float32)            # (blk_kv, d)
    v = v_ref[0].astype(jnp.float32)            # (blk_kv, d)

    def step():
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = kv_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)       # (blk_q, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (blk_q, blk_kv)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip fully-masked kv tiles (saves ~half the work on causal)
        @pl.when(kv_first <= q_first + blk_q - 1)
        def _run():
            step()
    else:
        step()

    @pl.when(j == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "blk_q", "blk_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, blk_q: int = 128,
                    blk_kv: int = 128, interpret: bool = False) -> jax.Array:
    """q (B,H,S,D), k/v (B,Hkv,Skv,D) → (B,H,S,D).

    Padding contract (ops.py): S % blk_q == 0, Skv % blk_kv == 0.
    Causal convention: q occupies the *last* S positions of the Skv
    timeline (prefill-with-prefix / train are S == Skv).
    """
    b, h, s, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                   # may differ from d (MLA)
    group = h // hkv
    assert s % blk_q == 0 and skv % blk_kv == 0, (s, skv, blk_q, blk_kv)
    nq, nkv = s // blk_q, skv // blk_kv
    scale = 1.0 / (d ** 0.5)
    q_offset = skv - s

    qf = q.reshape(b * h, s, d)
    kern = functools.partial(_fa_kernel, blk_q=blk_q, blk_kv=blk_kv,
                             nkv=nkv, causal=causal, q_offset=q_offset,
                             scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(b * h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, blk_kv, d),
                         lambda bh, i, j, g=group, hh=h: (
                             (bh // hh) * hkv + (bh % hh) // g, j, 0)),
            pl.BlockSpec((1, blk_kv, dv),
                         lambda bh, i, j, g=group, hh=h: (
                             (bh // hh) * hkv + (bh % hh) // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dv), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, dv), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, k.reshape(b * hkv, skv, d), v.reshape(b * hkv, skv, dv))
    return out.reshape(b, h, s, dv)


# ---------------------------------------------------------------------------
# decode (one new token against a long KV cache)
# ---------------------------------------------------------------------------

def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, blk_kv: int, nkv: int,
                   scale: float):
    bh = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale       # (group, d)
    k = k_ref[0].astype(jnp.float32)               # (blk_kv, d)
    v = v_ref[0].astype(jnp.float32)
    cache_len = len_ref[bh]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (group, blk_kv)
    kpos = j * blk_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < cache_len, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_prev + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_kv", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 cache_len: jax.Array, *, blk_kv: int = 512,
                 interpret: bool = False) -> jax.Array:
    """Decode attention. q (B,H,D), k/v (B,Hkv,S,D), cache_len (B,) int32.

    Returns (B,H,D). Padding contract: S % blk_kv == 0.
    """
    b, h, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    assert s % blk_kv == 0
    nkv = s // blk_kv
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b * hkv, group, d)
    # cache_len per (b·hkv) row
    len_rows = jnp.repeat(cache_len.astype(jnp.int32), hkv)

    kern = functools.partial(_decode_kernel, blk_kv=blk_kv, nkv=nkv,
                             scale=scale)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b * hkv, nkv),
            in_specs=[
                pl.BlockSpec((1, group, d), lambda bh, j, len_ref: (bh, 0, 0)),
                pl.BlockSpec((1, blk_kv, d), lambda bh, j, len_ref: (bh, j, 0)),
                pl.BlockSpec((1, blk_kv, dv), lambda bh, j, len_ref: (bh, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, group, dv),
                                   lambda bh, j, len_ref: (bh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, dv), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(len_rows, qg, k.reshape(b * hkv, s, d), v.reshape(b * hkv, s, dv))
    return out.reshape(b, h, dv)
