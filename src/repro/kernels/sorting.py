"""Bitonic sorting networks for exact in-kernel top-k.

Mosaic (Pallas TPU) does not lower ``jax.lax.top_k`` / ``sort`` inside
kernels, so the streaming top-k kernels keep their running (k,) register
tile sorted with compare-exchange networks built from pure vector ops
(roll + where + min/max) — every step is lane-parallel on the VPU and
static-shaped.  Costs: full sort of n elements = log²n/2 stages; merge of
two sorted k-tiles = log(2k) stages.

These helpers are plain jnp functions: they run identically inside a
Pallas kernel body, in interpret mode, and as host-side references (the
tests cross-check them against ``jnp.sort``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _compare_exchange(vals: jax.Array, ids: jax.Array, dist: int,
                      keep_max: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One compare-exchange stage at distance ``dist`` on the last axis.

    ``keep_max`` (bool, same shape): True where the position should keep
    the pairwise max, False where it keeps the min.
    """
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    is_lo = (iota % (2 * dist)) < dist
    pv = jnp.where(is_lo, jnp.roll(vals, -dist, axis=-1),
                   jnp.roll(vals, dist, axis=-1))
    pi = jnp.where(is_lo, jnp.roll(ids, -dist, axis=-1),
                   jnp.roll(ids, dist, axis=-1))
    take_partner = jnp.where(keep_max, pv > vals, pv < vals)
    new_v = jnp.where(take_partner, pv, vals)
    new_i = jnp.where(take_partner, pi, ids)
    return new_v, new_i


def bitonic_sort_desc(vals: jax.Array, ids: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Full descending sort along the last axis (power-of-two length)."""
    n = vals.shape[-1]
    assert _is_pow2(n), f"bitonic sort needs power-of-two length, got {n}"
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    stage = 2
    while stage <= n:
        desc = (iota & stage) == 0          # per-block direction
        if stage == n:
            desc = jnp.ones_like(desc)      # final merge: fully descending
        dist = stage // 2
        while dist >= 1:
            is_lo = (iota % (2 * dist)) < dist
            keep_max = is_lo == desc
            vals, ids = _compare_exchange(vals, ids, dist, keep_max)
            dist //= 2
        stage *= 2
    return vals, ids


def bitonic_merge_desc(vals: jax.Array, ids: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """Descending merge of a *bitonic* sequence along the last axis.

    Input convention: first half sorted descending, second half sorted
    ascending (i.e. ``concat(run_desc, flip(block_desc))``).
    """
    n = vals.shape[-1]
    assert _is_pow2(n), f"bitonic merge needs power-of-two length, got {n}"
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    dist = n // 2
    while dist >= 1:
        is_lo = (iota % (2 * dist)) < dist
        vals, ids = _compare_exchange(vals, ids, dist, is_lo)
        dist //= 2
    return vals, ids


def merge_topk_desc(run_v: jax.Array, run_i: jax.Array,
                    blk_v: jax.Array, blk_i: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Merge two descending-sorted k-tiles; return the descending top-k.

    Shapes: (..., k) each; k power of two.
    """
    v = jnp.concatenate([run_v, jnp.flip(blk_v, axis=-1)], axis=-1)
    i = jnp.concatenate([run_i, jnp.flip(blk_i, axis=-1)], axis=-1)
    v, i = bitonic_merge_desc(v, i)
    k = run_v.shape[-1]
    return v[..., :k], i[..., :k]


def block_topk_desc(scores: jax.Array, ids: jax.Array, k: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Exact top-k (descending) of a block via full bitonic sort."""
    v, i = bitonic_sort_desc(scores, ids)
    return v[..., :k], i[..., :k]
