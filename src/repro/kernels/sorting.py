"""Bitonic sorting networks for exact in-kernel top-k.

Mosaic (Pallas TPU) does not lower ``jax.lax.top_k`` / ``sort`` inside
kernels, so the streaming top-k kernels keep their running (k,) register
tile sorted with compare-exchange networks built from pure vector ops
(roll + where + min/max) — every step is lane-parallel on the VPU and
static-shaped.  Costs: full sort of n elements = log²n/2 stages; merge of
two sorted k-tiles = log(2k) stages.

These helpers are plain jnp functions: they run identically inside a
Pallas kernel body, in interpret mode, and as host-side references (the
tests cross-check them against ``jnp.sort``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _compare_exchange(vals: jax.Array, ids: jax.Array, dist: int,
                      keep_max: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One compare-exchange stage at distance ``dist`` on the last axis.

    ``keep_max`` (bool, same shape): True where the position should keep
    the pairwise max, False where it keeps the min.
    """
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    is_lo = (iota % (2 * dist)) < dist
    pv = jnp.where(is_lo, jnp.roll(vals, -dist, axis=-1),
                   jnp.roll(vals, dist, axis=-1))
    pi = jnp.where(is_lo, jnp.roll(ids, -dist, axis=-1),
                   jnp.roll(ids, dist, axis=-1))
    take_partner = jnp.where(keep_max, pv > vals, pv < vals)
    new_v = jnp.where(take_partner, pv, vals)
    new_i = jnp.where(take_partner, pi, ids)
    return new_v, new_i


def bitonic_sort_desc(vals: jax.Array, ids: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Full descending sort along the last axis (power-of-two length)."""
    n = vals.shape[-1]
    assert _is_pow2(n), f"bitonic sort needs power-of-two length, got {n}"
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    stage = 2
    while stage <= n:
        desc = (iota & stage) == 0          # per-block direction
        if stage == n:
            desc = jnp.ones_like(desc)      # final merge: fully descending
        dist = stage // 2
        while dist >= 1:
            is_lo = (iota % (2 * dist)) < dist
            keep_max = is_lo == desc
            vals, ids = _compare_exchange(vals, ids, dist, keep_max)
            dist //= 2
        stage *= 2
    return vals, ids


def bitonic_merge_desc(vals: jax.Array, ids: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """Descending merge of a *bitonic* sequence along the last axis.

    Input convention: first half sorted descending, second half sorted
    ascending (i.e. ``concat(run_desc, flip(block_desc))``).
    """
    n = vals.shape[-1]
    assert _is_pow2(n), f"bitonic merge needs power-of-two length, got {n}"
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    dist = n // 2
    while dist >= 1:
        is_lo = (iota % (2 * dist)) < dist
        vals, ids = _compare_exchange(vals, ids, dist, is_lo)
        dist //= 2
    return vals, ids


def merge_topk_desc(run_v: jax.Array, run_i: jax.Array,
                    blk_v: jax.Array, blk_i: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Merge two descending-sorted k-tiles; return the descending top-k.

    Shapes: (..., k) each; k power of two.
    """
    v = jnp.concatenate([run_v, jnp.flip(blk_v, axis=-1)], axis=-1)
    i = jnp.concatenate([run_i, jnp.flip(blk_i, axis=-1)], axis=-1)
    v, i = bitonic_merge_desc(v, i)
    k = run_v.shape[-1]
    return v[..., :k], i[..., :k]


def block_topk_desc(scores: jax.Array, ids: jax.Array, k: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Exact top-k (descending) of a block via full bitonic sort."""
    v, i = bitonic_sort_desc(scores, ids)
    return v[..., :k], i[..., :k]


# ---------------------------------------------------------------------------
# tie-aware variants: order by (value desc, position asc)
# ---------------------------------------------------------------------------
#
# ``_compare_exchange`` keeps its own element on an exact value tie, so
# the plain network's tie order depends on where elements happen to sit
# in the register tile — fine for the classic kernels (their tests break
# ties in data), wrong for the fused turn, whose ids/sel outputs must be
# *bit-identical* to ``lax.top_k`` over the reference flat layout.
# ``lax.top_k`` (and ``distributed_topk_ordered``) break value ties by
# smaller source position, so these variants carry an explicit position
# lane and sort by the composite key (value desc, position asc) — a
# total order, which also makes the padding convention exact: pads get
# (-inf, pos=INT32_MAX) and can never displace a real candidate.

#: position sentinel for padding lanes in the tie-aware networks
PAD_POS = jnp.iinfo(jnp.int32).max


def _compare_exchange_tie(vals: jax.Array, ids: jax.Array, pos: jax.Array,
                          dist: int, keep_max: jax.Array
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compare-exchange at ``dist`` under (value desc, position asc)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    is_lo = (iota % (2 * dist)) < dist
    pv = jnp.where(is_lo, jnp.roll(vals, -dist, axis=-1),
                   jnp.roll(vals, dist, axis=-1))
    pi = jnp.where(is_lo, jnp.roll(ids, -dist, axis=-1),
                   jnp.roll(ids, dist, axis=-1))
    pp = jnp.where(is_lo, jnp.roll(pos, -dist, axis=-1),
                   jnp.roll(pos, dist, axis=-1))
    gt = (pv > vals) | ((pv == vals) & (pp < pos))   # partner ranks higher
    take_partner = jnp.where(keep_max, gt, ~gt)
    new_v = jnp.where(take_partner, pv, vals)
    new_i = jnp.where(take_partner, pi, ids)
    new_p = jnp.where(take_partner, pp, pos)
    return new_v, new_i, new_p


def bitonic_sort_desc_tie(vals: jax.Array, ids: jax.Array, pos: jax.Array
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full sort by (value desc, position asc) along the last axis."""
    n = vals.shape[-1]
    assert _is_pow2(n), f"bitonic sort needs power-of-two length, got {n}"
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    stage = 2
    while stage <= n:
        desc = (iota & stage) == 0
        if stage == n:
            desc = jnp.ones_like(desc)
        dist = stage // 2
        while dist >= 1:
            is_lo = (iota % (2 * dist)) < dist
            keep_max = is_lo == desc
            vals, ids, pos = _compare_exchange_tie(vals, ids, pos, dist,
                                                   keep_max)
            dist //= 2
        stage *= 2
    return vals, ids, pos


def bitonic_merge_desc_tie(vals: jax.Array, ids: jax.Array, pos: jax.Array
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Merge a bitonic sequence under (value desc, position asc)."""
    n = vals.shape[-1]
    assert _is_pow2(n), f"bitonic merge needs power-of-two length, got {n}"
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    dist = n // 2
    while dist >= 1:
        is_lo = (iota % (2 * dist)) < dist
        vals, ids, pos = _compare_exchange_tie(vals, ids, pos, dist, is_lo)
        dist //= 2
    return vals, ids, pos


def merge_topk_desc_tie(run_v: jax.Array, run_i: jax.Array,
                        run_p: jax.Array, blk_v: jax.Array,
                        blk_i: jax.Array, blk_p: jax.Array,
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Merge two tiles sorted by (value desc, position asc); keep top-k."""
    v = jnp.concatenate([run_v, jnp.flip(blk_v, axis=-1)], axis=-1)
    i = jnp.concatenate([run_i, jnp.flip(blk_i, axis=-1)], axis=-1)
    p = jnp.concatenate([run_p, jnp.flip(blk_p, axis=-1)], axis=-1)
    v, i, p = bitonic_merge_desc_tie(v, i, p)
    k = run_v.shape[-1]
    return v[..., :k], i[..., :k], p[..., :k]


def block_topk_desc_tie(scores: jax.Array, ids: jax.Array, pos: jax.Array,
                        k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact top-k under (value desc, position asc) via full sort."""
    v, i, p = bitonic_sort_desc_tie(scores, ids, pos)
    return v[..., :k], i[..., :k], p[..., :k]
