"""Shared VMEM tile-split policy for the Pallas dispatch wrappers.

Every kernel wrapper in ``ops.py`` used to carry its own copy of the
same three decisions — pad k to a power of two, cap the streamed list
tile by VMEM bytes, round the streamed axis up to a tile multiple.  The
``kernel_budget`` analysis pass (PK401/PK402) re-derived the same
numbers independently, which meant the checker and the wrappers could
drift apart.  This module is now the single source of truth for both:
the wrappers ask it how to split, and the budget pass imports the same
constants it asserts against.

Layout constants (TPU register tiling / per-core VMEM) live here too so
the fused megakernel, the classic per-stage kernels, and the analysis
pass can never disagree on what "fits".
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# Per-core VMEM (TPU guide). The budget pass flags any kernel whose
# double-buffered blocks + scratch exceed this.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

# Per-stream VMEM slice for the dominant streamed tile: the pipeline
# double-buffers it, and queries/ids/outputs/scratch share the ~16 MiB
# core budget, so one buffer gets at most a quarter.
VMEM_TILE_BYTES = 4 * 1024 * 1024

# float32 register tiling: (sublane, lane) = (8, 128); narrower dtypes
# need proportionally taller sublane tiles.
LANE = 128


def sublane(itemsize: int) -> int:
    return {4: 8, 2: 16, 1: 32}.get(int(itemsize), 8)


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def pow2_floor(n: int) -> int:
    return max(next_pow2(n + 1) // 2, 1)


def is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def pad_axis(x: jax.Array, axis: int, to: int, value) -> jax.Array:
    n = x.shape[axis]
    if n == to:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, to - n)
    return jnp.pad(x, pads, constant_values=value)


def list_tile(lmax: int, row_bytes: int, *, kp: int = 1,
              max_tile: int = 2048) -> Tuple[int, int]:
    """Split a streamed posting-list axis: ``(blk_l, lpad)``.

    ``blk_l`` is the per-step tile (power of two, ≥ kp so the running
    top-k merge network has a full block to fold, ≤ ``max_tile`` rows,
    and byte-capped so the double-buffered ``(blk_l, row_bytes)`` tile
    stays inside its VMEM_TILE_BYTES slice — a row cap alone
    over-allocates at large d: d=1024 f32 → 8 MiB tile → 16 MiB in
    flight).  ``lpad`` is ``lmax`` rounded up to a ``blk_l`` multiple.
    """
    lpad = next_pow2(lmax)
    blk_l = min(lpad, max_tile)
    blk_l = min(blk_l, pow2_floor(VMEM_TILE_BYTES // max(row_bytes, 1)))
    blk_l = max(blk_l, kp)
    lpad = ((lpad + blk_l - 1) // blk_l) * blk_l
    return blk_l, lpad


def centroid_tile(p: int, kp: int, *, blk_p: int = 512
                  ) -> Tuple[int, int]:
    """Split the centroid axis: ``(blk, p_pad)``.

    The tile is a power of two ≥ kp (the merge network folds one block
    into the running (1, kp) top-k per step) and ``p_pad`` rounds the
    centroid count up to a tile multiple.
    """
    blk = min(blk_p, next_pow2(p))
    blk = max(blk, kp)
    p_pad = ((p + blk - 1) // blk) * blk
    return blk, p_pad
