"""jit'd dispatch wrappers for every kernel in this package.

Each public op has three execution paths, selected by ``mode``:

  * ``"ref"``       — pure-jnp oracle (``ref.py``): the CPU-container
                      default and the path lowered in the dry-run (Pallas
                      TPU kernels do not lower on the CPU backend).
  * ``"kernel"``    — the Pallas TPU kernel (real hardware).
  * ``"interpret"`` — the Pallas kernel body executed in Python
                      (correctness validation on CPU; used by tests).

``default_mode()`` picks ``kernel`` on TPU and ``ref`` elsewhere, so
call-sites never branch by hand.  Wrappers also own the padding
contracts (power-of-two k, block-aligned lengths) so kernels stay
assert-clean and callers stay shape-ignorant.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import tiling
from repro.kernels import centroid_topk as _ck
from repro.kernels import ivf_scan as _iv
from repro.kernels import pq_adc as _pq
from repro.kernels import fused_turn as _ft
from repro.kernels import flash_attention as _fa
from repro.kernels import embedding_bag as _eb

# tile-split policy is shared with the kernel_budget analysis pass —
# see kernels/tiling.py
_next_pow2 = tiling.next_pow2
_pad_axis = tiling.pad_axis


def default_mode() -> str:
    plat = jax.default_backend()
    return "kernel" if plat == "tpu" else "ref"


# ---------------------------------------------------------------------------
# centroid_topk
# ---------------------------------------------------------------------------

def centroid_topk(queries: jax.Array, centroids: jax.Array, k: int, *,
                  mode: Optional[str] = None, blk_p: int = 512
                  ) -> Tuple[jax.Array, jax.Array]:
    """Top-k centroid ids/scores for a query batch. See kernel docstring."""
    mode = mode or default_mode()
    if mode == "ref":
        return ref.centroid_topk(queries, centroids, k)
    p = centroids.shape[0]
    kp = _next_pow2(k)
    blk, p_pad = tiling.centroid_tile(p, kp, blk_p=blk_p)
    c = _pad_axis(centroids, 0, p_pad, 0.0)
    # guard: padded centroids must never win — push them to -inf via a
    # sentinel row of -inf scores (zero vectors tie at 0 for zero queries,
    # so mask by id instead inside merge: ids >= p are dropped post-hoc)
    v, i = _ck.centroid_topk(queries, c, kp, blk_p=blk,
                             interpret=(mode == "interpret"))
    v = jnp.where(i < p, v, -jnp.inf)
    v2, pos = jax.lax.top_k(v, k)
    return v2, jnp.take_along_axis(i, pos, axis=-1)


# ---------------------------------------------------------------------------
# ivf_scan
# ---------------------------------------------------------------------------

def ivf_scan(queries: jax.Array, list_vecs: jax.Array, list_ids: jax.Array,
             sel: jax.Array, k: int, *, mode: Optional[str] = None,
             max_tile: int = 2048) -> Tuple[jax.Array, jax.Array]:
    """Fused scan of the selected posting lists. sel (B, nprobe)."""
    mode = mode or default_mode()
    if mode == "ref":
        return ref.ivf_scan_batch(queries, list_vecs, list_ids, sel, k)
    p, lmax, d = list_vecs.shape
    kp = _next_pow2(k)
    blk_l, lpad = tiling.list_tile(lmax, d * 4, kp=kp, max_tile=max_tile)
    lv = _pad_axis(list_vecs, 1, lpad, 0.0)
    li = _pad_axis(list_ids, 1, lpad, -1)
    v, i = _iv.ivf_scan(queries, lv, li, sel, kp, blk_l=blk_l,
                        interpret=(mode == "interpret"))
    return v[:, :k], i[:, :k]


# ---------------------------------------------------------------------------
# pq_adc_scan
# ---------------------------------------------------------------------------

def pq_adc_scan(tables: jax.Array, list_codes: jax.Array,
                list_ids: jax.Array, sel: jax.Array, k: int, *,
                mode: Optional[str] = None, max_tile: int = 4096
                ) -> Tuple[jax.Array, jax.Array]:
    """Fused ADC scan of selected PQ posting lists. tables (B, m, codes),
    sel (B, nprobe).  Returns the ADC top-k candidates per query."""
    mode = mode or default_mode()
    if mode == "ref":
        return ref.pq_adc_scan_batch(tables, list_codes, list_ids, sel, k)
    p, lmax, m = list_codes.shape
    kp = _next_pow2(k)
    # uint8 code rows: the byte cap never binds before the row cap, so
    # this reduces to the historical max_tile policy (LUT sizing is the
    # (1, m, codes) table block, resident per query row)
    blk_l, lpad = tiling.list_tile(lmax, m, kp=kp, max_tile=max_tile)
    codes = _pad_axis(list_codes, 1, lpad, 0)
    li = _pad_axis(list_ids, 1, lpad, -1)
    v, i = _pq.pq_adc_scan(tables.astype(jnp.float32), codes, li, sel, kp,
                           blk_l=blk_l, interpret=(mode == "interpret"))
    return v[:, :k], i[:, :k]


# ---------------------------------------------------------------------------
# fused_turn / fused_scan — single-dispatch TopLoc turn
# ---------------------------------------------------------------------------


def _fused_depth(k: int, cap: int, *, over: int = 0, rerank: int = 0) -> int:
    """Exact candidate depth r: k·over (quantised IVF) or the PQ re-rank
    depth, clamped to the scannable candidate count and floored at k —
    the same clamp ``toploc._scan_lists_pq`` applies."""
    want = k * over if over else rerank
    return max(k, min(want, cap))


def fused_turn(queries: jax.Array, centroids: jax.Array,
               list_vecs: jax.Array, list_ids: jax.Array, *,
               nprobe: int, k: int, over: int = 2,
               precision: str = "f32", mode: Optional[str] = None,
               blk_p: int = 512, max_tile: int = 2048
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Whole IVF turn in one dispatch: centroid top-nprobe + list scan
    (+ float32 re-rank of the k·over survivors when quantised).

    Returns (values (B, k), ids (B, k), sel (B, nprobe)).  The f32 path
    is bit-identical to centroid_topk → ivf_scan; see the precision
    contract in ``kernels/fused_turn.py``.
    """
    mode = mode or default_mode()
    p, lmax, d = list_vecs.shape
    r = k if precision == "f32" else _fused_depth(k, nprobe * lmax,
                                                  over=over)
    np_pad = _next_pow2(nprobe)
    r_pad = _next_pow2(r)
    blk, p_pad = tiling.centroid_tile(p, np_pad, blk_p=blk_p)
    blk_l, lpad = tiling.list_tile(lmax, d * 4, kp=r_pad,
                                   max_tile=max_tile)
    c = _pad_axis(centroids, 0, p_pad, 0.0)
    lv = _pad_axis(list_vecs, 1, lpad, 0.0)
    li = _pad_axis(list_ids, 1, lpad, -1)
    if mode == "ref":
        return ref.fused_turn_ivf(queries, c, lv, li, p=p, lmax=lmax,
                                  nprobe=nprobe, k=k, r=r,
                                  precision=precision, blk_p=blk,
                                  blk_l=blk_l)
    v, i, s = _ft.fused_turn(queries, c, lv, li, nprobe=nprobe, k=k,
                             r=r, precision=precision, blk_p=blk,
                             blk_l=blk_l,
                             interpret=(mode == "interpret"))
    return v[:, :k], i[:, :k], s[:, :nprobe]


def fused_turn_pq(queries: jax.Array, centroids: jax.Array,
                  tables: jax.Array, list_codes: jax.Array,
                  list_ids: jax.Array, corpus: jax.Array, *,
                  nprobe: int, k: int, rerank: int,
                  precision: str = "f32", mode: Optional[str] = None,
                  blk_p: int = 512, max_tile: int = 4096
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Whole IVF-PQ turn in one dispatch: centroid top-nprobe + ADC scan
    + float32 exact re-rank of the top ``rerank`` candidates in-kernel.
    """
    mode = mode or default_mode()
    p, lmax, m = list_codes.shape
    r = _fused_depth(k, nprobe * lmax, rerank=rerank)
    np_pad = _next_pow2(nprobe)
    r_pad = _next_pow2(r)
    blk, p_pad = tiling.centroid_tile(p, np_pad, blk_p=blk_p)
    blk_l, lpad = tiling.list_tile(lmax, m, kp=r_pad, max_tile=max_tile)
    c = _pad_axis(centroids, 0, p_pad, 0.0)
    codes = _pad_axis(list_codes, 1, lpad, 0)
    li = _pad_axis(list_ids, 1, lpad, -1)
    if mode == "ref":
        return ref.fused_turn_pq(queries, c, tables, codes, li, corpus,
                                 p=p, lmax=lmax, nprobe=nprobe, k=k,
                                 r=r, precision=precision, blk_p=blk)
    v, i, s = _ft.fused_turn_pq(queries, c, tables.astype(jnp.float32),
                                codes, li, corpus, nprobe=nprobe, k=k,
                                r=r, precision=precision, blk_p=blk,
                                blk_l=blk_l,
                                interpret=(mode == "interpret"))
    return v[:, :k], i[:, :k], s[:, :nprobe]


def _convert_pos(pp: jax.Array, lpad: int, lmax: int) -> jax.Array:
    """Padded flat scan positions → reference (probe·lmax + off) numbering.

    The map is monotone, so tie-break order is preserved; PAD_POS lanes
    (value -inf) stay PAD_POS.
    """
    conv = (pp // lpad) * lmax + jax.lax.rem(pp, lpad)
    return jnp.where(pp == _ft.PAD_POS, _ft.PAD_POS, conv)


def fused_scan(queries: jax.Array, list_vecs: jax.Array,
               list_ids: jax.Array, sel: jax.Array, k: int, *,
               own: Optional[jax.Array] = None, over: int = 2,
               precision: str = "f32", mode: Optional[str] = None,
               max_tile: int = 2048
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused IVF list scan with a caller-supplied selection.

    Returns (values (B, k), ids (B, k), pos (B, k)); pos is the flat
    scan position (``distributed_topk_ordered`` tie-break key) for f32,
    and the candidate rank after the quantised paths' in-kernel
    re-rank (single-device use).  ``own`` masks lists this shard does
    not own (sharded locals).
    """
    mode = mode or default_mode()
    b = queries.shape[0]
    p, lmax, d = list_vecs.shape
    nprobe = sel.shape[1]
    rerank = precision != "f32"
    r = k if not rerank else _fused_depth(k, nprobe * lmax, over=over)
    r_pad = _next_pow2(r)
    blk_l, lpad = tiling.list_tile(lmax, d * 4, kp=r_pad,
                                   max_tile=max_tile)
    if own is None:
        own = jnp.ones((b, nprobe), jnp.int32)
    lv = _pad_axis(list_vecs, 1, lpad, 0.0)
    li = _pad_axis(list_ids, 1, lpad, -1)
    if mode == "ref":
        return ref.fused_scan_ivf(queries, lv, li, sel, own, lmax=lmax,
                                  k=k, r=r, precision=precision,
                                  blk_l=blk_l, rerank=rerank)
    v, i, pp = _ft.fused_scan(queries, lv, li, sel,
                              own.astype(jnp.int32), k=k, r=r,
                              precision=precision, blk_l=blk_l,
                              rerank=rerank,
                              interpret=(mode == "interpret"))
    v, i, pp = v[:, :k], i[:, :k], pp[:, :k]
    if not rerank:
        pp = _convert_pos(pp, lpad, lmax)
    return v, i, pp


def fused_scan_pq(tables: jax.Array, queries: jax.Array,
                  list_codes: jax.Array, list_ids: jax.Array,
                  sel: jax.Array, corpus: jax.Array, k: int, *,
                  rerank: int, own: Optional[jax.Array] = None,
                  precision: str = "f32", fuse_rerank: bool = True,
                  mode: Optional[str] = None, max_tile: int = 4096
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused PQ ADC scan with a caller-supplied selection.

    With ``fuse_rerank`` (single-device turns) the ADC pass and the
    float32 exact re-rank collapse into one dispatch → exact top-k.
    Without (sharded owner-computes locals) returns the ADC top-r with
    flat scan positions for the distributed merge.
    """
    mode = mode or default_mode()
    b = tables.shape[0]
    p, lmax, m = list_codes.shape
    nprobe = sel.shape[1]
    r = _fused_depth(k, nprobe * lmax, rerank=rerank)
    r_pad = _next_pow2(r)
    blk_l, lpad = tiling.list_tile(lmax, m, kp=r_pad, max_tile=max_tile)
    if own is None:
        own = jnp.ones((b, nprobe), jnp.int32)
    codes = _pad_axis(list_codes, 1, lpad, 0)
    li = _pad_axis(list_ids, 1, lpad, -1)
    if mode == "ref":
        return ref.fused_scan_pq(tables, queries, codes, li, sel, own,
                                 corpus, lmax=lmax, k=k, r=r,
                                 precision=precision,
                                 rerank=fuse_rerank)
    v, i, pp = _ft.fused_scan_pq(tables.astype(jnp.float32), queries,
                                 codes, li, sel, own.astype(jnp.int32),
                                 corpus, k=k, r=r, precision=precision,
                                 blk_l=blk_l, rerank=fuse_rerank,
                                 interpret=(mode == "interpret"))
    w = k if fuse_rerank else r
    v, i, pp = v[:, :w], i[:, :w], pp[:, :w]
    if not fuse_rerank:
        pp = _convert_pos(pp, lpad, lmax)
    return v, i, pp


# ---------------------------------------------------------------------------
# flash attention (custom_vjp: kernel fwd, reference-math bwd)
# ---------------------------------------------------------------------------

# threshold above which the jnp path switches to the chunked
# (flash-style) formulation — keeps the lowered graph free of (S, Skv)
# score tensors so dry-run memory reflects the streaming TPU kernel
_CHUNK_THRESHOLD = 2048


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _fa_core(q, k, v, causal: bool, mode: str):
    if mode == "ref":
        return _ref_attention(q, k, v, causal)
    return _fa.flash_attention(q, k, v, causal=causal,
                               interpret=(mode == "interpret"))


def _ref_attention(q, k, v, causal):
    if q.shape[2] * k.shape[2] > _CHUNK_THRESHOLD ** 2:
        return ref.chunked_attention(q, k, v, causal=causal)
    return ref.mha_attention(q, k, v, causal=causal)


def _fa_fwd(q, k, v, causal, mode):
    return _fa_core(q, k, v, causal, mode), (q, k, v)


def _fa_bwd(causal, mode, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _ref_attention(
        q_, k_, v_, causal), q, k, v)
    return vjp(g)


_fa_core.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, mode: Optional[str] = None
                    ) -> jax.Array:
    """Differentiable attention: Pallas fwd on TPU, jnp-math bwd."""
    mode = mode or default_mode()
    if mode != "ref":
        s, skv = q.shape[2], k.shape[2]
        if s % 128 or skv % 128:   # padding contract
            mode = "ref"
    return _fa_core(q, k, v, causal, mode)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 cache_len: jax.Array, *, mode: Optional[str] = None
                 ) -> jax.Array:
    """Decode attention (no grad path — serving only)."""
    mode = mode or default_mode()
    if mode == "ref":
        return ref.decode_attention(q, k, v, cache_len)
    s = k.shape[2]
    blk = 512 if s % 512 == 0 else (128 if s % 128 == 0 else 0)
    if blk == 0:
        return ref.decode_attention(q, k, v, cache_len)
    return _fa.flash_decode(q, k, v, cache_len, blk_kv=blk,
                            interpret=(mode == "interpret"))


# ---------------------------------------------------------------------------
# embedding_bag (custom_vjp: kernel fwd, gather-scatter bwd)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _eb_core(table, ids, weights, mode: str):
    if mode == "ref":
        return ref.embedding_bag(table, ids, weights, mode="sum")
    return _eb.embedding_bag(table, ids, weights,
                             interpret=(mode == "interpret"))


def _eb_fwd(table, ids, weights, mode):
    return _eb_core(table, ids, weights, mode), (table, ids, weights)


def _eb_bwd(mode, res, g):
    table, ids, weights = res
    _, vjp = jax.vjp(lambda t, w: ref.embedding_bag(t, ids, w, mode="sum"),
                     table, weights if weights is not None else
                     jnp.ones(ids.shape, jnp.float32))
    dt, dw = vjp(g)
    return dt, None, (dw if weights is not None else None)


_eb_core.defvjp(_eb_fwd, _eb_bwd)


def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: Optional[jax.Array] = None,
                  agg: str = "sum", *, mode: Optional[str] = None
                  ) -> jax.Array:
    """EmbeddingBag: (V,d) table, (B,L) bags (-1 pad) → (B,d)."""
    mode = mode or default_mode()
    out = _eb_core(table, ids, weights, mode)
    if agg == "mean":
        w = (ids >= 0).astype(jnp.float32)
        if weights is not None:
            w = w * weights
        denom = jnp.maximum(w.sum(-1, keepdims=True), 1.0)
        out = (out.astype(jnp.float32) / denom).astype(table.dtype)
    return out
