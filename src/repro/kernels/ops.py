"""jit'd dispatch wrappers for every kernel in this package.

Each public op has three execution paths, selected by ``mode``:

  * ``"ref"``       — pure-jnp oracle (``ref.py``): the CPU-container
                      default and the path lowered in the dry-run (Pallas
                      TPU kernels do not lower on the CPU backend).
  * ``"kernel"``    — the Pallas TPU kernel (real hardware).
  * ``"interpret"`` — the Pallas kernel body executed in Python
                      (correctness validation on CPU; used by tests).

``default_mode()`` picks ``kernel`` on TPU and ``ref`` elsewhere, so
call-sites never branch by hand.  Wrappers also own the padding
contracts (power-of-two k, block-aligned lengths) so kernels stay
assert-clean and callers stay shape-ignorant.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import centroid_topk as _ck
from repro.kernels import ivf_scan as _iv
from repro.kernels import pq_adc as _pq
from repro.kernels import flash_attention as _fa
from repro.kernels import embedding_bag as _eb


def default_mode() -> str:
    plat = jax.default_backend()
    return "kernel" if plat == "tpu" else "ref"


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _pow2_floor(n: int) -> int:
    return max(_next_pow2(n + 1) // 2, 1)


# per-stream VMEM slice for the dominant (blk_l, d) list tile: the
# pipeline double-buffers it, and queries/ids/outputs/scratch share the
# ~16 MiB core budget, so one buffer gets at most a quarter
_VMEM_TILE_BYTES = 4 * 1024 * 1024


def _pad_axis(x: jax.Array, axis: int, to: int, value) -> jax.Array:
    n = x.shape[axis]
    if n == to:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, to - n)
    return jnp.pad(x, pads, constant_values=value)


# ---------------------------------------------------------------------------
# centroid_topk
# ---------------------------------------------------------------------------

def centroid_topk(queries: jax.Array, centroids: jax.Array, k: int, *,
                  mode: Optional[str] = None, blk_p: int = 512
                  ) -> Tuple[jax.Array, jax.Array]:
    """Top-k centroid ids/scores for a query batch. See kernel docstring."""
    mode = mode or default_mode()
    if mode == "ref":
        return ref.centroid_topk(queries, centroids, k)
    p = centroids.shape[0]
    kp = _next_pow2(k)
    blk = min(blk_p, _next_pow2(p))
    blk = max(blk, kp)
    p_pad = ((p + blk - 1) // blk) * blk
    c = _pad_axis(centroids, 0, p_pad, 0.0)
    # guard: padded centroids must never win — push them to -inf via a
    # sentinel row of -inf scores (zero vectors tie at 0 for zero queries,
    # so mask by id instead inside merge: ids >= p are dropped post-hoc)
    v, i = _ck.centroid_topk(queries, c, kp, blk_p=blk,
                             interpret=(mode == "interpret"))
    v = jnp.where(i < p, v, -jnp.inf)
    v2, pos = jax.lax.top_k(v, k)
    return v2, jnp.take_along_axis(i, pos, axis=-1)


# ---------------------------------------------------------------------------
# ivf_scan
# ---------------------------------------------------------------------------

def ivf_scan(queries: jax.Array, list_vecs: jax.Array, list_ids: jax.Array,
             sel: jax.Array, k: int, *, mode: Optional[str] = None,
             max_tile: int = 2048) -> Tuple[jax.Array, jax.Array]:
    """Fused scan of the selected posting lists. sel (B, nprobe)."""
    mode = mode or default_mode()
    if mode == "ref":
        return ref.ivf_scan_batch(queries, list_vecs, list_ids, sel, k)
    p, lmax, d = list_vecs.shape
    kp = _next_pow2(k)
    lpad = _next_pow2(lmax)
    blk_l = min(lpad, max_tile)
    # VMEM-aware cap: the (blk_l, d) f32 list tile is double-buffered
    # by the pipeline, so a row cap of max_tile alone over-allocates at
    # large d (d=1024 → 8 MiB tile → 16 MiB in flight).  Bound the tile
    # by bytes, keeping it a power of two so it still divides lpad.
    blk_l = min(blk_l, _pow2_floor(_VMEM_TILE_BYTES // (d * 4)))
    blk_l = max(blk_l, kp)
    lpad = ((lpad + blk_l - 1) // blk_l) * blk_l
    lv = _pad_axis(list_vecs, 1, lpad, 0.0)
    li = _pad_axis(list_ids, 1, lpad, -1)
    v, i = _iv.ivf_scan(queries, lv, li, sel, kp, blk_l=blk_l,
                        interpret=(mode == "interpret"))
    return v[:, :k], i[:, :k]


# ---------------------------------------------------------------------------
# pq_adc_scan
# ---------------------------------------------------------------------------

def pq_adc_scan(tables: jax.Array, list_codes: jax.Array,
                list_ids: jax.Array, sel: jax.Array, k: int, *,
                mode: Optional[str] = None, max_tile: int = 4096
                ) -> Tuple[jax.Array, jax.Array]:
    """Fused ADC scan of selected PQ posting lists. tables (B, m, codes),
    sel (B, nprobe).  Returns the ADC top-k candidates per query."""
    mode = mode or default_mode()
    if mode == "ref":
        return ref.pq_adc_scan_batch(tables, list_codes, list_ids, sel, k)
    p, lmax, m = list_codes.shape
    kp = _next_pow2(k)
    lpad = _next_pow2(lmax)
    blk_l = min(lpad, max_tile)
    blk_l = max(blk_l, kp)
    lpad = ((lpad + blk_l - 1) // blk_l) * blk_l
    codes = _pad_axis(list_codes, 1, lpad, 0)
    li = _pad_axis(list_ids, 1, lpad, -1)
    v, i = _pq.pq_adc_scan(tables.astype(jnp.float32), codes, li, sel, kp,
                           blk_l=blk_l, interpret=(mode == "interpret"))
    return v[:, :k], i[:, :k]


# ---------------------------------------------------------------------------
# flash attention (custom_vjp: kernel fwd, reference-math bwd)
# ---------------------------------------------------------------------------

# threshold above which the jnp path switches to the chunked
# (flash-style) formulation — keeps the lowered graph free of (S, Skv)
# score tensors so dry-run memory reflects the streaming TPU kernel
_CHUNK_THRESHOLD = 2048


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _fa_core(q, k, v, causal: bool, mode: str):
    if mode == "ref":
        return _ref_attention(q, k, v, causal)
    return _fa.flash_attention(q, k, v, causal=causal,
                               interpret=(mode == "interpret"))


def _ref_attention(q, k, v, causal):
    if q.shape[2] * k.shape[2] > _CHUNK_THRESHOLD ** 2:
        return ref.chunked_attention(q, k, v, causal=causal)
    return ref.mha_attention(q, k, v, causal=causal)


def _fa_fwd(q, k, v, causal, mode):
    return _fa_core(q, k, v, causal, mode), (q, k, v)


def _fa_bwd(causal, mode, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _ref_attention(
        q_, k_, v_, causal), q, k, v)
    return vjp(g)


_fa_core.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, mode: Optional[str] = None
                    ) -> jax.Array:
    """Differentiable attention: Pallas fwd on TPU, jnp-math bwd."""
    mode = mode or default_mode()
    if mode != "ref":
        s, skv = q.shape[2], k.shape[2]
        if s % 128 or skv % 128:   # padding contract
            mode = "ref"
    return _fa_core(q, k, v, causal, mode)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 cache_len: jax.Array, *, mode: Optional[str] = None
                 ) -> jax.Array:
    """Decode attention (no grad path — serving only)."""
    mode = mode or default_mode()
    if mode == "ref":
        return ref.decode_attention(q, k, v, cache_len)
    s = k.shape[2]
    blk = 512 if s % 512 == 0 else (128 if s % 128 == 0 else 0)
    if blk == 0:
        return ref.decode_attention(q, k, v, cache_len)
    return _fa.flash_decode(q, k, v, cache_len, blk_kv=blk,
                            interpret=(mode == "interpret"))


# ---------------------------------------------------------------------------
# embedding_bag (custom_vjp: kernel fwd, gather-scatter bwd)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _eb_core(table, ids, weights, mode: str):
    if mode == "ref":
        return ref.embedding_bag(table, ids, weights, mode="sum")
    return _eb.embedding_bag(table, ids, weights,
                             interpret=(mode == "interpret"))


def _eb_fwd(table, ids, weights, mode):
    return _eb_core(table, ids, weights, mode), (table, ids, weights)


def _eb_bwd(mode, res, g):
    table, ids, weights = res
    _, vjp = jax.vjp(lambda t, w: ref.embedding_bag(t, ids, w, mode="sum"),
                     table, weights if weights is not None else
                     jnp.ones(ids.shape, jnp.float32))
    dt, dw = vjp(g)
    return dt, None, (dw if weights is not None else None)


_eb_core.defvjp(_eb_fwd, _eb_bwd)


def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: Optional[jax.Array] = None,
                  agg: str = "sum", *, mode: Optional[str] = None
                  ) -> jax.Array:
    """EmbeddingBag: (V,d) table, (B,L) bags (-1 pad) → (B,d)."""
    mode = mode or default_mode()
    out = _eb_core(table, ids, weights, mode)
    if agg == "mean":
        w = (ids >= 0).astype(jnp.float32)
        if weights is not None:
            w = w * weights
        denom = jnp.maximum(w.sum(-1, keepdims=True), 1.0)
        out = (out.astype(jnp.float32) / denom).astype(table.dtype)
    return out
