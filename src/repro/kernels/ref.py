"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(``tests/test_kernels_*.py`` sweeps shapes/dtypes with
``np.testing.assert_allclose``), and the CPU execution path used whenever
the TPU kernels are unavailable (``ops.py`` dispatch).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import fused_turn as _ft


# ---------------------------------------------------------------------------
# centroid_topk — fused (B,d)x(d,p) matmul + top-k   [TopLoc hot spot 1]
# ---------------------------------------------------------------------------

def centroid_topk(queries: jax.Array, centroids: jax.Array, k: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Top-k centroids by dot product. queries (B,d), centroids (p,d).

    Returns (values (B,k) f32, ids (B,k) int32), sorted descending.
    """
    scores = jnp.einsum("bd,pd->bp", queries.astype(jnp.float32),
                        centroids.astype(jnp.float32))
    v, i = jax.lax.top_k(scores, k)
    return v, i.astype(jnp.int32)


# ---------------------------------------------------------------------------
# ivf_scan — fused posting-list gather + dot + masked top-k  [hot spot 2]
# ---------------------------------------------------------------------------

def ivf_scan(query: jax.Array, list_vecs: jax.Array, list_ids: jax.Array,
             sel: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Scan the selected posting lists for one query.

    query (d,); list_vecs (p, Lmax, d); list_ids (p, Lmax) (-1 pad);
    sel (np,) int32 — selected partitions.
    Returns (values (k,), doc_ids (k,)) sorted descending.
    """
    lv = list_vecs[sel]                             # (np, Lmax, d)
    li = list_ids[sel]                              # (np, Lmax)
    scores = jnp.einsum("nld,d->nl", lv.astype(jnp.float32),
                        query.astype(jnp.float32))
    scores = jnp.where(li >= 0, scores, -jnp.inf)
    flat_v, flat_i = scores.reshape(-1), li.reshape(-1)
    v, pos = jax.lax.top_k(flat_v, k)
    return v, flat_i[pos].astype(jnp.int32)


def ivf_scan_batch(queries: jax.Array, list_vecs: jax.Array,
                   list_ids: jax.Array, sel: jax.Array, k: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """vmap of ivf_scan over a query batch; sel (B, np)."""
    return jax.vmap(lambda q, s: ivf_scan(q, list_vecs, list_ids, s, k)
                    )(queries, sel)


# ---------------------------------------------------------------------------
# pq_adc_scan — PQ asymmetric-distance scan of selected posting lists
# ---------------------------------------------------------------------------

def pq_adc_scan(table: jax.Array, list_codes: jax.Array,
                list_ids: jax.Array, sel: jax.Array, k: int
                ) -> Tuple[jax.Array, jax.Array]:
    """ADC scan of the selected PQ-compressed posting lists (one query).

    table (m, n_codes) f32 — the query's ADC lookup table
    (``pq.adc_table``); list_codes (p, Lmax, m) uint8; list_ids
    (p, Lmax) int32 (-1 pad); sel (np,) int32.
    Returns (values (k,), doc_ids (k,)) sorted descending by ADC score.
    """
    codes = list_codes[sel].astype(jnp.int32)       # (np, Lmax, m)
    ids = list_ids[sel]                             # (np, Lmax)
    npb, lmax, m = codes.shape
    # gather along the code axis of the LUT: (m, np·Lmax) partial sums,
    # reduced over the m subquantizers — elementwise per doc, so the
    # reduction order is independent of any batching above
    flat = codes.reshape(npb * lmax, m)
    gathered = jnp.take_along_axis(table, flat.T, axis=1)   # (m, np·Lmax)
    scores = jnp.sum(gathered, axis=0)
    scores = jnp.where(ids.reshape(-1) >= 0, scores, -jnp.inf)
    v, pos = jax.lax.top_k(scores, k)
    return v, ids.reshape(-1)[pos].astype(jnp.int32)


def pq_adc_scan_batch(tables: jax.Array, list_codes: jax.Array,
                      list_ids: jax.Array, sel: jax.Array, k: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """vmap of pq_adc_scan over a query batch; tables (B, m, n_codes),
    sel (B, np)."""
    return jax.vmap(lambda t, s: pq_adc_scan(t, list_codes, list_ids, s, k)
                    )(tables, sel)


# ---------------------------------------------------------------------------
# fused_turn / fused_scan — single-dispatch TopLoc turn oracles
# ---------------------------------------------------------------------------
#
# The f32 paths compose the exact 3-dispatch CPU formulations
# (einsum → masked top_k → gather → multiply-reduce re-rank), so the
# ops-wrapper "ref" mode is bit-identical to the unfused engines by
# construction.  The bf16/int8 paths emulate the kernel's *per-tile*
# quantisation by reshaping the padded operands into the same
# (blk_p / blk_l) tiles and scoring them with the very helpers the
# kernel runs (``fused_turn.score_tile`` / ``adc_score_tile``) —
# integer dots are exact, so interpret-vs-ref stays deterministic for
# int8 too.


def _fused_stage1_scores(queries: jax.Array, cents_pad: jax.Array,
                         p: int, precision: str, blk_p: int) -> jax.Array:
    """Centroid scores (B, p) under the fused precision contract."""
    if precision == "f32":
        c = cents_pad[:p]
        return jnp.einsum("bpd,bd->bp",
                          jnp.broadcast_to(c, (queries.shape[0],) + c.shape),
                          queries)
    nc = cents_pad.shape[0] // blk_p
    tiles = cents_pad.reshape(nc, blk_p, -1)
    s = jnp.concatenate(
        [_ft.score_tile(queries, tiles[t], precision) for t in range(nc)],
        axis=1)
    return s[:, :p]


def _fused_stage2_scores(queries: jax.Array, lv_pad: jax.Array,
                         sel: jax.Array, precision: str, blk_l: int,
                         lmax: int) -> jax.Array:
    """Probed-list scores (B, np, lmax) under the precision contract."""
    if precision == "f32":
        lv = lv_pad[:, :lmax][sel]
        return jnp.einsum("bd,bnld->bnl", queries, lv)
    b = queries.shape[0]
    npb = sel.shape[1]
    lpad, d = lv_pad.shape[1], lv_pad.shape[2]
    nsub = lpad // blk_l
    g = lv_pad[sel].reshape(b, npb * nsub, blk_l, d)

    def one(qrow, tiles):
        return jnp.concatenate(
            [_ft.score_tile(qrow[None], tiles[t], precision)[0]
             for t in range(npb * nsub)])

    s = jax.vmap(one)(queries, g).reshape(b, npb, lpad)
    return s[:, :, :lmax]


def _fused_adc_candidates(tables: jax.Array, codes_pad: jax.Array,
                          ids_pad: jax.Array, sel: jax.Array, r: int,
                          precision: str, lmax: int
                          ) -> Tuple[jax.Array, jax.Array]:
    """ADC top-r candidates under the precision contract (B, r)."""
    if precision == "f32":
        return pq_adc_scan_batch(tables, codes_pad[:, :lmax],
                                 ids_pad[:, :lmax], sel, r)

    def one(tbl, s):
        codes = codes_pad[:, :lmax][s].astype(jnp.int32)  # (np, lmax, m)
        ids = ids_pad[:, :lmax][s]
        flat = codes.reshape(-1, codes.shape[-1])
        if precision == "int8":
            ti, st = _ft.quantize_sym(tbl, axes=(0, 1))
            g = jnp.take_along_axis(ti.astype(jnp.int32), flat.T, axis=1)
            sc = jnp.sum(g, axis=0).astype(jnp.float32) / st[0, 0]
        else:
            g = jnp.take_along_axis(tbl.astype(jnp.bfloat16), flat.T,
                                    axis=1)
            sc = jnp.sum(g.astype(jnp.float32), axis=0)
        sc = jnp.where(ids.reshape(-1) >= 0, sc, -jnp.inf)
        v, pos = jax.lax.top_k(sc, r)
        return v, ids.reshape(-1)[pos].astype(jnp.int32)

    return jax.vmap(one)(tables, sel)


def fused_turn_ivf(queries: jax.Array, cents_pad: jax.Array,
                   lv_pad: jax.Array, li_pad: jax.Array, *, p: int,
                   lmax: int, nprobe: int, k: int, r: int,
                   precision: str, blk_p: int, blk_l: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the single-dispatch IVF turn.

    Operands are the kernel's padded tensors; returns unpadded
    (values (B, k), ids (B, k), sel (B, nprobe)).
    """
    b = queries.shape[0]
    cs = _fused_stage1_scores(queries, cents_pad, p, precision, blk_p)
    _, sel = jax.lax.top_k(cs, nprobe)
    sel = sel.astype(jnp.int32)
    li = li_pad[:, :lmax][sel]
    sc = _fused_stage2_scores(queries, lv_pad, sel, precision, blk_l, lmax)
    flat_v = jnp.where(li >= 0, sc, -jnp.inf).reshape(b, -1)
    flat_i = li.reshape(b, -1)
    if precision == "f32":
        v, pos = jax.lax.top_k(flat_v, k)
        return v, jnp.take_along_axis(flat_i, pos, -1).astype(jnp.int32), sel
    cv, pos = jax.lax.top_k(flat_v, r)
    cid = jnp.take_along_axis(flat_i, pos, -1)
    rows = lv_pad[:, :lmax][sel].reshape(b, -1, lv_pad.shape[-1])
    rows = jnp.take_along_axis(rows, pos[..., None], axis=1)
    exact = jnp.sum(rows.astype(jnp.float32) * queries[:, None, :], -1)
    exact = jnp.where(cid >= 0, exact, -jnp.inf)
    v, rpos = jax.lax.top_k(exact, k)
    return v, jnp.take_along_axis(cid, rpos, -1).astype(jnp.int32), sel


def fused_turn_pq(queries: jax.Array, cents_pad: jax.Array,
                  tables: jax.Array, codes_pad: jax.Array,
                  ids_pad: jax.Array, corpus: jax.Array, *, p: int,
                  lmax: int, nprobe: int, k: int, r: int,
                  precision: str, blk_p: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the single-dispatch IVF-PQ turn (ADC + exact re-rank)."""
    cs = _fused_stage1_scores(queries, cents_pad, p, precision, blk_p)
    _, sel = jax.lax.top_k(cs, nprobe)
    sel = sel.astype(jnp.int32)
    cand_v, cand_ids = _fused_adc_candidates(tables, codes_pad, ids_pad,
                                             sel, r, precision, lmax)
    safe = jnp.maximum(cand_ids, 0)
    exact = jnp.sum(corpus[safe] * queries[:, None, :], axis=-1)
    exact = jnp.where(cand_ids >= 0, exact, -jnp.inf)
    top_v, pos = jax.lax.top_k(exact, k)
    top_i = jnp.take_along_axis(cand_ids, pos, axis=-1)
    return top_v, top_i.astype(jnp.int32), sel


def fused_scan_ivf(queries: jax.Array, lv_pad: jax.Array,
                   li_pad: jax.Array, sel: jax.Array, own: jax.Array, *,
                   lmax: int, k: int, r: int, precision: str,
                   blk_l: int, rerank: bool
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused IVF scan with prefetched selection.

    Returns (values (B, k), ids (B, k), pos (B, k)) — pos is the
    unpadded flat scan position for the no-re-rank path (tie-break key
    for the distributed merge; undefined where values are -inf) and the
    candidate rank after re-rank.
    """
    b = queries.shape[0]
    li = li_pad[:, :lmax][sel]
    li = jnp.where(own[..., None] > 0, li, -1)
    sc = _fused_stage2_scores(queries, lv_pad, sel, precision, blk_l, lmax)
    flat_v = jnp.where(li >= 0, sc, -jnp.inf).reshape(b, -1)
    flat_i = li.reshape(b, -1)
    if not rerank:
        v, pos = jax.lax.top_k(flat_v, k)
        return (v, jnp.take_along_axis(flat_i, pos, -1).astype(jnp.int32),
                pos.astype(jnp.int32))
    cv, pos = jax.lax.top_k(flat_v, r)
    cid = jnp.take_along_axis(flat_i, pos, -1)
    rows = lv_pad[:, :lmax][sel].reshape(b, -1, lv_pad.shape[-1])
    rows = jnp.take_along_axis(rows, pos[..., None], axis=1)
    exact = jnp.sum(rows.astype(jnp.float32) * queries[:, None, :], -1)
    exact = jnp.where(cid >= 0, exact, -jnp.inf)
    v, rpos = jax.lax.top_k(exact, k)
    return (v, jnp.take_along_axis(cid, rpos, -1).astype(jnp.int32),
            rpos.astype(jnp.int32))


def fused_scan_pq(tables: jax.Array, queries: jax.Array,
                  codes_pad: jax.Array, ids_pad: jax.Array,
                  sel: jax.Array, own: jax.Array, corpus: jax.Array, *,
                  lmax: int, k: int, r: int, precision: str,
                  rerank: bool
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused PQ scan (+ optional exact re-rank).

    Without re-rank returns the ADC top-r (values, ids, flat pos) for
    the sharded owner-computes merge; with re-rank the exact top-k
    (pos = candidate rank).
    """
    b = tables.shape[0]
    li = ids_pad[:, :lmax][sel]
    li = jnp.where(own[..., None] > 0, li, -1)
    codes = codes_pad[:, :lmax][sel].astype(jnp.int32)
    m = codes.shape[-1]

    def one(tbl, c, ids):
        flat = c.reshape(-1, m)
        if precision == "int8":
            ti, st = _ft.quantize_sym(tbl, axes=(0, 1))
            g = jnp.take_along_axis(ti.astype(jnp.int32), flat.T, axis=1)
            sc = jnp.sum(g, axis=0).astype(jnp.float32) / st[0, 0]
        elif precision == "bf16":
            g = jnp.take_along_axis(tbl.astype(jnp.bfloat16), flat.T,
                                    axis=1)
            sc = jnp.sum(g.astype(jnp.float32), axis=0)
        else:
            g = jnp.take_along_axis(tbl, flat.T, axis=1)
            sc = jnp.sum(g, axis=0)
        sc = jnp.where(ids.reshape(-1) >= 0, sc, -jnp.inf)
        v, pos = jax.lax.top_k(sc, r)
        return v, ids.reshape(-1)[pos].astype(jnp.int32), pos

    cv, cid, cpos = jax.vmap(one)(tables, codes, li)
    if not rerank:
        return cv, cid, cpos.astype(jnp.int32)
    safe = jnp.maximum(cid, 0)
    exact = jnp.sum(corpus[safe] * queries[:, None, :], axis=-1)
    exact = jnp.where(cid >= 0, exact, -jnp.inf)
    v, rpos = jax.lax.top_k(exact, k)
    return (v, jnp.take_along_axis(cid, rpos, -1).astype(jnp.int32),
            rpos.astype(jnp.int32))


# ---------------------------------------------------------------------------
# flash_attention — causal/full softmax attention with GQA
# ---------------------------------------------------------------------------

def mha_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  logit_soft_cap: Optional[float] = None) -> jax.Array:
    """Reference attention. q (B,H,S,D), k/v (B,Hkv,Skv,D); Hkv divides H.

    f32 softmax accumulation regardless of input dtype (matches kernel).
    Value head dim may differ from qk head dim (MLA).
    """
    b, h, s, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    group = h // hkv
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, hkv, group, s, d)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qg, kf) / jnp.sqrt(d).astype(jnp.float32)
    if logit_soft_cap is not None:
        logits = logit_soft_cap * jnp.tanh(logits / logit_soft_cap)
    if causal:
        skv = k.shape[2]
        # queries occupy the last `s` positions of the kv timeline
        qpos = jnp.arange(s) + (skv - s)
        kpos = jnp.arange(skv)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", probs, vf)
    return out.reshape(b, h, s, dv).astype(q.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cache_len: Optional[jax.Array] = None) -> jax.Array:
    """Single-token decode attention. q (B,H,D), k/v (B,Hkv,S,D).

    ``cache_len`` (B,) masks positions >= cache_len (ragged cache fill).
    """
    b, h, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhtd->bhgt", qg, k.astype(jnp.float32))
    logits = logits / jnp.sqrt(d).astype(jnp.float32)
    if cache_len is not None:
        mask = jnp.arange(s)[None] < cache_len[:, None]      # (B, S)
        logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, dv).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, blk_kv: int = 1024) -> jax.Array:
    """Flash-style chunked attention in pure jnp (lax.scan over KV tiles,
    online softmax).  Numerically equivalent to ``mha_attention`` but
    never materialises the (S, Skv) score matrix — this is the path the
    dry-run lowers (so the compiled memory analysis reflects the
    streaming TPU kernel, not an S² artefact of the plain reference) and
    the grad path of ``ops.flash_attention`` (scan of jnp ops —
    differentiable as-is, recompute-friendly).
    """
    b, h, s, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    nblk = -(-skv // blk_kv)
    pad = nblk * blk_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    scale = 1.0 / (d ** 0.5)
    qg = (q.reshape(b, hkv, group, s, d).astype(jnp.float32) * scale)
    kb = k.reshape(b, hkv, nblk, blk_kv, d).astype(jnp.float32)
    vb = v.reshape(b, hkv, nblk, blk_kv, dv).astype(jnp.float32)
    kb = jnp.moveaxis(kb, 2, 0)              # (nblk, B, Hkv, blk, d)
    vb = jnp.moveaxis(vb, 2, 0)
    qpos = jnp.arange(s) + (skv - s)

    @jax.checkpoint
    def body(carry, xs):
        # rematerialised: without checkpoint, differentiating the scan
        # saves every chunk's (S, blk) score matrix — the S x Skv memory
        # this formulation exists to avoid. Recompute-per-chunk is the
        # flash-attention backward strategy.
        m, l, acc, j = carry[0], carry[1], carry[2], carry[3]
        kj, vj = xs
        sco = jnp.einsum("bhgsd,bhtd->bhgst", qg, kj)   # (B,Hkv,g,S,blk)
        kpos = j * blk_kv + jnp.arange(blk_kv)
        valid = (kpos[None, :] < skv) if pad else jnp.ones(
            (1, blk_kv), bool)
        if causal:
            valid = valid & (qpos[:, None] >= kpos[None, :])
        sco = jnp.where(valid[None, None, None], sco, -jnp.inf)
        m_cur = jnp.max(sco, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(sco - m_safe[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = alpha * l + jnp.sum(p, -1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bhtd->bhgsd", p, vj)
        return (m_new, l_new, acc_new, j + 1), None

    m0 = jnp.full((b, hkv, group, s), -jnp.inf)
    l0 = jnp.zeros((b, hkv, group, s))
    a0 = jnp.zeros((b, hkv, group, s, dv))
    (m, l, acc, _), _ = jax.lax.scan(
        body, (m0, l0, a0, jnp.asarray(0, jnp.int32)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, s, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# embedding_bag — fused gather + segment-sum   [recsys hot path]
# ---------------------------------------------------------------------------

def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: Optional[jax.Array] = None,
                  mode: str = "sum") -> jax.Array:
    """EmbeddingBag over fixed-width bags. table (V,d), ids (B,L) int32
    (-1 = pad). Returns (B,d). mode: 'sum' | 'mean'.

    JAX has no native EmbeddingBag — this gather+mask+reduce IS the
    substrate (see kernel_taxonomy §RecSys), and the Pallas kernel fuses
    the row gather with the reduction so rows stream HBM→VMEM once.
    """
    mask = (ids >= 0)
    safe = jnp.maximum(ids, 0)
    rows = table[safe]                               # (B, L, d)
    w = mask.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    out = jnp.einsum("bld,bl->bd", rows.astype(jnp.float32),
                     w.astype(jnp.float32))
    if mode == "mean":
        denom = jnp.maximum(jnp.sum(w.astype(jnp.float32), -1, keepdims=True), 1.0)
        out = out / denom
    return out.astype(table.dtype)
