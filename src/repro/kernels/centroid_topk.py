"""Pallas TPU kernel: fused query x centroid matmul + streaming exact top-k.

TopLoc hot spot #1 (DESIGN.md §2): every conversational turn scores the
query batch against a centroid set — the full ``(p, d)`` set on turn 0 /
refresh, the cached ``(h, d)`` set otherwise — and selects the top-np.

The naive path materialises the full ``(B, p)`` score matrix in HBM and
runs XLA top-k over it.  This kernel streams centroid tiles HBM→VMEM,
feeds the MXU with a ``(B, d) x (d, blk_p)`` matmul per tile, and keeps a
running descending ``(B, k)`` register tile merged with each tile's
bitonic-network top-k — scores never round-trip to HBM, so the op is
centroid-read bandwidth-bound (its roofline floor).

Grid: ``(p // blk_p,)`` — sequential ("arbitrary") so the running tile
carries across steps in VMEM scratch.

VMEM budget per step (defaults blk_p=512, d≤1024, B≤64, f32):
  centroid tile 2 MB + queries 0.25 MB + scores (B, blk_p) 128 KB
  + 2×(B, k) scratch — comfortably under the ~16 MB/core budget.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

from repro.kernels import sorting


def _kernel(q_ref, c_ref, out_v_ref, out_i_ref, run_v, run_i, *, k: int,
            blk_p: int, nblk: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, -jnp.inf)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)            # (B, d)
    c = c_ref[...].astype(jnp.float32)            # (blk_p, d)
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (B, blk_p)
    ids = (j * blk_p
           + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))

    blk_v, blk_i = sorting.block_topk_desc(scores, ids, k)
    mv, mi = sorting.merge_topk_desc(run_v[...], run_i[...], blk_v, blk_i)
    run_v[...] = mv
    run_i[...] = mi

    @pl.when(j == nblk - 1)
    def _finalize():
        out_v_ref[...] = run_v[...]
        out_i_ref[...] = run_i[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "blk_p", "interpret"))
def centroid_topk(queries: jax.Array, centroids: jax.Array, k: int, *,
                  blk_p: int = 512, interpret: bool = False
                  ) -> Tuple[jax.Array, jax.Array]:
    """Fused top-k centroid selection. queries (B,d), centroids (p,d).

    Returns (values (B,k) f32 desc-sorted, ids (B,k) int32).
    Padding contract: handled by ``ops.centroid_topk`` (p → multiple of
    blk_p with -inf fill, k → power of two).  Call through ops.py.
    """
    b, d = queries.shape
    p = centroids.shape[0]
    assert p % blk_p == 0, (p, blk_p)
    assert sorting._is_pow2(k) and sorting._is_pow2(blk_p) and k <= blk_p
    nblk = p // blk_p

    kern = functools.partial(_kernel, k=k, blk_p=blk_p, nblk=nblk)
    out_v, out_i = pl.pallas_call(
        kern,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),          # queries
            pl.BlockSpec((blk_p, d), lambda j: (j, 0)),      # centroid tile
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((b, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, k), jnp.float32),
            pltpu.VMEM((b, k), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(queries, centroids)
    return out_v, out_i
