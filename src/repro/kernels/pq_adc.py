"""Pallas TPU kernel: PQ asymmetric-distance scan of selected posting lists.

The IVF-PQ hot loop: after TopLoc centroid selection, the ``nprobe``
selected posting lists are scanned *compressed* — each doc is ``m``
uint8 codes, and its approximate score is the sum of ``m`` lookups into
the query's ``(m, n_codes)`` ADC table.  Compared to ``ivf_scan`` this
moves 4·d/m fewer bytes HBM→VMEM per doc (16x at d=128, m=32), which is
the memory-roofline term that dominates list scanning.

Layout mirrors ``ivf_scan``: scalar-prefetched selection indices drive
the code-tile index_map (data-dependent gather), the LUT tile stays
VMEM-resident across a query's probes, and a running per-query top-k
register tile is folded with the bitonic merge network.

The in-kernel "gather" is expressed as m one-hot matmuls
(``(blk_l, n_codes) @ (n_codes,)`` per subquantizer): Mosaic has no
general VMEM gather along lanes, but compare-against-iota + MXU dot is
exactly the accumulate-subquantizer-partial-sums schedule and keeps
every op lane-parallel.  Codes are loaded as uint8 (the compression is
the point) and widened in-register.

Grid: ``(B, nprobe·nsub)`` — probe axis sequential so the running tile
carries; batch axis parallel.  VMEM per step: LUT (m·n_codes·4 ≤ 64 KB
at m=64) + code tile (blk_l·m bytes) — tiny next to ivf_scan's float
tiles.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels import sorting


def _kernel(sel_ref, tbl_ref, codes_ref, ids_ref, out_v_ref, out_i_ref,
            run_v, run_i, *, k: int, nprobe: int, nsub: int):
    j = pl.program_id(1)          # probe-tile index (sequential)

    @pl.when(j == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, -jnp.inf)
        run_i[...] = jnp.full_like(run_i, -1)

    table = tbl_ref[...][0].astype(jnp.float32)           # (m, n_codes)
    codes = codes_ref[...][0].astype(jnp.int32)           # (blk_l, m)
    li = ids_ref[...]                                     # (1, blk_l)
    blk_l, m = codes.shape
    n_codes = table.shape[1]

    # ADC: scores[l] = sum_j table[j, codes[l, j]], realised as m
    # one-hot MXU dots (compare-with-iota selects the LUT entry)
    iota = jax.lax.broadcasted_iota(jnp.int32, (blk_l, n_codes), 1)
    scores = jnp.zeros((blk_l,), jnp.float32)
    for sq in range(m):
        onehot = (iota == codes[:, sq:sq + 1]).astype(jnp.float32)
        scores = scores + jax.lax.dot_general(
            onehot, table[sq], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    scores = jnp.where(li[0] >= 0, scores, -jnp.inf)[None]   # (1, blk_l)

    blk_v, blk_i = sorting.block_topk_desc(scores, li, k)
    mv, mi = sorting.merge_topk_desc(run_v[...], run_i[...], blk_v, blk_i)
    run_v[...] = mv
    run_i[...] = mi

    @pl.when(j == nprobe * nsub - 1)
    def _finalize():
        out_v_ref[...] = run_v[...]
        out_i_ref[...] = run_i[...]


@functools.partial(jax.jit, static_argnames=("k", "blk_l", "interpret"))
def pq_adc_scan(tables: jax.Array, list_codes: jax.Array,
                list_ids: jax.Array, sel: jax.Array, k: int, *,
                blk_l: int = 0, interpret: bool = False
                ) -> Tuple[jax.Array, jax.Array]:
    """Fused ADC scan over the selected PQ posting lists.

    tables (B, m, n_codes) f32 — per-query ADC lookup tables (built
    outside: a tiny einsum); list_codes (p, Lmax, m) uint8; list_ids
    (p, Lmax) int32 (-1 pad); sel (B, nprobe) int32.

    Returns (values (B, k) f32 desc, doc_ids (B, k) int32) — the ADC
    top-k candidates, to be exact-re-ranked by the caller.
    Padding contract (ops.py): Lmax multiple of blk_l, blk_l & k pow2,
    k ≤ blk_l.
    """
    b, m, n_codes = tables.shape
    p, lmax, _ = list_codes.shape
    nprobe = sel.shape[1]
    if blk_l == 0:
        blk_l = lmax
    assert lmax % blk_l == 0, (lmax, blk_l)
    nsub = lmax // blk_l
    assert sorting._is_pow2(k) and sorting._is_pow2(blk_l) and k <= blk_l

    kern = functools.partial(_kernel, k=k, nprobe=nprobe, nsub=nsub)
    grid = (b, nprobe * nsub)

    def codes_map(bi, j, sel_ref):
        return (sel_ref[bi, j // nsub], j % nsub, 0)

    def ids_map(bi, j, sel_ref):
        return (sel_ref[bi, j // nsub], j % nsub)

    out_v, out_i = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, m, n_codes),
                             lambda bi, j, sel_ref: (bi, 0, 0)),
                pl.BlockSpec((1, blk_l, m), codes_map),
                pl.BlockSpec((1, blk_l), ids_map),
            ],
            out_specs=[
                pl.BlockSpec((1, k), lambda bi, j, sel_ref: (bi, 0)),
                pl.BlockSpec((1, k), lambda bi, j, sel_ref: (bi, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((1, k), jnp.float32),
                pltpu.VMEM((1, k), jnp.int32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(sel, tables, list_codes, list_ids)
    return out_v, out_i
