"""Pallas TPU megakernel: one whole TopLoc turn in a single dispatch.

The classic path costs three device dispatches per turn — centroid
top-k (``centroid_topk``), probed-list scan / ADC (``ivf_scan`` /
``pq_adc``), exact re-rank (XLA) — with the intermediate probe ids and
candidate buffers bounced through HBM between them.  ``fused_turn``
runs all three stages inside one kernel:

  stage 1  centroid tiles stream in via BlockSpec and are scored on the
           MXU against the whole query batch; a running ``(B, nprobe)``
           probe set lives in a VMEM register tile (tie-aware bitonic
           merge, so the selection order matches ``lax.top_k``).
  stage 2  the probe ids move VMEM→SMEM once, then drive *in-kernel*
           double-buffered DMAs that gather list tiles straight from
           the HBM-resident (``ANY`` memory space) posting-list tensor
           — probe ids and candidates never round-trip through HBM.
           Candidates fold into a running ``(B, r)`` register tile.
  stage 3  per-candidate rows are DMA-gathered (by doc id for IVF-PQ,
           by flat scan position for quantised IVF) and re-ranked with
           a float32 multiply-reduce in-kernel; the final top-k comes
           off the tie-aware network.

``fused_scan`` is the same machinery minus stage 1: the selection is
scalar-prefetched (cached-centroid turns, sharded local scans) and the
kernel fuses scan + re-rank into one dispatch, emitting tie-break
positions compatible with ``distributed_topk_ordered``.

Precision contract
------------------
* ``"f32"``  — stages 1–2 score in float32.  Float IVF needs no
  re-rank; ids, scores and the probe selection match the 3-dispatch
  reference exactly (ties broken by smaller flat position, the
  ``lax.top_k`` order).
* ``"bf16"`` — stage 1–2 operands are cast to bfloat16 and accumulated
  in float32 on the MXU (half the MXU cycles per tile).
* ``"int8"`` — stage 1–2 operands are symmetrically quantised *per
  tile* (scale = 127/max|tile|; per-query-row scale for q), scored
  with integer MXU dots, dequantised once per tile.

Quantised variants keep a widened candidate set (``k·over`` for IVF,
the ADC re-rank depth for IVF-PQ) and ALWAYS finish with the float32
in-kernel re-rank of stage 3 against uncompressed rows, so the
*returned scores are exact float dot products*: quantisation can only
perturb which candidates survive stage 2, never the reported score.
That is why a pinned recall floor (fig8) is the acceptance for
bf16/int8 while f32 keeps strict bit-identity.

The scoring helpers below are pure jnp and shared with the ``ref.py``
oracles, so the reference emulation quantises at exactly the kernel's
tile granularity — integer dots are exact, making interpret-vs-ref
comparisons deterministic even for the int8 path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels import sorting

PAD_POS = sorting.PAD_POS


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# scoring helpers — pure jnp, shared with the ref.py oracles
# ---------------------------------------------------------------------------


def quantize_sym(x: jax.Array, axes) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantisation over ``axes``: (q_int8, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    scale = 127.0 / jnp.maximum(amax, 1e-30)
    q = jnp.clip(jnp.round(x * scale), -127, 127).astype(jnp.int8)
    return q, scale


def score_tile(q: jax.Array, tile: jax.Array, precision: str) -> jax.Array:
    """(B, d) × (T, d) → (B, T) scores under the precision contract.

    int8 quantises ``tile`` with one scale per call and ``q`` per row;
    the ref emulation reshapes the padded operand into the same tiles,
    so both paths see identical integer dots and identical dequant
    divides.
    """
    if precision == "f32":
        return jax.lax.dot_general(
            q, tile, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    if precision == "bf16":
        return jax.lax.dot_general(
            q.astype(jnp.bfloat16), tile.astype(jnp.bfloat16),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    if precision == "int8":
        qi, sq = quantize_sym(q, axes=(1,))               # (B, d), (B, 1)
        ti, st = quantize_sym(tile, axes=(0, 1))          # (T, d), (1, 1)
        acc = jax.lax.dot_general(
            qi.astype(jnp.int32), ti.astype(jnp.int32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)             # (B, T)
        return acc.astype(jnp.float32) / (sq * st[0])
    raise ValueError(f"unknown precision {precision!r}")


def adc_score_tile(table: jax.Array, codes: jax.Array, precision: str
                   ) -> jax.Array:
    """ADC scores for one code tile: (m, C) table × (T, m) codes → (T,).

    Realised as m one-hot MXU dots (cf. ``pq_adc``); bf16 casts the
    LUT, int8 quantises it with one scale per (m, C) table — tile
    granularity is irrelevant for PQ because the LUT is constant across
    tiles, which keeps the ref emulation (a plain gather of the same
    integer LUT) exact.
    """
    t, m = codes.shape
    n_codes = table.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (t, n_codes), 1)
    if precision == "int8":
        ti, st = quantize_sym(table, axes=(0, 1))         # (m, C) int8
        acc = jnp.zeros((t,), jnp.int32)
        for sq in range(m):
            onehot = (iota == codes[:, sq:sq + 1]).astype(jnp.int32)
            acc = acc + jax.lax.dot_general(
                onehot, ti[sq].astype(jnp.int32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) / st[0, 0]
    tbl = table.astype(jnp.bfloat16) if precision == "bf16" else table
    scores = jnp.zeros((t,), jnp.float32)
    for sq in range(m):
        onehot = (iota == codes[:, sq:sq + 1]).astype(tbl.dtype)
        scores = scores + jax.lax.dot_general(
            onehot, tbl[sq], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return scores


def _iota2(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def rerank_exact(rows: jax.Array, qrow: jax.Array) -> jax.Array:
    """Float32 exact re-rank: (R, d) rows × (1, d) query → (1, R).

    Explicit multiply-reduce (not a dot_general) mirroring
    ``toploc._scan_lists_pq``, so the fused and 3-dispatch paths lower
    the same reduction and produce the same floats on CPU.
    """
    return jnp.sum(rows.astype(jnp.float32) * qrow, axis=-1)[None]


# ---------------------------------------------------------------------------
# fused_turn — stages 1+2+3, one dispatch
# ---------------------------------------------------------------------------


def _turn_kernel(*refs, family: str, do_rerank: bool, precision: str,
                 b: int, p: int, nc: int, blk_p: int, nprobe: int,
                 nsub: int, blk_l: int, lpad: int, np_pad: int,
                 r: int, r_pad: int, kp: int):
    if family == "pq":
        (q_ref, cents_ref, tbl_ref, lists_hbm, li_hbm, corpus_hbm,
         out_v, out_i, out_sel,
         run_pv, run_pi, sel_smem, run_cv, run_ci, run_cp,
         lbuf, ibuf, cand_smem, rrow, lsem, ssem, rsem) = refs
    elif do_rerank:
        (q_ref, cents_ref, lists_hbm, li_hbm,
         out_v, out_i, out_sel,
         run_pv, run_pi, sel_smem, run_cv, run_ci, run_cp,
         lbuf, ibuf, cand_smem, rrow, lsem, ssem, rsem) = refs
    else:
        (q_ref, cents_ref, lists_hbm, li_hbm,
         out_v, out_i, out_sel,
         run_pv, run_pi, sel_smem, run_cv, run_ci, run_cp,
         lbuf, ibuf, lsem, ssem) = refs

    j = pl.program_id(0)
    npr = nprobe * nsub

    def list_dma(t, slot):
        bq = t // npr
        jp = t % npr
        pid = sel_smem[bq, jp // nsub]
        sub = jp % nsub
        vec = pltpu.make_async_copy(
            lists_hbm.at[pid, pl.ds(sub * blk_l, blk_l)],
            lbuf.at[slot], lsem.at[slot, 0])
        ids = pltpu.make_async_copy(
            li_hbm.at[pid, pl.ds(sub * blk_l, blk_l)],
            ibuf.at[slot, 0], lsem.at[slot, 1])
        return vec, ids

    # ---- stage 1: centroid tiles → running (B, np_pad) probe set -----
    @pl.when(j < nc)
    def _stage1():
        @pl.when(j == 0)
        def _init():
            run_pv[...] = jnp.full_like(run_pv, -jnp.inf)
            run_pi[...] = jnp.full_like(run_pi, PAD_POS)

        scores = score_tile(q_ref[...], cents_ref[...], precision)
        pos = j * blk_p + _iota2((b, blk_p), 1)
        valid = pos < p
        vals = jnp.where(valid, scores, -jnp.inf)
        posm = jnp.where(valid, pos, PAD_POS)
        # the global centroid index doubles as id and tie-break pos —
        # exactly lax.top_k's order over the flat centroid-score row
        bv, bi_, bp_ = sorting.block_topk_desc_tie(vals, posm, posm,
                                                   np_pad)
        mv, mi, _ = sorting.merge_topk_desc_tie(
            run_pv[...], run_pi[...], run_pi[...], bv, bi_, bp_)
        run_pv[...] = mv
        run_pi[...] = mi

        @pl.when(j == nc - 1)
        def _handoff():
            # probe ids leave VMEM exactly once: into SMEM, where they
            # steer the stage-2 gather DMAs as scalars
            cp = pltpu.make_async_copy(run_pi, sel_smem, ssem)
            cp.start()
            cp.wait()
            run_cv[...] = jnp.full_like(run_cv, -jnp.inf)
            run_ci[...] = jnp.full_like(run_ci, -1)
            run_cp[...] = jnp.full_like(run_cp, PAD_POS)
            v0, i0 = list_dma(0, 0)
            v0.start()
            i0.start()

    # ---- stage 2: probed-list tiles → running (B, r_pad) candidates --
    @pl.when((j >= nc) & (j < nc + b * npr))
    def _stage2():
        t = j - nc
        bq = t // npr
        jp = t % npr
        slot = jax.lax.rem(t, 2)

        @pl.when(t + 1 < b * npr)
        def _prefetch():
            vn, in_ = list_dma(t + 1, jax.lax.rem(t + 1, 2))
            vn.start()
            in_.start()

        vc, ic = list_dma(t, slot)
        vc.wait()
        ic.wait()
        tile = lbuf[slot]                                 # (blk_l, d|m)
        lid = ibuf[slot]                                  # (1, blk_l)

        if family == "pq":
            tbl = tbl_ref[pl.ds(bq, 1)][0]                # (m, C)
            s = adc_score_tile(tbl, tile.astype(jnp.int32),
                               precision)[None]
        else:
            qrow = q_ref[pl.ds(bq, 1), :]                 # (1, d)
            s = score_tile(qrow, tile, precision)         # (1, blk_l)

        pos = jp * blk_l + _iota2((1, blk_l), 1)
        valid = lid >= 0
        vals = jnp.where(valid, s, -jnp.inf)
        posm = jnp.where(valid, pos, PAD_POS)
        bv, bi_, bp_ = sorting.block_topk_desc_tie(vals, lid, posm,
                                                   r_pad)
        mv, mi, mp = sorting.merge_topk_desc_tie(
            run_cv[pl.ds(bq, 1), :], run_ci[pl.ds(bq, 1), :],
            run_cp[pl.ds(bq, 1), :], bv, bi_, bp_)
        run_cv[pl.ds(bq, 1), :] = mv
        run_ci[pl.ds(bq, 1), :] = mi
        run_cp[pl.ds(bq, 1), :] = mp

    # ---- stage 3: float32 in-kernel re-rank + write-out --------------
    @pl.when(j >= nc + b * npr)
    def _stage3():
        bq = j - (nc + b * npr)

        @pl.when(j == nc + b * npr)
        def _sel_out():
            out_sel[...] = run_pi[...]

        if not do_rerank:
            out_v[pl.ds(bq, 1), :] = run_cv[pl.ds(bq, 1), pl.ds(0, kp)]
            out_i[pl.ds(bq, 1), :] = run_ci[pl.ds(bq, 1), pl.ds(0, kp)]
        else:
            key_src = run_ci if family == "pq" else run_cp
            cp = pltpu.make_async_copy(key_src.at[pl.ds(bq, 1)],
                                       cand_smem, ssem)
            cp.start()
            cp.wait()
            copies = []
            for i in range(r_pad):
                if family == "pq":
                    row = jnp.maximum(cand_smem[0, i], 0)
                    c = pltpu.make_async_copy(corpus_hbm.at[row],
                                              rrow.at[i], rsem)
                else:
                    # flat pos → (probe, offset) → uncompressed list row
                    cpos = cand_smem[0, i]
                    probe_i = jnp.minimum(cpos // lpad, nprobe - 1)
                    off = jax.lax.rem(cpos, lpad)
                    pid2 = sel_smem[bq, probe_i]
                    c = pltpu.make_async_copy(lists_hbm.at[pid2, off],
                                              rrow.at[i], rsem)
                c.start()
                copies.append(c)
            for c in copies:
                c.wait()
            qrow = q_ref[pl.ds(bq, 1), :]
            ex = rerank_exact(rrow[...], qrow)            # (1, r_pad)
            ids_row = run_ci[pl.ds(bq, 1), :]
            rank = _iota2((1, r_pad), 1)
            # candidates past the exact depth r (pow2 padding) must not
            # re-enter: the 3-dispatch path never re-ranks them
            valid = (ids_row >= 0) & (rank < r)
            vals = jnp.where(valid, ex, -jnp.inf)
            bv, bi_, _ = sorting.block_topk_desc_tie(vals, ids_row,
                                                     rank, kp)
            out_v[pl.ds(bq, 1), :] = bv
            out_i[pl.ds(bq, 1), :] = bi_


def _turn_call(kern, *, family, do_rerank, b, d, m, n_codes, blk_p, nc,
               blk_l, np_pad, r_pad, kp, grid, list_dtype, interpret):
    def cents_map(j):
        return (jnp.minimum(j, nc - 1), 0)

    in_specs = [
        pl.BlockSpec((b, d), lambda j: (0, 0)),
        pl.BlockSpec((blk_p, d), cents_map),
    ]
    if family == "pq":
        in_specs.append(pl.BlockSpec((b, m, n_codes),
                                     lambda j: (0, 0, 0)))
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))      # lists
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))      # list ids
    if family == "pq":
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))  # corpus

    scratch = [
        pltpu.VMEM((b, np_pad), jnp.float32),        # run_pv
        pltpu.VMEM((b, np_pad), jnp.int32),          # run_pi
        pltpu.SMEM((b, np_pad), jnp.int32),          # sel_smem
        pltpu.VMEM((b, r_pad), jnp.float32),         # run_cv
        pltpu.VMEM((b, r_pad), jnp.int32),           # run_ci
        pltpu.VMEM((b, r_pad), jnp.int32),           # run_cp
        pltpu.VMEM((2, blk_l, m if family == "pq" else d),
                   list_dtype),                      # lbuf
        pltpu.VMEM((2, 1, blk_l), jnp.int32),        # ibuf
    ]
    if do_rerank:
        scratch.append(pltpu.SMEM((1, r_pad), jnp.int32))      # cand_smem
        scratch.append(pltpu.VMEM((r_pad, d), jnp.float32))    # rrow
    scratch.append(pltpu.SemaphoreType.DMA((2, 2)))            # lsem
    scratch.append(pltpu.SemaphoreType.DMA)                    # ssem
    if do_rerank:
        scratch.append(pltpu.SemaphoreType.DMA)                # rsem

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((b, kp), lambda j: (0, 0)),
            pl.BlockSpec((b, kp), lambda j: (0, 0)),
            pl.BlockSpec((b, np_pad), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kp), jnp.float32),
            jax.ShapeDtypeStruct((b, kp), jnp.int32),
            jax.ShapeDtypeStruct((b, np_pad), jnp.int32),
        ],
        scratch_shapes=scratch,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )


def _turn_dims(centroids, lists, nprobe, k, r, blk_p, blk_l):
    p_pad = centroids.shape[0]
    p, lpad = lists.shape[0], lists.shape[1]
    assert p_pad % blk_p == 0 and lpad % blk_l == 0, \
        (p_pad, blk_p, lpad, blk_l)
    nc = p_pad // blk_p
    nsub = lpad // blk_l
    kp = _next_pow2(k)
    np_pad = _next_pow2(nprobe)
    r_pad = _next_pow2(r)
    assert np_pad <= blk_p and r_pad <= blk_l and kp <= r_pad, \
        (np_pad, blk_p, r_pad, blk_l, kp)
    return p, nc, nsub, lpad, kp, np_pad, r_pad


@functools.partial(jax.jit, static_argnames=(
    "nprobe", "k", "r", "precision", "blk_p", "blk_l", "interpret"))
def fused_turn(queries: jax.Array, centroids: jax.Array,
               list_vecs: jax.Array, list_ids: jax.Array, *,
               nprobe: int, k: int, r: int, precision: str = "f32",
               blk_p: int = 512, blk_l: int = 2048,
               interpret: bool = False
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-dispatch IVF turn.

    queries (B, d); centroids (p_pad, d) zero-padded to a blk_p
    multiple (real count = list_vecs.shape[0]; padded rows are masked
    by position); list_vecs (p, lpad, d); list_ids (p, lpad) int32
    (-1 pad); ``r`` = exact candidate depth — k for f32 (no re-rank),
    k·over for quantised (stage 3 re-ranks in-kernel from the float
    list rows).

    Returns (values (B, kp), ids (B, kp), sel (B, np_pad)); callers
    slice to (k, nprobe).  Padding contract (ops.py): pow2 kp/np_pad/
    r_pad, np_pad ≤ blk_p, kp ≤ r_pad ≤ blk_l.
    """
    b, d = queries.shape
    p, nc, nsub, lpad, kp, np_pad, r_pad = _turn_dims(
        centroids, list_vecs, nprobe, k, r, blk_p, blk_l)
    do_rerank = precision != "f32"

    kern = functools.partial(
        _turn_kernel, family="ivf", do_rerank=do_rerank,
        precision=precision, b=b, p=p, nc=nc, blk_p=blk_p,
        nprobe=nprobe, nsub=nsub, blk_l=blk_l, lpad=lpad,
        np_pad=np_pad, r=r, r_pad=r_pad, kp=kp)
    call = _turn_call(
        kern, family="ivf", do_rerank=do_rerank, b=b, d=d, m=0,
        n_codes=0, blk_p=blk_p, nc=nc, blk_l=blk_l, np_pad=np_pad,
        r_pad=r_pad, kp=kp, grid=(nc + b * nprobe * nsub + b,),
        list_dtype=jnp.float32, interpret=interpret)
    return call(queries, centroids, list_vecs, list_ids)


@functools.partial(jax.jit, static_argnames=(
    "nprobe", "k", "r", "precision", "blk_p", "blk_l", "interpret"))
def fused_turn_pq(queries: jax.Array, centroids: jax.Array,
                  tables: jax.Array, list_codes: jax.Array,
                  list_ids: jax.Array, corpus: jax.Array, *,
                  nprobe: int, k: int, r: int, precision: str = "f32",
                  blk_p: int = 512, blk_l: int = 4096,
                  interpret: bool = False
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-dispatch IVF-PQ turn: centroid top-k + ADC + exact re-rank.

    tables (B, m, n_codes) f32 per-query ADC LUTs; list_codes
    (p, lpad, m) uint8; corpus (N, d) f32 re-rank rows gathered by
    candidate doc id.  ``r`` = ADC re-rank depth
    (max(k, min(rerank, nprobe·lmax)) upstream).  Stage 3 always runs.
    """
    b, d = queries.shape
    _, m, n_codes = tables.shape
    p, nc, nsub, lpad, kp, np_pad, r_pad = _turn_dims(
        centroids, list_codes, nprobe, k, r, blk_p, blk_l)

    kern = functools.partial(
        _turn_kernel, family="pq", do_rerank=True, precision=precision,
        b=b, p=p, nc=nc, blk_p=blk_p, nprobe=nprobe, nsub=nsub,
        blk_l=blk_l, lpad=lpad, np_pad=np_pad, r=r, r_pad=r_pad, kp=kp)
    call = _turn_call(
        kern, family="pq", do_rerank=True, b=b, d=d, m=m,
        n_codes=n_codes, blk_p=blk_p, nc=nc, blk_l=blk_l,
        np_pad=np_pad, r_pad=r_pad, kp=kp,
        grid=(nc + b * nprobe * nsub + b,),
        list_dtype=jnp.uint8, interpret=interpret)
    return call(queries, centroids, tables, list_codes, list_ids, corpus)


# ---------------------------------------------------------------------------
# fused_scan — stages 2+3 with a prefetched selection
# ---------------------------------------------------------------------------


def _scan_kernel(sel_ref, own_ref, *refs, family: str, do_rerank: bool,
                 precision: str, nprobe: int, nsub: int, blk_l: int,
                 lpad: int, r: int, r_pad: int, kp: int):
    if family == "pq":
        if do_rerank:
            (tbl_ref, q_ref, tiles_ref, li_ref, corpus_hbm,
             out_v, out_i, out_p, run_v, run_i, run_p,
             cand_smem, rrow, rsem, ssem) = refs
        else:
            (tbl_ref, tiles_ref, li_ref, out_v, out_i, out_p,
             run_v, run_i, run_p) = refs
    else:
        if do_rerank:
            (q_ref, tiles_ref, li_ref, lists_hbm,
             out_v, out_i, out_p, run_v, run_i, run_p,
             cand_smem, rrow, rsem, ssem) = refs
        else:
            (q_ref, tiles_ref, li_ref, out_v, out_i, out_p,
             run_v, run_i, run_p) = refs

    bi = pl.program_id(0)
    j = pl.program_id(1)
    npr = nprobe * nsub

    @pl.when(j == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, -jnp.inf)
        run_i[...] = jnp.full_like(run_i, -1)
        run_p[...] = jnp.full_like(run_p, PAD_POS)

    @pl.when(j < npr)
    def _scan():
        tile = tiles_ref[0]                               # (blk_l, d|m)
        lid = li_ref[...]                                 # (1, blk_l)
        # shard ownership mask (sharded locals): foreign lists → -1,
        # matching ShardedIVFScan's where(own, li, -1)
        lid_m = jnp.where(own_ref[bi, j // nsub] > 0, lid, -1)
        if family == "pq":
            s = adc_score_tile(tbl_ref[0], tile.astype(jnp.int32),
                               precision)[None]
        else:
            s = score_tile(q_ref[...], tile, precision)   # (1, blk_l)
        pos = j * blk_l + _iota2((1, blk_l), 1)
        valid = lid_m >= 0
        vals = jnp.where(valid, s, -jnp.inf)
        posm = jnp.where(valid, pos, PAD_POS)
        bv, bi_, bp_ = sorting.block_topk_desc_tie(vals, lid_m, posm,
                                                   r_pad)
        mv, mi, mp = sorting.merge_topk_desc_tie(
            run_v[...], run_i[...], run_p[...], bv, bi_, bp_)
        run_v[...] = mv
        run_i[...] = mi
        run_p[...] = mp

    @pl.when(j == npr)
    def _finalize():
        if not do_rerank:
            out_v[...] = run_v[...]
            out_i[...] = run_i[...]
            out_p[...] = run_p[...]
        else:
            key_src = run_i if family == "pq" else run_p
            cp = pltpu.make_async_copy(key_src, cand_smem, ssem)
            cp.start()
            cp.wait()
            copies = []
            for i in range(r_pad):
                if family == "pq":
                    row = jnp.maximum(cand_smem[0, i], 0)
                    c = pltpu.make_async_copy(corpus_hbm.at[row],
                                              rrow.at[i], rsem)
                else:
                    cpos = cand_smem[0, i]
                    probe_i = jnp.minimum(cpos // lpad, nprobe - 1)
                    off = jax.lax.rem(cpos, lpad)
                    pid2 = sel_ref[bi, probe_i]
                    c = pltpu.make_async_copy(lists_hbm.at[pid2, off],
                                              rrow.at[i], rsem)
                c.start()
                copies.append(c)
            for c in copies:
                c.wait()
            ex = rerank_exact(rrow[...], q_ref[...])      # (1, r_pad)
            rank = _iota2((1, r_pad), 1)
            valid = (run_i[...] >= 0) & (rank < r)
            vals = jnp.where(valid, ex, -jnp.inf)
            bv, bi_, bp_ = sorting.block_topk_desc_tie(
                vals, run_i[...], rank, kp)
            out_v[...] = bv
            out_i[...] = bi_
            # after re-rank the tie-break key is the candidate's ADC
            # rank, not a flat scan position (single-device use only)
            out_p[...] = bp_


def _scan_call(kern, *, family, do_rerank, b, d, m, n_codes, blk_l,
               nsub, npr, r_pad, w, grid, interpret):
    def lv_map(bi, j, sel_ref, own_ref):
        jj = jnp.minimum(j, npr - 1)
        return (sel_ref[bi, jj // nsub], jj % nsub, 0)

    def li_map(bi, j, sel_ref, own_ref):
        jj = jnp.minimum(j, npr - 1)
        return (sel_ref[bi, jj // nsub], jj % nsub)

    def row_map(bi, j, sel_ref, own_ref):
        return (bi, 0)

    in_specs = []
    if family == "pq":
        in_specs.append(pl.BlockSpec(
            (1, m, n_codes), lambda bi, j, s, o: (bi, 0, 0)))
        if do_rerank:
            in_specs.append(pl.BlockSpec((1, d), row_map))
        in_specs.append(pl.BlockSpec((1, blk_l, m), lv_map))
        in_specs.append(pl.BlockSpec((1, blk_l), li_map))
        if do_rerank:
            in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
    else:
        in_specs.append(pl.BlockSpec((1, d), row_map))
        in_specs.append(pl.BlockSpec((1, blk_l, d), lv_map))
        in_specs.append(pl.BlockSpec((1, blk_l), li_map))
        if do_rerank:
            in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))

    scratch = [
        pltpu.VMEM((1, r_pad), jnp.float32),
        pltpu.VMEM((1, r_pad), jnp.int32),
        pltpu.VMEM((1, r_pad), jnp.int32),
    ]
    if do_rerank:
        scratch.append(pltpu.SMEM((1, r_pad), jnp.int32))
        scratch.append(pltpu.VMEM((r_pad, d), jnp.float32))
        scratch.append(pltpu.SemaphoreType.DMA)
        scratch.append(pltpu.SemaphoreType.DMA)

    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, w), row_map),
                pl.BlockSpec((1, w), row_map),
                pl.BlockSpec((1, w), row_map),
            ],
            scratch_shapes=scratch,
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, w), jnp.float32),
            jax.ShapeDtypeStruct((b, w), jnp.int32),
            jax.ShapeDtypeStruct((b, w), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=(
    "k", "r", "precision", "blk_l", "rerank", "interpret"))
def fused_scan(queries: jax.Array, list_vecs: jax.Array,
               list_ids: jax.Array, sel: jax.Array, own: jax.Array, *,
               k: int, r: int, precision: str = "f32",
               blk_l: int = 2048, rerank: bool = False,
               interpret: bool = False
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused IVF scan (+ optional in-kernel re-rank), prefetched sel.

    own (B, nprobe) int32: 1 where this shard owns the probed list
    (ones for single-device).  Without re-rank returns the top r_pad
    candidates with flat *padded* scan positions — after the ops
    wrapper's pos conversion these are tie-break compatible with
    ``distributed_topk_ordered``; with re-rank (quantised precision)
    returns the exact-scored top kp.
    """
    b, d = queries.shape
    p, lpad, _ = list_vecs.shape
    nprobe = sel.shape[1]
    assert lpad % blk_l == 0, (lpad, blk_l)
    nsub = lpad // blk_l
    npr = nprobe * nsub
    kp = _next_pow2(k)
    r_pad = _next_pow2(r)
    assert kp <= r_pad <= blk_l, (kp, r_pad, blk_l)
    w = kp if rerank else r_pad

    kern = functools.partial(
        _scan_kernel, family="ivf", do_rerank=rerank,
        precision=precision, nprobe=nprobe, nsub=nsub, blk_l=blk_l,
        lpad=lpad, r=r, r_pad=r_pad, kp=kp)
    call = _scan_call(
        kern, family="ivf", do_rerank=rerank, b=b, d=d, m=0, n_codes=0,
        blk_l=blk_l, nsub=nsub, npr=npr, r_pad=r_pad, w=w,
        grid=(b, npr + 1), interpret=interpret)
    args = (sel, own, queries, list_vecs, list_ids)
    if rerank:
        args = args + (list_vecs,)
    return call(*args)


@functools.partial(jax.jit, static_argnames=(
    "k", "r", "precision", "blk_l", "rerank", "interpret"))
def fused_scan_pq(tables: jax.Array, queries: jax.Array,
                  list_codes: jax.Array, list_ids: jax.Array,
                  sel: jax.Array, own: jax.Array, corpus: jax.Array, *,
                  k: int, r: int, precision: str = "f32",
                  blk_l: int = 4096, rerank: bool = True,
                  interpret: bool = False
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused PQ ADC scan (+ optional in-kernel exact re-rank).

    With ``rerank`` the ADC pass and the float32 re-rank collapse into
    one dispatch (single-device turns); without, returns the ADC top
    r_pad with scan positions for the sharded owner-computes merge.
    """
    b, m, n_codes = tables.shape
    p, lpad, _ = list_codes.shape
    d = queries.shape[1]
    nprobe = sel.shape[1]
    assert lpad % blk_l == 0, (lpad, blk_l)
    nsub = lpad // blk_l
    npr = nprobe * nsub
    kp = _next_pow2(k)
    r_pad = _next_pow2(r)
    assert kp <= r_pad <= blk_l, (kp, r_pad, blk_l)
    w = kp if rerank else r_pad

    kern = functools.partial(
        _scan_kernel, family="pq", do_rerank=rerank,
        precision=precision, nprobe=nprobe, nsub=nsub, blk_l=blk_l,
        lpad=lpad, r=r, r_pad=r_pad, kp=kp)
    call = _scan_call(
        kern, family="pq", do_rerank=rerank, b=b, d=d, m=m,
        n_codes=n_codes, blk_l=blk_l, nsub=nsub, npr=npr, r_pad=r_pad,
        w=w, grid=(b, npr + 1), interpret=interpret)
    if rerank:
        return call(sel, own, tables, queries, list_codes, list_ids,
                    corpus)
    return call(sel, own, tables, list_codes, list_ids)
