"""Architecture configs — one module per assigned architecture.

Importing this package populates the registry (configs.common). Arch ids:
  LM:     grok-1-314b, deepseek-v2-lite-16b, qwen1.5-4b, qwen3-14b, yi-9b
  GNN:    gin-tu
  recsys: two-tower-retrieval, dcn-v2, bst, autoint
Plus the paper's own encoder configs (dragon / snowflake) used by the
reproduction pipeline.
"""
from repro.configs import common  # noqa: F401
from repro.configs import (  # noqa: F401
    autoint,
    bst,
    dcn_v2,
    deepseek_v2_lite_16b,
    gin_tu,
    grok_1_314b,
    qwen15_4b,
    qwen3_14b,
    two_tower_retrieval,
    yi_9b,
)
from repro.configs import encoders  # noqa: F401

get = common.get
list_archs = common.list_archs
