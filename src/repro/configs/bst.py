"""bst — Behaviour Sequence Transformer (Alibaba) [arXiv:1905.06874; paper]

embed_dim=32, behaviour seq_len=20, 1 transformer block (8 heads),
MLP 1024-512-256. Item vocab 5M + 8 profile features x 100k.
Ranking model — TopLoc inapplicable (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common
from repro.distributed import sharding as SH
from repro.models import recsys as R
from repro.optim import optimizers as OPT
from repro.optim import schedules as SCHED

SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="serve", batch=1_000_000),
}


SMOKE_SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=4096),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=8192),
    "retrieval_cand": dict(kind="serve", batch=65536),
}


def full_config() -> R.BSTConfig:
    return R.BSTConfig(item_vocab=5_000_000, other_vocab=100_000)


def smoke_config() -> R.BSTConfig:
    return R.BSTConfig(item_vocab=512, other_vocab=64, n_other=3,
                       mlp=(32, 16), seq_len=6)


def _flops_per_row(cfg: R.BSTConfig) -> float:
    e, s = cfg.embed_dim, cfg.seq_len + 1
    attn = cfg.n_blocks * (8.0 * s * e * e + 4.0 * s * s * e
                           + 4.0 * s * e * 4 * e)
    d_in = s * e + cfg.n_other * e
    deep, dims = 0.0, (d_in,) + cfg.mlp
    for a, b in zip(dims[:-1], dims[1:]):
        deep += 2.0 * a * b
    return attn + deep + 2.0 * cfg.mlp[-1]


def build_bundle(cfg: R.BSTConfig, shape: str, axes: SH.Axes, *,
                 n_dp: int = 1, smoke: bool = False,
                 shape_overrides=None, **kw) -> common.StepBundle:
    sp = dict(SMOKE_SHAPE_PARAMS[shape] if smoke else SHAPE_PARAMS[shape])
    sp.update(shape_overrides or {})
    b = sp["batch"]
    param_structs = jax.eval_shape(
        lambda: R.bst_init(cfg, jax.random.PRNGKey(0)))
    pspecs = SH.bst_param_specs(cfg, axes)
    dp = axes.dp
    batch_structs = {
        "history": common.struct((b, cfg.seq_len), jnp.int32),
        "target": common.struct((b,), jnp.int32),
        "other": common.struct((b, cfg.n_other), jnp.int32),
        "labels": common.struct((b,), jnp.float32),
    }
    bspecs = {"history": P(dp, None), "target": P(dp),
              "other": P(dp, None), "labels": P(dp)}
    meta = dict(model_flops=(3.0 if sp["kind"] == "train" else 1.0)
                * b * _flops_per_row(cfg),
                scan_trip_count=1, params=cfg.param_count(), tokens=b)

    if sp["kind"] == "train":
        opt = OPT.adamw(SCHED.constant(1e-3))
        opt_structs = jax.eval_shape(opt.init, param_structs)
        ospecs = SH.lm_opt_specs("adamw", pspecs)

        def loss_fn(params, batch):
            logits = R.bst_forward(params, cfg, batch["history"],
                                   batch["target"], batch["other"])
            return R.bce_loss(logits, batch["labels"])

        step = common.simple_train_step(loss_fn, opt)
        return common.StepBundle(
            arch="bst", shape=shape, kind="train", step_fn=step,
            arg_structs=(param_structs, opt_structs, batch_structs),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, None), donate_argnums=(0, 1),
            meta=meta)

    # serve deployments replicate ALL params (tables are a few GB,
    # dense layers are MBs — affordable per inference replica): pure
    # data-parallel inference with ZERO per-request collectives. The
    # first attempt replicated only the tables, but the Megatron-TP
    # tower MLP all-reduce then dominated (§Perf hillclimb 4 log).
    # Training keeps row-sharded tables + TP (optimizer state for the
    # tables must stay distributed).
    if sp["kind"] == "serve" and sp.get("replicate_params", True):
        pspecs = common.replicate_specs(param_structs)

    def serve_step(params, history, target, other):
        return R.bst_forward(params, cfg, history, target, other)

    # pure-DP serving: the idle model axis takes batch shards too
    flat = axes.data + (axes.model,)
    return common.StepBundle(
        arch="bst", shape=shape, kind="serve", step_fn=serve_step,
        arg_structs=(param_structs, batch_structs["history"],
                     batch_structs["target"], batch_structs["other"]),
        in_specs=(pspecs,
                  P(flat if b % 256 == 0 else dp, None),
                  P(flat if b % 256 == 0 else dp),
                  P(flat if b % 256 == 0 else dp, None)),
        out_specs=None, meta=meta)


ARCH = common.register(common.ArchDef(
    arch_id="bst", family="recsys", shapes=tuple(SHAPE_PARAMS),
    make_config=full_config, make_smoke_config=smoke_config,
    build_bundle=build_bundle,
    notes="sequential CTR; TopLoc inapplicable (DESIGN.md §4)"))
