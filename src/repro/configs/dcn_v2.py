"""dcn-v2 [arXiv:2008.13535; paper]

13 dense + 26 sparse features (embed 16), 3 full-rank cross layers,
deep MLP 1024-1024-512. Criteo-like skewed vocab distribution
(2x16.7M + 2x2M + 2x262k + 20x65k ≈ 39.6M rows). Ranking model —
TopLoc inapplicable (dense scoring of given candidates; DESIGN.md §4).
``retrieval_cand`` = offline scoring of 10⁶ candidate rows for one user.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common
from repro.distributed import sharding as SH
from repro.models import recsys as R
from repro.optim import optimizers as OPT
from repro.optim import schedules as SCHED

VOCABS = (2 ** 24, 2 ** 24, 2 ** 21, 2 ** 21, 2 ** 18, 2 ** 18
          ) + (2 ** 16,) * 20

SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="serve", batch=1_000_000),
}


SMOKE_SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=4096),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=8192),
    "retrieval_cand": dict(kind="serve", batch=65536),
}


def full_config() -> R.DCNv2Config:
    return R.DCNv2Config(vocab_sizes=VOCABS)


def smoke_config() -> R.DCNv2Config:
    return R.DCNv2Config(vocab_sizes=(64,) * 26, mlp=(64, 32),
                         embed_dim=8)


def _flops_per_row(cfg: R.DCNv2Config) -> float:
    d = cfg.d_input
    cross = cfg.n_cross_layers * 2.0 * d * d
    deep, dims = 0.0, (d,) + cfg.mlp
    for a, b in zip(dims[:-1], dims[1:]):
        deep += 2.0 * a * b
    return cross + deep + 2.0 * (d + cfg.mlp[-1])


def build_bundle(cfg: R.DCNv2Config, shape: str, axes: SH.Axes, *,
                 n_dp: int = 1, smoke: bool = False,
                 shape_overrides=None, **kw) -> common.StepBundle:
    sp = dict(SMOKE_SHAPE_PARAMS[shape] if smoke else SHAPE_PARAMS[shape])
    sp.update(shape_overrides or {})
    b = sp["batch"]
    param_structs = jax.eval_shape(
        lambda: R.dcnv2_init(cfg, jax.random.PRNGKey(0)))
    pspecs = SH.dcnv2_param_specs(cfg, axes)
    dp = axes.dp
    batch_structs = {
        "dense": common.struct((b, cfg.n_dense), jnp.float32),
        "sparse": common.struct((b, cfg.n_sparse), jnp.int32),
        "labels": common.struct((b,), jnp.float32),
    }
    bspecs = {"dense": P(dp, None), "sparse": P(dp, None), "labels": P(dp)}
    meta = dict(model_flops=(3.0 if sp["kind"] == "train" else 1.0)
                * b * _flops_per_row(cfg),
                scan_trip_count=1, params=cfg.param_count(), tokens=b)

    if sp["kind"] == "train":
        opt = OPT.adamw(SCHED.constant(1e-3))
        opt_structs = jax.eval_shape(opt.init, param_structs)
        ospecs = SH.lm_opt_specs("adamw", pspecs)

        def loss_fn(params, batch):
            logits = R.dcnv2_forward(params, cfg, batch["dense"],
                                     batch["sparse"])
            return R.bce_loss(logits, batch["labels"])

        step = common.simple_train_step(loss_fn, opt)
        return common.StepBundle(
            arch="dcn-v2", shape=shape, kind="train", step_fn=step,
            arg_structs=(param_structs, opt_structs, batch_structs),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, None), donate_argnums=(0, 1),
            meta=meta)

    # serve deployments replicate ALL params (tables are a few GB,
    # dense layers are MBs — affordable per inference replica): pure
    # data-parallel inference with ZERO per-request collectives. The
    # first attempt replicated only the tables, but the Megatron-TP
    # tower MLP all-reduce then dominated (§Perf hillclimb 4 log).
    # Training keeps row-sharded tables + TP (optimizer state for the
    # tables must stay distributed).
    if sp["kind"] == "serve" and sp.get("replicate_params", True):
        pspecs = common.replicate_specs(param_structs)

    def serve_step(params, dense, sparse):
        return R.dcnv2_forward(params, cfg, dense, sparse)

    # pure-DP serving: the idle model axis takes batch shards too
    flat = axes.data + (axes.model,)
    return common.StepBundle(
        arch="dcn-v2", shape=shape, kind="serve", step_fn=serve_step,
        arg_structs=(param_structs, batch_structs["dense"],
                     batch_structs["sparse"]),
        in_specs=(pspecs,
                  # retrieval_cand batch (10^6) is not divisible by the
                  # full 256/512-chip mesh — shard over data axes only
                  # (params replicated: still zero collectives)
                  P(flat if b % 256 == 0 else dp, None),
                  P(flat if b % 256 == 0 else dp, None)),
        out_specs=None, meta=meta)


ARCH = common.register(common.ArchDef(
    arch_id="dcn-v2", family="recsys", shapes=tuple(SHAPE_PARAMS),
    make_config=full_config, make_smoke_config=smoke_config,
    build_bundle=build_bundle,
    notes="ranking model; TopLoc inapplicable (DESIGN.md §4)"))
