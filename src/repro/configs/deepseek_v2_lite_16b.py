"""deepseek-v2-lite-16b [arXiv:2405.04434; hf]

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400, MLA kv_lora=512
(+64 decoupled rope dims), MoE 2 shared + 64 routed top-6.

Deviations from the HF checkpoint (recorded per DESIGN.md): the
assignment line says both "64e" and "160 routed"; we implement 64 routed
(the actual v2-lite count). The first dense layer (d_ff 10944) is
simplified to MoE-everywhere. Decode uses the absorbed-latent MLA form
(cache = 512+64 dims/token — the paper's memory win).
"""
import jax.numpy as jnp

from repro.configs import common
from repro.models.transformer import LMConfig


def full_config() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
        n_kv_heads=16, d_head=128, d_ff=1408, vocab=102400,
        attn_kind="mla", kv_lora_rank=512, d_rope=64,
        n_experts=64, top_k=6, n_shared=2, moe_d_ff=1408,
        param_dtype=jnp.bfloat16, dtype=jnp.bfloat16,
        remat=True, loss_chunk=512,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=48, vocab=512,
        attn_kind="mla", kv_lora_rank=32, d_rope=8,
        n_experts=8, top_k=2, n_shared=1, moe_d_ff=48,
        remat=False, loss_chunk=16,
    )


ARCH = common.lm_archdef(
    "deepseek-v2-lite-16b", full_config, smoke_config, optimizer="adamw",
    microbatches=4,   # 64-expert dispatch buffers scale 1/mb
    notes="MLA latent cache; absorbed decode; MoE shared+routed")
