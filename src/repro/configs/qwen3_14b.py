"""qwen3-14b [hf:Qwen/Qwen3-*; hf]

40L d_model=5120 40H (GQA kv=8) d_head=128 d_ff=17408 vocab=151936,
qk-norm (per-head RMSNorm on q and k — the qwen3 signature), SwiGLU.
"""
import jax.numpy as jnp

from repro.configs import common
from repro.models.transformer import LMConfig


def full_config() -> LMConfig:
    return LMConfig(
        name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40,
        n_kv_heads=8, d_head=128, d_ff=17408, vocab=151936,
        qk_norm=True,
        param_dtype=jnp.bfloat16, dtype=jnp.bfloat16,
        remat=True, loss_chunk=512,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen3-14b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512, qk_norm=True,
        remat=False, loss_chunk=16,
    )


ARCH = common.lm_archdef("qwen3-14b", full_config, smoke_config,
                         notes="dense, GQA kv=8, qk_norm")
