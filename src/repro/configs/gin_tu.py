"""gin-tu [arXiv:1810.00826; paper] — GIN, 5 layers, d_hidden=64, sum
aggregator, learnable eps.

Four kernel regimes (taxonomy §GNN), one per shape:
  full_graph_sm  — Cora-scale full-batch (n=2,708, e=10,556, d=1,433)
  minibatch_lg   — Reddit-scale sampled training (232,965 nodes,
                   114.6M edges, batch_nodes=1,024, fanout 15-10).
                   Sampled subgraphs are *per-seed trees* (1 + 15 + 150
                   nodes, 165 edges each): disjoint by construction, so
                   the batch shards over data axes with zero cross-shard
                   edges (DESIGN.md §5).
  ogb_products   — full-batch large (n=2,449,029, e=61,859,140, d=100):
                   edges shard over the whole mesh, node states
                   replicate, partial segment_sum + all-reduce.
  molecule       — batched small graphs (30 nodes / 64 edges x 128).

TopLoc: inapplicable (no ANN search in a GNN step) — DESIGN.md §4.
d_in / n_classes are shape-level (different datasets); params stay tiny
and replicated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common
from repro.distributed import sharding as SH
from repro.models import gnn
from repro.optim import optimizers as OPT
from repro.optim import schedules as SCHED

SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7, pad_edges_to=512),
    "minibatch_lg": dict(kind="train", batch_nodes=1024, fanouts=(15, 10),
                         d_feat=602, n_classes=41,
                         tree_nodes=166, tree_edges=165),
    "ogb_products": dict(kind="train", n_nodes=2_449_029,
                         n_edges=61_859_140, d_feat=100, n_classes=47,
                         pad_edges_to=512),
    "molecule": dict(kind="train", batch=128, n_nodes=30, n_edges=64,
                     d_feat=16, n_classes=2),
}


SMOKE_SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "full_graph_sm": dict(kind="train", n_nodes=512, n_edges=2048,
                          d_feat=32, n_classes=7, pad_edges_to=512),
    "minibatch_lg": dict(kind="train", batch_nodes=64, fanouts=(3, 2),
                         d_feat=32, n_classes=8, tree_nodes=10,
                         tree_edges=9),
    "ogb_products": dict(kind="train", n_nodes=4096, n_edges=16384,
                         d_feat=32, n_classes=16, pad_edges_to=512),
    "molecule": dict(kind="train", batch=32, n_nodes=10, n_edges=16,
                     d_feat=8, n_classes=2),
}


def full_config() -> gnn.GINConfig:
    return gnn.GINConfig(name="gin-tu", n_layers=5, d_hidden=64)


def smoke_config() -> gnn.GINConfig:
    return gnn.GINConfig(name="gin-tu-smoke", n_layers=3, d_hidden=16)


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def _optimizer():
    return OPT.adamw(SCHED.constant(1e-3))


def build_bundle(cfg: gnn.GINConfig, shape: str, axes: SH.Axes, *,
                 n_dp: int = 1, smoke: bool = False,
                 shape_overrides=None, **kw) -> common.StepBundle:
    sp = dict(SMOKE_SHAPE_PARAMS[shape] if smoke else SHAPE_PARAMS[shape])
    sp.update(shape_overrides or {})
    cfg = dataclasses.replace(cfg, d_in=sp["d_feat"],
                              n_classes=sp["n_classes"])
    opt = _optimizer()
    param_structs = jax.eval_shape(
        lambda: gnn.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = common.replicate_specs(param_structs)
    ospecs = common.replicate_specs(jax.eval_shape(opt.init, param_structs))
    opt_structs = jax.eval_shape(opt.init, param_structs)
    flat = axes.data + (axes.model,)

    if shape in ("full_graph_sm", "ogb_products"):
        n, e = sp["n_nodes"], sp["n_edges"]
        e_pad = _pad_to(e, sp["pad_edges_to"])
        batch_structs = {
            "x": common.struct((n, sp["d_feat"]), jnp.float32),
            "edge_src": common.struct((e_pad,), jnp.int32),
            "edge_dst": common.struct((e_pad,), jnp.int32),
            "edge_mask": common.struct((e_pad,), jnp.bool_),
            "labels": common.struct((n,), jnp.int32),
            "train_mask": common.struct((n,), jnp.bool_),
        }
        bspecs = {"x": P(), "edge_src": P(flat), "edge_dst": P(flat),
                  "edge_mask": P(flat), "labels": P(), "train_mask": P()}

        def loss_fn(params, b):
            return gnn.node_loss(params, cfg, b["x"], b["edge_src"],
                                 b["edge_dst"], b["labels"],
                                 b["train_mask"], b["edge_mask"])

        # fwd ≈ Σ_l 2·N·d_in·d_h + 2·N·d_h² + E·d_h ; train ≈ 3× fwd
        d_h = cfg.d_hidden
        fwd = (2 * n * sp["d_feat"] * d_h + 2 * n * d_h * d_h
               + (cfg.n_layers - 1) * (4 * n * d_h * d_h + e * d_h)
               + e * sp["d_feat"])
        meta = dict(model_flops=3.0 * fwd, scan_trip_count=1,
                    params=cfg.param_count(), tokens=n)

    elif shape == "minibatch_lg":
        bsz, tn, te = sp["batch_nodes"], sp["tree_nodes"], sp["tree_edges"]
        batch_structs = {
            "x": common.struct((bsz, tn, sp["d_feat"]), jnp.float32),
            "edge_src": common.struct((bsz, te), jnp.int32),
            "edge_dst": common.struct((bsz, te), jnp.int32),
            "edge_mask": common.struct((bsz, te), jnp.bool_),
            "labels": common.struct((bsz,), jnp.int32),
        }
        bspecs = {k: P(axes.dp) for k in batch_structs}

        def loss_fn(params, b):
            def tree_logits(x, es, ed, em):
                return gnn.forward_node(params, cfg, x, es, ed, em)[0]
            logits = jax.vmap(tree_logits)(
                b["x"], b["edge_src"], b["edge_dst"], b["edge_mask"])
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(
                logits, b["labels"][:, None], -1)[..., 0]
            loss = jnp.mean(logz - gold)
            acc = jnp.mean(jnp.argmax(logits, -1) == b["labels"])
            return loss, {"acc": acc}

        d_h = cfg.d_hidden
        fwd_tree = (2 * tn * sp["d_feat"] * d_h
                    + (cfg.n_layers) * (4 * tn * d_h * d_h + te * d_h))
        meta = dict(model_flops=3.0 * bsz * fwd_tree, scan_trip_count=1,
                    params=cfg.param_count(), tokens=bsz)

    else:  # molecule
        bsz, n, e = sp["batch"], sp["n_nodes"], sp["n_edges"]
        batch_structs = {
            "x": common.struct((bsz, n, sp["d_feat"]), jnp.float32),
            "edge_src": common.struct((bsz, e), jnp.int32),
            "edge_dst": common.struct((bsz, e), jnp.int32),
            "node_mask": common.struct((bsz, n), jnp.bool_),
            "edge_mask": common.struct((bsz, e), jnp.bool_),
            "labels": common.struct((bsz,), jnp.int32),
        }
        bspecs = {k: P(axes.dp) for k in batch_structs}

        def loss_fn(params, b):
            return gnn.graph_loss(params, cfg, b["x"], b["edge_src"],
                                  b["edge_dst"], b["node_mask"],
                                  b["labels"], b["edge_mask"])

        d_h = cfg.d_hidden
        fwd = bsz * (2 * n * sp["d_feat"] * d_h
                     + cfg.n_layers * (4 * n * d_h * d_h + e * d_h))
        meta = dict(model_flops=3.0 * fwd, scan_trip_count=1,
                    params=cfg.param_count(), tokens=bsz)

    step = common.simple_train_step(loss_fn, opt)
    return common.StepBundle(
        arch="gin-tu", shape=shape, kind="train", step_fn=step,
        arg_structs=(param_structs, opt_structs, batch_structs),
        in_specs=(pspecs, ospecs, bspecs),
        out_specs=(pspecs, ospecs, None),
        donate_argnums=(0, 1),
        meta=meta,
    )


ARCH = common.register(common.ArchDef(
    arch_id="gin-tu", family="gnn", shapes=tuple(SHAPE_PARAMS),
    make_config=full_config, make_smoke_config=smoke_config,
    build_bundle=build_bundle,
    notes="segment_sum message passing; TopLoc inapplicable (no ANN)"))
