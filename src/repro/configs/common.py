"""Config registry + step-bundle builders shared by all architectures.

Every architecture file registers an ``ArchDef``; the launcher asks it
for a ``StepBundle`` per (shape × mesh axes): the jittable step function,
ShapeDtypeStruct stand-ins for every argument (dry-run lowers without
allocating — a 314B param tree stays abstract), the PartitionSpec
pytrees for in/out, donation hints, and roofline metadata (analytic
MODEL_FLOPS, scan trip count for the while-body cost adjustment).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as SH
from repro.models import transformer as TF
from repro.optim import grad as G
from repro.optim import optimizers as OPT
from repro.optim import schedules as SCHED


@dataclasses.dataclass
class StepBundle:
    """Everything the dry-run / launcher needs for one (arch, shape)."""
    arch: str
    shape: str
    kind: str                       # "train" | "serve"
    step_fn: Callable
    arg_structs: Tuple[Any, ...]    # ShapeDtypeStruct pytrees
    in_specs: Tuple[Any, ...]       # PartitionSpec pytrees (None = auto)
    out_specs: Any
    donate_argnums: Tuple[int, ...] = ()
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ArchDef:
    arch_id: str
    family: str                     # "lm" | "gnn" | "recsys"
    shapes: Tuple[str, ...]
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    build_bundle: Callable[..., StepBundle]   # (config, shape, axes) → bundle
    skip_shapes: Dict[str, str] = dataclasses.field(default_factory=dict)
    notes: str = ""


_REGISTRY: Dict[str, ArchDef] = {}


def register(arch: ArchDef) -> ArchDef:
    _REGISTRY[arch.arch_id] = arch
    return arch


def get(arch_id: str) -> ArchDef:
    import repro.configs  # noqa: F401  (populate registry)
    return _REGISTRY[arch_id]


def list_archs():
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# shared structs helpers
# ---------------------------------------------------------------------------

def struct(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def eval_structs(fn, *args):
    return jax.eval_shape(fn, *args)


def replicate_specs(tree) -> Any:
    """P() for every leaf of a struct pytree."""
    return jax.tree.map(lambda _: P(), tree)


# ---------------------------------------------------------------------------
# LM bundles (shared by the five transformer archs)
# ---------------------------------------------------------------------------

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

LM_SHAPE_PARAMS = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="serve", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="serve", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="serve", seq_len=524288, global_batch=1),
}

LM_SKIPS = {
    "long_500k": ("pure full-attention arch: rule-mandated skip for "
                  "seq 524288 (sub-quadratic-only shape); KV cache is "
                  "sequence-shardable so the cell lowers, but it is "
                  "excluded from the graded table per the brief"),
}

# reduced shapes for harness debugging (--smoke); batch ≥ 32 so both
# production meshes shard the batch dim
LM_SMOKE_SHAPE_PARAMS = {
    "train_4k": dict(kind="train", seq_len=128, global_batch=64),
    "prefill_32k": dict(kind="serve", seq_len=128, global_batch=32),
    "decode_32k": dict(kind="serve", seq_len=256, global_batch=64),
    "long_500k": dict(kind="serve", seq_len=512, global_batch=32),
}


def make_lm_optimizer(name: str):
    lr = SCHED.warmup_cosine(3e-4, 2000, 200_000)
    if name == "adafactor":
        return OPT.adafactor(lr)
    return OPT.adamw(lr, weight_decay=0.1)


def lm_bundle(cfg: TF.LMConfig, arch_id: str, shape: str, axes: SH.Axes,
              *, optimizer: str = "adamw", n_dp: int = 1,
              smoke: bool = False, microbatches: int = 1,
              shape_overrides: Optional[dict] = None) -> StepBundle:
    sp = dict(LM_SMOKE_SHAPE_PARAMS[shape] if smoke
              else LM_SHAPE_PARAMS[shape])
    sp.update(shape_overrides or {})
    seq, batch = sp["seq_len"], sp["global_batch"]
    kind = sp["kind"]
    # moe routing groups == data-parallel shard count (DESIGN.md §5);
    # the group dim shards over the data axes via vmap spmd_axis_name.
    # Applies to train AND prefill — an ungrouped 1M-token prefill
    # dispatch is an (E, 330k, d) buffer (§Perf prefill iteration);
    # decode keeps groups=1 (decode_step forces it internally).
    if cfg.is_moe and not shape.startswith(("decode", "long")):
        cfg = dataclasses.replace(
            cfg, moe_groups=max(n_dp, 1),
            moe_group_axes=(axes.data if n_dp > 1 else None),
            moe_tp_axis=(axes.model if n_dp > 1 else None))
    # activation sharding: batch over data axes + sequence over model
    # (Megatron-style sequence parallelism between layers: the residual
    # stream — and therefore the scan's saved remat residuals — shards
    # tp-ways; XLA inserts the S all-gather before attention and the
    # reduce-scatter after. §Perf iteration 3.)  Requires the lower to
    # happen under `with mesh:` — launch/dryrun.py does.  Decode steps
    # skip it: their activations are (B, 1, d) and long_500k has B=1.
    act_mode = sp.get("act_spec", "sp")
    is_decode = shape.startswith(("decode", "long"))
    if n_dp > 1 and act_mode and not is_decode and batch % n_dp == 0:
        from jax.sharding import PartitionSpec as _P
        spec = (_P(axes.dp, axes.model, None) if act_mode == "sp"
                else _P(axes.dp, None, None))
        cfg = dataclasses.replace(cfg, act_spec=spec)

    param_structs = jax.eval_shape(
        lambda: TF.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = SH.lm_param_specs(cfg, axes)

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()

    if kind == "train":
        opt = make_lm_optimizer(optimizer)
        opt_structs = jax.eval_shape(opt.init, param_structs)
        ospecs = SH.lm_opt_specs(
            "adafactor" if optimizer == "adafactor" else "adamw", pspecs,
            param_structs)
        bspecs = SH.lm_batch_specs(axes)
        batch_structs = {"tokens": struct((batch, seq), jnp.int32),
                         "labels": struct((batch, seq), jnp.int32)}

        mb = int(sp.get("microbatches", microbatches))
        assert batch % max(mb, 1) == 0, (batch, mb)

        def train_step(params, opt_state, data):
            def lf(p, d):
                return TF.loss_fn(p, cfg, d["tokens"], d["labels"])

            if mb > 1:
                # gradient accumulation: scan over microbatches — peak
                # activation memory scales with batch/mb. Accumulator
                # dtype: f32 for adamw; param dtype (bf16) for adafactor,
                # whose per-tensor RMS-normalised updates tolerate it —
                # halves the largest remaining buffer on the 314B config.
                acc_dt = (jnp.float32 if optimizer == "adamw"
                          else cfg.param_dtype)
                data_r = jax.tree.map(
                    lambda t: t.reshape(mb, t.shape[0] // mb,
                                        *t.shape[1:]), data)

                def body(carry, d):
                    acc, loss_acc = carry
                    (loss, metrics), g = jax.value_and_grad(
                        lf, has_aux=True)(params, d)
                    acc = jax.tree.map(
                        lambda a, b: (a + (b / mb).astype(a.dtype)),
                        acc, g)
                    return (acc, loss_acc + loss / mb), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, acc_dt), params)
                (grads, loss), _ = jax.lax.scan(
                    body, (zeros, jnp.zeros((), jnp.float32)), data_r)
            else:
                (loss, _metrics), grads = jax.value_and_grad(
                    lf, has_aux=True)(params, data)
            grads, gnorm = G.clip_by_global_norm(grads, 1.0)
            updates, opt_state2 = opt.update(grads, opt_state, params)
            params2 = OPT.apply_updates(params, updates)
            return params2, opt_state2, {"loss": loss, "gnorm": gnorm}

        return StepBundle(
            arch=arch_id, shape=shape, kind="train", step_fn=train_step,
            arg_structs=(param_structs, opt_structs, batch_structs),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, None),
            donate_argnums=(0, 1),
            meta=dict(
                model_flops=6.0 * n_active * batch * seq,
                scan_trip_count=cfg.n_layers,
                params=n_params, active_params=n_active,
                tokens=batch * seq,
            ),
        )

    if shape.startswith("decode") or shape.startswith("long"):
        cache_structs = jax.eval_shape(
            lambda: TF.init_cache(cfg, batch, seq))
        cspecs = SH.lm_cache_specs(cfg, axes,
                                   shard_batch=batch % n_dp == 0)
        tok_struct = struct((batch,), jnp.int32)
        len_struct = struct((batch,), jnp.int32)

        def serve_step(params, cache, tokens, cache_len):
            return TF.decode_step(params, cfg, cache, tokens, cache_len)

        batch_spec = P(axes.dp) if batch >= 16 else P()
        return StepBundle(
            arch=arch_id, shape=shape, kind="serve", step_fn=serve_step,
            arg_structs=(param_structs, cache_structs, tok_struct,
                         len_struct),
            in_specs=(pspecs, cspecs, batch_spec, batch_spec),
            out_specs=(None, cspecs),
            donate_argnums=(1,),
            meta=dict(
                model_flops=2.0 * n_active * batch,
                scan_trip_count=cfg.n_layers,
                params=n_params, active_params=n_active,
                tokens=batch,
            ),
        )

    # prefill
    def serve_step(params, tokens):
        logits, cache, cache_len = TF.prefill(params, cfg, tokens, seq)
        return logits, cache, cache_len

    cspecs = SH.lm_cache_specs(cfg, axes)
    return StepBundle(
        arch=arch_id, shape=shape, kind="serve", step_fn=serve_step,
        arg_structs=(param_structs,
                     struct((batch, seq), jnp.int32)),
        in_specs=(pspecs, SH.lm_batch_specs(axes)["tokens"]),
        out_specs=(None, cspecs, None),
        meta=dict(
            model_flops=2.0 * n_active * batch * seq,
            scan_trip_count=cfg.n_layers,
            params=n_params, active_params=n_active,
            tokens=batch * seq,
        ),
    )


def lm_archdef(arch_id: str, full_cfg: Callable[[], TF.LMConfig],
               smoke_cfg: Callable[[], TF.LMConfig], *,
               optimizer: str = "adamw", microbatches: int = 1,
               notes: str = "") -> ArchDef:
    def build(cfg, shape, axes, *, n_dp: int = 1, smoke: bool = False,
              shape_overrides: Optional[dict] = None, **kw):
        return lm_bundle(cfg, arch_id, shape, axes, optimizer=optimizer,
                         n_dp=n_dp, smoke=smoke,
                         microbatches=1 if smoke else microbatches,
                         shape_overrides=shape_overrides)

    return register(ArchDef(
        arch_id=arch_id, family="lm", shapes=LM_SHAPES,
        make_config=full_cfg, make_smoke_config=smoke_cfg,
        build_bundle=build, skip_shapes=dict(LM_SKIPS), notes=notes))


# ---------------------------------------------------------------------------
# generic train-step factory (non-LM models)
# ---------------------------------------------------------------------------

def simple_train_step(loss_fn, optimizer):
    """loss_fn(params, batch) → (loss, metrics)."""
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, gnorm = G.clip_by_global_norm(grads, 1.0)
        updates, opt_state2 = optimizer.update(grads, opt_state, params)
        params2 = OPT.apply_updates(params, updates)
        out = {"loss": loss, "gnorm": gnorm}
        out.update(metrics)
        return params2, opt_state2, out
    return train_step
