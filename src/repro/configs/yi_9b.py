"""yi-9b [arXiv:2403.04652; hf]

48L d_model=4096 32H (GQA kv=4) d_head=128 d_ff=11008 vocab=64000,
llama-style GQA + SwiGLU.
"""
import jax.numpy as jnp

from repro.configs import common
from repro.models.transformer import LMConfig


def full_config() -> LMConfig:
    return LMConfig(
        name="yi-9b", n_layers=48, d_model=4096, n_heads=32,
        n_kv_heads=4, d_head=128, d_ff=11008, vocab=64000,
        param_dtype=jnp.bfloat16, dtype=jnp.bfloat16,
        remat=True, loss_chunk=512,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="yi-9b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512,
        remat=False, loss_chunk=16,
    )


ARCH = common.lm_archdef("yi-9b", full_config, smoke_config,
                         notes="dense llama-arch GQA kv=4")
