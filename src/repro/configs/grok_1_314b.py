"""grok-1-314b [hf:xai-org/grok-1; unverified]

64L d_model=6144 48H (GQA kv=8, d_head=128) d_ff=32768 vocab=131072,
MoE 8 experts top-2.  bf16 params + Adafactor (factored stats) — the
optimizer choice that lets 314B fit a 256-chip v5e pod (EXPERIMENTS.md
§Dry-run memory table); grok's attention-logit soft cap (30.0) included.
"""
import jax.numpy as jnp

from repro.configs import common
from repro.models.transformer import LMConfig


def full_config() -> LMConfig:
    return LMConfig(
        name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48,
        n_kv_heads=8, d_head=128, d_ff=32768, vocab=131072,
        n_experts=8, top_k=2, moe_d_ff=32768,
        logit_soft_cap=30.0,
        param_dtype=jnp.bfloat16, dtype=jnp.bfloat16,
        remat=True, loss_chunk=512,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="grok-1-314b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512,
        n_experts=4, top_k=2, moe_d_ff=128, logit_soft_cap=30.0,
        remat=False, loss_chunk=16,
    )


ARCH = common.lm_archdef(
    "grok-1-314b", full_config, smoke_config, optimizer="adafactor",
    microbatches=8,   # grad accumulation: 8x lower activation peak
    notes="MoE 8e top-2; TopLoc inapplicable (no ANN in step) — "
          "DESIGN.md §4")
