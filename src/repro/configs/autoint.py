"""autoint [arXiv:1810.11921; paper]

39 sparse fields (embed 16), 3 self-attention layers (2 heads, d_attn
32) with residual projections. Ranking model — TopLoc inapplicable
(DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common
from repro.distributed import sharding as SH
from repro.models import recsys as R
from repro.optim import optimizers as OPT
from repro.optim import schedules as SCHED

VOCABS = (2 ** 22,) * 2 + (2 ** 18,) * 5 + (2 ** 14,) * 32

SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="serve", batch=1_000_000),
}


SMOKE_SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=4096),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=8192),
    "retrieval_cand": dict(kind="serve", batch=65536),
}


def full_config() -> R.AutoIntConfig:
    return R.AutoIntConfig(vocab_sizes=VOCABS)


def smoke_config() -> R.AutoIntConfig:
    return R.AutoIntConfig(n_sparse=8, vocab_sizes=(64,) * 8,
                           embed_dim=8, d_attn=8)


def _flops_per_row(cfg: R.AutoIntConfig) -> float:
    f, d0 = cfg.n_sparse, cfg.embed_dim
    da, h = cfg.d_attn, cfg.n_heads
    flops, d_in = 0.0, d0
    for _ in range(cfg.n_attn_layers):
        d_out = da * h
        flops += 2.0 * f * d_in * d_out * 4          # q,k,v,res projections
        flops += 2.0 * f * f * d_out * 2             # scores + weighted sum
        d_in = d_out
    return flops + 2.0 * f * d_in


def build_bundle(cfg: R.AutoIntConfig, shape: str, axes: SH.Axes, *,
                 n_dp: int = 1, smoke: bool = False,
                 shape_overrides=None, **kw) -> common.StepBundle:
    sp = dict(SMOKE_SHAPE_PARAMS[shape] if smoke else SHAPE_PARAMS[shape])
    sp.update(shape_overrides or {})
    b = sp["batch"]
    param_structs = jax.eval_shape(
        lambda: R.autoint_init(cfg, jax.random.PRNGKey(0)))
    pspecs = SH.autoint_param_specs(cfg, axes)
    dp = axes.dp
    batch_structs = {
        "sparse": common.struct((b, cfg.n_sparse), jnp.int32),
        "labels": common.struct((b,), jnp.float32),
    }
    bspecs = {"sparse": P(dp, None), "labels": P(dp)}
    meta = dict(model_flops=(3.0 if sp["kind"] == "train" else 1.0)
                * b * _flops_per_row(cfg),
                scan_trip_count=1, params=cfg.param_count(), tokens=b)

    if sp["kind"] == "train":
        opt = OPT.adamw(SCHED.constant(1e-3))
        opt_structs = jax.eval_shape(opt.init, param_structs)
        ospecs = SH.lm_opt_specs("adamw", pspecs)

        def loss_fn(params, batch):
            logits = R.autoint_forward(params, cfg, batch["sparse"])
            return R.bce_loss(logits, batch["labels"])

        step = common.simple_train_step(loss_fn, opt)
        return common.StepBundle(
            arch="autoint", shape=shape, kind="train", step_fn=step,
            arg_structs=(param_structs, opt_structs, batch_structs),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, None), donate_argnums=(0, 1),
            meta=meta)

    # serve deployments replicate ALL params (tables are a few GB,
    # dense layers are MBs — affordable per inference replica): pure
    # data-parallel inference with ZERO per-request collectives. The
    # first attempt replicated only the tables, but the Megatron-TP
    # tower MLP all-reduce then dominated (§Perf hillclimb 4 log).
    # Training keeps row-sharded tables + TP (optimizer state for the
    # tables must stay distributed).
    if sp["kind"] == "serve" and sp.get("replicate_params", True):
        pspecs = common.replicate_specs(param_structs)

    def serve_step(params, sparse):
        return R.autoint_forward(params, cfg, sparse)

    # pure-DP serving: the idle model axis takes batch shards too
    flat = axes.data + (axes.model,)
    return common.StepBundle(
        arch="autoint", shape=shape, kind="serve", step_fn=serve_step,
        arg_structs=(param_structs, batch_structs["sparse"]),
        in_specs=(pspecs,
                  P(flat if b % 256 == 0 else dp, None)),
        out_specs=None, meta=meta)


ARCH = common.register(common.ArchDef(
    arch_id="autoint", family="recsys", shapes=tuple(SHAPE_PARAMS),
    make_config=full_config, make_smoke_config=smoke_config,
    build_bundle=build_bundle,
    notes="field self-attention CTR; TopLoc inapplicable (DESIGN.md §4)"))
