"""qwen1.5-4b [hf:Qwen/Qwen1.5-*; hf]

40L d_model=2560 20H (kv=20 — full MHA) d_head=128 d_ff=6912
vocab=151936, QKV bias (the qwen1.5 signature), SwiGLU.
"""
import jax.numpy as jnp

from repro.configs import common
from repro.models.transformer import LMConfig


def full_config() -> LMConfig:
    return LMConfig(
        name="qwen1.5-4b", n_layers=40, d_model=2560, n_heads=20,
        n_kv_heads=20, d_head=128, d_ff=6912, vocab=151936,
        qkv_bias=True,
        param_dtype=jnp.bfloat16, dtype=jnp.bfloat16,
        remat=True, loss_chunk=512,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen1.5-4b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=512, qkv_bias=True,
        remat=False, loss_chunk=16,
    )


ARCH = common.lm_archdef("qwen1.5-4b", full_config, smoke_config,
                         notes="dense, QKV bias, MHA")
