"""two-tower-retrieval [RecSys'19 (YouTube); unverified]

embed_dim=256, tower MLP 1024-512-256, dot interaction, in-batch sampled
softmax. User/item tables row-sharded over ``model``.

THE paper-representative architecture: ``retrieval_cand`` (1 query vs
10⁶ candidates) is the exact serving problem TopLoc accelerates — the
benchmark harness runs this cell both brute-force (the bundle below) and
through TopLoc_IVF over the item corpus (benchmarks/table1.py,
examples/recsys_retrieval.py). This is hillclimb cell #1 (§Perf).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import common
from repro.distributed import sharding as SH
from repro.models import recsys as R
from repro.optim import optimizers as OPT
from repro.optim import schedules as SCHED

SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="serve", batch=1, n_candidates=1_000_000,
                           k=100),
}


SMOKE_SHAPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=4096),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=8192),
    "retrieval_cand": dict(kind="serve", batch=1, n_candidates=65536,
                           k=100),
}


def full_config() -> R.TwoTowerConfig:
    return R.TwoTowerConfig(user_vocab=1_048_576, item_vocab=2_097_152,
                            history_len=50)


def smoke_config() -> R.TwoTowerConfig:
    return R.TwoTowerConfig(embed_dim=16, tower_mlp=(32, 16),
                            user_vocab=512, item_vocab=1024,
                            history_len=5)


def build_bundle(cfg: R.TwoTowerConfig, shape: str, axes: SH.Axes, *,
                 n_dp: int = 1, smoke: bool = False,
                 shape_overrides=None, **kw) -> common.StepBundle:
    sp = dict(SMOKE_SHAPE_PARAMS[shape] if smoke else SHAPE_PARAMS[shape])
    sp.update(shape_overrides or {})
    b = sp["batch"]
    param_structs = jax.eval_shape(
        lambda: R.two_tower_init(cfg, jax.random.PRNGKey(0)))
    pspecs = SH.two_tower_param_specs(cfg, axes)
    dp = axes.dp
    dense_flops = 2.0 * sum(
        a * bb for a, bb in zip((2 * cfg.embed_dim,) + cfg.tower_mlp[:-1],
                                cfg.tower_mlp)) + 2.0 * sum(
        a * bb for a, bb in zip((cfg.embed_dim,) + cfg.tower_mlp[:-1],
                                cfg.tower_mlp))

    if sp["kind"] == "train":
        opt = OPT.adamw(SCHED.constant(1e-3))
        opt_structs = jax.eval_shape(opt.init, param_structs)
        ospecs = SH.lm_opt_specs("adamw", pspecs)
        batch_structs = {
            "user_id": common.struct((b,), jnp.int32),
            "item_id": common.struct((b,), jnp.int32),
            "history": common.struct((b, cfg.history_len), jnp.int32),
        }
        bspecs = {k: P(dp) if v.ndim == 1 else P(dp, None)
                  for k, v in batch_structs.items()}

        def loss_fn(params, batch):
            return R.two_tower_loss(params, cfg, batch)

        step = common.simple_train_step(loss_fn, opt)
        return common.StepBundle(
            arch="two-tower-retrieval", shape=shape, kind="train",
            step_fn=step,
            arg_structs=(param_structs, opt_structs, batch_structs),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, None), donate_argnums=(0, 1),
            meta=dict(model_flops=3.0 * b * dense_flops
                      + 2.0 * b * b * cfg.tower_mlp[-1],  # in-batch logits
                      scan_trip_count=1, params=cfg.param_count(),
                      tokens=b))

    # serve deployments replicate ALL params (tables are a few GB,
    # dense layers are MBs — affordable per inference replica): pure
    # data-parallel inference with ZERO per-request collectives. The
    # first attempt replicated only the tables, but the Megatron-TP
    # tower MLP all-reduce then dominated (§Perf hillclimb 4 log).
    # Training keeps row-sharded tables + TP (optimizer state for the
    # tables must stay distributed).
    if sp["kind"] == "serve" and sp.get("replicate_params", True):
        pspecs = common.replicate_specs(param_structs)

    if shape == "retrieval_cand":
        n_cand, k = sp["n_candidates"], sp["k"]
        e = cfg.tower_mlp[-1]
        variant = sp.get("variant", "brute")

        if variant == "toploc_ivf_dist":
            # combined: TopLoc centroid-cache pruning + shard-local list
            # scan + k-wide merge (hillclimb cell #3 final form)
            p_parts = sp.get("partitions", 1024)
            lmax = sp.get("lmax", (n_cand // p_parts) * 5 // 4)
            h = sp.get("h", 128)
            nprobe = sp.get("nprobe", 32)

            def serve_step(params, user_id, history, list_vecs, list_ids,
                           cache_vecs, cache_ids):
                from repro.core.topk import distributed_topk
                u = R.user_tower(params, cfg, user_id, history)  # (1, e)
                csc = u @ cache_vecs.T
                _, sel_local = jax.lax.top_k(csc, nprobe)
                sel = cache_ids[sel_local]                       # (1, np)

                # per-shard slot cap: selected lists spread ~uniformly
                # over shards (Poisson λ = np/shards); 2λ+2 slots bound
                # the overflow-drop probability to a few percent — the
                # same bounded-spill philosophy as the balanced k-means
                # build. Each shard gathers/scans only `cap` lists
                # instead of all `nprobe` masked (16x less work/HBM).
                shards = sp.get("shards", 16)
                cap = sp.get("shard_cap",
                             max(2 * nprobe // shards + 2, 2))

                def local(lv, li, q, s):
                    p_local = lv.shape[0]
                    shard = jax.lax.axis_index(axes.model)
                    s_loc = s[0] - shard * p_local               # (np,)
                    own = (s_loc >= 0) & (s_loc < p_local)
                    # owned-first ordering, take the first `cap` slots
                    order = jnp.argsort(~own)[:cap]
                    s_cap = jnp.clip(s_loc[order], 0, p_local - 1)
                    own_cap = own[order]
                    lvs = lv[s_cap]                              # (cap,L,e)
                    lis = jnp.where(own_cap[:, None], li[s_cap], -1)
                    sc = jnp.einsum("bd,nld->bnl", q, lvs)
                    sc = jnp.where(lis[None] >= 0, sc, -jnp.inf)
                    v, pos = jax.lax.top_k(sc.reshape(1, -1), k)
                    ids = jnp.take_along_axis(lis.reshape(1, -1), pos,
                                              axis=-1)
                    return distributed_topk(v, ids, k, axes.model)

                return compat.shard_map(
                    local,
                    in_specs=(P(axes.model, None, None),
                              P(axes.model, None), P(None, None),
                              P(None, None)),
                    out_specs=(P(None, None), P(None, None)),
                    check_vma=False,
                )(list_vecs, list_ids, u, sel)

            arg_structs = (param_structs,
                           common.struct((b,), jnp.int32),
                           common.struct((b, cfg.history_len), jnp.int32),
                           common.struct((p_parts, lmax, e), jnp.float32),
                           common.struct((p_parts, lmax), jnp.int32),
                           common.struct((h, e), jnp.float32),
                           common.struct((h,), jnp.int32))
            work = h * e + nprobe * lmax * e
            return common.StepBundle(
                arch="two-tower-retrieval", shape=shape, kind="serve",
                step_fn=serve_step, arg_structs=arg_structs,
                in_specs=(pspecs, P(), P(),
                          P(axes.model, None, None), P(axes.model, None),
                          P(), P()),
                out_specs=None,
                meta=dict(model_flops=dense_flops + 2.0 * work,
                          scan_trip_count=1, params=cfg.param_count(),
                          tokens=nprobe * lmax,
                          note="TopLoc + shard-local scan + k-merge"))

        if variant == "toploc_ivf":
            # the paper's technique on this arch: the item corpus is IVF-
            # clustered offline; the serving step scores the conversation
            # session's cached centroids (h << p), scans the selected
            # posting lists (sharded by partition over `model`), and
            # merges per-shard top-k — work drops from N to
            # h + nprobe·Lmax per request (DESIGN.md §4).
            p_parts = sp.get("partitions", 1024)
            lmax = sp.get("lmax", (n_cand // p_parts) * 5 // 4)
            h = sp.get("h", 128)
            nprobe = sp.get("nprobe", 32)

            def serve_step(params, user_id, history, list_vecs, list_ids,
                           cache_vecs, cache_ids):
                u = R.user_tower(params, cfg, user_id, history)  # (1, e)
                csc = u @ cache_vecs.T                           # (1, h)
                _, sel_local = jax.lax.top_k(csc, nprobe)
                sel = cache_ids[sel_local]                       # (1, np)
                lv = list_vecs[sel[0]]                           # (np,L,e)
                li = list_ids[sel[0]]
                scores = jnp.einsum("nld,bd->bnl", lv, u)
                scores = jnp.where(li[None] >= 0, scores, -jnp.inf)
                flat = scores.reshape(1, -1)
                v, pos = jax.lax.top_k(flat, k)
                ids = jnp.take_along_axis(
                    li.reshape(1, -1), pos, axis=-1)
                return v, ids

            arg_structs = (param_structs,
                           common.struct((b,), jnp.int32),
                           common.struct((b, cfg.history_len), jnp.int32),
                           common.struct((p_parts, lmax, e), jnp.float32),
                           common.struct((p_parts, lmax), jnp.int32),
                           common.struct((h, e), jnp.float32),
                           common.struct((h,), jnp.int32))
            work = h * e + nprobe * lmax * e
            return common.StepBundle(
                arch="two-tower-retrieval", shape=shape, kind="serve",
                step_fn=serve_step, arg_structs=arg_structs,
                in_specs=(pspecs, P(), P(),
                          P(axes.model, None, None), P(axes.model, None),
                          P(), P()),
                out_specs=None,
                meta=dict(model_flops=dense_flops + 2.0 * work,
                          scan_trip_count=1, params=cfg.param_count(),
                          tokens=nprobe * lmax,
                          note="TopLoc_IVF-pruned candidate scan "
                               "(hillclimb cell #3, paper technique)"))

        if variant == "dist_topk":
            # beyond-paper: per-shard top-k + k-wide merge instead of
            # letting XLA all-gather the (1, N) score row

            def serve_step(params, user_id, history, corpus):
                u = R.user_tower(params, cfg, user_id, history)
                # shard_map resolves the mesh from jax.set_mesh context
                from repro.core.topk import distributed_topk

                def local(corpus_l, u_l):
                    n_local = corpus_l.shape[0]
                    idx = jax.lax.axis_index(axes.model)
                    scores = u_l @ corpus_l.T
                    v, i = jax.lax.top_k(scores, k)
                    gids = i.astype(jnp.int32) + idx * n_local
                    return distributed_topk(v, gids, k, axes.model)

                return compat.shard_map(
                    local,
                    in_specs=(P(axes.model, None), P(None, None)),
                    out_specs=(P(None, None), P(None, None)),
                    check_vma=False,  # replicated post k-merge
                )(corpus, u)

        else:
            def serve_step(params, user_id, history, corpus):
                u = R.user_tower(params, cfg, user_id, history)  # (1, e)
                scores = u @ corpus.T                            # (1, N)
                return jax.lax.top_k(scores, k)

        arg_structs = (param_structs,
                       common.struct((b,), jnp.int32),
                       common.struct((b, cfg.history_len), jnp.int32),
                       common.struct((n_cand, e), jnp.float32))
        return common.StepBundle(
            arch="two-tower-retrieval", shape=shape, kind="serve",
            step_fn=serve_step,
            arg_structs=arg_structs,
            in_specs=(pspecs, P(), P(), P(axes.model, None)),
            out_specs=None,
            meta=dict(model_flops=dense_flops + 2.0 * n_cand * e,
                      scan_trip_count=1, params=cfg.param_count(),
                      tokens=n_cand,
                      note=f"variant={variant}; TopLoc_IVF variant via "
                           "shape_overrides (hillclimb cell #3)"))

    # pairwise serve (p99 / bulk)
    def serve_step(params, user_id, history, item_id):
        u = R.user_tower(params, cfg, user_id, history)
        i = R.item_tower(params, cfg, item_id)
        return jnp.sum(u * i, -1)

    # pure-DP serving: the idle model axis takes batch shards too
    flat = axes.data + (axes.model,)
    arg_structs = (param_structs,
                   common.struct((b,), jnp.int32),
                   common.struct((b, cfg.history_len), jnp.int32),
                   common.struct((b,), jnp.int32))
    return common.StepBundle(
        arch="two-tower-retrieval", shape=shape, kind="serve",
        step_fn=serve_step,
        arg_structs=arg_structs,
        in_specs=(pspecs, P(flat), P(flat, None), P(flat)),
        out_specs=None,
        meta=dict(model_flops=b * dense_flops, scan_trip_count=1,
                  params=cfg.param_count(), tokens=b))


ARCH = common.register(common.ArchDef(
    arch_id="two-tower-retrieval", family="recsys",
    shapes=tuple(SHAPE_PARAMS),
    make_config=full_config, make_smoke_config=smoke_config,
    build_bundle=build_bundle,
    notes="paper-representative arch: retrieval_cand == TopLoc's serving "
          "problem"))
