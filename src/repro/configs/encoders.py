"""The paper's own dense-retrieval encoder configs (not graded archs).

dragon    — BERT-base-style dual encoder, 768-d, inner product [12]
snowflake — XLM-R-large-style shared encoder, 1024-d, cosine [22]

Used by the reproduction pipeline (examples/train_encoder.py encodes the
synthetic corpus; benchmarks then index those embeddings) and included
in the dry-run extras.
"""
from repro.models.encoder import EncoderConfig


def dragon_config() -> EncoderConfig:
    return EncoderConfig(name="dragon", n_layers=12, d_model=768,
                         n_heads=12, d_ff=3072, vocab=32768, max_len=256,
                         normalize=True, shared_towers=False)


def snowflake_config() -> EncoderConfig:
    return EncoderConfig(name="snowflake", n_layers=24, d_model=1024,
                         n_heads=16, d_ff=4096, vocab=32768, max_len=256,
                         normalize=True, shared_towers=True)


def small_encoder_config() -> EncoderConfig:
    """~100M-class trainable-in-container encoder (examples/)."""
    return EncoderConfig(name="mini-dragon", n_layers=4, d_model=256,
                         n_heads=8, d_ff=1024, vocab=8192, max_len=64,
                         out_dim=64, normalize=True, shared_towers=False)


def tiny_encoder_config() -> EncoderConfig:
    return EncoderConfig(name="tiny-encoder", n_layers=2, d_model=64,
                         n_heads=4, d_ff=128, vocab=1024, max_len=32,
                         out_dim=32, normalize=True, shared_towers=False)
