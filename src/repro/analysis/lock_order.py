"""Pass 7 — lock-order / lock-discipline lint (pure AST, LK7xx).

The serving layer is multi-threaded (client submitters, replica pump
threads, hedge pools); this pass checks the three lock-discipline
invariants that keep it deadlock-free, over ``serving/`` +
``distributed/``:

  LK701  lock-acquisition cycle: the per-class lock graph (edge L→K
         when K is acquired while L is held, including one level of
         ``self.<method>()`` calls) contains a cycle — two threads
         taking the locks in opposite orders deadlock.  Reentrant
         self-edges on ``RLock`` locks are exempt (that is what RLock
         is for).
  LK702  a ``threading`` primitive acquired outside a ``with`` block or
         ``try``/``finally`` — any exception between ``acquire()`` and
         ``release()`` leaks the lock and wedges every later acquirer.
  LK703  a blocking call (``Future.result``, ``block_until_ready``,
         ``Thread.join``, queue ``get``, pool ``shutdown``,
         ``time.sleep``, bare ``wait``) made while holding a lock —
         the classic lost-wakeup/convoy shape: whatever must run to
         unblock the call may itself need the held lock.
         ``cv.wait()`` *on the condition variable currently held by the
         enclosing ``with``* is exempt (that is the condvar protocol —
         wait releases the lock while sleeping).

Lock discovery is per class: ``self.X = threading.Lock()/RLock()``
declares lock attribute ``X``; ``self.Y = threading.Condition(self.X)``
makes ``Y`` an *alias* of ``X`` (entering the condition acquires the
underlying lock); ``self.Q = queue.Queue()`` marks ``Q`` so ``Q.get()``
counts as blocking.  ``@holds("_lock")`` (``repro.concurrency``) seeds
the held-set of a method whose caller holds the lock by contract.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.project import Module, Project
from repro.analysis.trace_safety import _attr_chain

PASS_ID = "lock-order"

#: repo-relative prefixes scanned when running over the whole project
SCOPE_PREFIXES = ("src/repro/serving/", "src/repro/distributed/")

_LOCK_CTORS = {"Lock", "RLock"}
_COND_CTORS = {"Condition"}
_QUEUE_CTORS = {"Queue", "SimpleQueue", "LifoQueue", "PriorityQueue"}

#: attribute calls that block the calling thread
_BLOCKING_ATTRS = {"result", "block_until_ready", "shutdown"}


def _with_lock_attr(item: ast.withitem,
                    locks: Dict[str, str]) -> Optional[str]:
    """Canonical lock attr for ``with self.X:`` (None if not a lock)."""
    chain = _attr_chain(item.context_expr)
    if chain and len(chain) == 2 and chain[0] == "self":
        return locks.get(chain[1])
    return None


class _ClassLocks:
    """Lock/queue attribute discovery for one class body."""

    def __init__(self, cnode: ast.ClassDef):
        self.cnode = cnode
        self.locks: Dict[str, str] = {}    # attr -> canonical lock attr
        self.rlocks: Set[str] = set()      # canonical attrs that are RLock
        self.queues: Set[str] = set()
        for node in ast.walk(cnode):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                self._assign(node)

    def _assign(self, node: ast.Assign) -> None:
        ctor = _attr_chain(node.value.func)
        if not ctor:
            return
        name = ctor[-1]
        for tgt in node.targets:
            chain = _attr_chain(tgt)
            if not (chain and len(chain) == 2 and chain[0] == "self"):
                continue
            attr = chain[1]
            if name in _LOCK_CTORS:
                self.locks[attr] = attr
                if name == "RLock":
                    self.rlocks.add(attr)
            elif name in _COND_CTORS:
                # Condition(self.X) aliases the underlying lock; a bare
                # Condition() owns a private lock — canonical = itself
                args = node.value.args
                inner = _attr_chain(args[0]) if args else None
                if inner and len(inner) == 2 and inner[0] == "self":
                    self.locks[attr] = inner[1]
                else:
                    self.locks[attr] = attr
            elif name in _QUEUE_CTORS:
                self.queues.add(attr)


class _MethodScan(ast.NodeVisitor):
    """One method body: collect lock edges + LK702/LK703 findings."""

    def __init__(self, mod: Module, cls: str, fn: ast.AST,
                 info: _ClassLocks, held0: Sequence[str],
                 findings: List[Finding]):
        self.mod = mod
        self.cls = cls
        self.fn = fn
        self.info = info
        self.findings = findings
        # stack of (canonical lock, with-object attr) currently held
        self.held: List[Tuple[str, str]] = [(h, h) for h in held0]
        # lock edges observed: (outer, inner, line)
        self.edges: List[Tuple[str, str, int]] = []
        # (canonical lock, line) of self-method calls made while held
        self.calls_held: List[Tuple[str, str, int]] = []

    def run(self) -> None:
        for stmt in self.fn.body:
            self.visit(stmt)

    def _emit(self, code: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            pass_id=PASS_ID, code=code, path=self.mod.rel,
            line=getattr(node, "lineno", 0),
            message=f"in `{self.cls}.{self.fn.name}`: {msg}"))

    # -- with / acquire tracking --------------------------------------

    def visit_With(self, node: ast.With) -> None:
        entered = []
        for item in node.items:
            lock = _with_lock_attr(item, self.info.locks)
            if lock is None:
                continue
            chain = _attr_chain(item.context_expr)
            for outer, _ in self.held:
                if outer == lock and lock in self.info.rlocks:
                    continue          # reentrant RLock self-acquire
                self.edges.append((outer, lock, item.context_expr.lineno))
            self.held.append((lock, chain[1]))
            entered.append(lock)
        for stmt in node.body:
            self.visit(stmt)
        for _ in entered:
            self.held.pop()

    def _is_release_in_finally(self, acq: ast.Call) -> bool:
        """``acquire()`` at statement position: accepted iff some
        enclosing/adjacent ``try`` has the matching ``release()`` in its
        ``finally``."""
        chain = _attr_chain(acq.func)
        target = ".".join(chain[:-1])
        for t in ast.walk(self.fn):
            if not (isinstance(t, ast.Try) and t.finalbody):
                continue
            for stmt in ast.walk(ast.Module(body=t.finalbody,
                                            type_ignores=[])):
                if isinstance(stmt, ast.Call):
                    c = _attr_chain(stmt.func)
                    if c and c[-1] == "release" \
                            and ".".join(c[:-1]) == target:
                        return True
        return False

    # -- call classification ------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if chain:
            attr = chain[-1]
            root = ".".join(chain[:-1])
            is_self_lock = (len(chain) == 3 and chain[0] == "self"
                            and chain[1] in self.info.locks)
            if attr == "acquire" and is_self_lock:
                if not self._is_release_in_finally(node):
                    self._emit(
                        "LK702", node,
                        f"`{root}.acquire()` outside `with`/"
                        f"try-finally — an exception before release() "
                        f"leaks the lock; use `with {root}:`")
            elif self.held:
                self._check_blocking(node, chain, attr, root)
            # one-level interprocedural edge propagation
            if (len(chain) == 2 and chain[0] == "self" and self.held):
                for outer, _ in self.held:
                    self.calls_held.append(
                        (outer, chain[1], node.lineno))
        elif isinstance(node.func, ast.Name) and self.held:
            if node.func.id in ("wait", "sleep"):
                self._emit(
                    "LK703", node,
                    f"blocking `{node.func.id}(…)` while holding "
                    f"`{self._held_str()}`")
        self.generic_visit(node)

    def _held_str(self) -> str:
        return ", ".join(sorted({h for h, _ in self.held}))

    def _check_blocking(self, node: ast.Call, chain: List[str],
                        attr: str, root: str) -> None:
        blocking = False
        if attr in _BLOCKING_ATTRS:
            blocking = True
        elif attr == "sleep" and chain[0] == "time":
            blocking = True
        elif attr == "wait":
            # cv.wait() on the condvar the enclosing `with` holds is
            # the condvar protocol (wait releases the lock) — exempt
            obj = chain[1] if len(chain) == 3 and chain[0] == "self" \
                else None
            if obj is None or all(held_obj != obj
                                  for _, held_obj in self.held):
                blocking = True
        elif attr == "join":
            # `.join()` with no args / a timeout kw / one numeric arg is
            # a thread join, not str.join
            if (not node.args and not node.keywords) or any(
                    kw.arg == "timeout" for kw in node.keywords) or (
                    len(node.args) == 1
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, (int, float))):
                blocking = True
        elif attr == "get":
            # queue waits only — a `.get` on a declared queue attribute
            blocking = (len(chain) == 3 and chain[0] == "self"
                        and chain[1] in self.info.queues)
        if blocking:
            self._emit(
                "LK703", node,
                f"blocking `{'.'.join(chain)}(…)` while holding "
                f"`{self._held_str()}` — whatever unblocks it may "
                f"need that lock")


def _holds_locks(fn: ast.AST) -> List[str]:
    """Lock names from ``@holds("…")`` decorators."""
    out: List[str] = []
    for dec in getattr(fn, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        chain = _attr_chain(dec.func) or []
        if chain and chain[-1] == "holds":
            out.extend(a.value for a in dec.args
                       if isinstance(a, ast.Constant)
                       and isinstance(a.value, str))
    return out


def _find_cycles(edges: Dict[Tuple[str, str], int]
                 ) -> List[Tuple[str, str, int]]:
    """Edges participating in a cycle of the lock graph."""
    graph: Dict[str, Set[str]] = {}
    for (a, b) in edges:
        graph.setdefault(a, set()).add(b)

    def reaches(src: str, dst: str) -> bool:
        seen, stack = set(), [src]
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(graph.get(n, ()))
        return False

    bad = []
    for (a, b), line in sorted(edges.items(), key=lambda kv: kv[1]):
        if a == b or reaches(b, a):
            bad.append((a, b, line))
    return bad


def _scan_class(mod: Module, cnode: ast.ClassDef,
                findings: List[Finding]) -> None:
    info = _ClassLocks(cnode)
    if not info.locks:
        return
    # method -> (edges, calls-while-held); acquired-set per method for
    # the one-level fixpoint
    acquires: Dict[str, Set[Tuple[str, int]]] = {}
    edges: Dict[Tuple[str, str], int] = {}
    calls_held: List[Tuple[str, str, int]] = []
    for node in cnode.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        scan = _MethodScan(mod, cnode.name, node, info,
                           _holds_locks(node), findings)
        scan.run()
        for a, b, line in scan.edges:
            edges.setdefault((a, b), line)
        calls_held.extend(scan.calls_held)
        # every lock this method acquires itself (all with-entries,
        # including depth-0 ones that produce no edge)
        acquires[node.name] = set()
        for n2 in ast.walk(node):
            if isinstance(n2, ast.With):
                for item in n2.items:
                    lk = _with_lock_attr(item, info.locks)
                    if lk is not None:
                        acquires[node.name].add(
                            (lk, item.context_expr.lineno))
    # one-level interprocedural: method called while holding L acquires K
    for outer, callee, line in calls_held:
        for lk, _ in acquires.get(callee, ()):
            if outer == lk and lk in info.rlocks:
                continue
            edges.setdefault((outer, lk), line)
    for a, b, line in _find_cycles(edges):
        findings.append(Finding(
            pass_id=PASS_ID, code="LK701", path=mod.rel, line=line,
            message=(f"in `{cnode.name}`: lock acquisition edge "
                     f"`{a}` → `{b}` closes a cycle in the lock-order "
                     f"graph (deadlock under opposing schedules)")))


def run(project: Optional[Project] = None,
        modules: Optional[Sequence[Module]] = None) -> List[Finding]:
    """Run the pass (project scope: serving/ + distributed/)."""
    if modules is not None:
        mods = list(modules)
    else:
        mods = [m for m in (project or Project()).modules
                if m.rel.startswith(SCOPE_PREFIXES)]
    findings: List[Finding] = []
    for mod in mods:
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                _scan_class(mod, node, findings)
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings
