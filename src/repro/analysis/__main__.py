"""CLI: ``python -m repro.analysis [--strict] [--select PASS …]``.

Exit codes: 0 clean (all findings suppressed or none), 1 active
findings, 2 (``--strict`` only) stale baseline entries — so CI can gate
on ``--strict`` while a local run stays informative.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import (all_passes, apply_baseline, load_baseline,
                            run_all)
from repro.analysis.project import Project, repo_root


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jit/Pallas/shard_map invariant linter")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale baseline entries")
    ap.add_argument("--baseline", default=None,
                    help="suppression file (default: "
                         "<repo>/analysis-baseline.txt)")
    ap.add_argument("--select", action="append", default=None,
                    metavar="PASS",
                    help=f"run only these passes (repeatable); "
                         f"available: {', '.join(sorted(all_passes()))}")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--list-passes", action="store_true",
                    help="list pass names and exit")
    args = ap.parse_args(argv)

    if args.list_passes:
        for name in sorted(all_passes()):
            print(name)
        return 0

    if args.select:
        unknown = sorted(set(args.select) - set(all_passes()))
        if unknown:
            print(f"error: unknown pass(es): {', '.join(unknown)}",
                  file=sys.stderr)
            print(f"valid pass names: "
                  f"{', '.join(sorted(all_passes()))}", file=sys.stderr)
            return 2

    baseline_path = args.baseline or str(
        repo_root() / "analysis-baseline.txt")
    patterns = load_baseline(baseline_path)

    findings = run_all(Project(), select=args.select)
    active, suppressed, stale = apply_baseline(findings, patterns)

    if args.as_json:
        print(json.dumps({
            "active": [vars(f) for f in active],
            "suppressed": [vars(f) for f in suppressed],
            "stale_baseline": stale,
        }, indent=2))
    else:
        for f in active:
            print(f.render())
        if suppressed:
            print(f"-- {len(suppressed)} finding(s) suppressed by "
                  f"baseline")
        for pat in stale:
            print(f"-- stale baseline entry (matches nothing): {pat}")
        n_passes = len(args.select or all_passes())
        print(f"{len(active)} finding(s) from {n_passes} pass(es)"
              + (" [strict]" if args.strict else ""))

    if active:
        return 1
    if args.strict and stale:
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
