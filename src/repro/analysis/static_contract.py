"""Pass 2 — jit-static contract checker for registered backends.

Every class in the ``core.backend`` registry rides through ``jax.jit``
as a *static argument* (the generic drivers declare
``static_argnames=("backend", "k")``).  That only works if the instance
is hashable, equality-stable, and array-free — an unhashable backend
raises at dispatch, an identity-hashed one silently retraces per
instance, and an array-valued field would bake device data into the
jit cache key.  Checked by *introspecting the live registry* (import,
construct, hash), never by string-matching source:

  SC201  registered class is not a frozen dataclass
  SC202  instances are not hashable, or two equal default instances
         hash differently (cache-key churn)
  SC203  a field holds (or is annotated as) a jax/numpy array
  SC204  driver surface incomplete: ``plain_batch`` missing, stateful
         backends missing ``start``/``step``/``start_batch``/
         ``step_batch``/``session_template``, ``step_batch`` not
         accepting ``is_first``, or ``name``/``index_kwarg`` left at
         the base-class placeholder
  SC205  backend not constructible via ``make(name)`` with defaults
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Dict, List, Optional, Type

import jax
import numpy as np

from repro.analysis.findings import Finding

PASS_ID = "static-contract"

_STATEFUL_SURFACE = ("start", "step", "start_batch", "step_batch")


def _is_array(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray))


def _check_class(name: str, cls: type, base: type,
                 findings: List[Finding]) -> None:
    where = f"backend {name!r} ({cls.__module__}.{cls.__qualname__})"

    if not (dataclasses.is_dataclass(cls)
            and cls.__dataclass_params__.frozen):
        findings.append(Finding(
            PASS_ID, "SC201", "", 0,
            f"{where} must be a frozen dataclass to be jit-static"))
        return  # downstream checks assume dataclass machinery

    # SC205 — default-constructible (make() with no knobs)
    try:
        inst = cls()
        inst2 = cls()
    except Exception as e:  # noqa: BLE001 - report, don't crash the pass
        findings.append(Finding(
            PASS_ID, "SC205", "", 0,
            f"{where} is not default-constructible: {e!r}"))
        return

    # SC202 — hashable and equality/hash-consistent across instances
    try:
        h1, h2 = hash(inst), hash(inst2)
    except TypeError as e:
        findings.append(Finding(
            PASS_ID, "SC202", "", 0,
            f"{where} is unhashable ({e}); it cannot be a jit static "
            f"argument"))
    else:
        if inst != inst2 or h1 != h2:
            findings.append(Finding(
                PASS_ID, "SC202", "", 0,
                f"{where}: two default instances are not equal with "
                f"equal hashes — every instance would retrace the "
                f"driver (jit cache-key churn)"))

    # SC203 — array-free fields (values and annotations)
    for f in dataclasses.fields(cls):
        v = getattr(inst, f.name, None)
        leaves = jax.tree.leaves(v)
        if _is_array(v) or any(_is_array(x) for x in leaves):
            findings.append(Finding(
                PASS_ID, "SC203", "", 0,
                f"{where}: field `{f.name}` holds an array — device "
                f"data must flow as a traced argument, not live on "
                f"the static backend"))
        elif "Array" in str(f.type) or "ndarray" in str(f.type):
            findings.append(Finding(
                PASS_ID, "SC203", "", 0,
                f"{where}: field `{f.name}` is annotated as an array "
                f"type; backends must be array-free to stay "
                f"jit-static"))

    # SC204 — driver surface
    if getattr(cls, "name", "?") in ("?", "", None):
        findings.append(Finding(
            PASS_ID, "SC204", "", 0,
            f"{where}: ClassVar `name` left at the base placeholder"))
    if getattr(cls, "index_kwarg", "?") in ("?", "", None):
        findings.append(Finding(
            PASS_ID, "SC204", "", 0,
            f"{where}: ClassVar `index_kwarg` left at the base "
            f"placeholder — the engines cannot route an index to it"))

    if not callable(getattr(cls, "plain_batch", None)):
        findings.append(Finding(
            PASS_ID, "SC204", "", 0,
            f"{where}: missing `plain_batch` — every backend must "
            f"serve stateless batched turns"))
    if not callable(getattr(cls, "plain", None)):
        findings.append(Finding(
            PASS_ID, "SC204", "", 0, f"{where}: missing `plain`"))

    if getattr(cls, "stateful", True):
        for meth in _STATEFUL_SURFACE:
            impl = getattr(cls, meth, None)
            if impl is None or impl is getattr(base, meth, None):
                findings.append(Finding(
                    PASS_ID, "SC204", "", 0,
                    f"{where}: stateful backend does not override "
                    f"`{meth}` (base raises NotImplementedError at "
                    f"trace time)"))
        sb = getattr(cls, "step_batch", None)
        if sb is not None and sb is not getattr(base, "step_batch",
                                                None):
            try:
                params = inspect.signature(sb).parameters
            except (TypeError, ValueError):
                params = {}
            if "is_first" not in params:
                findings.append(Finding(
                    PASS_ID, "SC204", "", 0,
                    f"{where}: `step_batch` does not accept "
                    f"`is_first` — the batched engine cannot route "
                    f"first turns through it"))
        st = getattr(cls, "session_template", None)
        if st is None or st is getattr(base, "session_template", None):
            findings.append(Finding(
                PASS_ID, "SC204", "", 0,
                f"{where}: stateful backend does not override "
                f"`session_template` — SessionStore cannot size its "
                f"slab"))


def run(project=None,
        registry: Optional[Dict[str, type]] = None,
        base: Optional[type] = None) -> List[Finding]:
    """Check every registered backend (or an injected ``registry``)."""
    from repro.core import backend as _backend
    reg: Dict[str, Type] = (dict(registry) if registry is not None
                            else dict(_backend._REGISTRY))
    base_cls = base if base is not None else _backend.RetrievalBackend
    findings: List[Finding] = []
    for name in sorted(reg):
        _check_class(name, reg[name], base_cls, findings)
    return findings
