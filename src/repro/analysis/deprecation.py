"""Pass 6 — deprecated-alias usage checker.

The 18 legacy ``toploc.*`` prefixed entry points survive for
downstream callers, but *internal* code (``src/``, ``benchmarks/``,
``examples/``) must be on the ``core.backend`` registry API.  The alias
set is collected live — every wrapper carries the
``__deprecated_alias__`` marker set by ``toploc._deprecated_alias`` —
so a newly deprecated entry point is covered with zero edits here.

  DA601  internal call or import of a deprecated ``toploc.*`` alias
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set

from repro.analysis.findings import Finding
from repro.analysis.project import Module, Project
from repro.analysis.trace_safety import _attr_chain

PASS_ID = "deprecated-alias"

_TOPLOC_MODULE = "repro.core.toploc"


def live_alias_names() -> Set[str]:
    """Names of all ``toploc`` functions marked deprecated."""
    from repro.core import toploc
    return {n for n in dir(toploc)
            if getattr(getattr(toploc, n), "__deprecated_alias__",
                       False)}


def _check_module(mod: Module, aliases: Set[str],
                  findings: List[Finding]) -> None:
    if mod.modname == _TOPLOC_MODULE:
        return  # the aliases' own definitions
    # local names bound to the toploc module (import aliases)
    toploc_names: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == _TOPLOC_MODULE:
                    toploc_names.add(a.asname
                                     or a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            if node.module == _TOPLOC_MODULE:
                for a in node.names:
                    if a.name in aliases:
                        findings.append(Finding(
                            PASS_ID, "DA601", mod.rel, node.lineno,
                            f"imports deprecated alias "
                            f"`toploc.{a.name}` — internal code must "
                            f"use the core.backend registry drivers"))
                    elif a.name == "toploc":
                        toploc_names.add(a.asname or a.name)
            elif node.module in ("repro.core", "repro"):
                for a in node.names:
                    if a.name == "toploc":
                        toploc_names.add(a.asname or a.name)
    if not toploc_names:
        return
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if (chain and len(chain) == 2
                    and chain[0] in toploc_names
                    and chain[1] in aliases):
                findings.append(Finding(
                    PASS_ID, "DA601", mod.rel, node.lineno,
                    f"uses deprecated alias `toploc.{chain[1]}` — "
                    f"internal code must use the core.backend "
                    f"registry drivers"))


def run(project: Optional[Project] = None,
        modules: Optional[Sequence[Module]] = None,
        aliases: Optional[Set[str]] = None) -> List[Finding]:
    mods = list(modules) if modules is not None else (
        project or Project()).modules
    names = aliases if aliases is not None else live_alias_names()
    findings: List[Finding] = []
    for mod in mods:
        _check_module(mod, names, findings)
    findings.sort(key=lambda f: (f.path, f.line))
    return findings
