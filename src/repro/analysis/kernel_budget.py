"""Pass 4 — Pallas kernel VMEM-budget / tile-alignment checker.

Rather than re-parsing kernel sources, this pass *captures the real
BlockSpecs*: it monkeypatches ``jax.experimental.pallas.pallas_call``
with a recorder and drives every ``kernels.ops`` dispatch wrapper
through ``jax.eval_shape`` under ``jax.disable_jit()`` on
representative shapes (the paper's serving regime plus a high-dim
stress point).  Nothing is lowered or executed — the recorder sees the
exact grid/BlockSpecs/scratch each wrapper would hand to Mosaic and
returns zeros of the declared out_shape.

  PK401  per-step VMEM footprint over budget: Σ in/out block bytes ×2
         (the pipeline double-buffers every HBM↔VMEM stream) + scratch
         bytes must fit the ~16 MiB/core VMEM.  An over-budget tile is
         a guaranteed Mosaic allocation failure on hardware — the CPU
         interpret path hides it.
  PK402  a *split* grid dimension whose block tile is misaligned to
         the (sublane, lane) = (8, 128) float32 register tiling
         (sublane 16/32 for 2-/1-byte dtypes).  Degenerate size-1
         blocks are exempt (single-row gather is the canonical
         scalar-prefetch pattern); so are unsplit dims (Mosaic pads
         the final partial tile itself).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.kernels import tiling as _tiling

PASS_ID = "kernel-budget"

# single source of truth shared with the ops-layer tile-split policy
# (kernels/tiling.py): the checker asserts against the same constants
# the wrappers split by, so the two can never disagree.
VMEM_BUDGET_BYTES = _tiling.VMEM_BUDGET_BYTES
LANE = _tiling.LANE
_sublane = _tiling.sublane


# ---------------------------------------------------------------------------
# pallas_call recorder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PallasCallRecord:
    """One captured ``pallas_call`` invocation."""

    kernel_name: str
    grid: Tuple[int, ...]
    in_blocks: List[Tuple[Tuple[int, ...], Tuple[int, ...], Any]]
    out_blocks: List[Tuple[Tuple[int, ...], Tuple[int, ...], Any]]
    scratch: List[Tuple[Tuple[int, ...], Any]]
    # operands pinned to the ANY memory space stay HBM-resident (the
    # kernel DMAs slices into its *scratch* buffers itself, and those
    # buffers are counted under ``scratch``) — they are recorded here
    # for visibility but excluded from the VMEM footprint.
    hbm_ops: List[Tuple[Tuple[int, ...], Any]] = dataclasses.field(
        default_factory=list)

    def vmem_bytes(self) -> int:
        total = 0
        for block, _shape, dtype in self.in_blocks + self.out_blocks:
            total += 2 * _block_bytes(block, dtype)   # double-buffered
        for shape, dtype in self.scratch:
            total += _block_bytes(shape, dtype)
        return total


def _block_bytes(shape: Sequence[int], dtype) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    try:
        return n * jnp.dtype(dtype).itemsize
    except TypeError:
        # DMA/regular semaphores: 32-bit hardware registers, not VMEM
        return 4 * n


def _kernel_name(kernel) -> str:
    f = kernel
    while isinstance(f, functools.partial):
        f = f.func
    return getattr(f, "__name__", repr(f))


def _spec_fields(spec) -> Tuple[Optional[Tuple[int, ...]], Any]:
    return getattr(spec, "block_shape", None), spec


def _is_hbm_resident(spec) -> bool:
    """True for operands pinned to the ANY memory space: Mosaic leaves
    them in HBM and the kernel moves slices with explicit DMAs, so the
    full array shape must not be charged to the VMEM budget."""
    ms = getattr(spec, "memory_space", None)
    return ms is not None and "ANY" in str(ms).upper()


def _zeros_like_out(out_shape):
    is_sds = lambda x: isinstance(x, jax.ShapeDtypeStruct)  # noqa: E731
    leaves, treedef = jax.tree.flatten(out_shape, is_leaf=is_sds)
    outs = [jnp.zeros(s.shape, s.dtype) for s in leaves]
    return jax.tree.unflatten(treedef, outs)


@contextlib.contextmanager
def record_pallas_calls(records: List[PallasCallRecord]):
    """Swap ``pallas_call`` for a recorder returning declared zeros."""
    import jax.experimental.pallas as pl_mod

    real = pl_mod.pallas_call

    def fake(kernel, out_shape=None, **kw):
        grid_spec = kw.get("grid_spec")
        if grid_spec is not None:
            grid = tuple(getattr(grid_spec, "grid", ()) or ())
            in_specs = list(getattr(grid_spec, "in_specs", ()) or ())
            out_specs = getattr(grid_spec, "out_specs", ())
            scratch = list(getattr(grid_spec, "scratch_shapes", ())
                           or ())
            n_prefetch = int(getattr(grid_spec, "num_scalar_prefetch",
                                     0) or 0)
        else:
            g = kw.get("grid", ())
            grid = tuple(g) if isinstance(g, (tuple, list)) else (g,)
            in_specs = list(kw.get("in_specs", ()) or ())
            out_specs = kw.get("out_specs", ())
            scratch = list(kw.get("scratch_shapes", ()) or ())
            n_prefetch = 0
        if not isinstance(out_specs, (tuple, list)):
            out_specs = [out_specs]
        out_specs = list(out_specs)

        def runner(*args):
            # scalar-prefetch operands live in SMEM: skip them
            arr_args = args[n_prefetch:]
            in_blocks = []
            hbm_ops = []
            for spec, a in zip(in_specs, arr_args):
                block, _ = _spec_fields(spec)
                shape = tuple(getattr(a, "shape", ()))
                if _is_hbm_resident(spec):
                    hbm_ops.append((shape,
                                    getattr(a, "dtype", jnp.float32)))
                    continue
                blk = tuple(shape[i] if (block is None
                                         or block[i] is None)
                            else int(block[i])
                            for i in range(len(shape))) if shape else ()
                in_blocks.append((blk, shape,
                                  getattr(a, "dtype", jnp.float32)))
            is_sds = lambda x: isinstance(  # noqa: E731
                x, jax.ShapeDtypeStruct)
            out_leaves = jax.tree.leaves(out_shape, is_leaf=is_sds)
            out_blocks = []
            for spec, s in zip(out_specs, out_leaves):
                block, _ = _spec_fields(spec)
                shape = tuple(s.shape)
                blk = tuple(shape[i] if (block is None
                                         or block[i] is None)
                            else int(block[i])
                            for i in range(len(shape))) if shape else ()
                out_blocks.append((blk, shape, s.dtype))
            scratch_info = []
            for sc in scratch:
                shp = tuple(getattr(sc, "shape", ()) or ())
                dt = getattr(sc, "dtype", jnp.float32)
                scratch_info.append((shp, dt))
            records.append(PallasCallRecord(
                kernel_name=_kernel_name(kernel), grid=grid,
                in_blocks=in_blocks, out_blocks=out_blocks,
                scratch=scratch_info, hbm_ops=hbm_ops))
            return _zeros_like_out(out_shape)

        return runner

    pl_mod.pallas_call = fake
    try:
        yield
    finally:
        pl_mod.pallas_call = real


# ---------------------------------------------------------------------------
# representative probes (ops-layer entry points)
# ---------------------------------------------------------------------------


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def default_probes() -> List[Tuple[str, Callable[[], Any]]]:
    """(label, thunk) pairs; each thunk runs one ops wrapper under
    eval_shape with the recorder active."""
    from repro.kernels import ops

    def ivf(d):
        return lambda: jax.eval_shape(
            lambda q, lv, li, sel: ops.ivf_scan(
                q, lv, li, sel, 32, mode="interpret"),
            _f32(8, d), _f32(64, 2048, d), _i32(64, 2048), _i32(8, 8))

    def pq():
        return jax.eval_shape(
            lambda t, c, li, sel: ops.pq_adc_scan(
                t, c, li, sel, 32, mode="interpret"),
            _f32(8, 16, 256),
            jax.ShapeDtypeStruct((64, 4096, 16), jnp.uint8),
            _i32(64, 4096), _i32(8, 8))

    def ctk():
        return jax.eval_shape(
            lambda q, c: ops.centroid_topk(q, c, 64, mode="interpret"),
            _f32(32, 128), _f32(4096, 128))

    def fa():
        return jax.eval_shape(
            lambda q, k, v: ops.flash_attention(
                q, k, v, causal=True, mode="interpret"),
            _f32(1, 4, 1024, 128), _f32(1, 4, 1024, 128),
            _f32(1, 4, 1024, 128))

    def fd():
        return jax.eval_shape(
            lambda q, k, v, n: ops.flash_decode(
                q, k, v, n, mode="interpret"),
            _f32(4, 8, 128), _f32(4, 2, 2048, 128),
            _f32(4, 2, 2048, 128), _i32(4))

    def eb():
        return jax.eval_shape(
            lambda t, ids: ops.embedding_bag(
                t, ids, mode="interpret"),
            _f32(50000, 256), _i32(8, 16))

    def fused(precision):
        return lambda: jax.eval_shape(
            lambda q, c, lv, li: ops.fused_turn(
                q, c, lv, li, nprobe=8, k=32, precision=precision,
                mode="interpret"),
            _f32(8, 128), _f32(4096, 128), _f32(4096, 512, 128),
            _i32(4096, 512))

    def fused_pq():
        return jax.eval_shape(
            lambda q, c, t, cd, li, dv: ops.fused_turn_pq(
                q, c, t, cd, li, dv, nprobe=8, k=32, rerank=64,
                mode="interpret"),
            _f32(8, 128), _f32(4096, 128), _f32(8, 16, 256),
            jax.ShapeDtypeStruct((4096, 512, 16), jnp.uint8),
            _i32(4096, 512), _f32(50000, 128))

    def fused_scan():
        return jax.eval_shape(
            lambda q, lv, li, sel: ops.fused_scan(
                q, lv, li, sel, 32, mode="interpret"),
            _f32(8, 128), _f32(4096, 512, 128), _i32(4096, 512),
            _i32(8, 8))

    return [
        ("ops.ivf_scan[d=128]", ivf(128)),
        ("ops.ivf_scan[d=1024]", ivf(1024)),
        ("ops.pq_adc_scan", pq),
        ("ops.centroid_topk", ctk),
        ("ops.flash_attention", fa),
        ("ops.flash_decode", fd),
        ("ops.embedding_bag", eb),
        ("ops.fused_turn[f32]", fused("f32")),
        ("ops.fused_turn[int8]", fused("int8")),
        ("ops.fused_turn_pq", fused_pq),
        ("ops.fused_scan", fused_scan),
    ]


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------


def _check_alignment(rec: PallasCallRecord, label: str,
                     findings: List[Finding]) -> None:
    for kind, blocks in (("in", rec.in_blocks), ("out",
                                                 rec.out_blocks)):
        for bi, (block, shape, dtype) in enumerate(blocks):
            if len(block) < 1 or len(shape) != len(block):
                continue
            itemsize = jnp.dtype(dtype).itemsize
            sub = _sublane(itemsize)
            for axis in range(len(block)):
                blk, full = block[axis], shape[axis]
                if blk >= full or blk == 1:
                    continue          # unsplit or degenerate gather dim
                from_last = len(block) - 1 - axis
                need = LANE if from_last == 0 else (
                    sub if from_last == 1 else None)
                if need is not None and blk % need:
                    findings.append(Finding(
                        PASS_ID, "PK402", "", 0,
                        f"{label} kernel `{rec.kernel_name}` "
                        f"{kind}[{bi}]: split axis {axis} tile {blk} "
                        f"of {full} is not a multiple of {need} "
                        f"({jnp.dtype(dtype).name} needs "
                        f"({sub}, {LANE}) tiles) — Mosaic will pad "
                        f"every step"))


def _check_budget(rec: PallasCallRecord, label: str,
                  findings: List[Finding],
                  budget: int = VMEM_BUDGET_BYTES) -> None:
    used = rec.vmem_bytes()
    if used > budget:
        detail = ", ".join(
            f"{name}={_block_bytes(b, dt) // 1024}KiB×2"
            for name, (b, _s, dt) in
            [(f"in{i}", t) for i, t in enumerate(rec.in_blocks)]
            + [(f"out{i}", t) for i, t in enumerate(rec.out_blocks)])
        findings.append(Finding(
            PASS_ID, "PK401", "", 0,
            f"{label} kernel `{rec.kernel_name}`: per-step VMEM "
            f"footprint {used / 2**20:.1f} MiB exceeds the "
            f"{budget / 2**20:.0f} MiB/core budget "
            f"(double-buffered blocks: {detail}; grid={rec.grid}) — "
            f"shrink the block tiles"))


def run(project=None,
        probes: Optional[Sequence[Tuple[str, Callable]]] = None,
        budget: int = VMEM_BUDGET_BYTES) -> List[Finding]:
    findings: List[Finding] = []
    for label, thunk in (probes if probes is not None
                         else default_probes()):
        records: List[PallasCallRecord] = []
        try:
            with record_pallas_calls(records), jax.disable_jit():
                thunk()
        except Exception as e:  # noqa: BLE001 - surface, don't abort
            findings.append(Finding(
                PASS_ID, "PK400", "", 0,
                f"{label}: kernel probe failed: "
                f"{type(e).__name__}: {e}"))
            continue
        if not records:
            findings.append(Finding(
                PASS_ID, "PK400", "", 0,
                f"{label}: no pallas_call reached the recorder — the "
                f"dispatch wrapper silently fell back to the ref "
                f"path, so the kernel is unchecked"))
        for rec in records:
            _check_budget(rec, label, findings, budget)
            _check_alignment(rec, label, findings)
    return findings
