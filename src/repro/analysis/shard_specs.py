"""Pass 5 — shard_map spec checker (live, 1-device mesh).

Cross-checks ``distributed/sharding.py`` placement specs against what
the registered scan plugins (``register_sharding`` consumers) actually
hand to ``shard_map``.  For every entry in ``_SHARDING_REGISTRY`` the
pass builds a tiny index, runs ``shard_backend`` on a 1-device mesh,
patches ``repro.compat.shard_map`` with a recording wrapper, and
drives the plugin once with concrete arrays — so the captured
``in_specs`` can be identity-matched to the placed index leaves:

  SS501  an array argument without a placement: an index leaf with no
         ``NamedSharding``, an ``in_specs`` tuple whose arity differs
         from the plugin's argument list, or a plugin ``in_spec`` that
         contradicts the placement the index leaf actually has (the
         resulting mid-jit reshard is a silent all-gather per call).
  SS502  replicated state partitioned (or vice versa): centroids /
         codebooks / adjacency / entry metadata must stay replicated;
         corpus-sized arrays (posting lists, code lists, doc rows)
         must shard over the mesh axis; non-index operands (queries,
         selections, ADC tables) and every output must be replicated —
         TopLoc session math runs identically on every device.
         ``SessionStore`` slabs built with a mesh must replicate too.
  SS503  plugin not jit-static (not a frozen hashable dataclass) or
         registered against a field the backend dataclass lacks.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.analysis.findings import Finding

PASS_ID = "shard-specs"

# index fields that must stay replicated / must shard (dim 0)
REPLICATED_FIELDS = {"centroids", "codewords", "adj0", "upper_adj",
                     "entry_point", "node_level", "deleted",
                     "delta_vecs", "delta_ids", "tombstone"}
SHARDED_FIELDS = {"list_vecs", "list_ids", "list_sizes", "list_codes",
                  "doc_vecs", "vectors"}


def _spec_tuple(spec) -> Tuple:
    """PartitionSpec → comparable tuple, trailing Nones stripped."""
    t = tuple(spec) if spec is not None else ()
    while t and t[-1] is None:
        t = t[:-1]
    return t


def _is_replicated(spec) -> bool:
    return _spec_tuple(spec) == ()


@dataclasses.dataclass
class ShardMapRecord:
    in_specs: Tuple
    out_specs: Any
    args: Tuple


@contextlib.contextmanager
def record_shard_maps(records: List[ShardMapRecord]):
    """Wrap ``repro.compat.shard_map`` to capture (specs, args)."""
    from repro import compat

    real = compat.shard_map

    def fake(fn, *, mesh, in_specs, out_specs, **kw):
        wrapped = real(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, **kw)

        def runner(*args):
            records.append(ShardMapRecord(
                in_specs=tuple(in_specs) if isinstance(
                    in_specs, (tuple, list)) else (in_specs,),
                out_specs=out_specs, args=args))
            return wrapped(*args)

        return runner

    compat.shard_map = fake
    try:
        yield
    finally:
        compat.shard_map = real


def _leaf_sharding_spec(leaf) -> Optional[Tuple]:
    sh = getattr(leaf, "sharding", None)
    if isinstance(sh, NamedSharding):
        return _spec_tuple(sh.spec)
    return None


def _call_plugin(name: str, backend, index, d: int):
    """Drive the plugged-in scan/search once with concrete operands."""
    q = jnp.zeros((4, d), jnp.float32)
    if name == "hnsw":
        return backend.search(index, q, ef=8, k=4)
    sel = jnp.zeros((4, 4), jnp.int32)
    if name == "ivf_pq":
        return backend.scan(index, q, sel, 4, 8)
    return backend.scan(index, q, sel, 4)


def _check_entry(name: str, entry, mesh, axis: str,
                 findings: List[Finding]) -> None:
    from repro.analysis.retrace import _tiny_indexes, _tiny_knobs
    from repro.core import backend as _backend
    from repro.serving.sessions import store_for_backend

    shard_index, plugin_cls, field = entry
    where = f"sharding[{name!r}]"

    # ---- SS503: plugin is a jit-static plug for a real field ---------
    if not (dataclasses.is_dataclass(plugin_cls)
            and plugin_cls.__dataclass_params__.frozen):
        findings.append(Finding(
            PASS_ID, "SS503", "", 0,
            f"{where}: plugin {plugin_cls.__name__} is not a frozen "
            f"dataclass — it cannot ride through jit on the backend"))
    cls = _backend.get(name)
    if field not in {f.name for f in dataclasses.fields(cls)}:
        findings.append(Finding(
            PASS_ID, "SS503", "", 0,
            f"{where}: registered field {field!r} does not exist on "
            f"backend class {cls.__name__} — shard_backend would "
            f"raise at replace()"))
        return

    be = _backend.make(name, **_tiny_knobs(name))
    index = _tiny_indexes()[cls.index_kwarg]
    # wire the *entry under check* (mirrors shard_backend, but honours
    # an injected registry — the fixture tests pass seeded-bad entries)
    idx2 = shard_index(mesh, index, axis=axis)
    be2 = dataclasses.replace(be, **{field: plugin_cls(mesh, axis)})
    try:
        hash(be2)
    except TypeError as e:
        findings.append(Finding(
            PASS_ID, "SS503", "", 0,
            f"{where}: backend with plugged {plugin_cls.__name__} is "
            f"unhashable ({e}) — the sharded drivers cannot jit it"))
        return

    # ---- placement of every index leaf + replication policy ----------
    field_names = getattr(type(idx2), "_fields",
                          tuple(range(len(jax.tree.leaves(idx2)))))
    by_id: Dict[int, str] = {}
    for fname in field_names:
        leaf = getattr(idx2, fname, None)
        if leaf is None:
            continue
        by_id[id(leaf)] = str(fname)
        spec = _leaf_sharding_spec(leaf)
        if spec is None:
            findings.append(Finding(
                PASS_ID, "SS501", "", 0,
                f"{where}: index leaf `{fname}` has no NamedSharding "
                f"after shard_backend — it would be re-placed on "
                f"every dispatch"))
            continue
        if fname in REPLICATED_FIELDS and spec != ():
            findings.append(Finding(
                PASS_ID, "SS502", "", 0,
                f"{where}: `{fname}` is replicated TopLoc state but "
                f"is placed with spec {spec} — partitioning it "
                f"breaks the every-device-identical session math"))
        if fname in SHARDED_FIELDS and spec == ():
            findings.append(Finding(
                PASS_ID, "SS502", "", 0,
                f"{where}: corpus-sized `{fname}` is fully "
                f"replicated — the placement buys no memory scaling; "
                f"expected dim-0 sharding over {axis!r}"))

    # ---- drive the plugin, capture the shard_map it builds -----------
    records: List[ShardMapRecord] = []
    try:
        with record_shard_maps(records):
            _call_plugin(name, be2, idx2, be.query_dim(index))
    except Exception as e:  # noqa: BLE001 - surface, don't abort
        findings.append(Finding(
            PASS_ID, "SS500", "", 0,
            f"{where}: plugin probe failed: {type(e).__name__}: {e}"))
        return
    if not records:
        findings.append(Finding(
            PASS_ID, "SS500", "", 0,
            f"{where}: plugin never called compat.shard_map — the "
            f"sharded path is unchecked"))
        return

    for rec in records:
        if len(rec.in_specs) != len(rec.args):
            findings.append(Finding(
                PASS_ID, "SS501", "", 0,
                f"{where}: shard_map in_specs arity "
                f"{len(rec.in_specs)} != {len(rec.args)} arguments — "
                f"an array operand is missing its placement"))
            continue
        for pos, (spec, arg) in enumerate(zip(rec.in_specs, rec.args)):
            declared = _spec_tuple(spec)
            fname = by_id.get(id(arg))
            if fname is not None:
                placed = _leaf_sharding_spec(arg)
                if placed is not None and placed != declared:
                    findings.append(Finding(
                        PASS_ID, "SS501", "", 0,
                        f"{where}: `{fname}` is placed as {placed} "
                        f"but the plugin declares in_spec "
                        f"{declared} — every call pays a silent "
                        f"reshard"))
            elif declared != ():
                findings.append(Finding(
                    PASS_ID, "SS502", "", 0,
                    f"{where}: non-index operand #{pos} (queries/"
                    f"selection/tables) declared with partitioned "
                    f"in_spec {declared} — per-turn TopLoc inputs "
                    f"must be replicated"))
        outs = (rec.out_specs if isinstance(rec.out_specs,
                                            (tuple, list))
                else (rec.out_specs,))
        for pos, ospec in enumerate(outs):
            if not _is_replicated(ospec):
                findings.append(Finding(
                    PASS_ID, "SS502", "", 0,
                    f"{where}: out_specs[{pos}] = "
                    f"{_spec_tuple(ospec)} is partitioned — merged "
                    f"top-k results must come back replicated"))

    # ---- session slab replication ------------------------------------
    store = store_for_backend(be2, idx2, n_slots=2, mesh=mesh)
    if store is not None:
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                store._slab)[0]:
            spec = _leaf_sharding_spec(leaf)
            if spec is None or spec != ():
                findings.append(Finding(
                    PASS_ID, "SS502", "", 0,
                    f"{where}: SessionStore slab leaf "
                    f"`{jax.tree_util.keystr(path)}` is not "
                    f"replicated over the mesh (spec={spec}) — "
                    f"sessions are replicated TopLoc state"))


def run(project=None, registry: Optional[Dict] = None,
        axis: str = "model") -> List[Finding]:
    from repro.distributed import retrieval as _ret

    reg = registry if registry is not None else _ret._SHARDING_REGISTRY
    mesh = _ret.retrieval_mesh(1, axis=axis)
    findings: List[Finding] = []
    for name in sorted(reg):
        try:
            _check_entry(name, reg[name], mesh, axis, findings)
        except Exception as e:  # noqa: BLE001 - surface, don't abort
            findings.append(Finding(
                PASS_ID, "SS500", "", 0,
                f"sharding[{name!r}]: probe failed: "
                f"{type(e).__name__}: {e}"))
    return findings
