"""repro.analysis — jit/Pallas/shard_map/concurrency invariant linter.

Eight passes over the tree (``python -m repro.analysis``), each
encoding an invariant the test suite could only catch after the fact:

  ==============  =====================================================
  trace-safety    AST: host `if`/`while`/`bool()`/`np.*`/clock/RNG in
                  functions reachable from a jit boundary  (TS1xx)
  contract        live registry: backends frozen/hashable/array-free
                  with the full driver surface              (SC2xx)
  retrace         abstract tracing: cache-key churn, dtype/weak-type
                  drift across batch sizes and engines      (RT3xx)
  kernels         recorded pallas_call: per-step VMEM budget and
                  (8,128) tile alignment                    (PK4xx)
  shard           recorded shard_map: placements vs in_specs,
                  replicated TopLoc state never partitioned (SS5xx)
  deprecated      AST: internal use of legacy toploc.* aliases (DA6xx)
  lock-order      AST over serving/ + distributed/: lock-graph cycles,
                  bare acquire(), blocking under a lock     (LK7xx)
  guarded-fields  AST: `@guarded_by` declarations vs actual lock
                  domination; undeclared shared mutables    (GF8xx)
  ==============  =====================================================

The concurrency passes have a dynamic counterpart —
``repro.analysis.tsan`` (vector-clock race detection over an
instrumented ``threading``) driven by ``repro.analysis.schedules``
(seeded deterministic-schedule exploration); see DESIGN.md §8.

See DESIGN.md §8 for the invariant catalogue and
``analysis-baseline.txt`` for the (empty) suppression baseline.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.analysis.findings import (            # noqa: F401
    Finding, apply_baseline, load_baseline)
from repro.analysis.project import Project       # noqa: F401


def all_passes() -> Dict[str, Callable]:
    """pass name → ``run(project) -> List[Finding]`` (import-lazy)."""
    from repro.analysis import (deprecation, guarded_fields,
                                kernel_budget, lock_order, retrace,
                                shard_specs, static_contract,
                                trace_safety)
    return {
        "trace-safety": trace_safety.run,
        "contract": static_contract.run,
        "retrace": retrace.run,
        "kernels": kernel_budget.run,
        "shard": shard_specs.run,
        "deprecated": deprecation.run,
        "lock-order": lock_order.run,
        "guarded-fields": guarded_fields.run,
    }


def run_all(project: Project = None,
            select: List[str] = None) -> List[Finding]:
    """Run the selected (default: all) passes over the tree."""
    passes = all_passes()
    if select:
        unknown = set(select) - set(passes)
        if unknown:
            raise ValueError(
                f"unknown pass(es) {sorted(unknown)}; available: "
                f"{sorted(passes)}")
        passes = {k: v for k, v in passes.items() if k in select}
    proj = project if project is not None else Project()
    findings: List[Finding] = []
    for name, fn in passes.items():
        findings.extend(fn(proj))
    return findings
