"""Pass 3 — retrace / promotion analyzer (abstract tracing).

Abstractly evaluates every registered backend through the generic
``toploc`` drivers across batch sizes {1, 8} on a tiny synthetic index
(milliseconds; nothing is compiled to XLA, ``jax.eval_shape`` only):

  RT301  avoidable recompile: calling a driver with a *fresh but
         equal* backend instance (or the same shapes twice) grows the
         jit cache — the static argument churns the cache key, so
         sustained serving would retrace per request.
  RT302  dtype drift between the sequential and batched paths (or
         between B=1 and B=8), and between ``start``'s session and the
         backend's ``session_template`` — either silently breaks the
         bit-identity contract / the SessionStore slab layout.
  RT303  weak-typed output leaf: a weakly-typed score array takes the
         *other* operand's dtype at the next op, so downstream math
         can diverge between the sequential and batched engines.

The tiny-index workload is built once per run with plain numpy (host)
and exercised via ``jax.eval_shape`` so no kernels execute.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding

PASS_ID = "retrace"

_BATCH_SIZES = (1, 8)


# ---------------------------------------------------------------------------
# tiny synthetic workload (host-built, milliseconds)
# ---------------------------------------------------------------------------


def _tiny_corpus(n: int = 96, d: int = 16) -> np.ndarray:
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


@functools.lru_cache(maxsize=None)
def _tiny_indexes() -> Dict[str, Any]:
    """One small index per registered index kind."""
    from repro.core import hnsw as _hnsw
    from repro.core import ivf as _ivf
    from repro.core import pq as _pq

    from repro.core import backend as _backend
    from repro.core import segment as _segment

    docs = _tiny_corpus()
    ivf_index = _ivf.build(docs, 8, iters=4)
    out: Dict[str, Any] = {
        "ivf_index": ivf_index,
        "ivf_pq_index": _pq.build_ivf_pq(ivf_index, docs, 4, iters=4,
                                         n_codes=16),
        "hnsw_index": _hnsw.build(docs, m=4, seed=0),
        "doc_vecs": jnp.asarray(docs),
    }
    out["segmented_index"] = _segment.make_segmented(
        _backend.make("ivf", **_tiny_knobs("ivf")), ivf_index, cap=8)
    return out


def _tiny_knobs(name: str) -> Dict[str, Any]:
    """Knobs scaled to the tiny corpus (h ≤ p, nprobe ≤ h, …)."""
    if name == "segmented":
        # the wrapper's only knob is the inner backend; its default
        # (h=1024) is sized for real corpora, not the tiny probe one
        from repro.core import backend as _backend
        return {"inner": _backend.make("ivf", **_tiny_knobs("ivf"))}
    return {"h": 8, "nprobe": 4, "alpha": 0.5, "rerank": 8, "ef": 8,
            "up": 2}


def _queries(b: int, d: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((b, d), jnp.float32)


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------


def _leaf_dtypes(tree: Any) -> List[Tuple[str, str, bool]]:
    """(keypath, dtype, weak_type) per leaf of an eval_shape result."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves_with_paths:
        key = jax.tree_util.keystr(path)
        out.append((key, str(getattr(leaf, "dtype", "?")),
                    bool(getattr(leaf, "weak_type", False))))
    return out


def _eval(fn, be, *args, **kwargs):
    # the backend is a jit-static argument: bind it (and k) in the
    # partial so eval_shape only abstracts the array operands
    return jax.eval_shape(functools.partial(fn, be, **kwargs), *args)


def _cache_size(fn) -> Optional[int]:
    try:
        return fn._cache_size()
    except AttributeError:
        return None


def _check_backend(name: str, findings: List[Finding],
                   k: int = 4) -> None:
    from repro.core import backend as _backend
    from repro.core import toploc as _tl

    cls = _backend.get(name)
    knobs = _tiny_knobs(name)
    be = _backend.make(name, **knobs)
    index = _tiny_indexes()[cls.index_kwarg]
    d = be.query_dim(index)
    where = f"backend {name!r}"

    # ---- RT302: sequential vs batched dtype agreement ----------------
    per_b: Dict[int, Any] = {}
    for b in _BATCH_SIZES:
        per_b[b] = _eval(_tl.plain_batch, be, index, _queries(b, d),
                         k=k)
    seq = _eval(_tl.plain, be, index,
                jax.ShapeDtypeStruct((d,), jnp.float32), k=k)

    d1 = _leaf_dtypes(per_b[_BATCH_SIZES[0]])
    for b in _BATCH_SIZES[1:]:
        db = _leaf_dtypes(per_b[b])
        for (k1, t1, _), (k2, t2, _) in zip(d1, db):
            if t1 != t2:
                findings.append(Finding(
                    PASS_ID, "RT302", "", 0,
                    f"{where}: `plain_batch` leaf `{k2}` is {t1} at "
                    f"B={_BATCH_SIZES[0]} but {t2} at B={b} — dtype "
                    f"must be batch-size-stable for bit-identity"))
    for (k1, t1, _), (k2, t2, _) in zip(_leaf_dtypes(seq), d1):
        if t1 != t2:
            findings.append(Finding(
                PASS_ID, "RT302", "", 0,
                f"{where}: sequential `plain` leaf `{k1}` is {t1} but "
                f"the batched path yields {t2} — promotion drift "
                f"between engines"))

    # ---- RT303: weak-typed outputs -----------------------------------
    for key, dt, weak in _leaf_dtypes(per_b[_BATCH_SIZES[-1]]):
        if weak:
            findings.append(Finding(
                PASS_ID, "RT303", "", 0,
                f"{where}: `plain_batch` leaf `{key}` ({dt}) is "
                f"weak-typed — it will adopt the other operand's "
                f"dtype downstream; anchor it with an explicit "
                f"`jnp.asarray(…, dtype)`"))

    # ---- stateful surface: start/step + session_template -------------
    if getattr(cls, "stateful", True):
        q0 = jax.ShapeDtypeStruct((d,), jnp.float32)
        v, i, sess, stats = _eval(_tl.start, be, index, q0, k=k)
        tmpl = be.session_template(index)
        t_sess = _leaf_dtypes(sess)
        t_tmpl = _leaf_dtypes(tmpl)
        for (k1, t1, _), (k2, t2, _) in zip(t_sess, t_tmpl):
            if t1 != t2:
                findings.append(Finding(
                    PASS_ID, "RT302", "", 0,
                    f"{where}: `start` session leaf `{k1}` is {t1} "
                    f"but `session_template` declares {t2} — the "
                    f"SessionStore slab would promote on scatter"))
        # step must preserve the session layout exactly
        _, _, sess2, _ = _eval(_tl.step, be, index, sess, q0, k=k)
        for (k1, t1, _), (k2, t2, _) in zip(t_sess,
                                            _leaf_dtypes(sess2)):
            if t1 != t2:
                findings.append(Finding(
                    PASS_ID, "RT302", "", 0,
                    f"{where}: `step` changes session leaf `{k1}` "
                    f"from {t1} to {t2} — sessions must be "
                    f"layout-stable across turns"))

    # ---- RT301: cache-key churn --------------------------------------
    # Drivers are jitted with backend/k static.  A fresh-but-equal
    # backend instance and a repeat same-shape call must both hit the
    # existing cache entry; growth means the static key churns.
    driver = _tl.plain_batch
    before = _cache_size(driver)
    if before is not None:
        q = jnp.zeros((2, d), jnp.float32)
        driver(be, index, q, k=k)
        warm = _cache_size(driver)
        be_fresh = _backend.make(name, **knobs)
        driver(be_fresh, index, q, k=k)
        driver(be, index, jnp.ones((2, d), jnp.float32), k=k)
        after = _cache_size(driver)
        if after > warm:
            findings.append(Finding(
                PASS_ID, "RT301", "", 0,
                f"{where}: re-calling `toploc.plain_batch` with a "
                f"fresh equal backend (or equal shapes) grew the jit "
                f"cache {warm}→{after} — static-arg churn forces a "
                f"retrace per instance"))


def run(project=None,
        names: Optional[Sequence[str]] = None) -> List[Finding]:
    from repro.core import backend as _backend

    todo = list(names) if names is not None else list(_backend.names())
    findings: List[Finding] = []
    for name in sorted(todo):
        try:
            _check_backend(name, findings)
        except Exception as e:  # noqa: BLE001 - surface, don't abort
            findings.append(Finding(
                PASS_ID, "RT300", "", 0,
                f"backend {name!r}: retrace probe itself failed: "
                f"{type(e).__name__}: {e}"))
    return findings
