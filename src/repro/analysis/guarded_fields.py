"""Pass 8 — declared lock→field guard lint (pure AST, GF8xx).

Classes that participate in the serving layer's threading declare
their locking convention with ``repro.concurrency.guarded_by``::

    @guarded_by("_lock", "_queue", "batch_sizes")
    class MicroBatcher: ...

This pass checks the declaration against every method body:

  GF801  a read or write of a guarded field not dominated by the owning
         lock — neither inside a ``with self.<lock>:`` block (lock
         aliases resolve: a ``Condition(self._lock)`` counts as
         ``_lock``) nor in a method declared ``@holds("<lock>")``.
  GF802  a field mutated from ≥ 2 distinct methods with *no* declared
         guard — the tell-tale shape of an undeclared shared mutable.
         Fields initialised to a ``threading`` primitive (Event, Lock,
         …) are exempt (they synchronise themselves), as is
         ``__init__`` (objects under construction are single-owner).
         Mutator *method calls* (``self.x.append(…)``) count only for
         fields initialised to a plain container — a call like
         ``self._slab.clear(rows)`` on a constructed component object
         delegates to that object's API, which owns its own
         synchronisation (direct assignments always count).

Only annotated classes are checked — the pass is opt-in per class, so
single-threaded code pays nothing.  The dynamic checker
(``repro.analysis.tsan``) enforces the same declarations at runtime via
``__guarded_fields__``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.lock_order import _ClassLocks, _holds_locks
from repro.analysis.project import Module, Project
from repro.analysis.trace_safety import _attr_chain

PASS_ID = "guarded-fields"

_THREADING_CTORS = {"Event", "Lock", "RLock", "Condition", "Semaphore",
                    "BoundedSemaphore", "Barrier"}

#: method calls that mutate their receiver in place
_MUTATOR_CALLS = {"append", "appendleft", "pop", "popleft", "add",
                  "remove", "clear", "update", "extend", "insert",
                  "setdefault", "discard", "move_to_end"}

#: constructors whose instances are plain (unsynchronised) containers
_CONTAINER_CTORS = {"list", "dict", "set", "frozenset", "deque",
                    "OrderedDict", "defaultdict", "Counter"}


def _guard_decl(cnode: ast.ClassDef) -> Dict[str, str]:
    """field → owning lock attr from the ``@guarded_by`` decorators."""
    decl: Dict[str, str] = {}
    for dec in cnode.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        chain = _attr_chain(dec.func) or []
        if not (chain and chain[-1] == "guarded_by"):
            continue
        consts = [a.value for a in dec.args
                  if isinstance(a, ast.Constant)
                  and isinstance(a.value, str)]
        if len(consts) >= 2:
            lock, fields = consts[0], consts[1:]
            for f in fields:
                decl[f] = lock
    return decl


class _GuardScan(ast.NodeVisitor):
    """GF801 over one method body, tracking the held-lock set."""

    def __init__(self, mod: Module, cls: str, fn: ast.AST,
                 decl: Dict[str, str], locks: Dict[str, str],
                 findings: List[Finding]):
        self.mod = mod
        self.cls = cls
        self.fn = fn
        self.decl = decl
        self.locks = locks      # lock attr -> canonical (alias-resolved)
        self.findings = findings
        self.held: List[str] = [self.locks.get(h, h)
                                for h in _holds_locks(fn)]

    def run(self) -> None:
        for stmt in self.fn.body:
            self.visit(stmt)

    def visit_With(self, node: ast.With) -> None:
        entered = 0
        for item in node.items:
            chain = _attr_chain(item.context_expr)
            if chain and len(chain) == 2 and chain[0] == "self" \
                    and chain[1] in self.locks:
                self.held.append(self.locks[chain[1]])
                entered += 1
        for stmt in node.body:
            self.visit(stmt)
        for _ in range(entered):
            self.held.pop()

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            lock = self.decl.get(node.attr)
            if lock is not None and \
                    self.locks.get(lock, lock) not in self.held:
                kind = ("write" if isinstance(node.ctx,
                                              (ast.Store, ast.Del))
                        else "read")
                self.findings.append(Finding(
                    pass_id=PASS_ID, code="GF801", path=self.mod.rel,
                    line=node.lineno,
                    message=(f"in `{self.cls}.{self.fn.name}`: {kind} "
                             f"of `self.{node.attr}` (guarded by "
                             f"`{lock}`) outside `with self.{lock}:`")))
        self.generic_visit(node)


def _mutated_fields(fn: ast.AST,
                    call_exempt: Set[str] = frozenset()) -> Dict[str, int]:
    """self-attribute → first mutation line, for one method body.

    ``call_exempt``: attributes whose mutator-call mutations are
    ignored (constructed component objects with their own API)."""
    out: Dict[str, int] = {}

    def note(attr: str, line: int) -> None:
        out.setdefault(attr, line)

    def self_attr(node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Attribute) and isinstance(
                node.value, ast.Name) and node.value.id == "self":
            return node.attr
        return None

    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target] if isinstance(node,
                                                        ast.AugAssign)
                       else node.targets)
            for tgt in targets:
                for t in ast.walk(tgt):
                    attr = self_attr(t)
                    if attr is not None and not isinstance(
                            getattr(t, "ctx", None), ast.Load):
                        note(attr, t.lineno)
                    # self.x[i] = … mutates self.x
                    if isinstance(t, ast.Subscript):
                        attr = self_attr(t.value)
                        if attr is not None:
                            note(attr, t.lineno)
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain and len(chain) == 3 and chain[0] == "self" \
                    and chain[2] in _MUTATOR_CALLS \
                    and chain[1] not in call_exempt:
                note(chain[1], node.lineno)
    return out


def _call_exempt_attrs(cnode: ast.ClassDef) -> Set[str]:
    """Attributes initialised from a non-container constructor call —
    mutator calls on them delegate to that object's own API."""
    out: Set[str] = set()
    for node in ast.walk(cnode):
        if isinstance(node, ast.Assign) and isinstance(
                node.value, ast.Call):
            ctor = _attr_chain(node.value.func) or []
            if not ctor or ctor[-1] in _CONTAINER_CTORS:
                continue
            for tgt in node.targets:
                chain = _attr_chain(tgt)
                if chain and len(chain) == 2 and chain[0] == "self":
                    out.add(chain[1])
    return out


def _threading_attrs(cnode: ast.ClassDef) -> Set[str]:
    """self-attributes initialised to a ``threading`` primitive."""
    out: Set[str] = set()
    for node in ast.walk(cnode):
        if isinstance(node, ast.Assign) and isinstance(
                node.value, ast.Call):
            ctor = _attr_chain(node.value.func) or []
            if ctor and ctor[-1] in _THREADING_CTORS:
                for tgt in node.targets:
                    chain = _attr_chain(tgt)
                    if chain and len(chain) == 2 and chain[0] == "self":
                        out.add(chain[1])
    return out


def _scan_class(mod: Module, cnode: ast.ClassDef,
                findings: List[Finding]) -> None:
    decl = _guard_decl(cnode)
    if not decl:
        return
    locks = _ClassLocks(cnode).locks
    sync_attrs = _threading_attrs(cnode)
    call_exempt = _call_exempt_attrs(cnode)
    mutations: Dict[str, List[Tuple[str, int]]] = {}
    for node in cnode.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name == "__init__":
            continue
        _GuardScan(mod, cnode.name, node, decl, locks, findings).run()
        for attr, line in _mutated_fields(node, call_exempt).items():
            if attr in decl or attr in locks or attr in sync_attrs:
                continue
            mutations.setdefault(attr, []).append((node.name, line))
    for attr, sites in sorted(mutations.items()):
        methods = sorted({m for m, _ in sites})
        if len(methods) < 2:
            continue
        line = min(ln for _, ln in sites)
        findings.append(Finding(
            pass_id=PASS_ID, code="GF802", path=mod.rel, line=line,
            message=(f"in `{cnode.name}`: `self.{attr}` mutated from "
                     f"{len(methods)} methods ({', '.join(methods)}) "
                     f"with no declared guard — add it to a "
                     f"`@guarded_by(…)` or document why it is "
                     f"single-threaded")))


def run(project: Optional[Project] = None,
        modules: Optional[Sequence[Module]] = None) -> List[Finding]:
    """Run the pass over every annotated class in scope."""
    mods = list(modules) if modules is not None else (
        project or Project()).modules
    findings: List[Finding] = []
    for mod in mods:
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                _scan_class(mod, node, findings)
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings
