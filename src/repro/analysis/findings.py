"""Finding/baseline plumbing shared by every analysis pass.

A *finding* is one diagnostic: ``path:line: CODE message``.  The
baseline file (``analysis-baseline.txt`` at the repo root) holds
``fnmatch`` patterns, one per line, matched against that rendered form;
a finding matching any pattern is *suppressed* (reported separately,
never fatal).  The tree's contract (ISSUE 6) is that the baseline stays
empty — the suppression machinery exists so a future regression can be
landed under a dated entry instead of reverting, and so ``--strict``
can flag stale entries the moment the underlying violation is fixed.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic emitted by an analysis pass."""

    pass_id: str      # e.g. "trace-safety"
    code: str         # e.g. "TS101"
    path: str         # repo-relative posix path ("" for live checks)
    line: int         # 1-based; 0 when no source location applies
    message: str

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.path else "<live>"
        return f"{loc}: {self.code} {self.message}"


def load_baseline(path: str) -> List[str]:
    """Suppression patterns from ``path`` (missing file ⇒ empty)."""
    try:
        with open(path, "r", encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    except FileNotFoundError:
        return []
    out = []
    for raw in lines:
        s = raw.strip()
        if s and not s.startswith("#"):
            out.append(s)
    return out


def apply_baseline(
    findings: Sequence[Finding], patterns: Sequence[str],
) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split findings into (active, suppressed); also return the
    baseline patterns that matched nothing (stale entries)."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    used = set()
    for f in findings:
        rendered = f.render()
        hit = None
        for pat in patterns:
            if fnmatch.fnmatch(rendered, pat) or fnmatch.fnmatch(
                    rendered, f"*{pat}*"):
                hit = pat
                break
        if hit is None:
            active.append(f)
        else:
            used.add(hit)
            suppressed.append(f)
    stale = [p for p in patterns if p not in used]
    return active, suppressed, stale
