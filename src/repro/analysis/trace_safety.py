"""Pass 1 — trace-safety lint (pure AST).

Finds host-side Python that silently misbehaves under ``jax.jit``
tracing, *scoped to functions actually reachable from a jit boundary*:

  TS101  Python ``if``/``while`` branching on a traced value (a
         non-static parameter of a jitted function, or the result of a
         ``jnp``/``jax.lax`` call) — under trace this raises
         ``TracerBoolConversionError`` or, worse, bakes one branch in.
  TS102  ``bool()``/``int()``/``float()`` materialisation of a traced
         expression.
  TS103  ``np.*`` calls inside traced code — numpy silently forces the
         tracer to a concrete array (ConcretizationError) or computes
         on the host at trace time, freezing the value into the jaxpr.
  TS104  wall-clock / RNG reads (``time.*``, ``random.*``,
         ``np.random.*``) inside traced code — evaluated once at trace
         time, then constant-folded into every later call.

Reachability: seeds are (a) functions decorated with ``jax.jit`` /
``functools.partial(jax.jit, …)`` / ``jax.custom_vjp``, (b) callables
handed to tracing higher-order ops (``pallas_call``, ``lax.cond`` /
``scan`` / ``while_loop`` / ``fori_loop`` / ``switch``, ``vmap``,
``shard_map``, ``defvjp``, …), and (c) methods of ``RetrievalBackend``
subclasses and of frozen-dataclass scan/search plugins (both ride
through jit as static arguments, so their methods are traced).  From
the seeds, reachability propagates through plain calls and callable
references (``list_scan=self._list_scan``) across module boundaries via
import-alias resolution.

Precision model: for directly-jitted seeds the decorator's
``static_argnames`` are known, so branching on a *non-static* parameter
is flagged; for transitively-traced helpers parameter staticness is
unknown, so only the conservative rules fire (``jnp``/``jax`` call
results, ``np.*``, clocks/RNG).  ``x is None`` tests, ``.shape`` /
``.ndim`` / ``.dtype`` / ``.size`` reads, ``len()`` and
``isinstance()`` stay exempt everywhere — those are static under
tracing by construction.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.project import Module, Project

PASS_ID = "trace-safety"

# higher-order ops whose callable arguments are traced
_TRACING_HOFS = {
    "pallas_call", "cond", "scan", "while_loop", "fori_loop", "switch",
    "vmap", "pmap", "shard_map", "custom_vjp", "defvjp", "checkpoint",
    "remat", "associative_scan", "map", "custom_jvp", "defjvp",
    "eval_shape", "grad", "value_and_grad", "make_jaxpr",
}

# attribute reads that stay static under tracing
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "weak_type"}
# jnp helpers returning static (python) values
_STATIC_JNP_FNS = {"shape", "ndim", "size", "result_type", "issubdtype",
                   "iinfo", "finfo", "dtype"}
# np attribute *calls* that are trace-safe (dtype constructors on host
# literals)
_SAFE_NP_CALLS = {"dtype", "float16", "float32", "float64", "int8",
                  "int16", "int32", "int64", "uint8", "uint16", "uint32",
                  "bool_"}
_EXEMPT_CALLS = {"len", "isinstance", "issubclass", "getattr", "hasattr",
                 "type", "range"}

_BACKEND_DRIVER_METHODS = {
    "start", "step", "plain", "start_batch", "step_batch", "plain_batch",
}


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` → ["a", "b", "c"]; None for non-name-rooted chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return None


class _ModuleIndex:
    """Per-module symbol tables used by the reachability analysis."""

    def __init__(self, mod: Module):
        self.mod = mod
        self.imports: Dict[str, str] = {}        # alias -> dotted module
        self.from_imports: Dict[str, Tuple[str, str]] = {}
        self.functions: Dict[str, ast.AST] = {}  # qualname -> def node
        self.func_class: Dict[str, Optional[str]] = {}
        self.classes: Dict[str, ast.ClassDef] = {}
        self._collect()

    def _collect(self) -> None:
        for node in self.mod.tree.body:
            self._top(node)

    def _top(self, node: ast.AST) -> None:
        if isinstance(node, ast.Import):
            for a in node.names:
                self.imports[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.level == 0:
                for a in node.names:
                    local = a.asname or a.name
                    self.from_imports[local] = (node.module, a.name)
                    # ``from repro.core import ivf as _ivf`` imports a
                    # *module* under an alias — treat it like an import
                    self.imports.setdefault(
                        local, f"{node.module}.{a.name}")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.functions[node.name] = node
            self.func_class[node.name] = None
        elif isinstance(node, ast.ClassDef):
            self.classes[node.name] = node
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                    q = f"{node.name}.{sub.name}"
                    self.functions[q] = sub
                    self.func_class[q] = node.name

    def resolve_alias(self, name: str) -> Optional[str]:
        """Local name → dotted module it refers to (or None)."""
        return self.imports.get(name)


FuncKey = Tuple[str, str]        # (modname, qualname)


class _Reachability:
    """Fixed-point propagation of 'traced' across the project."""

    def __init__(self, indexes: Dict[str, _ModuleIndex]):
        self.indexes = indexes
        # traced functions → known static param names (None = unknown)
        self.traced: Dict[FuncKey, Optional[Set[str]]] = {}
        self._work: List[FuncKey] = []

    def mark(self, key: FuncKey,
             static: Optional[Set[str]] = None) -> None:
        if key in self.traced:
            if static is not None and self.traced[key] is None:
                self.traced[key] = static
            return
        self.traced[key] = static
        self._work.append(key)

    # -- seed discovery ------------------------------------------------

    def seed(self) -> None:
        for modname, idx in self.indexes.items():
            for qual, fn in idx.functions.items():
                static = self._jit_decorator_static(fn, idx)
                if static is not None:
                    self.mark((modname, qual), static)
                    # positional static/nondiff argnums → param names
                    posns = self._static_positions(fn, idx)
                    if posns:
                        args = _BodyChecker._all_args(fn)
                        static.update(args[i].arg for i in posns
                                      if i < len(args))
            for cname, cnode in idx.classes.items():
                if self._is_traced_class(cnode, idx):
                    for qual, cls in idx.func_class.items():
                        if cls == cname:
                            self.mark((modname, qual), None)
            # callables handed to tracing HOFs anywhere in the module
            for call in ast.walk(idx.mod.tree):
                if isinstance(call, ast.Call):
                    self._seed_hof_args(call, modname, idx)

    def _jit_decorator_static(self, fn: ast.AST,
                              idx: _ModuleIndex) -> Optional[Set[str]]:
        """Static-argname set if ``fn`` is jit-decorated, else None."""
        for dec in getattr(fn, "decorator_list", []):
            found = self._jit_expr_static(dec, idx)
            if found is not None:
                return found
        return None

    def _jit_expr_static(self, expr: ast.AST,
                         idx: _ModuleIndex) -> Optional[Set[str]]:
        chain = _attr_chain(expr)
        if chain and chain[-1] in ("jit", "custom_vjp", "custom_jvp"):
            return set()
        if isinstance(expr, ast.Call):
            fchain = _attr_chain(expr.func) or []
            if fchain and fchain[-1] in ("jit", "custom_vjp",
                                         "custom_jvp"):
                return self._static_names(expr)
            if fchain and fchain[-1] == "partial" and expr.args:
                inner = _attr_chain(expr.args[0]) or []
                if inner and inner[-1] in ("jit", "custom_vjp",
                                           "custom_jvp"):
                    return self._static_names(expr)
        return None

    @staticmethod
    def _static_names(call: ast.Call) -> Set[str]:
        names: Set[str] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                v = kw.value
                elts = (v.elts if isinstance(v, (ast.Tuple, ast.List))
                        else [v])
                for e in elts:
                    if isinstance(e, ast.Constant) and isinstance(
                            e.value, str):
                        names.add(e.value)
        return names

    def _static_positions(self, fn: ast.AST,
                          idx: _ModuleIndex) -> Set[int]:
        """``static_argnums``/``nondiff_argnums`` positions from any
        jit/custom_vjp decorator on ``fn``."""
        posns: Set[int] = set()
        for dec in getattr(fn, "decorator_list", []):
            if not isinstance(dec, ast.Call):
                continue
            if self._jit_expr_static(dec, idx) is None:
                continue
            for kw in dec.keywords:
                if kw.arg in ("static_argnums", "nondiff_argnums"):
                    v = kw.value
                    elts = (v.elts if isinstance(v, (ast.Tuple,
                                                     ast.List))
                            else [v])
                    for e in elts:
                        if isinstance(e, ast.Constant) and isinstance(
                                e.value, int):
                            posns.add(e.value)
        return posns

    def _is_traced_class(self, cnode: ast.ClassDef,
                         idx: _ModuleIndex) -> bool:
        """Backend subclasses and frozen-dataclass callables are jit-
        static values whose methods execute under trace."""
        for base in cnode.bases:
            chain = _attr_chain(base) or []
            if chain and chain[-1].endswith("Backend"):
                return True
        has_call = any(
            isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n.name == "__call__" for n in cnode.body)
        if not has_call:
            return False
        for dec in cnode.decorator_list:
            chain = _attr_chain(dec if not isinstance(dec, ast.Call)
                                else dec.func) or []
            if chain and chain[-1] == "dataclass":
                return True
        return False

    def _seed_hof_args(self, call: ast.Call, modname: str,
                       idx: _ModuleIndex) -> None:
        fchain = _attr_chain(call.func) or []
        if not fchain or fchain[-1] not in _TRACING_HOFS:
            return
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            self._mark_callable_ref(arg, modname, idx)

    def _mark_callable_ref(self, node: ast.AST, modname: str,
                           idx: _ModuleIndex) -> None:
        if isinstance(node, ast.Call):
            # pallas_call(functools.partial(kernel, …), …) and friends
            chain = _attr_chain(node.func) or []
            if chain and chain[-1] == "partial" and node.args:
                self._mark_callable_ref(node.args[0], modname, idx)
            return
        if isinstance(node, ast.Name):
            if node.id in idx.functions:
                self.mark((modname, node.id), None)
            elif node.id in idx.from_imports:
                srcmod, orig = idx.from_imports[node.id]
                self._mark_external(srcmod, orig)
        elif isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if chain and len(chain) == 2:
                target = idx.resolve_alias(chain[0])
                if target:
                    self._mark_external(target, chain[1])

    def _mark_external(self, modname: str, qual: str) -> None:
        idx = self.indexes.get(modname)
        if idx is not None and qual in idx.functions:
            self.mark((modname, qual), None)

    # -- propagation ---------------------------------------------------

    def propagate(self) -> None:
        while self._work:
            modname, qual = self._work.pop()
            idx = self.indexes.get(modname)
            if idx is None:
                continue
            fn = idx.functions.get(qual)
            if fn is None:
                continue
            cls = idx.func_class.get(qual)
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    self._follow_call(node, modname, idx, cls)
                elif isinstance(node, (ast.Name, ast.Attribute)):
                    self._follow_ref(node, modname, idx, cls)

    def _follow_call(self, call: ast.Call, modname: str,
                     idx: _ModuleIndex, cls: Optional[str]) -> None:
        f = call.func
        if isinstance(f, ast.Name):
            if f.id in idx.functions:
                self.mark((modname, f.id), None)
            elif f.id in idx.from_imports:
                srcmod, orig = idx.from_imports[f.id]
                self._mark_external(srcmod, orig)
        elif isinstance(f, ast.Attribute):
            chain = _attr_chain(f)
            if chain is None:
                return
            if chain[0] == "self" and cls and len(chain) == 2:
                q = f"{cls}.{chain[1]}"
                if q in idx.functions:
                    self.mark((modname, q), None)
            elif len(chain) == 2:
                target = idx.resolve_alias(chain[0])
                if target:
                    self._mark_external(target, chain[1])

    def _follow_ref(self, node: ast.AST, modname: str,
                    idx: _ModuleIndex, cls: Optional[str]) -> None:
        """Callable *references* (``list_scan=self._list_scan``,
        ``kern = functools.partial(_kernel, …)``, ``self.scan or
        _ivf._scan_lists``) flow into traced code."""
        if isinstance(node, ast.Name) and node.id in idx.functions \
                and idx.func_class.get(node.id) is None:
            self.mark((modname, node.id), None)
            return
        if isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if chain and chain[0] == "self" and cls and len(chain) == 2:
                q = f"{cls}.{chain[1]}"
                if q in idx.functions:
                    self.mark((modname, q), None)
            elif chain and len(chain) == 2:
                target = idx.resolve_alias(chain[0])
                if target:
                    self._mark_external(target, chain[1])


class _BodyChecker(ast.NodeVisitor):
    """Emit TS1xx findings for one traced function body."""

    def __init__(self, mod: Module, idx: _ModuleIndex, qual: str,
                 static: Optional[Set[str]],
                 findings: List[Finding]):
        self.mod = mod
        self.idx = idx
        self.qual = qual
        self.static = static
        self.findings = findings
        fn = idx.functions[qual]
        self.params = {a.arg for a in self._all_args(fn)}

    @staticmethod
    def _all_args(fn) -> list:
        a = fn.args
        return (list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
                + ([a.vararg] if a.vararg else [])
                + ([a.kwarg] if a.kwarg else []))

    def run(self) -> None:
        fn = self.idx.functions[self.qual]
        for stmt in fn.body:
            self.visit(stmt)

    def _emit(self, code: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            pass_id=PASS_ID, code=code, path=self.mod.rel,
            line=getattr(node, "lineno", 0),
            message=f"in traced `{self.qual}`: {msg}"))

    # -- classification helpers ---------------------------------------

    def _module_of(self, root: str) -> Optional[str]:
        return self.idx.resolve_alias(root)

    def _is_jax_call(self, node: ast.AST) -> bool:
        """A call whose result is a traced array (jnp/jax.lax/...)."""
        if not isinstance(node, ast.Call):
            return False
        chain = _attr_chain(node.func)
        if not chain:
            return False
        target = self._module_of(chain[0]) or chain[0]
        if target.startswith("jax") or target == "jnp":
            return chain[-1] not in _STATIC_JNP_FNS
        return False

    def _tracer_names(self, expr: ast.AST) -> List[ast.Name]:
        """Occurrences of non-static params used as array values."""
        if self.static is None:
            return []
        out: List[ast.Name] = []
        parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(expr):
            for child in ast.iter_child_nodes(parent):
                parents[child] = parent
        for node in ast.walk(expr):
            if not (isinstance(node, ast.Name)
                    and node.id in self.params
                    and node.id not in self.static
                    and node.id != "self"):
                continue
            if self._exempt_occurrence(node, parents):
                continue
            out.append(node)
        return out

    @staticmethod
    def _exempt_occurrence(node: ast.AST,
                           parents: Dict[ast.AST, ast.AST]) -> bool:
        cur = node
        while cur in parents:
            p = parents[cur]
            if isinstance(p, ast.Attribute) and p.attr in _SHAPE_ATTRS:
                return True
            if isinstance(p, ast.Subscript) and p.value is not cur:
                return True          # x only used as an *index* source
            if isinstance(p, ast.Call):
                chain = _attr_chain(p.func) or []
                if chain and (chain[-1] in _EXEMPT_CALLS
                              or chain[-1] in _STATIC_JNP_FNS):
                    return True
            if isinstance(p, ast.Compare) and any(
                    isinstance(op, (ast.Is, ast.IsNot))
                    for op in p.ops):
                return True
            cur = p
        return False

    def _condition_issue(self, test: ast.AST) -> Optional[str]:
        for sub in ast.walk(test):
            if self._is_jax_call(sub):
                chain = _attr_chain(sub.func) or ["?"]
                return f"`{'.'.join(chain)}(…)` result"
        names = self._tracer_names(test)
        if names:
            return f"traced parameter `{names[0].id}`"
        return None

    # -- visitors ------------------------------------------------------

    def visit_If(self, node: ast.If) -> None:
        what = self._condition_issue(node.test)
        if what is not None:
            self._emit("TS101", node,
                       f"Python `if` on {what}; use `jnp.where` / "
                       f"`jax.lax.cond` instead")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        what = self._condition_issue(node.test)
        if what is not None:
            self._emit("TS101", node,
                       f"Python `while` on {what}; use "
                       f"`jax.lax.while_loop` instead")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        what = self._condition_issue(node.test)
        if what is not None:
            self._emit("TS101", node,
                       f"conditional expression on {what}; use "
                       f"`jnp.where` instead")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        # TS102 — bool()/int()/float() materialisation
        if (isinstance(func, ast.Name)
                and func.id in ("bool", "int", "float") and node.args):
            arg = node.args[0]
            if self._is_jax_call(arg) or any(
                    self._is_jax_call(s) for s in ast.walk(arg)) or \
                    self._tracer_names(arg):
                self._emit("TS102", node,
                           f"`{func.id}()` forces a traced value to a "
                           f"host scalar (ConcretizationError under "
                           f"jit)")
        chain = _attr_chain(func)
        if chain:
            root_target = self._module_of(chain[0]) or chain[0]
            # TS104 — clocks / RNG first (np.random.* is also an np call)
            if (root_target in ("time", "datetime")
                    or root_target == "random"
                    or (root_target in ("numpy", "np")
                        and len(chain) >= 2 and chain[1] == "random")):
                self._emit("TS104", node,
                           f"`{'.'.join(chain)}()` read inside traced "
                           f"code is evaluated once at trace time and "
                           f"constant-folded into the jaxpr")
            # TS103 — numpy ops on (potentially) traced operands
            elif root_target == "numpy" and len(chain) >= 2 \
                    and chain[-1] not in _SAFE_NP_CALLS:
                self._emit("TS103", node,
                           f"`{'.'.join(chain)}()` inside traced code "
                           f"runs on the host at trace time; use the "
                           f"`jnp` equivalent")
        self.generic_visit(node)

    # nested defs/lambdas inside a traced function are traced too —
    # generic_visit already descends into them.


def run(project: Optional[Project] = None,
        modules: Optional[Sequence[Module]] = None) -> List[Finding]:
    """Run the pass over ``project`` (or an explicit module list)."""
    mods = list(modules) if modules is not None else (
        project or Project()).modules
    indexes = {m.modname: _ModuleIndex(m) for m in mods}
    reach = _Reachability(indexes)
    reach.seed()
    reach.propagate()

    findings: List[Finding] = []
    for (modname, qual), static in sorted(reach.traced.items()):
        idx = indexes[modname]
        if qual not in idx.functions:
            continue
        _BodyChecker(idx.mod, idx, qual, static, findings).run()
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


def traced_functions(
        modules: Iterable[Module]) -> Dict[FuncKey, Optional[Set[str]]]:
    """Expose the reachability result (used by tests/debugging)."""
    indexes = {m.modname: _ModuleIndex(m) for m in modules}
    reach = _Reachability(indexes)
    reach.seed()
    reach.propagate()
    return reach.traced
