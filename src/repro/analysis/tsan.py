"""Dynamic concurrency sanitizer: instrumented ``threading`` shim.

The static passes (``lock_order``/``guarded_fields``) reason about the
AST; this module checks the same invariants on a *live* schedule, the
way TSan does for native code:

  * ``instrument(runtime)`` monkeypatches ``threading.Lock`` /
    ``RLock`` / ``Event`` / ``Thread`` with recording wrappers (the
    stdlib ``Condition`` composes with the wrapped locks through its
    documented fallback protocol, so ``Condition(self._lock)`` is
    instrumented for free).  Every acquisition records (a) the
    **lock-order graph** — an edge L→K whenever K is acquired while L
    is held; a new edge that closes a cycle is reported as a
    lock-order inversion — and (b) **happens-before** edges via vector
    clocks: release→acquire on the same lock, thread start→run and
    exit→join, event set→wait.
  * ``watch(runtime, Cls, …)`` patches ``__getattribute__`` /
    ``__setattr__`` on classes annotated with
    ``repro.concurrency.guarded_by`` so every access to a declared
    field is checked two ways: FastTrack-style vector-clock **race
    detection** (two accesses, ≥ one write, unordered by
    happens-before) and a **lockset check** (the declared owning lock
    must actually be held once the object is shared between threads).
  * ``runtime.schedule`` may hold a ``schedules.ScheduleExplorer``;
    the wrappers call its ``hook`` at every instrumented boundary, so
    the explorer can inject deterministic preemptions (sleeps) and
    steer the interleaving — seeded schedule replay.

Wrappers go inert the moment ``instrument`` exits (``runtime.active``
flips off and the real classes are restored), so objects that outlive
the context keep working at full speed.

Usage::

    rt = Runtime(schedule=ScheduleExplorer(seed=7))
    with instrument(rt):
        eng = BatchedConversationalSearchEngine(...)   # built inside!
        with watch(rt, MicroBatcher, SessionStore):
            ... run threaded traffic ...
    assert_clean(rt)
"""
from __future__ import annotations

import contextlib
import dataclasses
import sys
import threading
from typing import Any, Dict, List, Optional, Set, Tuple

import _thread

from repro.concurrency import GUARD_ATTR

_RawLock = _thread.allocate_lock
_get_ident = threading.get_ident

# real classes, captured at import so wrappers survive the patch
_RealLock = threading.Lock
_RealRLock = threading.RLock
_RealEvent = threading.Event
_RealThread = threading.Thread


@dataclasses.dataclass(frozen=True)
class Report:
    """One observed violation (data race / inversion / lockset)."""

    kind: str       # "race" | "lock-order" | "lockset"
    message: str

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return f"[{self.kind}] {self.message}"


def _join(dst: Dict[int, int], src: Dict[int, int]) -> None:
    for t, c in src.items():
        if dst.get(t, 0) < c:
            dst[t] = c


def _creation_site(depth: int = 3) -> str:
    f = sys._getframe(depth)
    return f"{f.f_code.co_filename.rsplit('/', 1)[-1]}:{f.f_lineno}"


class _VarState:
    """FastTrack-lite per-(object, field) access history."""

    __slots__ = ("w", "reads")

    def __init__(self) -> None:
        self.w: Optional[Tuple[int, int]] = None   # (tid, clock)
        self.reads: Dict[int, int] = {}            # tid -> clock


class Runtime:
    """Shared state of one sanitizer session (vector clocks, lock
    graph, reports).  All mutation happens under one raw internal lock
    (``_thread.allocate_lock`` — the patched ``threading.Lock`` must
    never be used here, or instrumentation would recurse)."""

    def __init__(self, schedule: Any = None):
        self.schedule = schedule
        self.active = False
        self.reports: List[Report] = []
        self._mu = _RawLock()
        self._vc: Dict[int, Dict[int, int]] = {}
        self._held: Dict[int, List[Any]] = {}        # tid -> lock stack
        self._edges: Dict[int, Set[int]] = {}        # lock-id graph
        self._edge_seen: Set[Tuple[int, int]] = set()
        self._lock_names: Dict[int, str] = {}
        self._vars: Dict[Tuple[int, str], _VarState] = {}
        self._obj_tids: Dict[int, Set[int]] = {}
        self._reported: Set[Tuple] = set()
        self._tls = threading.local()
        # OS thread idents are recycled the moment a thread exits; two
        # short-lived threads can share one ident, which would fuse
        # their vector clocks and hide real races.  All bookkeeping
        # therefore runs on *logical* tids: allocated on first sight of
        # an ident, retired at child_end so a recycled ident gets a
        # fresh logical identity.
        self._logical: Dict[int, int] = {}
        self._next_tid = 0

    # -- schedule hook -------------------------------------------------

    def maybe_preempt(self, kind: str) -> None:
        """Give the schedule explorer a preemption opportunity.  Never
        called while ``_mu`` is held (the injected sleep must extend
        *application* critical sections, not the sanitizer's).  A
        per-thread reentrancy guard keeps the hook from recursing when
        the explorer itself touches an instrumented primitive."""
        sched = self.schedule
        if sched is None or not self.active:
            return
        if getattr(self._tls, "in_hook", False):
            return
        self._tls.in_hook = True
        try:
            sched.hook(kind)
        finally:
            self._tls.in_hook = False

    # -- reporting -----------------------------------------------------

    def _report(self, key: Tuple, kind: str, message: str) -> None:
        if key in self._reported:
            return
        self._reported.add(key)
        self.reports.append(Report(kind=kind, message=message))

    # -- vector clocks -------------------------------------------------

    def _tid_locked(self) -> int:
        """Logical tid for the calling thread (``_mu`` must be held)."""
        ident = _get_ident()
        t = self._logical.get(ident)
        if t is None:
            t = self._logical[ident] = self._next_tid
            self._next_tid += 1
        return t

    def _vc_of(self, tid: int) -> Dict[int, int]:
        vc = self._vc.get(tid)
        if vc is None:
            vc = self._vc[tid] = {tid: 1}
        return vc

    def fork_vc(self) -> Dict[int, int]:
        """Parent side of a thread start: snapshot + advance."""
        with self._mu:
            tid = self._tid_locked()
            vc = self._vc_of(tid)
            snap = dict(vc)
            vc[tid] = vc.get(tid, 0) + 1
        return snap

    def child_begin(self, parent_vc: Optional[Dict[int, int]]) -> None:
        with self._mu:
            vc = self._vc_of(self._tid_locked())
            if parent_vc:
                _join(vc, parent_vc)

    def child_end(self) -> Dict[int, int]:
        with self._mu:
            snap = dict(self._vc_of(self._tid_locked()))
            # retire the ident→logical mapping: the OS may hand this
            # ident to the next thread the moment we exit
            self._logical.pop(_get_ident(), None)
            return snap

    def join_vc(self, child_vc: Optional[Dict[int, int]]) -> None:
        with self._mu:
            if child_vc:
                _join(self._vc_of(self._tid_locked()), child_vc)

    # -- lock events ---------------------------------------------------

    def note_acquire(self, lock: Any) -> None:
        with self._mu:
            tid = self._tid_locked()
            held = self._held.setdefault(tid, [])
            for h in held:
                if h is not lock:
                    self._add_edge(h, lock)
            held.append(lock)
            _join(self._vc_of(tid), lock._release_vc)

    def note_release(self, lock: Any) -> None:
        with self._mu:
            tid = self._tid_locked()
            held = self._held.get(tid, [])
            if lock in held:
                # remove the most recent acquisition
                for i in range(len(held) - 1, -1, -1):
                    if held[i] is lock:
                        del held[i]
                        break
            vc = self._vc_of(tid)
            lock._release_vc = dict(vc)
            vc[tid] = vc.get(tid, 0) + 1

    def _add_edge(self, a: Any, b: Any) -> None:
        ka, kb = id(a), id(b)
        if (ka, kb) in self._edge_seen:
            return
        self._edge_seen.add((ka, kb))
        # does b already reach a?  then a→b closes a cycle
        if self._reaches(kb, ka):
            self._report(
                ("lock-order", ka, kb), "lock-order",
                f"lock-order inversion: `{self._name(a)}` acquired "
                f"before `{self._name(b)}` here, but the opposite "
                f"order was also observed")
        self._edges.setdefault(ka, set()).add(kb)

    def _reaches(self, src: int, dst: int) -> bool:
        seen: Set[int] = set()
        stack = [src]
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(self._edges.get(n, ()))
        return False

    def _name(self, lock: Any) -> str:
        return self._lock_names.get(id(lock), "lock")

    def register_lock(self, lock: Any, name: str) -> None:
        with self._mu:
            self._lock_names[id(lock)] = name

    def holds(self, lock: Any) -> bool:
        with self._mu:
            tid = self._tid_locked()
            return any(h is lock for h in self._held.get(tid, []))

    # -- guarded-field events ------------------------------------------

    def on_field(self, obj: Any, name: str, lockname: str,
                 write: bool) -> None:
        cls = type(obj).__name__
        with self._mu:
            tid = self._tid_locked()
            tids = self._obj_tids.setdefault(id(obj), set())
            tids.add(tid)
            shared = len(tids) > 1
            vc = self._vc_of(tid)
            # lockset: the declared owner must actually be held
            if shared:
                try:
                    lock = object.__getattribute__(obj, lockname)
                except AttributeError:
                    lock = None
                if lock is not None and hasattr(lock, "_release_vc") \
                        and not any(h is lock for h in
                                    self._held.get(tid, [])):
                    self._report(
                        ("lockset", id(obj), name, write), "lockset",
                        f"{'write' if write else 'read'} of "
                        f"`{cls}.{name}` (guarded by `{lockname}`) "
                        f"without holding the lock, on shared object")
            # FastTrack-lite race detection
            st = self._vars.setdefault((id(obj), name), _VarState())
            me = vc.get(tid, 1)
            if st.w is not None:
                wt, wc = st.w
                if wt != tid and vc.get(wt, 0) < wc:
                    self._report(
                        ("race", id(obj), name,
                         "w" if write else "r"), "race",
                        f"data race on `{cls}.{name}`: "
                        f"{'write' if write else 'read'} unordered "
                        f"with a previous write (no happens-before "
                        f"edge between the threads)")
            if write:
                for rt_, rc in st.reads.items():
                    if rt_ != tid and vc.get(rt_, 0) < rc:
                        self._report(
                            ("race", id(obj), name, "rw"), "race",
                            f"data race on `{cls}.{name}`: write "
                            f"unordered with a previous read")
                        break
                st.w = (tid, me)
                st.reads = {}
            else:
                st.reads[tid] = me


# -- wrapper classes ---------------------------------------------------


class _LockWrapper:
    """Recording stand-in for ``threading.Lock``.  Also satisfies the
    stdlib ``Condition`` fallback protocol (plain acquire/release), so
    ``Condition(wrapped_lock)`` works unmodified."""

    _kind = "Lock"

    def __init__(self, rt: Runtime):
        self._rt = rt
        self._raw = _RawLock()
        self._release_vc: Dict[int, int] = {}
        rt.register_lock(self, f"{self._kind}@{_creation_site()}")

    def acquire(self, blocking: bool = True, timeout: float = -1):
        rt = self._rt
        if rt.active and blocking:
            rt.maybe_preempt("lock-acquire")
        ok = (self._raw.acquire(True, timeout) if blocking
              else self._raw.acquire(False))
        if ok and rt.active:
            rt.note_acquire(self)
        return ok

    def release(self) -> None:
        if self._rt.active:
            self._rt.note_release(self)
        self._raw.release()

    def locked(self) -> bool:
        return self._raw.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class _RLockWrapper:
    """Recording stand-in for ``threading.RLock`` — only the outermost
    acquire/release of a reentrant series is recorded."""

    _kind = "RLock"

    def __init__(self, rt: Runtime):
        self._rt = rt
        self._raw = _RealRLock()
        self._release_vc: Dict[int, int] = {}
        self._owner: Optional[int] = None
        self._depth = 0
        rt.register_lock(self, f"{self._kind}@{_creation_site()}")

    def acquire(self, blocking: bool = True, timeout: float = -1):
        rt = self._rt
        tid = _get_ident()
        outer = self._owner != tid
        if rt.active and blocking and outer:
            rt.maybe_preempt("lock-acquire")
        ok = (self._raw.acquire(True, timeout) if blocking
              else self._raw.acquire(False))
        if ok:
            # owner/depth only ever touched while the raw lock is held
            self._owner = tid
            self._depth += 1
            if self._depth == 1 and rt.active:
                rt.note_acquire(self)
        return ok

    def release(self) -> None:
        self._depth -= 1
        if self._depth == 0:
            self._owner = None
            if self._rt.active:
                self._rt.note_release(self)
        self._raw.release()

    def _is_owned(self) -> bool:
        return self._owner == _get_ident()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class _EventWrapper:
    """Recording stand-in for ``threading.Event`` with a set→wait
    happens-before edge."""

    def __init__(self, rt: Runtime):
        self._rt = rt
        self._raw = _RealEvent()
        self._set_vc: Dict[int, int] = {}

    def set(self) -> None:
        rt = self._rt
        if rt.active:
            with rt._mu:
                tid = rt._tid_locked()
                vc = rt._vc_of(tid)
                _join(self._set_vc, vc)
                vc[tid] = vc.get(tid, 0) + 1
        self._raw.set()

    def clear(self) -> None:
        self._raw.clear()

    def is_set(self) -> bool:
        return self._raw.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        rt = self._rt
        if rt.active:
            rt.maybe_preempt("event-wait")
        ok = self._raw.wait(timeout)
        if ok and rt.active:
            with rt._mu:
                _join(rt._vc_of(rt._tid_locked()), self._set_vc)
        return ok


@contextlib.contextmanager
def instrument(runtime: Runtime):
    """Patch ``threading`` so every Lock/RLock/Event/Thread created in
    the scope records into ``runtime``.  Restores the real classes on
    exit and flips ``runtime.active`` off, leaving escaped wrappers
    inert."""

    def _lock() -> _LockWrapper:
        return _LockWrapper(runtime)

    def _rlock() -> _RLockWrapper:
        return _RLockWrapper(runtime)

    def _event() -> _EventWrapper:
        return _EventWrapper(runtime)

    class _Thread(_RealThread):
        """Thread with start→run / exit→join happens-before edges."""

        def start(self) -> None:
            if runtime.active:
                self._tsan_parent_vc = runtime.fork_vc()
            super().start()

        def run(self) -> None:
            if runtime.active:
                runtime.child_begin(
                    getattr(self, "_tsan_parent_vc", None))
            try:
                super().run()
            finally:
                if runtime.active:
                    self._tsan_final_vc = runtime.child_end()

        def join(self, timeout: Optional[float] = None) -> None:
            super().join(timeout)
            if runtime.active and not self.is_alive():
                runtime.join_vc(getattr(self, "_tsan_final_vc", None))

    saved = (threading.Lock, threading.RLock, threading.Event,
             threading.Thread)
    threading.Lock = _lock
    threading.RLock = _rlock
    threading.Event = _event
    threading.Thread = _Thread
    runtime.active = True
    try:
        yield runtime
    finally:
        runtime.active = False
        (threading.Lock, threading.RLock, threading.Event,
         threading.Thread) = saved


@contextlib.contextmanager
def watch(runtime: Runtime, *classes: type):
    """Intercept every access to the ``@guarded_by`` fields of
    ``classes`` (lockset + race checks).  Class-wide: affects all live
    instances for the duration of the scope."""
    saved = []
    for cls in classes:
        guarded: Dict[str, str] = dict(getattr(cls, GUARD_ATTR, {}))
        if not guarded:
            continue
        orig_get = cls.__getattribute__
        orig_set = cls.__setattr__

        def _make(guarded=guarded, orig_get=orig_get,
                  orig_set=orig_set):
            def __getattribute__(obj, name):
                if name in guarded and runtime.active:
                    runtime.maybe_preempt("field-read")
                    runtime.on_field(obj, name, guarded[name],
                                     write=False)
                return orig_get(obj, name)

            def __setattr__(obj, name, value):
                if name in guarded and runtime.active:
                    runtime.maybe_preempt("field-write")
                    runtime.on_field(obj, name, guarded[name],
                                     write=True)
                orig_set(obj, name, value)
            return __getattribute__, __setattr__

        cls.__getattribute__, cls.__setattr__ = _make()
        saved.append((cls, orig_get, orig_set))
    try:
        yield runtime
    finally:
        for cls, g, s in saved:
            cls.__getattribute__ = g
            cls.__setattr__ = s


def assert_clean(runtime: Runtime) -> None:
    """Raise with every report if the session observed any violation."""
    if runtime.reports:
        lines = "\n".join(f"  {r}" for r in runtime.reports)
        raise AssertionError(
            f"{len(runtime.reports)} concurrency violation(s):\n{lines}")
