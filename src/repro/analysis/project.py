"""Source discovery + parsed-module model for the AST passes.

The scanned tree is ``src/repro`` + ``benchmarks`` + ``examples`` — the
code that must honour the jit/registry contracts.  ``tests/`` is out of
scope (tests legitimately poke legacy aliases, host branches, etc.), as
are the seeded-violation fixtures under ``tests/analysis_fixtures/``
(they exist precisely to violate the contracts).
"""
from __future__ import annotations

import ast
import dataclasses
import os
from pathlib import Path
from typing import Dict, List, Optional, Sequence

SCAN_DIRS = ("src/repro", "benchmarks", "examples")


def repo_root() -> Path:
    """The repo checkout containing this package (…/src/repro/analysis)."""
    return Path(__file__).resolve().parents[3]


@dataclasses.dataclass
class Module:
    """One parsed source file."""

    path: Path            # absolute
    rel: str              # repo-relative posix path
    modname: str          # dotted module name ("repro.core.ivf", …)
    tree: ast.Module

    @classmethod
    def parse(cls, path: Path, root: Path) -> Optional["Module"]:
        try:
            src = path.read_text(encoding="utf-8")
            tree = ast.parse(src, filename=str(path))
        except (OSError, SyntaxError):
            return None
        rel = path.relative_to(root).as_posix()
        return cls(path=path, rel=rel, modname=_modname(rel), tree=tree)


def _modname(rel: str) -> str:
    """Dotted import name for a repo-relative path (best effort)."""
    parts = rel[:-3].split("/") if rel.endswith(".py") else rel.split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class Project:
    """The set of modules an AST pass runs over."""

    def __init__(self, root: Optional[Path] = None,
                 scan_dirs: Sequence[str] = SCAN_DIRS):
        self.root = Path(root) if root is not None else repo_root()
        self.scan_dirs = tuple(scan_dirs)
        self._modules: Optional[List[Module]] = None

    @property
    def modules(self) -> List[Module]:
        if self._modules is None:
            self._modules = self._discover()
        return self._modules

    def by_modname(self) -> Dict[str, Module]:
        return {m.modname: m for m in self.modules}

    def _discover(self) -> List[Module]:
        out: List[Module] = []
        for d in self.scan_dirs:
            base = self.root / d
            if not base.exists():
                continue
            for dirpath, dirnames, filenames in os.walk(base):
                dirnames[:] = [n for n in dirnames
                               if n not in ("__pycache__",)]
                for fn in sorted(filenames):
                    if not fn.endswith(".py"):
                        continue
                    mod = Module.parse(Path(dirpath) / fn, self.root)
                    if mod is not None:
                        out.append(mod)
        return out


def modules_from_paths(paths: Sequence[Path],
                       root: Optional[Path] = None) -> List[Module]:
    """Parse an explicit file list (used by the fixture tests)."""
    r = Path(root) if root is not None else repo_root()
    out = []
    for p in paths:
        p = Path(p)
        try:
            rel_root = r if p.resolve().is_relative_to(r) else p.parent
        except AttributeError:  # pragma: no cover - py<3.9
            rel_root = p.parent
        mod = Module.parse(p, rel_root)
        if mod is not None:
            out.append(mod)
    return out
