"""Seeded deterministic-schedule exploration for the tsan harness.

Systematic schedule exploration needs two properties the plain OS
scheduler lacks: *coverage* (interleavings you would wait weeks to see
under natural timing) and *replay* (the same seed must produce the same
interleaving, or a found bug cannot be reproduced).  The explorer gets
both by injecting **deterministic preemptions** at the sanitizer's
instrumented boundaries (lock acquire, event wait, guarded-field
access — ``tsan.Runtime.maybe_preempt``):

  * the decision at the *n*-th boundary of thread *T* is a pure
    function of ``(seed, T.name, n, boundary kind)`` — a stable
    ``crc32`` hash, NOT Python's per-process-randomized ``hash()``
    and NOT wall-clock anything;
  * a "preempt" decision sleeps the thread for a hash-derived duration
    (0 .. ``max_sleep_s``), widening the race window exactly where a
    context switch would hurt;
  * every decision is recorded in a per-thread **trace**, so a test
    can pin determinism by replaying a seed twice and comparing
    traces, and a failure report can name the exact boundary.

Determinism caveat: traces are keyed by thread *name*.  Explicitly
named threads (test clients, ``replica-pump-N``) replay exactly;
anonymous pool threads get arrival-order names from the pool, so their
traces are only comparable when the scenario drives the pool
deterministically.

``replay`` wires one seed end to end: build a ``tsan.Runtime`` with
the explorer attached, run the scenario under ``instrument`` (+
optional ``watch``), assert no violation was observed, and hand back
the scenario result + the explorer for trace/identity assertions.
The fixed seed matrix ``SEEDS`` (20 schedules) is what the
``concurrency`` CI job replays over the overlapped-wave engine, router
mutation, and cache-invalidation paths (tests/test_concurrency.py).
"""
from __future__ import annotations

import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import _thread

from repro.analysis import tsan

#: the fixed seed matrix replayed by the CI concurrency job
SEEDS: Tuple[int, ...] = tuple(range(20))


class ScheduleExplorer:
    """Deterministic preemption injector (see module docstring).

    ``hook(kind)`` is called by the instrumented primitives at every
    boundary; it must be cheap when the decision is "run on" (the
    common case) — one counter bump + one crc32.
    """

    def __init__(self, seed: int, *, preempt_prob: float = 0.15,
                 max_sleep_s: float = 5e-4):
        self.seed = int(seed)
        self.preempt_prob = float(preempt_prob)
        self.max_sleep_s = float(max_sleep_s)
        self._mu = _thread.allocate_lock()
        self._counters: Dict[str, int] = {}
        #: thread name -> [(boundary #, kind, preempted)]
        self.traces: Dict[str, List[Tuple[int, str, bool]]] = {}

    def decision(self, tname: str, n: int, kind: str
                 ) -> Tuple[bool, float]:
        """(preempt?, sleep seconds) — pure function of the inputs."""
        h = zlib.crc32(f"{self.seed}|{tname}|{n}|{kind}".encode())
        preempt = (h % 1000) / 1000.0 < self.preempt_prob
        sleep_s = (((h >> 10) % 97) / 96.0) * self.max_sleep_s \
            if preempt else 0.0
        return preempt, sleep_s

    def hook(self, kind: str) -> None:
        # NOT threading.current_thread(): during thread bootstrap the
        # thread is not yet in ``threading._active``, and for such a
        # thread current_thread() constructs a _DummyThread whose
        # __init__ sets an (instrumented) Event — infinite recursion
        # back into this hook.  Resolve the registry directly and skip
        # the bootstrap/teardown boundaries instead; their dummy names
        # would be nondeterministic trace noise anyway.
        t = threading._active.get(_thread.get_ident())
        if t is None:
            return
        tname = t.name
        with self._mu:
            n = self._counters.get(tname, 0)
            self._counters[tname] = n + 1
        preempt, sleep_s = self.decision(tname, n, kind)
        with self._mu:
            self.traces.setdefault(tname, []).append((n, kind, preempt))
        if preempt:
            time.sleep(sleep_s)


def run_threads(targets: Sequence[Callable[[], Any]], *,
                names: Optional[Sequence[str]] = None) -> None:
    """Run ``targets`` on named threads, join all, re-raise the first
    failure.  Under ``tsan.instrument`` the threads are instrumented
    (start/join happens-before edges); deterministic names keep the
    explorer's traces replayable."""
    errs: List[BaseException] = []

    def _wrap(fn: Callable[[], Any]) -> Callable[[], None]:
        def go() -> None:
            try:
                fn()
            except BaseException as e:    # noqa: BLE001 - re-raised
                errs.append(e)
        return go

    threads = [
        threading.Thread(target=_wrap(fn),
                         name=(names[i] if names else f"client-{i}"))
        for i, fn in enumerate(targets)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]


def replay(seed: int, scenario: Callable[[tsan.Runtime], Any], *,
           watch_classes: Sequence[type] = (),
           preempt_prob: float = 0.15,
           max_sleep_s: float = 5e-4,
           ) -> Tuple[Any, ScheduleExplorer, tsan.Runtime]:
    """Run ``scenario`` under one seeded schedule, assert race-freedom.

    ``scenario(runtime)`` executes with ``threading`` instrumented (so
    every object it *builds* gets recording locks) and the classes in
    ``watch_classes`` under guarded-field interception.  Raises
    ``AssertionError`` listing every violation if the schedule exposed
    a data race, lock-order inversion, or lockset break; otherwise
    returns (scenario result, explorer, runtime) for bit-identity and
    trace-determinism assertions.
    """
    explorer = ScheduleExplorer(seed, preempt_prob=preempt_prob,
                                max_sleep_s=max_sleep_s)
    rt = tsan.Runtime(schedule=explorer)
    with tsan.instrument(rt):
        if watch_classes:
            with tsan.watch(rt, *watch_classes):
                result = scenario(rt)
        else:
            result = scenario(rt)
    tsan.assert_clean(rt)
    return result, explorer, rt
