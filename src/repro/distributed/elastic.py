"""Elastic scaling: rebuild the mesh for whatever devices survive.

Checkpoints restore as host arrays (mesh-independent), so elasticity is
(1) pick a mesh shape for the new device count, (2) re-place params with
the same logical PartitionSpecs on the new mesh.  Divisibility rule:
keep the model axis as large as possible (≤ requested tp) while it still
divides the device count; the remainder becomes data parallelism —
shrinking tp changes math-per-device, shrinking dp only changes
throughput, so dp absorbs the loss.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding


def choose_mesh_shape(n_devices: int, *, preferred_model: int = 16,
                      multi_pod: bool = False) -> Tuple[Tuple[int, ...],
                                                        Tuple[str, ...]]:
    """Largest model axis ≤ preferred_model dividing n_devices; rest → data."""
    tp = min(preferred_model, n_devices)
    while tp > 1 and n_devices % tp:
        tp -= 1
    rest = n_devices // tp
    if multi_pod and rest % 2 == 0 and rest > 1:
        return (2, rest // 2, tp), ("pod", "data", "model")
    return (rest, tp), ("data", "model")


def make_elastic_mesh(n_devices: Optional[int] = None, *,
                      preferred_model: int = 16,
                      multi_pod: bool = False) -> Mesh:
    devs = jax.devices()
    n = n_devices or len(devs)
    shape, axes = choose_mesh_shape(n, preferred_model=preferred_model,
                                    multi_pod=multi_pod)
    return jax.make_mesh(shape, axes, devices=devs[:n])


def replace_on_mesh(tree: Any, specs: Any, mesh: Mesh) -> Any:
    """Place a host pytree on ``mesh`` with logical ``specs``.

    Used after an elastic restore: the same PartitionSpecs work on any
    mesh that keeps the axis names (sizes may differ)."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, specs,
                        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
