"""Mesh/sharding rules, retrieval collectives, fault tolerance, elastic."""
from repro.distributed import collectives, elastic, fault, sharding  # noqa: F401
