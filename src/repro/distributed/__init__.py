"""Mesh/sharding rules, retrieval collectives, fault tolerance, elastic."""
from repro.distributed import (  # noqa: F401
    collectives, elastic, fault, retrieval, sharding)
