"""Distributed retrieval collectives (shard_map building blocks).

The serving-scale primitive: posting lists / candidate corpora are
sharded over the ``model`` axis; each shard reduces its local candidates
to k entries and a single k-wide all-gather + merge yields the global
top-k — the collective payload is O(k·shards), independent of corpus
size (DESIGN.md §2 'Distribution').
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

from repro.core.topk import distributed_topk


def sharded_corpus_topk(mesh: Mesh, corpus: jax.Array, queries: jax.Array,
                        k: int, *, axis: str = "model"
                        ) -> Tuple[jax.Array, jax.Array]:
    """Exact top-k of ``queries @ corpus.T`` with corpus rows sharded over
    ``axis``. queries replicated over ``axis``; batch over data axes.

    Returns (scores (B,k), global ids (B,k)) replicated over ``axis``.
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local(corpus_l, queries_l):
        n_local = corpus_l.shape[0]
        idx = jax.lax.axis_index(axis)
        scores = queries_l @ corpus_l.T                     # (B, n_local)
        v, i = jax.lax.top_k(scores, min(k, n_local))
        gids = i.astype(jnp.int32) + idx * n_local
        return distributed_topk(v, gids, k, axis)

    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(data_axes, None)),
        out_specs=(P(data_axes, None), P(data_axes, None)),
        check_vma=False,   # result IS replicated over `axis` post-merge
    )
    return fn(corpus, queries)


def sharded_ivf_probe(mesh: Mesh, list_vecs: jax.Array, list_ids: jax.Array,
                      queries: jax.Array, sel: jax.Array, k: int, *,
                      axis: str = "model") -> Tuple[jax.Array, jax.Array]:
    """Distributed IVF list scan: posting lists sharded by partition over
    ``axis``; every shard scans the selected lists it owns (others are
    masked), then k-wide merge.

    sel (B, nprobe) *global* partition ids (from the replicated-centroid
    selection step).
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    p = list_vecs.shape[0]

    def local(lv, li, q, s):
        p_local = lv.shape[0]
        shard = jax.lax.axis_index(axis)
        lo = shard * p_local
        s_local = s - lo
        own = (s_local >= 0) & (s_local < p_local)           # (B, np)
        s_safe = jnp.clip(s_local, 0, p_local - 1)
        lvs = lv[s_safe]                                      # (B,np,L,d)
        lis = jnp.where(own[..., None], li[s_safe], -1)       # mask foreign
        scores = jnp.einsum("bd,bnld->bnl", q, lvs)
        scores = jnp.where(lis >= 0, scores, -jnp.inf)
        b = q.shape[0]
        flat_v = scores.reshape(b, -1)
        flat_i = lis.reshape(b, -1)
        v, pos = jax.lax.top_k(flat_v, k)
        ids = jnp.take_along_axis(flat_i, pos, axis=-1)
        return distributed_topk(v, ids, k, axis)

    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None),
                  P(data_axes, None), P(data_axes, None)),
        out_specs=(P(data_axes, None), P(data_axes, None)),
        check_vma=False,   # result IS replicated over `axis` post-merge
    )
    return fn(list_vecs, list_ids, queries, sel)
