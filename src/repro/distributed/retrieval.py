"""Device-sharded TopLoc retrieval over a corpus mesh.

The paper's single-device corpus caps scale; this module partitions it
over a ``jax`` mesh (DESIGN.md §2 'Distribution'):

  * **IVF / IVF-PQ** — posting lists (float vectors or PQ codes) are
    sharded *by partition* over the ``model`` axis
    (``sharding.ivf_index_specs`` / ``ivf_pq_index_specs``); coarse
    centroids and PQ codebooks stay replicated.  Each shard ADC/float-
    scans the selection with only its owned lists unmasked, reduces to
    a local top-k, and one k-wide all-gather + ordered merge yields the
    global result — collective payload O(k·shards), independent of
    corpus size.
  * **HNSW** — the vector corpus is sharded *by document row*
    (``hnsw_index_specs``); the (integer) adjacency is replicated so the
    beam traversal itself stays local, and candidate scoring is
    owner-computes + ``psum`` (exactly one shard contributes a non-zero
    dot per candidate, so the sum is exact).
  * The IVF-PQ exact re-rank corpus is doc-row sharded the same way.

TopLoc session state (centroid cache, Eq. 1 drift proxy, refresh gate,
privileged entry point) stays **replicated**: the cheap per-turn
selection math runs identically on every device, only the corpus-sized
scans are distributed.

What sharding buys — and what it doesn't, yet: each device *stores*
only 1/S of the posting lists / code lists / vector corpus (the memory
term that caps single-device corpus size), and each real distance is
*owned* by exactly one shard (the per-device ``real``/``code_d`` work
counters shrink ~linearly — what a sparse scheduler would pay).  The
dense SPMD formulation itself, however, still gathers and multiplies
the full ``(B, nprobe, Lmax, d)`` selection on every shard with foreign
probes clipped-and-masked — per-device FLOPs of one scan dispatch are
not reduced, because skipping foreign probes needs data-dependent
shapes XLA cannot express.  Routing each probe to its owner shard
host-side (variable per-shard probe counts, padded to a static bound)
is the follow-up that converts the owned-work counters into dense
per-device FLOP savings.

Bit-identity contract: for all three backends, sharded results — scores,
ids, every ``TurnStats`` counter — equal the single-device path bit for
bit at any shard count.  Three mechanisms make this hold:

  1. per-candidate arithmetic is shaped exactly like the single-device
     scan (same gather → same einsum/multiply-reduce shapes), so each
     owned candidate's score has the same reduction order;
  2. cross-shard float merges either move *selected candidates* (never
     partial sums) or ``psum`` a single non-zero against exact zeros;
  3. top-k merges use ``core.topk.distributed_topk_ordered``, which
     breaks score ties by global flat candidate position — the same
     tie-break a single-device ``lax.top_k`` applies.

The scan callables below are frozen dataclasses (hashable on the mesh)
so they can ride through ``jax.jit`` as static arguments — they plug
into the ``scan=`` / ``search=`` hooks of ``core.toploc`` and the
serving engines' ``ServingConfig.shards`` knob.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import hnsw as _hnsw
from repro.core import ivf as _ivf
from repro.core import pq as _pq
from repro.core.topk import distributed_topk_ordered
from repro.distributed import sharding as SH
from repro.kernels import ops as _kops


# ---------------------------------------------------------------------------
# mesh + index placement
# ---------------------------------------------------------------------------

def retrieval_mesh(shards: int, *, axis: str = "model",
                   replicas: int = 1,
                   replica_axis: str = "replica") -> Mesh:
    """A corpus mesh over the first ``replicas * shards`` local devices.

    ``replicas == 1`` (the default) keeps the historical 1-D ``(axis,)``
    mesh.  ``replicas > 1`` builds the 2-D ``(replica_axis, axis)`` mesh
    of the serving tier: every replica group holds a **full sharded
    corpus** — all index placement specs name only ``axis``, so
    corpus-sharded arrays auto-replicate along ``replica_axis`` and the
    existing shard_map scan plugins run unchanged on either mesh shape.
    The replica axis is consumed host-side by the
    ``serving.router.ReplicatedSearchEngine``, which slices the mesh
    into per-replica 1-D submeshes (``replica_submeshes``) so each
    replica engine owns a disjoint device group.
    """
    devs = jax.devices()
    if shards < 1 or replicas < 1 or replicas * shards > len(devs):
        raise ValueError(
            f"replicas={replicas} x shards={shards} needs "
            f"{max(replicas, 1) * max(shards, 1)} device(s) but "
            f"{len(devs)} available "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "for a host-platform mesh)")
    if replicas == 1:
        return Mesh(np.asarray(devs[:shards]), (axis,))
    grid = np.asarray(devs[:replicas * shards]).reshape(replicas, shards)
    return Mesh(grid, (replica_axis, axis))


def replica_submeshes(mesh: Mesh, *,
                      replica_axis: str = "replica") -> list:
    """Split a 2-D ``(replica, shard)`` mesh into per-replica 1-D corpus
    meshes (one entry per replica group, disjoint devices, shard axis
    name preserved).  A mesh without ``replica_axis`` is already a
    single replica group and is returned as ``[mesh]``.
    """
    if replica_axis not in mesh.axis_names:
        return [mesh]
    ri = mesh.axis_names.index(replica_axis)
    rest = tuple(a for a in mesh.axis_names if a != replica_axis)
    return [Mesh(np.take(mesh.devices, r, axis=ri), rest)
            for r in range(mesh.shape[replica_axis])]


def _pad_dim0(x: jax.Array, mult: int, value) -> jax.Array:
    """Pad dim 0 to a multiple of ``mult`` (shardable row count)."""
    pad = (-x.shape[0]) % mult
    if not pad:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=value)


def _put(mesh: Mesh, x: jax.Array, spec: P) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, spec))


def shard_ivf_index(mesh: Mesh, index: _ivf.IVFIndex, *,
                    axis: str = "model") -> _ivf.IVFIndex:
    """Place an ``IVFIndex`` on the mesh per ``sharding.ivf_index_specs``.

    Posting-list arrays are padded with empty partitions (sizes 0, ids
    -1) up to a multiple of the shard count; padded partitions are never
    selected (centroids are *not* padded, so ``top_k`` over the p real
    centroids cannot reach them) and contribute no work.
    """
    s = mesh.shape[axis]
    specs = SH.ivf_index_specs(SH.Axes(model=axis))
    return _ivf.IVFIndex(
        centroids=_put(mesh, index.centroids, specs.centroids),
        list_vecs=_put(mesh, _pad_dim0(index.list_vecs, s, 0.0),
                       specs.list_vecs),
        list_ids=_put(mesh, _pad_dim0(index.list_ids, s, -1),
                      specs.list_ids),
        list_sizes=_put(mesh, _pad_dim0(index.list_sizes, s, 0),
                        specs.list_sizes),
    )


def shard_ivf_pq_index(mesh: Mesh, index: _pq.IVFPQIndex, *,
                       axis: str = "model") -> _pq.IVFPQIndex:
    """Place an ``IVFPQIndex`` on the mesh per ``ivf_pq_index_specs``.

    Code lists pad like the float lists; the re-rank corpus pads with
    zero rows (only ever gathered through real candidate ids).
    """
    s = mesh.shape[axis]
    specs = SH.ivf_pq_index_specs(SH.Axes(model=axis))
    return _pq.IVFPQIndex(
        centroids=_put(mesh, index.centroids, specs.centroids),
        codewords=_put(mesh, index.codewords, specs.codewords),
        list_codes=_put(mesh, _pad_dim0(index.list_codes, s, 0),
                        specs.list_codes),
        list_ids=_put(mesh, _pad_dim0(index.list_ids, s, -1),
                      specs.list_ids),
        list_sizes=_put(mesh, _pad_dim0(index.list_sizes, s, 0),
                        specs.list_sizes),
        doc_vecs=_put(mesh, _pad_dim0(index.doc_vecs, s, 0.0),
                      specs.doc_vecs),
    )


def shard_hnsw_index(mesh: Mesh, index: _hnsw.HNSWIndex, *,
                     axis: str = "model") -> _hnsw.HNSWIndex:
    """Place an ``HNSWIndex`` on the mesh per ``hnsw_index_specs``.

    Vector rows pad with zeros (adjacency only references real nodes,
    so padded rows are unreachable); adjacency stays replicated.
    """
    s = mesh.shape[axis]
    specs = SH.hnsw_index_specs(SH.Axes(model=axis))
    return _hnsw.HNSWIndex(
        vectors=_put(mesh, _pad_dim0(index.vectors, s, 0.0),
                     specs.vectors),
        adj0=_put(mesh, index.adj0, specs.adj0),
        upper_adj=_put(mesh, index.upper_adj, specs.upper_adj),
        entry_point=_put(mesh, index.entry_point, specs.entry_point),
        node_level=_put(mesh, _pad_dim0(index.node_level, s, 0),
                        specs.node_level),
        # tombstones replicate (gathered per candidate id, like adjacency)
        deleted=(None if index.deleted is None
                 else _put(mesh, index.deleted, P(None))),
    )


def place_segmented(mesh: Mesh, seg, *, axis: str = "model"):
    """Replicate a ``core.segment.SegmentedIndex``'s mutable arrays
    (delta buffer + tombstone mask) over the mesh.

    The delta segment is scanned *exactly* on every device — it is tiny
    (``cap`` rows) and its scan must merge with the already-replicated
    base top-k, so replication is the right placement; sharding it would
    add a collective for O(cap) work.  The wrapped base index inside
    ``seg.base`` is placed separately by ``shard_backend`` before
    wrapping.
    """
    return seg._replace(
        delta_vecs=_put(mesh, seg.delta_vecs, P(None, None)),
        delta_ids=_put(mesh, seg.delta_ids, P(None)),
        tombstone=_put(mesh, seg.tombstone, P(None)))


# ---------------------------------------------------------------------------
# sharded scan callables (static-arg plugins for core.toploc / engines)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedIVFScan:
    """Drop-in for ``ivf._scan_lists`` over partition-sharded lists.

    Each shard gathers the selected lists it owns (foreign probes are
    clipped to a valid local row and id-masked to -1, so their scores
    never merge), scans them with the exact single-device einsum shape,
    reduces to a local top-k, and the ordered k-wide merge produces the
    global top-k.  ``real`` work counters psum exactly (int32).

    ``fused`` (a ``toploc.FusedTurn``) routes the local gather + scan +
    top-k through the single-dispatch fused kernel; its flat scan
    positions use the same selection-relative numbering as the dense
    path, so the ordered merge — and with it f32 bit-identity to the
    single-device scan — is preserved.
    """
    mesh: Mesh
    axis: str = "model"
    fused: Optional[object] = None

    def __call__(self, index: _ivf.IVFIndex, queries: jax.Array,
                 sel: jax.Array, k: int
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        axis = self.axis
        fused = self.fused

        def local(lv, li, ls, q, s):
            p_local = lv.shape[0]
            lo = jax.lax.axis_index(axis) * p_local
            s_local = s - lo
            own = (s_local >= 0) & (s_local < p_local)       # (B, np)
            ss = jnp.clip(s_local, 0, p_local - 1)
            b = q.shape[0]
            if fused is not None:
                v, ids, pos = _kops.fused_scan(
                    q, lv, li, ss, k, own=own.astype(jnp.int32),
                    over=fused.over, precision=fused.precision,
                    mode=fused.mode)
            else:
                lvs = lv[ss]                                  # (B,np,L,d)
                lis = jnp.where(own[..., None], li[ss], -1)
                scores = jnp.einsum("bd,bnld->bnl", q, lvs)
                flat_v = jnp.where(lis >= 0, scores,
                                   -jnp.inf).reshape(b, -1)
                flat_i = lis.reshape(b, -1)
                v, pos = jax.lax.top_k(flat_v, k)
                ids = jnp.take_along_axis(flat_i, pos, axis=-1)
            top_v, top_i = distributed_topk_ordered(v, pos, ids, k, axis)
            real = jax.lax.psum(
                jnp.sum(jnp.where(own, ls[ss], 0), axis=-1), axis)
            return top_v, top_i, real.astype(jnp.int32)

        fn = compat.shard_map(
            local, mesh=self.mesh,
            in_specs=(P(axis, None, None), P(axis, None), P(axis),
                      P(None, None), P(None, None)),
            out_specs=(P(None, None), P(None, None), P(None)),
            check_vma=False)
        return fn(index.list_vecs, index.list_ids, index.list_sizes,
                  queries, sel)


@dataclasses.dataclass(frozen=True)
class ShardedPQScan:
    """Drop-in for ``toploc._scan_lists_pq`` over a sharded PQ corpus.

    ADC lookup tables build replicated (tiny); each shard ADC-scans its
    owned code lists with the ``pq.adc_scores_masked`` formulation (bit-
    identical to the single-device reference scan), local top-R merges
    ordered into the global ADC candidate list, and the exact re-rank is
    owner-computes + psum over the doc-row-sharded float corpus.

    ``fused`` routes the local ADC scan + top-R through the fused kernel
    (``fuse_rerank=False`` — the exact re-rank must stay in the
    owner-computes psum, as candidate doc rows live on other shards);
    flat positions share the dense path's numbering so the ordered
    candidate merge is unchanged.
    """
    mesh: Mesh
    axis: str = "model"
    fused: Optional[object] = None

    def __call__(self, index: _pq.IVFPQIndex, queries: jax.Array,
                 sel: jax.Array, k: int, rerank: int
                 ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        from repro.core import toploc as _toploc
        axis = self.axis
        fused = self.fused
        nprobe = sel.shape[1]
        r = max(k, min(rerank, nprobe * index.lmax))
        tables = _toploc._adc_tables(index, queries)          # replicated

        def local(lc, li, ls, dv, tab, q, s):
            p_local = lc.shape[0]
            shard = jax.lax.axis_index(axis)
            lo = shard * p_local
            s_local = s - lo
            own = (s_local >= 0) & (s_local < p_local)
            ss = jnp.clip(s_local, 0, p_local - 1)
            b = q.shape[0]
            if fused is not None:
                cv, cids, cpos = _kops.fused_scan_pq(
                    tab, q, lc, li, ss, dv, k, rerank=rerank,
                    own=own.astype(jnp.int32), precision=fused.precision,
                    fuse_rerank=False, mode=fused.mode)
            else:
                codes = lc[ss].astype(jnp.int32)              # (B,np,L,m)
                ids = jnp.where(own[..., None], li[ss], -1)
                flat_c = codes.reshape(b, -1, codes.shape[-1])
                flat_i = ids.reshape(b, -1)
                scores = _pq.adc_scores_masked(tab, flat_c, flat_i)
                cv, cpos = jax.lax.top_k(scores, r)
                cids = jnp.take_along_axis(flat_i, cpos, axis=-1)
            cand_v, cand_ids = distributed_topk_ordered(cv, cpos, cids,
                                                        r, axis)
            # exact re-rank: owner computes the single-device multiply-
            # reduce, foreign shards contribute exact zeros to the psum
            n_local = dv.shape[0]
            d_local = cand_ids - shard * n_local
            own_doc = (d_local >= 0) & (d_local < n_local) & (cand_ids >= 0)
            rows = dv[jnp.clip(d_local, 0, n_local - 1)]      # (B, r, d)
            ex = jnp.sum(rows * q[:, None, :], axis=-1)
            exact = jax.lax.psum(jnp.where(own_doc, ex, 0.0), axis)
            exact = jnp.where(cand_ids >= 0, exact, -jnp.inf)
            top_v, pos = jax.lax.top_k(exact, k)
            top_i = jnp.take_along_axis(cand_ids, pos, axis=-1)
            code_d = jax.lax.psum(
                jnp.sum(jnp.where(own, ls[ss], 0), axis=-1), axis)
            rerank_d = jnp.sum((cand_ids >= 0), axis=-1)
            return (top_v, top_i, code_d.astype(jnp.int32),
                    rerank_d.astype(jnp.int32))

        fn = compat.shard_map(
            local, mesh=self.mesh,
            in_specs=(P(axis, None, None), P(axis, None), P(axis),
                      P(axis, None), P(None, None, None), P(None, None),
                      P(None, None)),
            out_specs=(P(None, None), P(None, None), P(None), P(None)),
            check_vma=False)
        return fn(index.list_codes, index.list_ids, index.list_sizes,
                  index.doc_vecs, tables, queries, sel)


@dataclasses.dataclass(frozen=True)
class ShardedHNSWSearch:
    """Drop-in for ``hnsw.search`` over a doc-row-sharded vector corpus.

    The traversal (``hnsw._search_impl``) runs replicated inside
    ``shard_map`` — every shard walks the identical beam because every
    score it branches on is the exact psum-merged dot — while each
    candidate's vector row is read from exactly one shard.
    """
    mesh: Mesh
    axis: str = "model"

    def __call__(self, index: _hnsw.HNSWIndex, queries: jax.Array, *,
                 ef: int, k: int,
                 entry_override: Optional[jax.Array] = None,
                 use_entry_override: bool = False
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        axis = self.axis
        n_pad = index.vectors.shape[0]
        top_level = index.top_level
        if entry_override is None:
            entry_override = jnp.zeros((queries.shape[0],), jnp.int32)

        def local(vec_l, adj0, upper, entry_pt, q, override, *dead):
            n_local = vec_l.shape[0]
            lo = jax.lax.axis_index(axis) * n_local

            def factory(qrow):
                def dots_at(ids):
                    loc = ids - lo
                    own = (loc >= 0) & (loc < n_local)
                    rows = vec_l[jnp.clip(loc, 0, n_local - 1)]
                    s = jnp.where(own, _hnsw._dots(rows, qrow), 0.0)
                    return jax.lax.psum(s, axis)
                return dots_at

            return _hnsw._search_impl(
                factory, n_pad, top_level, adj0, upper, entry_pt, q,
                override, ef=ef, k=k,
                use_entry_override=use_entry_override,
                deleted=dead[0] if dead else None)

        # the tombstone mask rides along (replicated) only when present,
        # keeping the no-deletions program byte-identical to before
        in_specs = (P(axis, None), P(None, None), P(None, None, None),
                    P(), P(None, None), P(None))
        operands = [index.vectors, index.adj0, index.upper_adj,
                    index.entry_point, queries, entry_override]
        if index.deleted is not None:
            in_specs = in_specs + (P(None),)
            operands.append(index.deleted)
        fn = compat.shard_map(
            local, mesh=self.mesh,
            in_specs=in_specs,
            out_specs=(P(None, None), P(None, None), P(None)),
            check_vma=False)
        return fn(*operands)


# ---------------------------------------------------------------------------
# backend-registry wiring
#
# A data-driven table — not string branching — maps each registered
# ``core.backend`` name to (index placement fn, scan plugin class, the
# backend dataclass field the plugin plugs into).  ``shard_backend`` is
# the one call the serving engines make; a new backend becomes sharded
# by one ``register_sharding`` call, with zero engine edits.
# ---------------------------------------------------------------------------

_SHARDING_REGISTRY: dict = {}


def register_sharding(name: str, shard_index, plugin_cls,
                      field: str = "scan") -> None:
    """Teach ``shard_backend`` how to place backend ``name`` on a mesh."""
    _SHARDING_REGISTRY[name] = (shard_index, plugin_cls, field)


register_sharding("ivf", shard_ivf_index, ShardedIVFScan, "scan")
register_sharding("ivf_pq", shard_ivf_pq_index, ShardedPQScan, "scan")
register_sharding("hnsw", shard_hnsw_index, ShardedHNSWSearch, "search")


def shard_backend(mesh: Mesh, backend, index, *, axis: str = "model"):
    """Place ``index`` on ``mesh`` and plug the matching sharded scan
    into ``backend``.  Backends with no registered sharding (e.g. the
    stateless exact backend) pass through unchanged.

    Returns (backend', index').
    """
    entry = _SHARDING_REGISTRY.get(backend.name)
    if entry is None:
        return backend, index
    shard_index, plugin_cls, field = entry
    plugin = plugin_cls(mesh, axis)
    fused = getattr(backend, "fused", None)
    if fused is not None and any(f.name == "fused"
                                 for f in dataclasses.fields(plugin)):
        plugin = dataclasses.replace(plugin, fused=fused)
    return (dataclasses.replace(backend, **{field: plugin}),
            shard_index(mesh, index, axis=axis))


# ---------------------------------------------------------------------------
# diagnostics (benchmarks/fig4_sharded.py)
# ---------------------------------------------------------------------------

def per_shard_list_work(list_sizes: np.ndarray, sel: np.ndarray,
                        n_shards: int) -> np.ndarray:
    """Per-device posting-list scan work for a probe selection.

    ``list_sizes`` (p,) real list sizes; ``sel`` any shape of selected
    partition ids; shard s owns the contiguous partition block
    ``[s·⌈p/S⌉, (s+1)·⌈p/S⌉)`` — the same mapping the sharded scans use.
    Returns (S,) int64 — real float/code distances each device computes.
    """
    sizes = np.asarray(list_sizes)
    sel = np.asarray(sel).reshape(-1)
    p_local = -(-len(sizes) // n_shards)
    owner = sel // p_local
    work = np.zeros(n_shards, np.int64)
    for s in range(n_shards):
        work[s] = sizes[sel[owner == s]].sum()
    return work
