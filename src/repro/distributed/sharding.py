"""Per-architecture sharding rules (PartitionSpec pytrees).

Conventions (DESIGN.md §5):
  * ``tp``  — the ``model`` mesh axis: tensor-parallel dims (attention
    heads, FFN hidden, vocab, embedding-table rows, posting lists).
  * ``fsdp`` — the data axes (``('data',)`` single-pod,
    ``('pod','data')`` multi-pod): parameter/optimizer sharding; XLA
    inserts the per-layer all-gathers (which the layer scan overlaps).
  * batch always shards over the data axes.
  * decode KV caches shard the *sequence* dim over ``model`` — kv-head
    counts (8, 20, 4…) do not divide a 16-wide model axis, sequence
    always does, and XLA turns the softmax into a clean two-pass
    partial-reduce (ring-attention-lite).

Spec builders mirror each model's param pytree structure exactly; a
structural zip failure here fails loudly at dry-run time, not silently.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models import transformer as TF


@dataclasses.dataclass(frozen=True)
class Axes:
    """Logical → mesh axis binding.

    ``replica`` names the serving-tier replica axis of a 2-D
    ``(replica, shard)`` retrieval mesh (``None`` on 1-D meshes).  The
    index spec builders below deliberately never mention it: a
    ``PartitionSpec`` that names only ``model`` replicates the array
    along every other mesh axis, so each replica group automatically
    holds a full sharded corpus and the same specs serve both mesh
    shapes.
    """
    data: Tuple[str, ...] = ("data",)
    model: str = "model"
    replica: Optional[str] = None

    @property
    def dp(self):                  # batch / fsdp axes
        return self.data if len(self.data) > 1 else self.data[0]


def from_mesh(mesh) -> Axes:
    names = mesh.axis_names
    data = tuple(a for a in ("pod", "data") if a in names)
    model = "model" if "model" in names else names[-1]
    return Axes(data=data, model=model,
                replica="replica" if "replica" in names else None)


# ---------------------------------------------------------------------------
# LM transformer
# ---------------------------------------------------------------------------

def lm_param_specs(cfg: TF.LMConfig, ax: Axes) -> Dict[str, Any]:
    dp, tp = ax.dp, ax.model
    n = P(None)

    def attn_specs() -> Dict[str, Any]:
        if cfg.attn_kind == "mla":
            return {
                "wq": P(None, dp, tp),
                "w_dkv": P(None, dp, None),
                "w_krope": P(None, dp, None),
                "w_uk": P(None, None, tp),
                "w_uv": P(None, None, tp),
                "kv_norm": {"scale": P(None, None)},
                "wo": P(None, tp, dp),
            }
        s: Dict[str, Any] = {
            "wq": P(None, dp, tp),
            "wk": P(None, dp, tp),
            "wv": P(None, dp, tp),
            "wo": P(None, tp, dp),
        }
        if cfg.qkv_bias:
            s["bq"] = P(None, tp)
            s["bk"] = P(None, tp)
            s["bv"] = P(None, tp)
        if cfg.qk_norm:
            s["q_norm"] = {"scale": P(None, None)}
            s["k_norm"] = {"scale": P(None, None)}
        return s

    layer: Dict[str, Any] = {
        "norm_attn": {"scale": P(None, None)},
        "norm_mlp": {"scale": P(None, None)},
        "attn": attn_specs(),
    }
    if cfg.is_moe:
        layer["moe"] = {
            "router": P(None, dp, None),
            "w_gate": P(None, None, dp, tp),
            "w_up": P(None, None, dp, tp),
            "w_down": P(None, None, tp, dp),
        }
        if cfg.n_shared:
            layer["moe"]["shared"] = {
                "w_gate": P(None, dp, tp),
                "w_up": P(None, dp, tp),
                "w_down": P(None, tp, dp),
            }
    else:
        layer["mlp"] = {
            "w_gate": P(None, dp, tp),
            "w_up": P(None, dp, tp),
            "w_down": P(None, tp, dp),
        }

    return {
        "embed": P(tp, dp),
        "layers": layer,
        "final_norm": {"scale": n},
        "lm_head": P(dp, tp),
    }


def lm_batch_specs(ax: Axes) -> Dict[str, Any]:
    return {"tokens": P(ax.dp, None), "labels": P(ax.dp, None)}


def lm_cache_specs(cfg: TF.LMConfig, ax: Axes,
                   shard_batch: bool = True) -> Dict[str, Any]:
    """shard_batch=False: batch too small to split (e.g. long_500k B=1);
    the sequence dim still shards over the model axis."""
    dp, tp = (ax.dp if shard_batch else None), ax.model
    if cfg.attn_kind == "mla":
        return {"ckv": P(None, dp, tp, None),
                "krope": P(None, dp, tp, None)}
    # (L, B, Hkv, S, dh): sequence over tp
    return {"k": P(None, dp, None, tp, None),
            "v": P(None, dp, None, tp, None)}


def lm_opt_specs(opt_name: str, param_specs, param_structs=None) -> Any:
    """Optimizer-state specs mirror param specs (moments shard like
    weights).  Adafactor's factoring decision is SHAPE-based (both
    trailing dims ≥ 128 — optimizers.adafactor._factored), so the spec
    tree must consult ``param_structs`` to know which leaves carry
    factored (vr, vc) vs full (v) statistics."""
    if opt_name == "adamw":
        return {"m": param_specs, "v": param_specs,
                "step": P()}
    if opt_name == "sgd":
        return {"m": param_specs, "step": P()}
    if opt_name == "adafactor":
        assert param_structs is not None, \
            "adafactor specs need param shapes (pass param_structs)"

        def factored(spec, struct):
            if not isinstance(spec, P):
                spec = P()
            parts = tuple(spec)
            shape = struct.shape
            parts = parts + (None,) * (len(shape) - len(parts))
            if (len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128):
                return {"vr": P(*parts[:-1]),
                        "vc": P(*(parts[:-2] + parts[-1:]))}
            return {"v": P(*parts) if parts else P()}

        v = jax.tree.map(factored, param_specs, param_structs,
                         is_leaf=lambda x: isinstance(x, P) or x is None)
        return {"v": v, "step": P()}
    raise ValueError(opt_name)


# ---------------------------------------------------------------------------
# GIN
# ---------------------------------------------------------------------------

def gin_param_specs(params: Any) -> Any:
    """GIN params are tiny (≈100k): replicate everything."""
    return jax.tree.map(lambda _: P(), params)


def gin_batch_specs(ax: Axes, *, full_graph: bool, batched: bool = False
                    ) -> Dict[str, Any]:
    flat = ax.data + (ax.model,)
    if batched:                      # molecule: batch over everything
        return {"x": P(ax.dp), "edge_src": P(ax.dp), "edge_dst": P(ax.dp),
                "node_mask": P(ax.dp), "edge_mask": P(ax.dp),
                "labels": P(ax.dp)}
    if full_graph:                   # edges sharded over the whole mesh
        return {"x": P(), "edge_src": P(flat), "edge_dst": P(flat),
                "labels": P(), "train_mask": P(), "edge_mask": P(flat)}
    # sampled minibatch: node/edge sets sharded over data axes
    return {"x": P(ax.dp), "edge_src": P(ax.dp), "edge_dst": P(ax.dp),
            "labels": P(ax.dp), "seed_mask": P(ax.dp),
            "edge_mask": P(ax.dp)}


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

def _mlp_specs(n_layers: int, dp, tp, alt: bool = True) -> Dict[str, Any]:
    """Tower MLP: alternate hidden dim over tp (megatron 2-layer pattern)."""
    layers = []
    for i in range(n_layers):
        w = P(None, tp) if (i % 2 == 0 and alt) else P(tp, None)
        b = P(tp) if (i % 2 == 0 and alt) else P(None)
        layers.append({"w": w, "b": b})
    return {"layers": layers}


def two_tower_param_specs(cfg, ax: Axes) -> Any:
    dp, tp = ax.dp, ax.model
    nt = len(cfg.tower_mlp)
    return {
        "emb": {"table": P(tp, None)},
        "user_mlp": _mlp_specs(nt, dp, tp),
        "item_mlp": _mlp_specs(nt, dp, tp),
    }


def dcnv2_param_specs(cfg, ax: Axes) -> Any:
    dp, tp = ax.dp, ax.model
    return {
        "emb": {"table": P(tp, None)},
        # cross layers are (d_input, d_input) with d_input = 13 + 26·16 =
        # 429 — not divisible by the model axis and tiny (~184k params):
        # replicate them
        "cross": [{"w": P(None, None), "b": P(None)}
                  for _ in range(cfg.n_cross_layers)],
        "deep": _mlp_specs(len(cfg.mlp), dp, tp),
        "head": P(None, None),
    }


def bst_param_specs(cfg, ax: Axes) -> Any:
    dp, tp = ax.dp, ax.model
    blocks = [{
        "attn": {"wq": P(None, tp), "wk": P(None, tp), "wv": P(None, tp),
                 "wo": P(tp, None)},
        "norm1": {"scale": P(None), "bias": P(None)},
        "norm2": {"scale": P(None), "bias": P(None)},
        "ff": {"layers": [{"w": P(None, tp), "b": P(tp)},
                          {"w": P(tp, None), "b": P(None)}]},
    } for _ in range(cfg.n_blocks)]
    return {
        "emb": {"table": P(tp, None)},
        "other_emb": {"table": P(tp, None)},
        "pos": P(None, None),
        "blocks": blocks,
        "deep": _mlp_specs(len(cfg.mlp), dp, tp),
        "head": P(None, None),
    }


def autoint_param_specs(cfg, ax: Axes) -> Any:
    tp = ax.model
    layers = [{"wq": P(None, tp), "wk": P(None, tp), "wv": P(None, tp),
               "wres": P(None, tp)} for _ in range(cfg.n_attn_layers)]
    return {
        "emb": {"table": P(tp, None)},
        "attn": layers,
        "head": P(None, None),
    }


# ---------------------------------------------------------------------------
# bi-encoder (paper's Dragon/Snowflake)
# ---------------------------------------------------------------------------

def encoder_param_specs(cfg, ax: Axes) -> Any:
    dp, tp = ax.dp, ax.model
    tower = {
        "embed": P(tp, dp),
        "pos": P(None, None),
        "layers": {
            "attn": {"wq": P(None, dp, tp), "wk": P(None, dp, tp),
                     "wv": P(None, dp, tp), "wo": P(None, tp, dp)},
            "norm1": {"scale": P(None, None)},
            "norm2": {"scale": P(None, None)},
            "mlp": {"w_gate": P(None, dp, tp), "w_up": P(None, dp, tp),
                    "w_down": P(None, tp, dp)},
        },
        "final_norm": {"scale": P(None)},
        "proj": P(dp, None),
    }
    return {"query": tower, "doc": tower}


# ---------------------------------------------------------------------------
# retrieval index (core.ivf.IVFIndex as a distributed structure)
# ---------------------------------------------------------------------------

def ivf_index_specs(ax: Axes) -> Any:
    """Centroids replicated; posting lists sharded by partition over the
    model axis (each shard scans its own lists; top-k merge is one
    all-gather of k entries — core.topk.distributed_topk)."""
    tp = ax.model
    from repro.core.ivf import IVFIndex
    return IVFIndex(
        centroids=P(None, None),
        list_vecs=P(tp, None, None),
        list_ids=P(tp, None),
        list_sizes=P(tp),
    )


def ivf_pq_index_specs(ax: Axes) -> Any:
    """IVF-PQ corpus layout (DESIGN.md §2): coarse centroids and PQ
    codebooks replicated (tiny, read every turn); PQ code lists sharded
    by partition like the float lists; the exact-re-rank corpus sharded
    by *document* row so only 1/S of the uncompressed floats live on
    each device (owner computes the re-rank dot, psum merges)."""
    tp = ax.model
    from repro.core.pq import IVFPQIndex
    return IVFPQIndex(
        centroids=P(None, None),
        codewords=P(None, None, None),
        list_codes=P(tp, None, None),
        list_ids=P(tp, None),
        list_sizes=P(tp),
        doc_vecs=P(tp, None),
    )


def hnsw_index_specs(ax: Axes) -> Any:
    """HNSW: the vector corpus (the memory-heavy field, 4·d bytes/node)
    sharded by node row over the model axis; adjacency (ints, ~2M·4
    bytes/node) and entry metadata replicated so the beam traversal
    stays local — only candidate *scoring* is distributed (owner
    computes the dot, psum merges; distributed.retrieval)."""
    tp = ax.model
    from repro.core.hnsw import HNSWIndex
    return HNSWIndex(
        vectors=P(tp, None),
        adj0=P(None, None),
        upper_adj=P(None, None, None),
        entry_point=P(),
        node_level=P(None),
    )
