"""Fault tolerance: failure injection + restart-from-checkpoint driver.

At 1000+ nodes, *something* is always failing; the framework contract is
(a) checkpoints are never corrupted by a crash (atomic publish —
checkpoint/checkpoint.py), (b) a restarted job resumes bit-exactly, and
(c) restarts are bounded-cost (keep-last-k + async writes).  This module
provides the harness that proves (b): a failure injector that kills the
training loop at arbitrary steps and a supervisor that restarts it, used
by tests/test_fault_tolerance.py and launch/train.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List


class SimulatedFailure(RuntimeError):
    """Stands in for a node loss / preemption."""


@dataclasses.dataclass
class FailureInjector:
    """Raises SimulatedFailure when the step hits one of ``fail_at``
    (each trigger fires once — a restarted run passes the same step)."""
    fail_at: List[int]

    def __post_init__(self):
        self._pending = sorted(set(self.fail_at))

    def check(self, step: int) -> None:
        if self._pending and step == self._pending[0]:
            self._pending.pop(0)
            raise SimulatedFailure(f"injected failure at step {step}")


def run_with_restarts(run_fn: Callable[[bool], int], *,
                      max_restarts: int = 10) -> int:
    """Supervisor: call ``run_fn(resume)`` until it completes.

    ``run_fn`` must checkpoint its own progress and, when ``resume`` is
    True, continue from the latest checkpoint (launch/train.py does).
    Returns the final step. Raises after ``max_restarts`` genuine crashes
    — a crash-looping job should page an operator, not spin.
    """
    restarts = 0
    while True:
        try:
            return run_fn(restarts > 0)
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
