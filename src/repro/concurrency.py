"""Concurrency annotations: declared lock→field guard maps.

The serving layer is genuinely multi-threaded (client submitters,
replica pump threads, hedge pools), and its correctness argument —
per-session bit-identity under any schedule — rests on a small set of
locking conventions.  This module makes those conventions *declared*
instead of implied, so both halves of the concurrency sanitizer can
check them:

  * ``repro.analysis.guarded_fields`` (static, GF8xx) reads the
    ``@guarded_by`` decorators from the AST and flags any access to a
    guarded field that is not dominated by a ``with self.<lock>:``
    block (or a ``@holds`` declaration);
  * ``repro.analysis.tsan`` (dynamic) reads ``__guarded_fields__`` off
    the live class and checks, at runtime, that every access to a
    guarded field of a *shared* object happens while the owning lock is
    held — on top of its vector-clock race detection.

The decorators are deliberately inert at runtime: they only attach
metadata (``__guarded_fields__`` on classes, ``__holds_locks__`` on
methods) and never wrap calls, so annotated classes pay zero overhead
in production.

Usage::

    @guarded_by("_lock", "_queue", "batch_sizes")
    @guarded_by("_drain_lock", "_inflight")
    class MicroBatcher:
        def __init__(self):
            self._lock = threading.Lock()
            ...

        @holds("_drain_lock")
        def _retire_oldest_locked(self):   # caller holds _drain_lock
            ...

A ``threading.Condition`` built on a declared lock counts as that lock:
``with self._work:`` (where ``self._work = Condition(self._lock)``)
dominates fields guarded by ``"_lock"`` — both analyses resolve the
alias.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple, Type, TypeVar

_C = TypeVar("_C")
_F = TypeVar("_F", bound=Callable)

#: class attribute holding the declared field → lock-attr mapping
GUARD_ATTR = "__guarded_fields__"
#: function attribute naming locks the *caller* is required to hold
HOLDS_ATTR = "__holds_locks__"


def guarded_by(lock: str, *fields: str) -> Callable[[Type[_C]], Type[_C]]:
    """Class decorator: declare ``fields`` as guarded by ``self.<lock>``.

    Stackable — each application merges into the class's
    ``__guarded_fields__`` dict (field name → lock attribute name).
    Subclasses inherit and may extend the parent's map.
    """
    if not fields:
        raise ValueError("guarded_by needs at least one field name")

    def deco(cls: Type[_C]) -> Type[_C]:
        # copy (never mutate) so a subclass's map doesn't leak upward
        mapping: Dict[str, str] = dict(getattr(cls, GUARD_ATTR, {}))
        for f in fields:
            prev = mapping.get(f)
            if prev is not None and prev != lock:
                raise ValueError(
                    f"field {f!r} already guarded by {prev!r}; cannot "
                    f"re-guard with {lock!r}")
            mapping[f] = lock
        setattr(cls, GUARD_ATTR, mapping)
        return cls
    return deco


def holds(*locks: str) -> Callable[[_F], _F]:
    """Method decorator: the *caller* is contractually holding
    ``self.<lock>`` for each named lock when this method runs (the
    ``_locked``-suffix convention, made machine-readable).  The static
    pass treats the whole body as dominated by those locks; the dynamic
    checker verifies they really are held on entry.
    """
    if not locks:
        raise ValueError("holds needs at least one lock name")

    def deco(fn: _F) -> _F:
        held: Tuple[str, ...] = tuple(getattr(fn, HOLDS_ATTR, ()))
        setattr(fn, HOLDS_ATTR, held + tuple(locks))
        return fn
    return deco


def guard_map(cls: type) -> Dict[str, str]:
    """The declared field → lock-attribute map of ``cls`` ({} if none)."""
    return dict(getattr(cls, GUARD_ATTR, {}))
