"""RecSys architectures: two-tower retrieval, DCN-v2, BST, AutoInt.

Shared substrate: a concatenated sparse-feature embedding table (one
(Σvocab, dim) tensor + per-field offsets) row-sharded over the ``model``
mesh axis, looked up with plain gathers (single-valent fields) or the
fused ``ops.embedding_bag`` (multi-hot bags / user history).  JAX has no
native EmbeddingBag — this module IS that substrate (taxonomy §B.6).

The two-tower arch is where the paper's technique plugs in: its
``retrieval_cand`` serving shape (1 query vs 10⁶ candidates) is exactly
the ANN problem TopLoc accelerates — ``retrieval_topk`` exposes brute
force, and serving/engine.py swaps in TopLoc_IVF over the item corpus.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sparse embedding substrate
# ---------------------------------------------------------------------------

def field_offsets(vocab_sizes: Sequence[int]) -> Tuple[int, ...]:
    """Static per-field row offsets into the concatenated table.

    A plain python tuple (NOT a param-tree leaf): offsets are integers and
    must stay out of the differentiable param pytree — jax.grad rejects
    int-dtype inputs."""
    out, acc = [], 0
    for v in vocab_sizes:
        out.append(acc)
        acc += int(v)
    return tuple(out)


def embed_table_init(key, vocab_sizes: Sequence[int], dim: int,
                     dtype=jnp.float32) -> Params:
    total = int(sum(vocab_sizes))
    scale = dim ** -0.5
    table = (jax.random.normal(key, (total, dim), jnp.float32) * scale
             ).astype(dtype)
    return {"table": table}


def embed_fields(emb: Params, offsets: Sequence[int],
                 ids: jax.Array) -> jax.Array:
    """Single-valent lookup: ids (B, F) per-field → (B, F, dim)."""
    flat = ids + jnp.asarray(offsets, jnp.int32)[None, :]
    return jnp.take(emb["table"], flat, axis=0)


def embed_bag(emb: Params, offset: int, ids: jax.Array,
              agg: str = "mean") -> jax.Array:
    """Multi-hot bag for one field: ids (B, L) (-1 pad) → (B, dim)."""
    shifted = jnp.where(ids >= 0, ids + offset, -1)
    return ops.embedding_bag(emb["table"], shifted, agg=agg)


# ---------------------------------------------------------------------------
# two-tower retrieval (Yi et al., RecSys'19)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    user_vocab: int = 1_000_000
    item_vocab: int = 2_097_152
    history_len: int = 50
    temperature: float = 0.05
    dtype: Any = jnp.float32

    def param_count(self) -> int:
        e = self.embed_dim
        emb = (self.user_vocab + self.item_vocab) * e
        def tower(d_in):
            n, dims = 0, (d_in,) + self.tower_mlp
            for a, b in zip(dims[:-1], dims[1:]):
                n += a * b + b
            return n
        return emb + tower(2 * e) + tower(e)


def two_tower_init(cfg: TwoTowerConfig, key) -> Params:
    ks = jax.random.split(key, 3)
    e = cfg.embed_dim
    return {
        "emb": embed_table_init(ks[0], (cfg.user_vocab, cfg.item_vocab), e,
                                cfg.dtype),
        "user_mlp": L.mlp_init(ks[1], (2 * e,) + cfg.tower_mlp, cfg.dtype),
        "item_mlp": L.mlp_init(ks[2], (e,) + cfg.tower_mlp, cfg.dtype),
    }


def user_tower(params: Params, cfg: TwoTowerConfig, user_id: jax.Array,
               history: jax.Array) -> jax.Array:
    """user_id (B,), history (B, L) item ids (-1 pad) → (B, out)."""
    offs = field_offsets((cfg.user_vocab, cfg.item_vocab))
    ue = embed_fields(params["emb"], offs[:1], user_id[:, None])[:, 0]
    he = embed_bag(params["emb"], offs[1], history, agg="mean")
    x = jnp.concatenate([ue, he], -1)
    out = L.mlp_apply(params["user_mlp"], x)
    return out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True),
                             1e-6)


def item_tower(params: Params, cfg: TwoTowerConfig,
               item_id: jax.Array) -> jax.Array:
    offs = field_offsets((cfg.user_vocab, cfg.item_vocab))
    ie = embed_fields(params["emb"], offs[1:], item_id[:, None])[:, 0]
    out = L.mlp_apply(params["item_mlp"], ie)
    return out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True),
                             1e-6)


def two_tower_loss(params: Params, cfg: TwoTowerConfig, batch: Params
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """In-batch sampled softmax (every other row is a negative)."""
    u = user_tower(params, cfg, batch["user_id"], batch["history"])
    i = item_tower(params, cfg, batch["item_id"])
    logits = (u @ i.T) / cfg.temperature
    labels = jnp.arange(u.shape[0])
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[..., 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"acc": acc}


def retrieval_topk(user_vec: jax.Array, item_corpus: jax.Array, k: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Brute-force candidate scoring: (B, e) x (N, e) → top-k.

    The TopLoc-accelerated path replaces this with core.ivf search over a
    clustered item corpus (see serving/engine.py and benchmarks).
    """
    scores = user_vec @ item_corpus.T
    return jax.lax.top_k(scores, k)


# ---------------------------------------------------------------------------
# DCN-v2 (arXiv:2008.13535)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DCNv2Config:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: Tuple[int, ...] = (1024, 1024, 512)
    vocab_sizes: Tuple[int, ...] = ()   # len == n_sparse
    dtype: Any = jnp.float32

    @property
    def d_input(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim

    def param_count(self) -> int:
        d = self.d_input
        emb = sum(self.vocab_sizes) * self.embed_dim
        cross = self.n_cross_layers * (d * d + d)
        deep, dims = 0, (d,) + self.mlp
        for a, b in zip(dims[:-1], dims[1:]):
            deep += a * b + b
        return emb + cross + deep + (d + self.mlp[-1]) + 1


def dcnv2_init(cfg: DCNv2Config, key) -> Params:
    ks = jax.random.split(key, 4 + cfg.n_cross_layers)
    d = cfg.d_input
    cross = [{"w": L.dense_init(ks[i], d, d, cfg.dtype),
              "b": jnp.zeros((d,), cfg.dtype)}
             for i in range(cfg.n_cross_layers)]
    return {
        "emb": embed_table_init(ks[-3], cfg.vocab_sizes, cfg.embed_dim,
                                cfg.dtype),
        "cross": cross,
        "deep": L.mlp_init(ks[-2], (d,) + cfg.mlp, cfg.dtype),
        "head": L.dense_init(ks[-1], d + cfg.mlp[-1], 1, cfg.dtype),
    }


def dcnv2_forward(params: Params, cfg: DCNv2Config, dense: jax.Array,
                  sparse_ids: jax.Array) -> jax.Array:
    """dense (B, 13) f32, sparse_ids (B, 26) int32 → logit (B,)."""
    se = embed_fields(params["emb"], field_offsets(cfg.vocab_sizes),
                      sparse_ids)                      # (B, 26, e)
    x0 = jnp.concatenate(
        [dense.astype(cfg.dtype), se.reshape(se.shape[0], -1)], -1)
    x = x0
    for cp in params["cross"]:                         # x ← x0 ⊙ (Wx+b) + x
        x = x0 * (x @ cp["w"] + cp["b"]) + x
    deep = L.mlp_apply(params["deep"], x0, final_act=True)
    both = jnp.concatenate([x, deep], -1)
    return (both @ params["head"])[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# BST — Behaviour Sequence Transformer (arXiv:1905.06874)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    mlp: Tuple[int, ...] = (1024, 512, 256)
    item_vocab: int = 5_000_000
    n_other: int = 8                 # other categorical profile features
    other_vocab: int = 100_000
    dtype: Any = jnp.float32

    def param_count(self) -> int:
        e = self.embed_dim
        emb = self.item_vocab * e + self.n_other * self.other_vocab * e
        attn = self.n_blocks * (4 * e * e + 2 * e * 4 * e + 4 * e)
        d_in = (self.seq_len + 1) * e + self.n_other * e
        deep, dims = 0, (d_in,) + self.mlp
        for a, b in zip(dims[:-1], dims[1:]):
            deep += a * b + b
        return emb + attn + deep + self.mlp[-1] + 1


def bst_init(cfg: BSTConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    e = cfg.embed_dim
    blocks = []
    for k in jax.random.split(ks[0], cfg.n_blocks):
        k1, k2 = jax.random.split(k)
        blocks.append({
            "attn": L.attn_init(k1, L.AttnConfig(e, cfg.n_heads,
                                                 cfg.n_heads,
                                                 e // cfg.n_heads,
                                                 causal=False), cfg.dtype),
            "norm1": L.layernorm_init(e, cfg.dtype),
            "norm2": L.layernorm_init(e, cfg.dtype),
            "ff": L.mlp_init(k2, (e, 4 * e, e), cfg.dtype),
        })
    d_in = (cfg.seq_len + 1) * e + cfg.n_other * e
    return {
        "emb": embed_table_init(ks[1], (cfg.item_vocab,), e, cfg.dtype),
        "other_emb": embed_table_init(
            ks[2], (cfg.other_vocab,) * cfg.n_other, e, cfg.dtype),
        "pos": (jax.random.normal(ks[3], (cfg.seq_len + 1, e), jnp.float32)
                * 0.02).astype(cfg.dtype),
        "blocks": blocks,
        "deep": L.mlp_init(ks[4], (d_in,) + cfg.mlp, cfg.dtype),
        "head": L.dense_init(ks[5], cfg.mlp[-1], 1, cfg.dtype),
    }


def bst_forward(params: Params, cfg: BSTConfig, history: jax.Array,
                target: jax.Array, other_ids: jax.Array) -> jax.Array:
    """history (B, seq), target (B,), other_ids (B, n_other) → logit (B,)."""
    b = history.shape[0]
    seq_ids = jnp.concatenate([history, target[:, None]], 1)   # (B, S+1)
    x = embed_fields(params["emb"], (0,),
                     seq_ids.reshape(b * (cfg.seq_len + 1), 1)
                     ).reshape(b, cfg.seq_len + 1, cfg.embed_dim)
    x = x + params["pos"][None]
    acfg = L.AttnConfig(cfg.embed_dim, cfg.n_heads, cfg.n_heads,
                        cfg.embed_dim // cfg.n_heads, causal=False)
    for blk in params["blocks"]:
        h = L.attn_apply(blk["attn"], acfg, L.layernorm(blk["norm1"], x))
        x = x + h
        h = L.mlp_apply(blk["ff"], L.layernorm(blk["norm2"], x),
                        act=jax.nn.gelu)
        x = x + h
    oe = embed_fields(params["other_emb"],
                      field_offsets((cfg.other_vocab,) * cfg.n_other),
                      other_ids)                                # (B, F, e)
    feat = jnp.concatenate([x.reshape(b, -1), oe.reshape(b, -1)], -1)
    out = L.mlp_apply(params["deep"], feat, act=jax.nn.leaky_relu)
    return (out @ params["head"])[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# AutoInt (arXiv:1810.11921)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    vocab_sizes: Tuple[int, ...] = ()
    dtype: Any = jnp.float32

    def param_count(self) -> int:
        emb = sum(self.vocab_sizes) * self.embed_dim
        d0, da, h = self.embed_dim, self.d_attn, self.n_heads
        n, d_in = 0, d0
        for _ in range(self.n_attn_layers):
            n += d_in * da * h * 3 + d_in * da * h   # qkv + residual proj
            d_in = da * h
        return emb + n + self.n_sparse * d_in + 1


def autoint_init(cfg: AutoIntConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_attn_layers + 2)
    layers, d_in = [], cfg.embed_dim
    for i in range(cfg.n_attn_layers):
        k1, k2, k3, k4 = jax.random.split(ks[i], 4)
        d_out = cfg.d_attn * cfg.n_heads
        layers.append({
            "wq": L.dense_init(k1, d_in, d_out, cfg.dtype),
            "wk": L.dense_init(k2, d_in, d_out, cfg.dtype),
            "wv": L.dense_init(k3, d_in, d_out, cfg.dtype),
            "wres": L.dense_init(k4, d_in, d_out, cfg.dtype),
        })
        d_in = d_out
    return {
        "emb": embed_table_init(ks[-2], cfg.vocab_sizes, cfg.embed_dim,
                                cfg.dtype),
        "attn": layers,
        "head": L.dense_init(ks[-1], cfg.n_sparse * d_in, 1, cfg.dtype),
    }


def autoint_forward(params: Params, cfg: AutoIntConfig,
                    sparse_ids: jax.Array) -> jax.Array:
    """sparse_ids (B, 39) → logit (B,). Self-attention over fields."""
    x = embed_fields(params["emb"], field_offsets(cfg.vocab_sizes),
                      sparse_ids)                          # (B, F, e)
    b, f, _ = x.shape
    h, da = cfg.n_heads, cfg.d_attn
    for lp in params["attn"]:
        q = (x @ lp["wq"]).reshape(b, f, h, da).swapaxes(1, 2)
        k = (x @ lp["wk"]).reshape(b, f, h, da).swapaxes(1, 2)
        v = (x @ lp["wv"]).reshape(b, f, h, da).swapaxes(1, 2)
        logits = jnp.einsum("bhfd,bhgd->bhfg", q, k) / (da ** 0.5)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1
                               ).astype(x.dtype)
        o = jnp.einsum("bhfg,bhgd->bhfd", probs, v)
        o = o.swapaxes(1, 2).reshape(b, f, h * da)
        x = jax.nn.relu(o + x @ lp["wres"])
    return (x.reshape(b, -1) @ params["head"])[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# shared losses
# ---------------------------------------------------------------------------

def bce_loss(logits: jax.Array, labels: jax.Array
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Binary cross-entropy on click labels (CTR models)."""
    lf = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(lf, 0) - lf * y + jnp.log1p(jnp.exp(-jnp.abs(lf))))
    acc = jnp.mean((lf > 0) == (y > 0.5))
    return loss, {"acc": acc}
