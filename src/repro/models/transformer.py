"""Decoder-only LM covering all assigned transformer architectures.

One config dataclass spans the five LM archs: dense SwiGLU (qwen1.5 w/
QKV bias, qwen3 w/ qk-norm, yi) and MoE (grok-1 top-2, deepseek-v2-lite
MLA + shared/routed top-6).  Layer parameters are *stacked* on a leading
``layers`` axis and the forward pass is a ``jax.lax.scan`` — small HLO,
fast multi-pod compiles, and the FSDP all-gather of layer l overlaps
layer l−1's compute (DESIGN.md §7).

Entry points:
  init_params        — stacked pytree (vmapped per-layer init)
  forward            — logits for training (optionally remat per layer)
  loss_fn            — chunked cross-entropy (never materialises the full
                       (B,S,V) logits — V is 100k+ here)
  prefill / decode_step — serving path with per-layer KV (or MLA latent)
                       caches stacked on the layer axis
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # attention flavour
    attn_kind: str = "gqa"            # "gqa" | "mla"
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    logit_soft_cap: Optional[float] = None
    # MLA
    kv_lora_rank: int = 512
    d_rope: int = 64
    # MoE (None → dense)
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0                 # per-expert hidden (if != d_ff)
    capacity_factor: float = 1.25
    moe_groups: int = 1               # routing groups == data shards
    moe_group_axes: Any = None        # mesh axes the group dim shards over
    moe_tp_axis: Any = None           # mesh axis of the expert ff dim
    # numerics
    param_dtype: Any = jnp.float32
    dtype: Any = jnp.float32
    remat: bool = True
    loss_chunk: int = 512             # seq chunk for the CE loss
    unroll: bool = False              # python-loop layers instead of scan
                                      # (roofline calibration lowers only:
                                      # XLA cost analysis counts a while
                                      # body once — launch/analysis.py)
    act_spec: Any = None              # PartitionSpec for (B, S, d)
                                      # activations; set by the launcher
                                      # (requires an ambient mesh). Without
                                      # it XLA's propagation lets the
                                      # embed gather steal the data axis
                                      # for d and un-shards the batch.

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def attn_cfg(self) -> L.AttnConfig:
        return L.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                            self.d_head, self.qkv_bias, self.qk_norm,
                            self.rope_theta, causal=True)

    def mla_cfg(self) -> L.MLAConfig:
        return L.MLAConfig(self.d_model, self.n_heads, self.kv_lora_rank,
                           self.d_head, self.d_rope, self.rope_theta)

    def moe_cfg(self) -> L.MoEConfig:
        return L.MoEConfig(self.n_experts, self.top_k, self.d_model,
                           self.expert_ff, self.n_shared,
                           self.capacity_factor, self.moe_groups,
                           self.moe_group_axes, self.moe_tp_axis)

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline terms)."""
        d, dh = self.d_model, self.d_head
        if self.attn_kind == "mla":
            attn = (d * self.n_heads * (dh + self.d_rope)          # wq
                    + d * self.kv_lora_rank + d * self.d_rope       # down
                    + self.kv_lora_rank * self.n_heads * dh * 2     # up k,v
                    + self.n_heads * dh * d)                        # wo
        else:
            attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.is_moe:
            ff = 3 * d * self.expert_ff * (self.n_experts + self.n_shared)
            ff += d * self.n_experts                                # router
        else:
            ff = 3 * d * self.d_ff
        per_layer = attn + ff + 2 * d
        return (self.n_layers * per_layer + 2 * self.vocab * d + d)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full_ff = 3 * d * self.expert_ff * (self.n_experts + self.n_shared)
        act_ff = 3 * d * self.expert_ff * (self.top_k + self.n_shared)
        return self.param_count() - self.n_layers * (full_ff - act_ff)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: LMConfig) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm_attn": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
                 "norm_mlp": L.rmsnorm_init(cfg.d_model, cfg.param_dtype)}
    if cfg.attn_kind == "mla":
        p["attn"] = L.mla_init(ks[0], cfg.mla_cfg(), cfg.param_dtype)
    else:
        p["attn"] = L.attn_init(ks[0], cfg.attn_cfg(), cfg.param_dtype)
    if cfg.is_moe:
        p["moe"] = L.moe_init(ks[1], cfg.moe_cfg(), cfg.param_dtype)
    else:
        p["mlp"] = L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff,
                                 cfg.param_dtype)
    return p


def init_params(cfg: LMConfig, key) -> Params:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    return {
        "embed": L.dense_init(k_emb, cfg.vocab, cfg.d_model,
                              cfg.param_dtype, scale=1.0),
        "layers": stacked,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.vocab,
                                cfg.param_dtype),
    }


# ---------------------------------------------------------------------------
# forward (train / prefill trunk)
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: LMConfig, x: jax.Array, lp: Params,
               positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    h = L.rmsnorm(lp["norm_attn"], x)
    if cfg.attn_kind == "mla":
        h = L.mla_apply(lp["attn"], cfg.mla_cfg(), h, positions)
    else:
        h = L.attn_apply(lp["attn"], cfg.attn_cfg(), h, positions)
    x = x + h
    h = L.rmsnorm(lp["norm_mlp"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        h, aux = L.moe_apply(lp["moe"], cfg.moe_cfg(), h)
    else:
        h = L.swiglu(lp["mlp"], h)
    return x + h, aux


def _constrain(x: jax.Array, cfg: LMConfig) -> jax.Array:
    if cfg.act_spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, cfg.act_spec)


def trunk(params: Params, cfg: LMConfig, tokens: jax.Array
          ) -> Tuple[jax.Array, jax.Array]:
    """Embed + all layers + final norm. Returns (hidden (B,S,d), aux)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = _constrain(x, cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, lp):
        x, aux = _layer_fwd(cfg, x, lp, positions)
        return _constrain(x, cfg), aux

    if cfg.unroll:
        auxs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, aux = body(x, lp)
            auxs.append(aux)
        aux_mean = jnp.mean(jnp.stack(auxs))
    else:
        if cfg.remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, params["layers"])
        aux_mean = jnp.mean(auxs)
    x = L.rmsnorm(params["final_norm"], x)
    return x, aux_mean


def forward(params: Params, cfg: LMConfig, tokens: jax.Array) -> jax.Array:
    """Full logits (B, S, V) — use only for small vocab / tests."""
    x, _ = trunk(params, cfg, tokens)
    logits = x @ params["lm_head"].astype(cfg.dtype)
    if cfg.logit_soft_cap:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits


def loss_fn(params: Params, cfg: LMConfig, tokens: jax.Array,
            labels: jax.Array, aux_weight: float = 0.01
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked next-token cross-entropy.

    The (B,S,V) logits tensor never fully materialises: the sequence axis
    is processed in ``cfg.loss_chunk`` slices inside a scan (peak memory
    B·chunk·V instead of B·S·V — at vocab 151k / seq 4k that is an 8×
    activation saving, and XLA overlaps the head matmul chunks).
    """
    x, aux = trunk(params, cfg, tokens)               # (B, S, d)
    b, s, d = x.shape
    chunk = min(cfg.loss_chunk, s)
    assert s % chunk == 0, (s, chunk)
    head = params["lm_head"].astype(cfg.dtype)
    xc = x.reshape(b, s // chunk, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, s // chunk, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(carry, xs):
        # rematerialised: without checkpoint the scan saves every chunk's
        # (B, chunk, V) f32 logits for the backward — at V≈150k that is
        # the largest buffer in the whole step. Recompute costs one extra
        # head matmul per chunk.
        xi, li = xs
        logits = (xi @ head).astype(jnp.float32)
        if cfg.logit_soft_cap:
            logits = cfg.logit_soft_cap * jnp.tanh(
                logits / cfg.logit_soft_cap)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, li[..., None], -1)[..., 0]
        nll = logz - gold
        mask = (li >= 0).astype(jnp.float32)
        return (carry[0] + jnp.sum(nll * mask), carry[1] + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with stacked per-layer caches
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Params:
    """Per-layer cache pytree, layer-stacked (leading axis L)."""
    ln = cfg.n_layers
    if cfg.attn_kind == "mla":
        return {
            "ckv": jnp.zeros((ln, batch, max_len, cfg.kv_lora_rank),
                             cfg.dtype),
            "krope": jnp.zeros((ln, batch, max_len, cfg.d_rope), cfg.dtype),
        }
    return {
        "k": jnp.zeros((ln, batch, cfg.n_kv_heads, max_len, cfg.d_head),
                       cfg.dtype),
        "v": jnp.zeros((ln, batch, cfg.n_kv_heads, max_len, cfg.d_head),
                       cfg.dtype),
    }


def decode_step(params: Params, cfg: LMConfig, cache: Params,
                tokens: jax.Array, cache_len: jax.Array
                ) -> Tuple[jax.Array, Params]:
    """One decode step. tokens (B,) int32; cache_len (B,) current fill.

    Returns (logits (B, V), updated cache). The layer scan carries the
    hidden state and threads each layer's cache slice through as
    scanned-over xs/ys.
    """
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cfg.dtype)
    x = _constrain(x, cfg)

    if cfg.attn_kind == "mla":
        def body(x, xs):
            lp, ckv, krope = xs
            h = L.rmsnorm(lp["norm_attn"], x)
            h, ckv, krope = L.mla_decode(lp["attn"], cfg.mla_cfg(), h,
                                         ckv, krope, cache_len)
            x = x + h
            h = L.rmsnorm(lp["norm_mlp"], x)
            if cfg.is_moe:
                h, _ = L.moe_apply(lp["moe"],
                                   dataclasses.replace(cfg, moe_groups=1
                                                       ).moe_cfg(), h)
            else:
                h = L.swiglu(lp["mlp"], h)
            return _constrain(x + h, cfg), (ckv, krope)

        if cfg.unroll:
            ckvs, kropes = [], []
            for i in range(cfg.n_layers):
                xs_i = jax.tree.map(lambda a: a[i],
                                    (params["layers"], cache["ckv"],
                                     cache["krope"]))
                x, (c1, c2) = body(x, xs_i)
                ckvs.append(c1)
                kropes.append(c2)
            new_cache = {"ckv": jnp.stack(ckvs),
                         "krope": jnp.stack(kropes)}
        else:
            x, (ckv, krope) = jax.lax.scan(
                body, x, (params["layers"], cache["ckv"], cache["krope"]))
            new_cache = {"ckv": ckv, "krope": krope}
    else:
        def body(x, xs):
            lp, kc, vc = xs
            h = L.rmsnorm(lp["norm_attn"], x)
            h, kc, vc = L.attn_decode(lp["attn"], cfg.attn_cfg(), h,
                                      kc, vc, cache_len)
            x = x + h
            h = L.rmsnorm(lp["norm_mlp"], x)
            if cfg.is_moe:
                h, _ = L.moe_apply(lp["moe"],
                                   dataclasses.replace(cfg, moe_groups=1
                                                       ).moe_cfg(), h)
            else:
                h = L.swiglu(lp["mlp"], h)
            return _constrain(x + h, cfg), (kc, vc)

        if cfg.unroll:
            kcs, vcs = [], []
            for i in range(cfg.n_layers):
                xs_i = jax.tree.map(lambda a: a[i],
                                    (params["layers"], cache["k"],
                                     cache["v"]))
                x, (c1, c2) = body(x, xs_i)
                kcs.append(c1)
                vcs.append(c2)
            new_cache = {"k": jnp.stack(kcs), "v": jnp.stack(vcs)}
        else:
            x, (kc, vc) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            new_cache = {"k": kc, "v": vc}

    x = L.rmsnorm(params["final_norm"], x)[:, 0]       # (B, d)
    logits = (x @ params["lm_head"].astype(cfg.dtype)).astype(jnp.float32)
    if cfg.logit_soft_cap:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits, new_cache


def prefill(params: Params, cfg: LMConfig, tokens: jax.Array,
            max_len: int) -> Tuple[jax.Array, Params, jax.Array]:
    """Prefill the cache from a full prompt. tokens (B, S).

    Returns (last-token logits (B, V), cache sized max_len, cache_len).
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = _constrain(x, cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pad = max_len - s

    if cfg.attn_kind == "mla":
        def body(x, lp):
            h = L.rmsnorm(lp["norm_attn"], x)
            mcfg = cfg.mla_cfg()
            c_kv = L.rmsnorm(lp["attn"]["kv_norm"], h @ lp["attn"]["w_dkv"])
            k_rope = L.apply_rope((h @ lp["attn"]["w_krope"])[:, None],
                                  positions[:, None], cfg.rope_theta)[:, 0]
            h = L.mla_apply(lp["attn"], mcfg, h, positions)
            x = x + h
            h = L.rmsnorm(lp["norm_mlp"], x)
            if cfg.is_moe:
                h, _ = L.moe_apply(lp["moe"], cfg.moe_cfg(), h)
            else:
                h = L.swiglu(lp["mlp"], h)
            return (_constrain(x + h, cfg),
                    (jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                     jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))))

        if cfg.unroll:
            c1s, c2s = [], []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, (c1, c2) = body(x, lp)
                c1s.append(c1)
                c2s.append(c2)
            cache = {"ckv": jnp.stack(c1s), "krope": jnp.stack(c2s)}
        else:
            body = jax.checkpoint(body) if cfg.remat else body
            x, (ckv, krope) = jax.lax.scan(body, x, params["layers"])
            cache = {"ckv": ckv, "krope": krope}
    else:
        acfg = cfg.attn_cfg()

        def body(x, lp):
            h = L.rmsnorm(lp["norm_attn"], x)
            q, k, v = L._project_qkv(lp["attn"], acfg, h, positions)
            from repro.kernels import ops as _ops
            o = _ops.flash_attention(q, k, v, causal=True)
            o = o.swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.d_head)
            x = x + o @ lp["attn"]["wo"]
            h = L.rmsnorm(lp["norm_mlp"], x)
            if cfg.is_moe:
                h, _ = L.moe_apply(lp["moe"], cfg.moe_cfg(), h)
            else:
                h = L.swiglu(lp["mlp"], h)
            return (_constrain(x + h, cfg),
                    (jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
                     jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))))

        if cfg.unroll:
            c1s, c2s = [], []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, (c1, c2) = body(x, lp)
                c1s.append(c1)
                c2s.append(c2)
            cache = {"k": jnp.stack(c1s), "v": jnp.stack(c2s)}
        else:
            body = jax.checkpoint(body) if cfg.remat else body
            x, (kc, vc) = jax.lax.scan(body, x, params["layers"])
            cache = {"k": kc, "v": vc}

    x = L.rmsnorm(params["final_norm"], x)[:, -1]
    logits = (x @ params["lm_head"].astype(cfg.dtype)).astype(jnp.float32)
    if cfg.logit_soft_cap:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    cache_len = jnp.full((b,), s, jnp.int32)
    return logits, cache, cache_len
