"""Model zoo: LM transformers (dense/MoE/MLA), GIN, recsys, bi-encoders."""
from repro.models import encoder, gnn, layers, recsys, transformer  # noqa: F401
