"""Bi-encoder dense retrieval models (the paper's Dragon / Snowflake).

Dragon (arXiv:2305.xxxx / facebook/dragon-plus): BERT-style dual encoder,
separate query/context towers, 768-d, inner-product similarity (embeddings
L2-normalised before HNSW indexing per the paper's methodology [2]).
Snowflake arctic-embed-l-v2 (arXiv:2412.04506): XLM-R-large-style single
shared encoder, 1024-d, cosine similarity (normalised).

We cannot ship pretrained weights in this offline container, so these
encoders are *trained here* (examples/train_encoder.py: InfoNCE over the
synthetic topic corpus) — giving real learned embedding geometry for the
TopLoc reproduction instead of raw gaussians.

Bidirectional transformer built from the shared layer blocks
(AttnConfig(causal=False)); CLS pooling + optional normalisation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    name: str = "dragon"
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    d_ff: int = 3072
    vocab: int = 32768
    max_len: int = 256
    out_dim: int = 0              # 0 → d_model
    normalize: bool = True        # L2-normalise pooled embedding
    shared_towers: bool = False   # Snowflake: one tower; Dragon: two
    dtype: Any = jnp.float32

    @property
    def d_out(self) -> int:
        return self.out_dim or self.d_model

    def attn_cfg(self) -> L.AttnConfig:
        return L.AttnConfig(self.d_model, self.n_heads, self.n_heads,
                            self.d_model // self.n_heads, causal=False)

    def param_count(self) -> int:
        d = self.d_model
        per = 4 * d * d + 3 * d * self.d_ff + 4 * d
        emb = self.vocab * d + self.max_len * d
        towers = 1 if self.shared_towers else 2
        return towers * (emb + self.n_layers * per + d * self.d_out)


def _tower_init(cfg: EncoderConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)

    def one_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn": L.attn_init(k1, cfg.attn_cfg(), cfg.dtype),
            "norm1": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            "norm2": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype),
        }

    return {
        "embed": L.dense_init(ks[1], cfg.vocab, cfg.d_model, cfg.dtype,
                              scale=1.0),
        "pos": (jax.random.normal(ks[2], (cfg.max_len, cfg.d_model),
                                  jnp.float32) * 0.02).astype(cfg.dtype),
        "layers": jax.vmap(one_layer)(layer_keys),
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "proj": L.dense_init(ks[3], cfg.d_model, cfg.d_out, cfg.dtype),
    }


def init_params(cfg: EncoderConfig, key) -> Params:
    kq, kd = jax.random.split(key)
    if cfg.shared_towers:
        tower = _tower_init(cfg, kq)
        return {"query": tower, "doc": tower}
    return {"query": _tower_init(cfg, kq), "doc": _tower_init(cfg, kd)}


def encode(tower: Params, cfg: EncoderConfig, tokens: jax.Array,
           mask: jax.Array) -> jax.Array:
    """tokens (B, S) int32, mask (B, S) bool → embeddings (B, d_out).

    CLS pooling: position 0 (the tokenizer prepends a CLS id).
    """
    b, s = tokens.shape
    x = jnp.take(tower["embed"], tokens, axis=0).astype(cfg.dtype)
    x = x + tower["pos"][None, :s]
    x = x * mask[..., None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    acfg = cfg.attn_cfg()

    def body(x, lp):
        h = L.attn_apply(lp["attn"], acfg, L.rmsnorm(lp["norm1"], x),
                         positions)
        x = x + h * mask[..., None].astype(h.dtype)
        h = L.swiglu(lp["mlp"], L.rmsnorm(lp["norm2"], x))
        return x + h * mask[..., None].astype(h.dtype), None

    x, _ = jax.lax.scan(body, x, tower["layers"])
    pooled = L.rmsnorm(tower["final_norm"], x)[:, 0]       # CLS
    out = pooled @ tower["proj"]
    if cfg.normalize:
        out = out / jnp.maximum(
            jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)
    return out


def encode_queries(params: Params, cfg: EncoderConfig, tokens, mask):
    return encode(params["query"], cfg, tokens, mask)


def encode_docs(params: Params, cfg: EncoderConfig, tokens, mask):
    return encode(params["doc"], cfg, tokens, mask)


def contrastive_loss(params: Params, cfg: EncoderConfig, batch: Params,
                     temperature: float = 0.05
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """InfoNCE with in-batch negatives (standard dense-retrieval recipe)."""
    q = encode_queries(params, cfg, batch["q_tokens"], batch["q_mask"])
    d = encode_docs(params, cfg, batch["d_tokens"], batch["d_mask"])
    logits = (q @ d.T) / temperature
    labels = jnp.arange(q.shape[0])
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[..., 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"acc": acc}
