"""GIN (Graph Isomorphism Network, arXiv:1810.00826) in pure JAX.

Message passing is built on ``jax.ops.segment_sum`` over an edge-index →
node scatter (JAX has no sparse SpMM beyond BCOO; the segment-reduce
formulation IS the substrate — kernel_taxonomy §GNN).  Sum aggregation +
learnable ε per layer, 2-layer MLP update, per-layer sum-pool readouts
for graph classification (the paper's jumping-knowledge head).

Shapes served (configs/gin_tu.py):
  full_graph_sm / ogb_products — full-batch node classification
  minibatch_lg                 — sampled subgraph (data/graph.py sampler)
  molecule                     — batched small graphs (vmapped)

Distribution: edges shard over the flattened mesh; node states replicate
(partial segment_sum per shard + psum — XLA SPMD inserts the reduce from
the sharding annotations; DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 1433
    n_classes: int = 7
    task: str = "node"            # "node" | "graph"
    eps_learnable: bool = True
    dtype: Any = jnp.float32

    def param_count(self) -> int:
        per = 2 * self.d_hidden * self.d_hidden + 2 * self.d_hidden + 1
        first = (self.d_in * self.d_hidden + self.d_hidden * self.d_hidden
                 + 2 * self.d_hidden + 1)
        head = self.n_layers * self.d_hidden * self.n_classes
        return first + (self.n_layers - 1) * per + head


def _gin_mlp_init(key, d_in: int, d_out: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"w1": L.dense_init(k1, d_in, d_out, dtype),
            "b1": jnp.zeros((d_out,), dtype),
            "w2": L.dense_init(k2, d_out, d_out, dtype),
            "b2": jnp.zeros((d_out,), dtype),
            "norm": L.layernorm_init(d_out, dtype)}


def _gin_mlp(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    h = h @ p["w2"] + p["b2"]
    return jax.nn.relu(L.layernorm(p["norm"], h))


def init_params(cfg: GINConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_in if i == 0 else cfg.d_hidden
        layers.append({
            "mlp": _gin_mlp_init(ks[i], d_in, cfg.d_hidden, cfg.dtype),
            "eps": jnp.zeros((), jnp.float32),
        })
    heads = [L.dense_init(k, cfg.d_hidden, cfg.n_classes, cfg.dtype)
             for k in jax.random.split(ks[-1], cfg.n_layers)]
    return {"layers": layers, "heads": heads}


def aggregate(h: jax.Array, edge_src: jax.Array, edge_dst: jax.Array,
              n_nodes: int, edge_mask: Optional[jax.Array] = None
              ) -> jax.Array:
    """Sum aggregation: out[i] = Σ_{(j→i)∈E} h[j].  Padded edges masked."""
    msg = h[edge_src]                                     # (E, d)
    if edge_mask is not None:
        msg = msg * edge_mask[:, None].astype(msg.dtype)
    return jax.ops.segment_sum(msg, edge_dst, num_segments=n_nodes)


def forward_node(params: Params, cfg: GINConfig, x: jax.Array,
                 edge_src: jax.Array, edge_dst: jax.Array,
                 edge_mask: Optional[jax.Array] = None) -> jax.Array:
    """Node classification logits. x (N, d_in), edges (E,) each."""
    n = x.shape[0]
    h = x.astype(cfg.dtype)
    logits = jnp.zeros((n, cfg.n_classes), jnp.float32)
    for lp, head in zip(params["layers"], params["heads"]):
        agg = aggregate(h, edge_src, edge_dst, n, edge_mask)
        h = _gin_mlp(lp["mlp"], (1.0 + lp["eps"]) * h + agg)
        logits = logits + (h @ head).astype(jnp.float32)
    return logits


def forward_graph(params: Params, cfg: GINConfig, x: jax.Array,
                  edge_src: jax.Array, edge_dst: jax.Array,
                  node_mask: jax.Array,
                  edge_mask: Optional[jax.Array] = None) -> jax.Array:
    """Graph classification logits for ONE padded graph; vmap for batches.

    x (N, d_in), node_mask (N,) — sum-pool readout per GIN layer.
    """
    n = x.shape[0]
    h = x.astype(cfg.dtype)
    logits = jnp.zeros((cfg.n_classes,), jnp.float32)
    for lp, head in zip(params["layers"], params["heads"]):
        agg = aggregate(h, edge_src, edge_dst, n, edge_mask)
        h = _gin_mlp(lp["mlp"], (1.0 + lp["eps"]) * h + agg)
        pooled = jnp.sum(h * node_mask[:, None].astype(h.dtype), 0)
        logits = logits + (pooled @ head).astype(jnp.float32)
    return logits


def node_loss(params: Params, cfg: GINConfig, x, edge_src, edge_dst,
              labels, train_mask, edge_mask=None
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = forward_node(params, cfg, x, edge_src, edge_dst, edge_mask)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[..., 0]
    nll = (logz - gold) * train_mask.astype(jnp.float32)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(train_mask), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * train_mask
                  ) / jnp.maximum(jnp.sum(train_mask), 1.0)
    return loss, {"acc": acc}


def graph_loss(params: Params, cfg: GINConfig, x, edge_src, edge_dst,
               node_mask, labels, edge_mask=None
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Batched graph classification. Leading batch axis on every input."""
    logits = jax.vmap(
        lambda xi, es, ed, nm, em: forward_graph(
            params, cfg, xi, es, ed, nm, em)
    )(x, edge_src, edge_dst, node_mask, edge_mask)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[..., 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"acc": acc}
