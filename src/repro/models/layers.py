"""Shared neural building blocks (pure JAX, pytree params).

Everything is a pair of functions — ``*_init(key, ...) -> params`` and
``*_apply(params, x, ...) -> y`` — over plain dict pytrees, so models
compose without a framework dependency and sharding specs can mirror the
param tree exactly (distributed/sharding.py).

Covers every feature the assigned architectures need: RMS/LayerNorm,
RoPE, GQA attention with optional QKV bias (qwen1.5) and qk-norm
(qwen3), SwiGLU/GELU MLPs, group-local top-k MoE with shared experts
(grok-1, deepseek-v2-lite), and MLA (deepseek's multi-head latent
attention, kv_lora compression + decoupled RoPE keys).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initialisers / norms
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x (..., S, d_head); positions (..., S) int32 (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (covers MHA; optional qkv bias / qk-norm)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p: Params = {
        "wq": dense_init(ks[0], d, h * dh, dtype),
        "wk": dense_init(ks[1], d, hkv * dh, dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype),
        "wo": dense_init(ks[3], h * dh, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, dtype)
        p["k_norm"] = rmsnorm_init(dh, dtype)
    return p


def _project_qkv(params: Params, cfg: AttnConfig, x: jax.Array,
                 positions: jax.Array):
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = apply_rope(q.swapaxes(1, 2), positions[:, None], cfg.rope_theta)
    k = apply_rope(k.swapaxes(1, 2), positions[:, None], cfg.rope_theta)
    return q, k, v.swapaxes(1, 2)   # (B,H,S,dh), (B,Hkv,S,dh), (B,Hkv,S,dh)


def attn_apply(params: Params, cfg: AttnConfig, x: jax.Array,
               positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention (train / prefill). x (B, S, d)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = ops.flash_attention(q, k, v, causal=cfg.causal)   # (B,H,S,dh)
    out = out.swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.d_head)
    return out @ params["wo"]


def attn_decode(params: Params, cfg: AttnConfig, x: jax.Array,
                k_cache: jax.Array, v_cache: jax.Array,
                cache_len: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x (B, 1, d); caches (B, Hkv, S, dh);
    cache_len (B,) = current fill. Returns (out (B,1,d), k_cache, v_cache)."""
    b = x.shape[0]
    positions = cache_len[:, None].astype(jnp.int32)          # (B,1)
    q, k, v = _project_qkv(params, cfg, x, positions)         # S==1
    # scatter the new kv at position cache_len: writes B·Hkv·dh elements
    # (the earlier one-hot formulation read+wrote the ENTIRE cache every
    # step — O(S) HBM traffic per token; §Perf decode iteration)
    hkv = k_cache.shape[1]
    b_ix = jnp.arange(b)[:, None]
    h_ix = jnp.arange(hkv)[None, :]
    k_cache = k_cache.at[b_ix, h_ix, cache_len[:, None], :].set(
        k[:, :, 0].astype(k_cache.dtype), mode="drop")
    v_cache = v_cache.at[b_ix, h_ix, cache_len[:, None], :].set(
        v[:, :, 0].astype(v_cache.dtype), mode="drop")
    out = ops.flash_decode(q[:, :, 0], k_cache, v_cache, cache_len + 1)
    out = out.reshape(b, 1, cfg.n_heads * cfg.d_head)
    return out @ params["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {"w_gate": dense_init(ks[0], d, d_ff, dtype),
            "w_up": dense_init(ks[1], d, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d, dtype)}


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
            ) @ params["w_down"]


def mlp_init(key, dims, dtype=jnp.float32, bias: bool = True) -> Params:
    """Plain MLP tower (recsys): dims = [in, h1, ..., out]."""
    ks = jax.random.split(key, len(dims) - 1)
    layers = []
    for i in range(len(dims) - 1):
        lp = {"w": dense_init(ks[i], dims[i], dims[i + 1], dtype)}
        if bias:
            lp["b"] = jnp.zeros((dims[i + 1],), dtype)
        layers.append(lp)
    return {"layers": layers}


def mlp_apply(params: Params, x: jax.Array, act=jax.nn.relu,
              final_act: bool = False) -> jax.Array:
    n = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        x = x @ lp["w"]
        if "b" in lp:
            x = x + lp["b"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# MoE — group-local top-k routing (sort-based dispatch, static shapes)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    n_shared: int = 0            # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25
    n_groups: int = 1            # routing groups == data shards at scale;
                                 # each group routes locally (static shapes,
                                 # no cross-shard sort under SPMD)
    group_axes: Any = None       # mesh axis name(s) the group dim shards
                                 # over (vmap spmd_axis_name) — without it
                                 # XLA replicates every group's dispatch
                                 # buffers on every device
    tp_axis: Any = None          # mesh axis of the expert ff dim; used for
                                 # in-vmap sharding constraints on the
                                 # (E, C, ff) expert activations (vmap
                                 # prepends group_axes via spmd_axis_name)


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = (1.0 / d) ** 0.5

    def bank(k, n, din, dout):
        return (jax.random.normal(k, (n, din, dout), jnp.float32) * scale
                ).astype(dtype)

    p: Params = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router in f32
        "w_gate": bank(ks[1], e, d, f),
        "w_up": bank(ks[2], e, d, f),
        "w_down": bank(ks[3], e, f, d),
    }
    if cfg.n_shared:
        p["shared"] = swiglu_init(ks[4], d, f * cfg.n_shared, dtype)
    return p


def _dispatch_group(x: jax.Array, logits: jax.Array, top_k: int,
                    capacity: int):
    """Sort-based dispatch for one routing group.

    x (T, d), logits (T, E) → (dispatched (E, C, d), gather_tok (E*C,),
    weights (E*C,), aux_loss ()).
    """
    t, d = x.shape
    e = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_w, top_e = jax.lax.top_k(probs, top_k)                 # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): e * sum(frac_tokens * frac_probs)
    me = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), 0)
    ce = jnp.mean(probs, 0)
    aux = e * jnp.sum(me * ce)

    s = t * top_k
    flat_e = top_e.reshape(-1)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    order = jnp.argsort(flat_e)                                # stable
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    counts = jax.ops.segment_sum(jnp.ones((s,), jnp.int32), se, e)
    start = jnp.cumsum(counts) - counts                        # (E,)
    pos = jnp.arange(s, dtype=jnp.int32) - start[se]
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, e * capacity)  # OOB → drop
    gather_tok = jnp.full((e * capacity,), t, jnp.int32
                          ).at[slot].set(stok, mode="drop")
    weights = jnp.zeros((e * capacity,), jnp.float32
                        ).at[slot].set(sw, mode="drop")
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], 0)
    dispatched = x_pad[gather_tok].reshape(e, capacity, d)
    return dispatched, gather_tok, weights, aux


def moe_apply(params: Params, cfg: MoEConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) → (out (B, S, d), aux_loss ()).

    Tokens are reshaped into ``n_groups`` routing groups (set n_groups to
    the data-shard count at scale); each group dispatches locally so the
    sort/scatter stays shard-resident under SPMD (DESIGN.md §5).
    """
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t_total = tokens.shape[0]
    g = cfg.n_groups
    assert t_total % g == 0, (t_total, g)
    t_local = t_total // g
    capacity = max(cfg.top_k, int(cfg.capacity_factor * t_local *
                                  cfg.top_k / cfg.n_experts + 0.9999))
    xg = tokens.reshape(g, t_local, d)
    if cfg.group_axes is not None:
        # the (B@dp, S@tp) → (G, T_local) reshape merges two sharded dims
        # and XLA drops the sharding; re-pin groups to the data axes
        from jax.sharding import PartitionSpec as _P
        xg = jax.lax.with_sharding_constraint(
            xg, _P(tuple(cfg.group_axes), None, None))
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])

    def _pin(t, spec):
        # inside the vmap, spmd_axis_name prepends the group axes to the
        # constraint — this is what actually shards the expert tensors
        # (propagation alone drops them inside the layer-scan body)
        if cfg.group_axes is None:
            return t
        from jax.sharding import PartitionSpec as _P
        return jax.lax.with_sharding_constraint(t, _P(*spec))

    def group_fn(xl, ll):
        dispatched, gather_tok, weights, aux = _dispatch_group(
            xl, ll, cfg.top_k, capacity)
        dispatched = _pin(dispatched, (None, None, None))
        h = jnp.einsum("ecd,edf->ecf", dispatched, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", dispatched, params["w_up"])
        h = _pin(h, (None, None, cfg.tp_axis))
        u = _pin(u, (None, None, cfg.tp_axis))
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, params["w_down"])
        y = _pin(y, (None, None, None))
        y_flat = y.reshape(-1, d) * weights[:, None].astype(y.dtype)
        out = jnp.zeros((t_local + 1, d), y.dtype
                        ).at[gather_tok].add(y_flat)[:t_local]
        return _pin(out, (None, None)), aux

    spmd = cfg.group_axes
    if spmd is not None and not isinstance(spmd, str):
        spmd = tuple(spmd)
    out, aux = jax.vmap(group_fn, spmd_axis_name=spmd)(xg, logits)
    out = out.reshape(b, s, d).astype(x.dtype)
    if cfg.n_shared:
        out = out + swiglu(params["shared"], x)
    return out, jnp.mean(aux)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    d_head: int = 128            # nope part of qk, and v
    d_rope: int = 64             # decoupled rope key dim (shared per head)
    rope_theta: float = 10000.0


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    d, h, r = cfg.d_model, cfg.n_heads, cfg.kv_lora_rank
    dh, dr = cfg.d_head, cfg.d_rope
    return {
        "wq": dense_init(ks[0], d, h * (dh + dr), dtype),
        "w_dkv": dense_init(ks[1], d, r, dtype),          # down: x → c_kv
        "w_krope": dense_init(ks[2], d, dr, dtype),       # decoupled k
        "w_uk": dense_init(ks[3], r, h * dh, dtype),      # up: c_kv → k_nope
        "w_uv": dense_init(ks[4], r, h * dh, dtype),      # up: c_kv → v
        "kv_norm": rmsnorm_init(r, dtype),
        "wo": dense_init(ks[5], h * dh, d, dtype),
    }


def mla_apply(params: Params, cfg: MLAConfig, x: jax.Array,
              positions: Optional[jax.Array] = None) -> jax.Array:
    """Train/prefill MLA (materialised K/V). x (B, S, d)."""
    b, s, _ = x.shape
    h, dh, dr, r = cfg.n_heads, cfg.d_head, cfg.d_rope, cfg.kv_lora_rank
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    q = (x @ params["wq"]).reshape(b, s, h, dh + dr)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions[:, None],
                        cfg.rope_theta)                   # (B,H,S,dr)

    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"])   # (B,S,r)
    k_rope = apply_rope((x @ params["w_krope"])[:, None],    # shared head
                        positions[:, None], cfg.rope_theta)  # (B,1,S,dr)
    k_nope = (c_kv @ params["w_uk"]).reshape(b, s, h, dh).swapaxes(1, 2)
    v = (c_kv @ params["w_uv"]).reshape(b, s, h, dh).swapaxes(1, 2)

    q_full = jnp.concatenate([q_nope.swapaxes(1, 2), q_rope], -1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, h, s, dr))], -1)
    # flash kernel handles GQA-style head mapping; here Hkv == H
    out = ops.flash_attention(q_full, k_full, v, causal=True)
    out = out.swapaxes(1, 2).reshape(b, s, h * dh)
    return out @ params["wo"]


def mla_decode(params: Params, cfg: MLAConfig, x: jax.Array,
               ckv_cache: jax.Array, krope_cache: jax.Array,
               cache_len: jax.Array
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-form MLA decode — attends in the compressed latent space.

    Caches only (B, S, r) latents + (B, S, dr) rope keys (the MLA memory
    win). q·k = (q_nope W_uk^T)·c_kv + q_rope·k_rope;  out = attn·c_kv
    then expanded through W_uv ("weight absorption", DeepSeek-V2 §2.1).
    x (B, 1, d); cache_len (B,).
    """
    b = x.shape[0]
    h, dh, dr, r = cfg.n_heads, cfg.d_head, cfg.d_rope, cfg.kv_lora_rank
    s_max = ckv_cache.shape[1]
    positions = cache_len[:, None].astype(jnp.int32)

    q = (x @ params["wq"]).reshape(b, 1, h, dh + dr)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions[:, None],
                        cfg.rope_theta)[:, :, 0]          # (B,H,dr)

    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"])[:, 0]   # (B,r)
    k_rope = apply_rope((x @ params["w_krope"])[:, None],
                        positions[:, None], cfg.rope_theta)[:, 0, 0]  # (B,dr)

    # scatter-write the new latent at cache_len (O(r) traffic per row,
    # not O(S·r) — see attn_decode)
    b_ix = jnp.arange(b)
    ckv_cache = ckv_cache.at[b_ix, cache_len, :].set(
        c_kv.astype(ckv_cache.dtype), mode="drop")
    krope_cache = krope_cache.at[b_ix, cache_len, :].set(
        k_rope.astype(krope_cache.dtype), mode="drop")

    # absorb W_uk into q:  q_lat (B,H,r)
    w_uk = params["w_uk"].reshape(r, h, dh)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                         ckv_cache.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                           krope_cache.astype(jnp.float32)))
    logits = logits / jnp.asarray((dh + dr) ** 0.5, jnp.float32)
    mask = jnp.arange(s_max)[None] < (cache_len + 1)[:, None]
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, -1)
    o_lat = jnp.einsum("bhs,bsr->bhr", probs,
                       ckv_cache.astype(jnp.float32))     # (B,H,r)
    w_uv = params["w_uv"].reshape(r, h, dh)
    out = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, h * dh).astype(x.dtype)
    return out @ params["wo"], ckv_cache, krope_cache
