"""Fault-tolerance substrate: sharded checkpoint save/restore.

Design (DESIGN.md §7):
  * pytrees flatten to ``{path: array}`` and save as ``.npz`` with an
    **atomic publish** (write to ``.tmp``, fsync, rename) so a crash
    mid-write never corrupts the latest checkpoint;
  * ``AsyncCheckpointer`` moves serialisation off the training thread
    (device→host copy happens synchronously — cheap — the compression +
    disk write overlaps the next steps);
  * ``keep_last_k`` garbage collection;
  * ``latest_step`` / ``restore`` implement crash-recovery resume
    (launch/train.py --resume auto); restore is *mesh-independent* —
    arrays come back as host numpy and are re-placed by the caller's
    shardings, which is what makes elastic re-scaling work
    (distributed/elastic.py re-places them on a different mesh).
"""
from __future__ import annotations

import os
import re
import threading
from typing import Any, Dict, List, Optional

import numpy as np
import jax


SEP = "//"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten(like: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_leaves:
        key = SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(directory: str, step: int, tree: Any, *, prefix: str = "ckpt"
         ) -> str:
    """Atomic checkpoint write. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"{prefix}_{step:010d}.npz")
    tmp = final + ".tmp.npz"
    flat = _flatten(tree)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)
    return final


def list_steps(directory: str, prefix: str = "ckpt") -> List[int]:
    if not os.path.isdir(directory):
        return []
    pat = re.compile(rf"{re.escape(prefix)}_(\d+)\.npz$")
    steps = []
    for name in os.listdir(directory):
        m = pat.match(name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str, prefix: str = "ckpt") -> Optional[int]:
    steps = list_steps(directory, prefix)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like: Any, *, prefix: str = "ckpt"
            ) -> Any:
    path = os.path.join(directory, f"{prefix}_{step:010d}.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(like, flat)


def keep_last_k(directory: str, k: int, prefix: str = "ckpt") -> None:
    steps = list_steps(directory, prefix)
    for s in steps[:-k] if k > 0 else []:
        try:
            os.remove(os.path.join(directory, f"{prefix}_{s:010d}.npz"))
        except OSError:
            pass


class AsyncCheckpointer:
    """Background checkpoint writer (one in flight; newer wins)."""

    def __init__(self, directory: str, *, keep: int = 3,
                 prefix: str = "ckpt"):
        self.directory = directory
        self.keep = keep
        self.prefix = prefix
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save(self.directory, step, host_tree, prefix=self.prefix)
                keep_last_k(self.directory, self.keep, self.prefix)
            except BaseException as e:   # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
