"""Fault-tolerance substrate: atomic sharded checkpoints, async writer."""
from repro.checkpoint import checkpoint  # noqa: F401
