"""Compiled-artifact analysis: cost extraction + roofline terms.

Import-safe (no device-count side effects) — the dry-run CLI and tests
both use it.

Sources (ROOFLINE ANALYSIS spec):
  * ``compiled.cost_analysis()``    → HLO FLOPs / bytes accessed
  * ``compiled.memory_analysis()``  → per-device argument/output/temp bytes
  * post-SPMD HLO text              → collective payload bytes (parsed
    here; shapes in partitioned HLO are per-device)

Scan adjustment: XLA cost analysis counts a ``while`` body ONCE.  For
layer-scanned LMs we *calibrate*: lower the same cell with unrolled 1-
and 2-layer variants; per-layer deltas give exact linear coefficients
(flops(L) = fixed + L·per_layer), applied to flops, bytes and collective
bytes.  Non-scanned archs pass trip_count=1 (no adjustment).

Collective cost model (ring, group size g parsed from replica_groups):
  all-gather       bytes·(g-1)/g     (result is the gathered tensor)
  reduce-scatter   bytes·(g-1)       (operand = g × result)
  all-reduce       bytes·2(g-1)/g    (reduce-scatter + all-gather)
  all-to-all       bytes·(g-1)/g
  collective-permute  bytes
where ``bytes`` is the op's *result* buffer size in the per-device HLO.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*([a-z0-9\[\],{}() ]*?)"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(typestr: str) -> int:
    """Bytes of the leading shape in e.g. ``bf16[16,384]{1,0}``; tuples
    sum their elements."""
    total = 0
    for m in _SHAPE_RE.finditer(typestr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _coll_cost(kind: str, rbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return rbytes * (g - 1) / g
    if kind == "reduce-scatter":
        return rbytes * (g - 1)
    if kind == "all-reduce":
        return rbytes * 2 * (g - 1) / g
    if kind == "all-to-all":
        return rbytes * (g - 1) / g
    return float(rbytes)        # collective-permute


def split_computations(hlo: str) -> Dict[str, str]:
    """computation name → body text (brace-balanced blocks).

    Header lines look like ``%name (args) -> type {`` — args/types can
    contain nested parens (tuples), so match only the name and the
    trailing open-brace."""
    comps: Dict[str, str] = {}
    lines = hlo.splitlines()
    i = 0
    name_re = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)")
    while i < len(lines):
        line = lines[i]
        is_header = (line.rstrip().endswith("{")
                     and ("->" in line or line.lstrip().startswith(
                         ("ENTRY", "%"))) and "=" not in line.split("(")[0])
        m = name_re.match(line) if is_header else None
        if m:
            name = m.group(1)
            depth = lines[i].count("{") - lines[i].count("}")
            body = [lines[i]]
            i += 1
            while i < len(lines) and depth > 0:
                depth += lines[i].count("{") - lines[i].count("}")
                body.append(lines[i])
                i += 1
            comps[name] = "\n".join(body)
        else:
            i += 1
    return comps


def while_body_names(hlo: str) -> List[str]:
    return [m.group(1).lstrip("%")
            for m in re.finditer(r"body=%?([\w.\-]+)", hlo)]


def collective_bytes_in(text: str, default_group: int) -> Tuple[float, Dict[str, float]]:
    """Per-device collective payload bytes in a block of HLO text."""
    total = 0.0
    by_kind: Dict[str, float] = {}
    for line in text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:          # async pair: count only the start
            continue
        kind = m.group(3)
        rbytes = _shape_bytes(line.split("=", 1)[1].split("(", 1)[0])
        g = _group_size(line, default_group)
        c = _coll_cost(kind, rbytes, g)
        total += c
        by_kind[kind] = by_kind.get(kind, 0.0) + c
    return total, by_kind


@dataclasses.dataclass
class CellCost:
    """Per-device cost record for one (arch, shape, mesh) cell."""
    flops: float                 # per-device, scan-adjusted
    hbm_bytes: float             # per-device, scan-adjusted
    coll_bytes: float            # per-device payload, scan-adjusted
    coll_by_kind: Dict[str, float]
    mem_args: float
    mem_temp: float
    mem_output: float
    peak_memory: float
    raw_flops: float             # unadjusted (body counted once)
    adjust_note: str = ""


def analyze_compiled(compiled, *, trip_count: int = 1,
                     default_group: int = 16,
                     calibration: Optional[Tuple[float, float, float]] = None
                     ) -> CellCost:
    """Extract per-device costs from a compiled executable.

    ``calibration``: optional (per_layer_flops, per_layer_bytes,
    per_layer_coll) per-device linear coefficients from the unrolled 1/2
    layer lowers; when given they OVERRIDE the crude while-body×trip
    adjustment.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    comps = split_computations(hlo)
    bodies = while_body_names(hlo)
    total_coll, by_kind = collective_bytes_in(hlo, default_group)

    body_coll = 0.0
    for b in bodies:
        if b in comps:
            c, _ = collective_bytes_in(comps[b], default_group)
            body_coll += c

    note = ""
    if calibration is not None:
        per_flops, per_bytes, per_coll = calibration
        # fixed costs = once-counted totals minus one body instance
        flops_adj = flops + (trip_count - 1) * per_flops
        hbm_adj = hbm + (trip_count - 1) * per_bytes
        coll_adj = total_coll + (trip_count - 1) * per_coll
        note = f"calibrated per-layer x{trip_count}"
    elif trip_count > 1:
        # crude: replicate every while-body collective trip_count times;
        # flops/bytes cannot be split without calibration → scale bodies
        coll_adj = total_coll + (trip_count - 1) * body_coll
        flops_adj = flops * trip_count   # upper bound note
        hbm_adj = hbm * trip_count
        note = "crude while-bodyxtrip scaling (use calibration)"
    else:
        flops_adj, hbm_adj, coll_adj = flops, hbm, total_coll

    ma = compiled.memory_analysis()
    args = float(getattr(ma, "argument_size_in_bytes", 0))
    temp = float(getattr(ma, "temp_size_in_bytes", 0))
    outp = float(getattr(ma, "output_size_in_bytes", 0))
    code = float(getattr(ma, "generated_code_size_in_bytes", 0))
    # donated steps (train: params/opt, decode: cache) alias outputs onto
    # inputs, so args+temp+code is the honest peak there; the strict sum
    # is the no-donation upper bound. Both are recorded.
    peak = args + temp + outp + code
    return CellCost(flops_adj, hbm_adj, coll_adj, by_kind,
                    args, temp, outp, peak, flops, note)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_ratio: float      # MODEL_FLOPS / (chips·HLO_FLOPs)

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(cost: CellCost, *, chips: int, model_flops: float,
                   links: int = 1) -> Roofline:
    """cost fields are per-device; the brief's formulas divide GLOBAL
    totals by chips — identical numbers either way."""
    compute = cost.flops / PEAK_FLOPS
    memory = cost.hbm_bytes / HBM_BW
    coll = cost.coll_bytes / (LINK_BW * links)
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dom = max(terms, key=terms.get)
    ratio = model_flops / max(chips * cost.flops, 1.0)
    return Roofline(compute, memory, coll, dom, ratio)
