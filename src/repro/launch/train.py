"""Training driver: any registered arch, smoke or full config, with the
fault-tolerance loop wired in (auto-resume, async checkpoints, failure
injection for drills).

Container-scale examples:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 50 --ckpt-dir /tmp/ck --resume auto
  ... --fail-at 20 --fail-at 35   (injected crashes; supervisor restarts)

On a real cluster the same driver runs under the production mesh — the
step bundle carries the shardings; only --mesh changes.
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as CKPT
from repro.configs import get as get_arch
from repro.distributed import fault
from repro.distributed import sharding as SH


def materialize_params(arch, cfg, key):
    if arch.family == "lm":
        from repro.models import transformer as TF
        return TF.init_params(cfg, key)
    if arch.arch_id == "gin-tu":
        raise ValueError("use the bundle's d_in-specialised config")
    from repro.models import recsys as R
    init = {"two-tower-retrieval": R.two_tower_init, "dcn-v2": R.dcnv2_init,
            "bst": R.bst_init, "autoint": R.autoint_init}[arch.arch_id]
    return init(cfg, key)


def synth_batch(structs, rng, vocab_hi: int) -> Dict[str, Any]:
    def mk(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(
                rng.integers(0, vocab_hi, s.shape).astype(np.int32))
        if s.dtype == jnp.bool_:
            return jnp.ones(s.shape, bool)
        return jnp.asarray(rng.normal(size=s.shape).astype(np.float32)
                           ).astype(s.dtype)
    return jax.tree.map(mk, structs)


def run(arch_id: str, *, steps: int, smoke: bool, ckpt_dir: Optional[str],
        ckpt_every: int, resume: bool, injector: fault.FailureInjector,
        shape: str = "train_4k", shape_overrides: Optional[dict] = None
        ) -> int:
    arch = get_arch(arch_id)
    cfg = arch.make_smoke_config() if smoke else arch.make_config()
    axes = SH.Axes(data=("data",), model="model")
    train_shape = shape if shape in arch.shapes else arch.shapes[0]
    bundle = arch.build_bundle(cfg, train_shape, axes, n_dp=1, smoke=smoke,
                               shape_overrides=shape_overrides or {})
    assert bundle.kind == "train", train_shape

    rng = np.random.default_rng(0)
    if arch.family == "lm":
        params = materialize_params(arch, bundle_cfg(bundle, cfg),
                                    jax.random.PRNGKey(0))
        vocab_hi = cfg.vocab
    else:
        params = jax.tree.map(
            lambda s: (jax.random.normal(jax.random.PRNGKey(hash(str(s.shape)) % 2**31),
                                         s.shape) * 0.02).astype(s.dtype)
            if jnp.issubdtype(s.dtype, jnp.floating)
            else jnp.zeros(s.shape, s.dtype),
            bundle.arg_structs[0])
        vocab_hi = 32
    opt_state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             bundle.arg_structs[1])

    start = 0
    ckpt = CKPT.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if resume and ckpt_dir:
        latest = CKPT.latest_step(ckpt_dir)
        if latest is not None:
            state = CKPT.restore(ckpt_dir, latest,
                                 {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest
            print(f"[train] resumed from step {latest}")

    step_fn = jax.jit(bundle.step_fn, donate_argnums=bundle.donate_argnums)
    t0 = time.time()
    for s in range(start, steps):
        injector.check(s)
        batch = synth_batch(bundle.arg_structs[2],
                            np.random.default_rng(1000 + s), vocab_hi)
        if "labels" in batch and batch["labels"].dtype == jnp.int32:
            pass
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (s + 1) % max(1, steps // 10) == 0 or s + 1 == steps:
            loss = float(metrics["loss"])
            print(f"[train] step {s+1:5d} loss {loss:.4f} "
                  f"({(time.time()-t0)/(s-start+1):.2f}s/step)")
        if ckpt and ((s + 1) % ckpt_every == 0 or s + 1 == steps):
            ckpt.save(s + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.wait()
    return steps


def bundle_cfg(bundle, cfg):
    """The bundle may have replaced cfg (moe groups / act specs); for
    param init shapes those replacements are irrelevant — reuse cfg."""
    return cfg


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", choices=["auto", "never"], default="auto")
    ap.add_argument("--fail-at", type=int, action="append", default=[])
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0)
    args = ap.parse_args()

    overrides = {}
    if args.seq_len:
        overrides["seq_len"] = args.seq_len
    if args.batch:
        overrides["global_batch"] = args.batch
        overrides["batch"] = args.batch

    injector = fault.FailureInjector(args.fail_at)

    def attempt(resume: bool) -> int:
        return run(args.arch, steps=args.steps, smoke=args.smoke,
                   ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                   resume=resume and args.resume == "auto",
                   injector=injector, shape_overrides=overrides)

    final = fault.run_with_restarts(attempt)
    print(f"[train] done at step {final}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
