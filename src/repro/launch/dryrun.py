import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init); 512 host devices back both production meshes:
16x16 (single pod) and 2x16x16 (two pods).

Per cell this driver:
  1. builds the StepBundle (ShapeDtypeStruct args — zero allocation),
  2. ``jax.jit(step, in_shardings=…, out_shardings=…).lower().compile()``,
  3. records ``memory_analysis()`` (fits-per-device proof) and
     ``cost_analysis()`` + parsed collective bytes (§Roofline inputs),
  4. for layer-scanned LMs, runs the 1/2-layer unrolled *calibration*
     lowers so scan-body costs are counted exactly
     (launch/analysis.py docstring),
  5. appends a JSON record to the output log.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
      --out artifacts/dryrun.jsonl
  ... --arch grok-1-314b --shape train_4k --mesh single   (one cell)
  ... --include-skips    (also lower rule-skipped cells, e.g. long_500k)
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import common as cc

from repro.configs import get as get_arch, list_archs
from repro.distributed import sharding as SH
from repro.launch import analysis
from repro.launch import mesh as mesh_lib


def to_shardings(mesh, spec_tree):
    """PartitionSpec pytree (possibly a prefix tree) → NamedSharding tree."""
    if spec_tree is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda s: s is None or isinstance(s, P))


def lower_and_compile(bundle: cc.StepBundle, mesh):
    in_sh = tuple(to_shardings(mesh, s) for s in bundle.in_specs)
    out_sh = to_shardings(mesh, bundle.out_specs)
    kwargs: Dict[str, Any] = {"in_shardings": in_sh}
    if out_sh is not None:
        kwargs["out_shardings"] = out_sh
    if bundle.donate_argnums:
        kwargs["donate_argnums"] = bundle.donate_argnums
    jitted = jax.jit(bundle.step_fn, **kwargs)
    # `with mesh:` backs PartitionSpec-based sharding constraints;
    # jax.set_mesh additionally backs shard_map with mesh=None (the
    # distributed top-k serving paths)
    with compat.set_mesh(mesh):
        with mesh:
            lowered = jitted.lower(*bundle.arg_structs)
            compiled = lowered.compile()
    return lowered, compiled


def _lm_calibration(arch, shape, axes, mesh, n_dp: int):
    """Per-layer cost coefficients from unrolled 1- and 2-layer lowers."""
    import dataclasses as dc
    base = arch.make_config()
    vals = {}
    for L in (1, 2):
        cfg = dc.replace(base, n_layers=L, unroll=True, remat=False,
                         loss_chunk=cc.LM_SHAPE_PARAMS[shape]["seq_len"])
        # microbatches=1: the mb scan is another once-counted while body;
        # total math is identical, so the linear model stays exact
        bundle = arch.build_bundle(cfg, shape, axes, n_dp=n_dp,
                                   shape_overrides={"microbatches": 1})
        _, compiled = lower_and_compile(bundle, mesh)
        cost = analysis.analyze_compiled(compiled, trip_count=1)
        vals[L] = cost
    per_flops = vals[2].flops - vals[1].flops
    per_bytes = vals[2].hbm_bytes - vals[1].hbm_bytes
    per_coll = vals[2].coll_bytes - vals[1].coll_bytes
    return (per_flops, per_bytes, per_coll)


def run_cell(arch_id: str, shape: str, mesh_name: str, *,
             smoke: bool = False, calibrate: bool = True
             ) -> Dict[str, Any]:
    arch = get_arch(arch_id)
    multi = mesh_name == "multi"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
    axes = SH.from_mesh(mesh)
    n_dp = 1
    for a in axes.data:
        n_dp *= mesh.shape[a]
    chips = mesh.size

    cfg = arch.make_smoke_config() if smoke else arch.make_config()
    rec: Dict[str, Any] = {
        "arch": arch_id, "shape": shape, "mesh": mesh_name,
        "chips": chips, "ts": time.time(),
    }
    t0 = time.time()
    try:
        bundle = arch.build_bundle(cfg, shape, axes, n_dp=n_dp,
                                   smoke=smoke)
        lowered, compiled = lower_and_compile(bundle, mesh)
        trip = int(bundle.meta.get("scan_trip_count", 1))
        calib = None
        if calibrate and arch.family == "lm" and trip > 1 and not smoke:
            calib = _lm_calibration(arch, shape, axes, mesh, n_dp)
        cost = analysis.analyze_compiled(
            compiled, trip_count=trip,
            default_group=mesh.shape[axes.model], calibration=calib)
        roof = analysis.roofline_terms(
            cost, chips=chips,
            model_flops=float(bundle.meta.get("model_flops", 0.0)))
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            kind=bundle.kind,
            meta={k: v for k, v in bundle.meta.items()
                  if isinstance(v, (int, float, str))},
            cost=dataclasses.asdict(cost),
            roofline=roof.as_dict(),
            calibrated=calib is not None,
        )
    except Exception as e:  # record failures — they are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   compile_s=round(time.time() - t0, 1))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="artifacts/dryrun.jsonl")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--include-skips", action="store_true")
    ap.add_argument("--no-calibration", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    failures = 0
    with open(args.out, "a") as f:
        for arch_id in archs:
            arch = get_arch(arch_id)
            shapes = (arch.shapes if args.shape == "all"
                      else [args.shape])
            for shape in shapes:
                if shape in arch.skip_shapes and not args.include_skips:
                    rec = {"arch": arch_id, "shape": shape,
                           "mesh": "-", "status": "skipped",
                           "reason": arch.skip_shapes[shape]}
                    print(f"[skip] {arch_id:24s} {shape:16s} "
                          f"{arch.skip_shapes[shape][:60]}")
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    continue
                for mesh_name in meshes:
                    rec = run_cell(arch_id, shape, mesh_name,
                                   smoke=args.smoke,
                                   calibrate=not args.no_calibration)
                    ok = rec["status"] == "ok"
                    failures += 0 if ok else 1
                    if ok:
                        r = rec["roofline"]
                        print(f"[ ok ] {arch_id:24s} {shape:16s} "
                              f"{mesh_name:6s} compile={rec['compile_s']:6.1f}s "
                              f"dom={r['dominant']:10s} "
                              f"c={r['compute_s']:.3e} m={r['memory_s']:.3e} "
                              f"x={r['collective_s']:.3e}")
                    else:
                        print(f"[FAIL] {arch_id:24s} {shape:16s} "
                              f"{mesh_name:6s} {rec['error'][:100]}")
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
    print(f"dry-run complete; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
