"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import and only then builds meshes.

Production target: TPU v5e pods.
  single-pod:  (16, 16)      = 256 chips, axes ("data", "model")
  multi-pod:   (2, 16, 16)   = 512 chips, axes ("pod", "data", "model")
The ``pod`` axis composes with ``data`` for gradient reductions and
batch sharding (DCN-crossing collectives live only on that axis).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for {axes} mesh, found {len(devices)} — "
            "run under launch/dryrun.py (it forces 512 host devices)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for sharding tests (uses however many devices exist)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
