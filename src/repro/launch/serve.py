"""Serving driver: the full paper pipeline on the synthetic workload.

Builds the topic corpus, the IVF and HNSW indexes, then serves every
conversation through the selected strategy, reporting the paper's
metrics (MRR@10 / NDCG@3 / NDCG@10), wall-clock, and the
hardware-independent work counters.

  PYTHONPATH=src python -m repro.launch.serve --backend ivf \
      --strategy toploc+ --n-docs 20000 --nprobe 16 --h 64
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hnsw as HN
from repro.core import ivf as IV
from repro.data import synthetic as SY
from repro.serving.engine import ConversationalSearchEngine, ServingConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="ivf",
                    choices=["ivf", "hnsw", "exact"])
    ap.add_argument("--strategy", default="toploc+",
                    choices=["plain", "toploc", "toploc+"])
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--n-topics", type=int, default=64)
    ap.add_argument("--partitions", type=int, default=128)
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--h", type=int, default=32)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--ef", type=int, default=32)
    ap.add_argument("--up", type=int, default=2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--conversations", type=int, default=10)
    ap.add_argument("--turns", type=int, default=8)
    ap.add_argument("--shift-prob", type=float, default=0.1)
    args = ap.parse_args()

    print(f"[serve] building workload: {args.n_docs} docs, "
          f"{args.conversations}x{args.turns} turns")
    wl = SY.make_workload(SY.WorkloadConfig(
        n_docs=args.n_docs, d=args.d, n_topics=args.n_topics,
        n_conversations=args.conversations,
        turns_per_conversation=args.turns, shift_prob=args.shift_prob))

    kw = {}
    if args.backend == "ivf":
        t0 = time.time()
        kw["ivf_index"] = IV.build(jnp.asarray(wl.doc_vecs),
                                   p=args.partitions, iters=8,
                                   key=jax.random.PRNGKey(0))
        print(f"[serve] IVF built in {time.time()-t0:.1f}s "
              f"(p={args.partitions}, Lmax={kw['ivf_index'].lmax})")
    elif args.backend == "hnsw":
        t0 = time.time()
        kw["hnsw_index"] = HN.build(wl.doc_vecs, m=16, ef_construction=64)
        print(f"[serve] HNSW built in {time.time()-t0:.1f}s")
    else:
        kw["doc_vecs"] = jnp.asarray(wl.doc_vecs)

    eng = ConversationalSearchEngine(ServingConfig(
        backend=args.backend, strategy=args.strategy, k=args.k,
        nprobe=args.nprobe, h=args.h, alpha=args.alpha,
        ef_search=args.ef, up=args.up), **kw)

    run = np.zeros((args.conversations, args.turns, args.k), np.int64)
    t0 = time.time()
    for c in range(args.conversations):
        for t in range(args.turns):
            _, ids = eng.query(f"conv{c}",
                               jnp.asarray(wl.conversations[c, t]))
            run[c, t] = ids
        eng.end_conversation(f"conv{c}")
    wall = time.time() - t0

    metrics = SY.evaluate_run(run, wl, k=args.k)
    s = eng.summary()
    print(f"[serve] {args.backend}/{args.strategy}: "
          f"MRR@10={metrics['mrr@10']:.3f} NDCG@3={metrics['ndcg@3']:.3f} "
          f"NDCG@10={metrics['ndcg@10']:.3f}")
    print(f"[serve] wall {wall:.2f}s "
          f"({1e3*wall/(args.conversations*args.turns):.2f} ms/turn); "
          f"work: centroid={s['mean_centroid_dists']:.0f} "
          f"list={s['mean_list_dists']:.0f} graph={s['mean_graph_dists']:.0f} "
          f"refresh_rate={s['refresh_rate']:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
