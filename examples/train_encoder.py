"""End-to-end training driver: train a bi-encoder (Dragon-style dual
towers) with InfoNCE on the synthetic topic corpus for a few hundred
steps, then run the FULL paper pipeline on the learned embeddings:
encode corpus → build IVF → serve conversations with TopLoc.

This is the ~100M-class train driver scaled to the container (pass
--model mini for the 4-layer/256-d variant used by default here; the
real dragon/snowflake configs in repro.configs.encoders lower on the
production mesh via the dry-run).

  PYTHONPATH=src python examples/train_encoder.py --steps 300
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.encoders import small_encoder_config, tiny_encoder_config
from repro.core import ivf, toploc
from repro.core.backend import IVFBackend
from repro.data import synthetic as SY
from repro.models import encoder as E
from repro.optim import grad as G
from repro.optim import optimizers as O
from repro.optim import schedules as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--model", choices=["mini", "tiny"], default="tiny")
    ap.add_argument("--n-docs", type=int, default=4000)
    args = ap.parse_args()

    cfg = (small_encoder_config() if args.model == "mini"
           else tiny_encoder_config())
    wl = SY.make_workload(SY.WorkloadConfig(
        n_docs=args.n_docs, d=32, n_topics=32, n_conversations=4,
        turns_per_conversation=6, seed=11))
    docs_txt, conv_txt = SY.make_text_corpus(wl, vocab=cfg.vocab,
                                             doc_len=cfg.max_len,
                                             query_len=16)

    params = E.init_params(cfg, jax.random.PRNGKey(0))
    opt = O.adamw(S.warmup_cosine(3e-4, 50, args.steps))
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            E.contrastive_loss, has_aux=True)(params, cfg, batch)
        grads, _ = G.clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        return O.apply_updates(params, updates), opt_state, loss, metrics

    rng = np.random.default_rng(0)
    n_topics = wl.topic_centers.shape[0]
    t0 = time.time()
    for s in range(args.steps):
        # a positive pair = (short query, doc) from the same topic
        doc_ids = rng.choice(args.n_docs, args.batch, replace=False)
        d_tok = docs_txt[doc_ids]
        q_tok = np.stack([
            SY.topic_text(rng, int(wl.doc_topic[i]), n_topics, cfg.vocab,
                          16) for i in doc_ids])
        q_tok = np.pad(q_tok, ((0, 0), (0, cfg.max_len - 16)))
        batch = {
            "q_tokens": jnp.asarray(q_tok),
            "q_mask": jnp.asarray(q_tok > 0),
            "d_tokens": jnp.asarray(d_tok),
            "d_mask": jnp.asarray(d_tok > 0),
        }
        params, opt_state, loss, metrics = step(params, opt_state, batch)
        if (s + 1) % max(1, args.steps // 10) == 0:
            print(f"step {s+1:4d}  loss {float(loss):6.3f}  "
                  f"in-batch acc {float(metrics['acc']):5.2f}  "
                  f"({(time.time()-t0)/(s+1):.2f}s/step)")

    # --- full pipeline on LEARNED embeddings --------------------------
    print("\nencoding corpus with the trained doc tower …")
    enc = jax.jit(lambda t, m: E.encode_docs(params, cfg, t, m))
    embs = []
    for i in range(0, args.n_docs, 256):
        tok = jnp.asarray(docs_txt[i: i + 256])
        embs.append(np.asarray(enc(tok, tok > 0)))
    doc_embs = np.concatenate(embs)

    print("building IVF over learned embeddings …")
    index = ivf.build(jnp.asarray(doc_embs), p=32, iters=8,
                      key=jax.random.PRNGKey(1))

    qenc = jax.jit(lambda t, m: E.encode_queries(params, cfg, t, m))
    hits_plain, hits_tl, work_plain, work_tl = 0, 0, 0, 0
    for c in range(conv_txt.shape[0]):
        qt = conv_txt[c]
        qt = np.pad(qt, ((0, 0), (0, cfg.max_len - qt.shape[1])))
        qv = jnp.asarray(np.asarray(qenc(jnp.asarray(qt), qt > 0)))
        bk = IVFBackend(h=8, nprobe=4, alpha=0.1)
        _, ids_p, st_p = toploc.conversation(bk, index, qv, k=10,
                                             mode="plain")
        _, ids_t, st_t = toploc.conversation(bk, index, qv, k=10)
        gold = wl.conv_topics[c]
        hits_plain += sum(wl.doc_topic[np.asarray(ids_p[t, 0])] == gold[t]
                          for t in range(qv.shape[0]))
        hits_tl += sum(wl.doc_topic[np.asarray(ids_t[t, 0])] == gold[t]
                       for t in range(qv.shape[0]))
        work_plain += int(np.asarray(st_p.centroid_dists).sum())
        work_tl += int(np.asarray(st_t.centroid_dists).sum())

    turns = conv_txt.shape[0] * conv_txt.shape[1]
    print(f"\ntopic-precision@1: plain {hits_plain/turns:.2f} "
          f"vs toploc {hits_tl/turns:.2f}; "
          f"centroid work {work_plain} → {work_tl} "
          f"({work_plain/max(work_tl,1):.1f}x less)")


if __name__ == "__main__":
    main()
