"""RAG composition: a decoder LM served WITH a TopLoc retriever.

DESIGN.md §4 notes the LM archs don't use TopLoc in their own steps —
but a retrieval-augmented serving stack calls TopLoc for its retriever
on every conversational turn. This example wires the two first-class
features together: per-turn retrieval through the conversational
engine (centroid cache warm across turns) feeds retrieved doc tokens
into a (tiny, randomly initialised) LM's prefill+decode loop.

The point is the *serving-stack composition* — session state, retrieval
work accounting and decode caching in one loop — not output quality
(the LM is untrained).

  PYTHONPATH=src python examples/rag_serving.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ivf
from repro.data import synthetic as SY
from repro.models import transformer as T
from repro.serving.engine import ConversationalSearchEngine, ServingConfig

# --- corpus + retriever ----------------------------------------------------
N_DOCS, D = 5000, 32
wl = SY.make_workload(SY.WorkloadConfig(
    n_docs=N_DOCS, d=D, n_topics=32, n_conversations=2,
    turns_per_conversation=4, seed=17))
docs_txt, conv_txt = SY.make_text_corpus(wl, vocab=512, doc_len=24,
                                         query_len=8)
index = ivf.build(jnp.asarray(wl.doc_vecs), p=256, iters=6,
                  key=jax.random.PRNGKey(0))
retriever = ConversationalSearchEngine(
    ServingConfig(backend="ivf", strategy="toploc+", nprobe=8, h=32,
                  alpha=0.25, k=3), ivf_index=index)

# --- tiny LM ---------------------------------------------------------------
cfg = T.LMConfig(name="rag-lm", n_layers=2, d_model=64, n_heads=4,
                 n_kv_heads=2, d_head=16, d_ff=128, vocab=512,
                 remat=False, loss_chunk=8)
params = T.init_params(cfg, jax.random.PRNGKey(1))
MAX_LEN, GEN = 96, 8

prefill = jax.jit(lambda p, t: T.prefill(p, cfg, t, MAX_LEN))
decode = jax.jit(lambda p, c, t, l: T.decode_step(p, cfg, c, t, l))

for c in range(conv_txt.shape[0]):
    print(f"\n=== conversation {c} ===")
    for t in range(conv_txt.shape[1]):
        qvec = jnp.asarray(wl.conversations[c, t])
        # 1. retrieve with the conversation-warm TopLoc session
        _, doc_ids = retriever.query(f"conv{c}", qvec)
        # 2. prompt = [retrieved docs] + [query tokens]
        ctx = np.concatenate([docs_txt[d][:16] for d in doc_ids[:3]])
        prompt = np.concatenate([ctx, conv_txt[c, t]])[: MAX_LEN - GEN]
        tokens = jnp.asarray(prompt[None].astype(np.int32))
        # 3. prefill + greedy decode
        logits, cache, clen = prefill(params, tokens)
        out = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(GEN):
            out.append(int(tok[0]))
            logits, cache = decode(params, cache, tok, clen)
            clen = clen + 1
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        rec = retriever.records[-1]
        print(f"turn {t}: retrieved {list(map(int, doc_ids[:3]))} "
              f"(centroid work {rec.centroid_dists}, "
              f"refresh={rec.refreshed}) → generated {out}")

s = retriever.summary()
print(f"\nretriever work/turn: {s['mean_centroid_dists']:.0f} centroid + "
      f"{s['mean_list_dists']:.0f} list dists "
      f"(vs {index.p} centroid dists/turn for plain IVF); "
      f"refresh rate {s['refresh_rate']:.2f}")
