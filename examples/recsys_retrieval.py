"""TopLoc applied to the assigned two-tower-retrieval architecture.

The ``retrieval_cand`` serving shape (1 user vs 10⁶ candidates) is the
paper's problem wearing recsys clothes: repeated queries from the same
user session are topically local over the *item* embedding space.  This
example builds a (reduced) item corpus from a trained-ish two-tower
model, clusters it with IVF, and serves multi-request user sessions
brute-force vs TopLoc_IVF.

  PYTHONPATH=src python examples/recsys_retrieval.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ivf, toploc
from repro.core.backend import IVFBackend
from repro.models import recsys as R

N_ITEMS = 50_000
E_DIM = 32
SESSIONS = 6
REQS = 6

cfg = R.TwoTowerConfig(embed_dim=E_DIM, tower_mlp=(64, 32),
                       user_vocab=1000, item_vocab=N_ITEMS,
                       history_len=8)
params = R.two_tower_init(cfg, jax.random.PRNGKey(0))

# item corpus: encode every item through the item tower (batched)
print("encoding item corpus …")
item_tower = jax.jit(lambda ids: R.item_tower(params, cfg, ids))
corpus = np.concatenate([
    np.asarray(item_tower(jnp.arange(i, min(i + 4096, N_ITEMS))))
    for i in range(0, N_ITEMS, 4096)])

print("clustering items (IVF over the item corpus) …")
index = ivf.build(jnp.asarray(corpus), p=128, iters=8,
                  key=jax.random.PRNGKey(1))

user_tower = jax.jit(lambda u, h: R.user_tower(params, cfg, u, h))
rng = np.random.default_rng(0)

tot_work_brute = tot_work_tl = 0
recall = []
for s in range(SESSIONS):
    uid = jnp.asarray([rng.integers(1000)])
    base_hist = rng.integers(0, N_ITEMS, 8)
    sess = None
    for r in range(REQS):
        # session drift: history shifts by one item per request
        hist = np.roll(base_hist, r)
        hist[0] = rng.integers(0, N_ITEMS)
        uvec = user_tower(uid, jnp.asarray(hist[None]))[0]
        # brute force scores the whole corpus
        ev, ei = ivf.exact_search(jnp.asarray(corpus), uvec[None], 10)
        tot_work_brute += N_ITEMS
        # TopLoc session over the item clusters
        bk = IVFBackend(h=16, nprobe=8, alpha=0.1)
        if sess is None:
            v, ids, sess, st = toploc.start(bk, index, uvec, k=10)
        else:
            v, ids, sess, st = toploc.step(bk, index, sess, uvec, k=10)
        tot_work_tl += int(st.centroid_dists) + int(st.list_dists)
        got = set(np.asarray(ids).tolist())
        gold = set(np.asarray(ei[0]).tolist())
        recall.append(len(got & gold) / 10)

print(f"\nrecall@10 vs brute force: {np.mean(recall):.2f}")
print(f"distance computations: brute {tot_work_brute:,} vs "
      f"TopLoc_IVF {tot_work_tl:,} "
      f"({tot_work_brute/max(tot_work_tl,1):.1f}x less)")
