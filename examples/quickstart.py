"""Quickstart: TopLoc in ~60 lines.

Builds a topic-clustered corpus, an IVF index, and runs one conversation
through plain IVF vs TopLoc_IVF+ — printing the per-turn work and the
identical (or nearly) results.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import ivf, toploc
from repro.core.backend import IVFBackend
from repro.data import synthetic as SY

# 1. a CAsT-like workload: clustered corpus + drifting conversations
wl = SY.make_workload(SY.WorkloadConfig(
    n_docs=10_000, d=64, n_topics=64, n_conversations=1,
    turns_per_conversation=8, query_drift=0.15, seed=7))

# 2. offline indexing: balanced k-means → bucketed IVF
index = ivf.build(jnp.asarray(wl.doc_vecs), p=64, iters=8,
                  key=jax.random.PRNGKey(0))
print(f"IVF index: p={index.p} partitions, Lmax={index.lmax}")

conv = jnp.asarray(wl.conversations[0])       # (turns, d)

# 3. plain IVF: every turn scores all p centroids
backend = IVFBackend(h=16, nprobe=8, alpha=0.1)
_, ids_plain, st_plain = toploc.conversation(
    backend, index, conv, k=10, mode="plain")

# 4. TopLoc_IVF+: turn 0 caches the top-h centroids; follow-ups score
#    only the cache; the |I0| proxy triggers refresh on topic drift
_, ids_tl, st_tl = toploc.conversation(
    backend, index, conv, k=10, mode="toploc")

print("\nturn | plain work | toploc work | |I0| | refreshed | same top-1")
for t in range(conv.shape[0]):
    same = int(ids_plain[t, 0]) == int(ids_tl[t, 0])
    print(f"  {t}  |   {int(st_plain.centroid_dists[t]):5d}    |"
          f"   {int(st_tl.centroid_dists[t]):5d}     |"
          f"  {int(st_tl.i0[t]):2d}  |   {bool(st_tl.refreshed[t])!s:5s}  "
          f"|   {same}")

speedup = (float(st_plain.centroid_dists.sum())
           / float(st_tl.centroid_dists.sum()))
print(f"\ncentroid-selection work reduced {speedup:.1f}x "
      f"(paper reports 4.4-8.7x at full scale with h<<p)")
