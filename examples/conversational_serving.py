"""End-to-end conversational serving: the paper's full pipeline.

corpus → IVF + HNSW indexes → serving engine with per-conversation
TopLoc sessions → multiple interleaved conversations → effectiveness +
latency + work report, for all three strategies.

  PYTHONPATH=src python examples/conversational_serving.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hnsw, ivf
from repro.data import synthetic as SY
from repro.serving.engine import ConversationalSearchEngine, ServingConfig

N_DOCS, D = 8000, 64
wl = SY.make_workload(SY.WorkloadConfig(
    n_docs=N_DOCS, d=D, n_topics=48, n_conversations=6,
    turns_per_conversation=6, query_drift=0.18, shift_prob=0.15, seed=3))

print("building indexes …")
# paper regime: p >> sqrt(n) so the centroid scan dominates; h << p
ivf_idx = ivf.build(jnp.asarray(wl.doc_vecs), p=512, iters=8,
                    key=jax.random.PRNGKey(0))
hnsw_idx = hnsw.build(wl.doc_vecs, m=12, ef_construction=48)

configs = {
    "IVF plain": ServingConfig(backend="ivf", strategy="plain", nprobe=8,
                               k=10),
    "TopLoc_IVF+": ServingConfig(backend="ivf", strategy="toploc+",
                                 nprobe=8, h=64, alpha=0.25, k=10),
    "HNSW plain": ServingConfig(backend="hnsw", strategy="plain",
                                ef_search=24, k=10),
    "TopLoc_HNSW": ServingConfig(backend="hnsw", strategy="toploc",
                                 ef_search=24, up=2, k=10),
}

print(f"\n{'strategy':14s} {'MRR@10':>7s} {'NDCG@10':>8s} {'ms/turn':>8s} "
      f"{'work':>8s} {'refresh':>8s}")
for name, cfg in configs.items():
    eng = ConversationalSearchEngine(
        cfg, ivf_index=ivf_idx if cfg.backend == "ivf" else None,
        hnsw_index=hnsw_idx if cfg.backend == "hnsw" else None)
    run = np.zeros(wl.conversations.shape[:2] + (10,), np.int64)
    # interleave conversations — sessions are independent and sticky
    for t in range(wl.conversations.shape[1]):
        for c in range(wl.conversations.shape[0]):
            _, ids = eng.query(f"c{c}", jnp.asarray(wl.conversations[c, t]))
            run[c, t] = ids
    m = SY.evaluate_run(run, wl)
    s = eng.summary()
    work = (s["mean_centroid_dists"] + s["mean_list_dists"]
            + s["mean_graph_dists"])
    print(f"{name:14s} {m['mrr@10']:7.3f} {m['ndcg@10']:8.3f} "
          f"{s['mean_latency_ms']:8.2f} {work:8.0f} "
          f"{s['refresh_rate']:8.2f}")

print("\nTopLoc rows should match plain effectiveness at a fraction of "
      "the work — the paper's core claim.")
