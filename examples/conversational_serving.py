"""End-to-end conversational serving: the paper's full pipeline.

corpus → IVF + HNSW indexes → serving engine with per-conversation
TopLoc sessions → multiple interleaved conversations → effectiveness +
latency + work report, for all three strategies — then the same traffic
through the *batched* engine (one device dispatch per micro-batch of
concurrent conversations, sessions resident in a SessionStore slab),
which must return bit-identical rankings at higher throughput.

  PYTHONPATH=src python examples/conversational_serving.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hnsw, ivf
from repro.data import synthetic as SY
from repro.serving.engine import (BatchedConversationalSearchEngine,
                                  ConversationalSearchEngine, ServingConfig)

N_DOCS, D = 8000, 64
wl = SY.make_workload(SY.WorkloadConfig(
    n_docs=N_DOCS, d=D, n_topics=48, n_conversations=6,
    turns_per_conversation=6, query_drift=0.18, shift_prob=0.15, seed=3))

print("building indexes …")
# paper regime: p >> sqrt(n) so the centroid scan dominates; h << p
ivf_idx = ivf.build(jnp.asarray(wl.doc_vecs), p=512, iters=8,
                    key=jax.random.PRNGKey(0))
hnsw_idx = hnsw.build(wl.doc_vecs, m=12, ef_construction=48)

configs = {
    "IVF plain": ServingConfig(backend="ivf", strategy="plain", nprobe=8,
                               k=10),
    "TopLoc_IVF+": ServingConfig(backend="ivf", strategy="toploc+",
                                 nprobe=8, h=64, alpha=0.25, k=10),
    "HNSW plain": ServingConfig(backend="hnsw", strategy="plain",
                                ef_search=24, k=10),
    "TopLoc_HNSW": ServingConfig(backend="hnsw", strategy="toploc",
                                 ef_search=24, up=2, k=10),
}

print(f"\n{'strategy':14s} {'MRR@10':>7s} {'NDCG@10':>8s} {'ms/turn':>8s} "
      f"{'work':>8s} {'refresh':>8s}")
for name, cfg in configs.items():
    eng = ConversationalSearchEngine(
        cfg, ivf_index=ivf_idx if cfg.backend == "ivf" else None,
        hnsw_index=hnsw_idx if cfg.backend == "hnsw" else None)
    run = np.zeros(wl.conversations.shape[:2] + (10,), np.int64)
    # interleave conversations — sessions are independent and sticky
    for t in range(wl.conversations.shape[1]):
        for c in range(wl.conversations.shape[0]):
            _, ids = eng.query(f"c{c}", jnp.asarray(wl.conversations[c, t]))
            run[c, t] = ids
    m = SY.evaluate_run(run, wl)
    s = eng.summary()
    work = (s["mean_centroid_dists"] + s["mean_list_dists"]
            + s["mean_graph_dists"])
    print(f"{name:14s} {m['mrr@10']:7.3f} {m['ndcg@10']:8.3f} "
          f"{s['mean_latency_ms']:8.2f} {work:8.0f} "
          f"{s['refresh_rate']:8.2f}")

print("\nTopLoc rows should match plain effectiveness at a fraction of "
      "the work — the paper's core claim.")

# ---------------------------------------------------------------------------
# Batched serving: N interleaved conversations per device dispatch
# ---------------------------------------------------------------------------

N_CONVS, N_TURNS = wl.conversations.shape[:2]
print(f"\nbatched serving — {N_CONVS} interleaved conversations, one "
      f"micro-batch per turn round:")
print(f"{'strategy':14s} {'ms/turn seq':>12s} {'ms/turn batch':>14s} "
      f"{'speedup':>8s} {'identical':>10s}")
for name, cfg in configs.items():
    seq = ConversationalSearchEngine(
        cfg, ivf_index=ivf_idx if cfg.backend == "ivf" else None,
        hnsw_index=hnsw_idx if cfg.backend == "hnsw" else None)
    def make_batched():
        return BatchedConversationalSearchEngine(
            cfg, ivf_index=ivf_idx if cfg.backend == "ivf" else None,
            hnsw_index=hnsw_idx if cfg.backend == "hnsw" else None,
            n_slots=N_CONVS, max_batch=N_CONVS, max_wait_s=0.0)

    # untimed warmup replay compiles the batched programs (jit cache is
    # process-global, so the timed engine below starts warm but clean)
    warm = make_batched()
    for t in range(N_TURNS):
        for c in range(N_CONVS):
            warm.submit(f"c{c}", jnp.asarray(wl.conversations[c, t]))
        warm.drain()
    bat = make_batched()

    # sequential reference pass (also warms the sequential jit cache)
    seq_ids = {}
    t0 = time.perf_counter()
    for t in range(N_TURNS):
        for c in range(N_CONVS):
            _, ids = seq.query(f"c{c}", jnp.asarray(wl.conversations[c, t]))
            seq_ids[c, t] = ids
    seq_s = time.perf_counter() - t0

    # batched pass: submit a whole turn round, then one flush serves it
    same = True
    t0 = time.perf_counter()
    for t in range(N_TURNS):
        futs = [(c, bat.submit(f"c{c}", jnp.asarray(wl.conversations[c, t])))
                for c in range(N_CONVS)]
        bat.drain()
        for c, fut in futs:
            _, ids = fut.result()
            same &= bool(np.array_equal(ids, seq_ids[c, t]))
    bat_s = time.perf_counter() - t0

    turns = N_CONVS * N_TURNS
    print(f"{name:14s} {seq_s / turns * 1e3:12.2f} "
          f"{bat_s / turns * 1e3:14.2f} {seq_s / bat_s:8.2f}x "
          f"{'yes' if same else 'NO':>10s}")

print("\nThe batched engine serves every conversation's turn in one "
      "dispatch (SessionStore gather → jitted batched TopLoc step → "
      "scatter) and must reproduce the sequential rankings exactly.\n"
      "With only 6 conversations the dispatch savings are modest (TopLoc "
      "turns are already tiny); benchmarks/fig3_batched_serving.py sweeps "
      "batch sizes 1/8/32 where batching wins decisively.")
