"""End-to-end behaviour tests for the paper's system.

The full loop on a reduced workload: synthetic conversational corpus →
index build → conversational serving with TopLoc sessions → IR metrics.
Asserts the paper's qualitative claims hold end to end:
  (a) effectiveness of TopLoc ≈ plain ANN (within tolerance),
  (b) work strictly decreases,
  (c) the refresh mechanism fires on the topic-shifted (hard) set and
      recovers effectiveness vs the static cache.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import hnsw, ivf, toploc
from repro.data import synthetic as SY


@pytest.fixture(scope="module")
def system():
    wl = SY.make_workload(SY.WorkloadConfig(
        n_docs=4000, d=32, n_topics=24, n_conversations=6,
        turns_per_conversation=6, query_drift=0.15, shift_prob=0.2,
        seed=5))
    # h << p is the regime where the |I0| proxy discriminates (paper
    # uses h ∈ {512..8192} against p ∈ {2^15..2^18})
    index = ivf.build(jnp.asarray(wl.doc_vecs), p=128, iters=6,
                      key=jax.random.PRNGKey(0))
    return wl, index


def _run_all(index, wl, mode, alpha, h=16, nprobe=4):
    ids_all, work = [], 0
    refreshes = 0
    for c in range(wl.conversations.shape[0]):
        conv = jnp.asarray(wl.conversations[c])
        from repro.core.backend import IVFBackend
        bk = IVFBackend(h=h, nprobe=nprobe, alpha=alpha)
        _, ids, st = toploc.conversation(bk, index, conv, k=10, mode=mode)
        ids_all.append(np.asarray(ids))
        work += int(np.asarray(st.centroid_dists).sum())
        refreshes += int(np.asarray(st.refreshed)[1:].sum())
    metrics = SY.evaluate_run(np.stack(ids_all), wl)
    return metrics, work, refreshes


def test_end_to_end_effectiveness_and_work(system):
    wl, index = system
    m_plain, w_plain, _ = _run_all(index, wl, "plain", -1.0)
    m_tl, w_tl, _ = _run_all(index, wl, "toploc", -1.0)
    m_tlp, w_tlp, r_tlp = _run_all(index, wl, "toploc", 0.3)

    # (a) effectiveness within tolerance of plain (paper: little loss)
    assert m_tlp["ndcg@10"] >= m_plain["ndcg@10"] - 0.08, (m_tlp, m_plain)
    # (b) work strictly decreases (h=16 vs p=128 per turn)
    assert w_tl < 0.5 * w_plain
    # TopLoc_IVF+ cost model (paper §2, Eq. 1): each of the C=6 first
    # turns pays a full scan (p), each of the F=30 follow-ups pays the
    # cache (h), and each refresh pays one extra full scan on top:
    #   W+ = C·p + F·h + r·p.
    # r is data-dependent: shift_prob=0.2 alone gives E[r] ≈ 6 and the
    # |I0| proxy also (correctly) fires on drift, so r ≈ 10 on this
    # seed — a 0.5·W_plain bound would need r ≤ 8.25 and was
    # miscalibrated.  Assert the exact identity, then the regime claim
    # it encodes: W+ ≤ W_plain·(C + r)/T + F·h, i.e. the cache still
    # saves ≥ 40% of plain's centroid work at this refresh rate.
    C, T = wl.conversations.shape[:2]
    F = C * (T - 1)
    assert w_tlp == C * index.p + F * 16 + r_tlp * index.p, (w_tlp, r_tlp)
    assert w_tlp < 0.6 * w_plain
    # (c) refresh fires on the shifted set and closes the static-cache gap
    assert r_tlp > 0
    assert m_tlp["ndcg@10"] >= m_tl["ndcg@10"] - 1e-9


def test_end_to_end_hnsw(system):
    wl, _ = system
    index = hnsw.build(wl.doc_vecs, m=8, ef_construction=32, seed=0)
    ids_t, ids_p = [], []
    work_t = work_p = 0
    for c in range(3):
        conv = jnp.asarray(wl.conversations[c])
        from repro.core.backend import HNSWBackend
        bk = HNSWBackend(ef=24, up=2)
        _, it, st = toploc.conversation(bk, index, conv, k=10)
        _, ip, sp = toploc.conversation(bk, index, conv, k=10,
                                        mode="plain")
        ids_t.append(np.asarray(it))
        ids_p.append(np.asarray(ip))
        work_t += int(np.asarray(st.graph_dists)[1:].sum())
        work_p += int(np.asarray(sp.graph_dists)[1:].sum())
    wl3 = wl._replace(conversations=wl.conversations[:3])
    m_t = SY.evaluate_run(np.stack(ids_t), wl3)
    m_p = SY.evaluate_run(np.stack(ids_p), wl3)
    assert work_t < work_p                       # entry point saves work
    assert m_t["ndcg@10"] >= m_p["ndcg@10"] - 0.1


def test_serving_engine_matches_library_path(system):
    """The engine (session orchestration) must agree with the pure
    library conversation scan."""
    from repro.serving.engine import (ConversationalSearchEngine,
                                      ServingConfig)
    wl, index = system
    conv = jnp.asarray(wl.conversations[0])
    from repro.core.backend import IVFBackend
    _, ids_lib, _ = toploc.conversation(IVFBackend(h=16, nprobe=8),
                                        index, conv, k=10)
    eng = ConversationalSearchEngine(
        ServingConfig(backend="ivf", strategy="toploc", nprobe=8, h=16,
                      k=10), ivf_index=index)
    for t in range(conv.shape[0]):
        _, ids_eng = eng.query("c", conv[t])
        np.testing.assert_array_equal(ids_eng, np.asarray(ids_lib[t]))
