"""Golden cost-model regression: pinned TurnStats for all three backends.

The paper's speedup claims reduce to the work counters; an optimization
that silently changes them (scans a different number of lists, skips the
drift check, re-ranks a different depth) would invalidate the reported
accounting even if retrieval quality looks fine.  This test pins the
*exact* per-turn counters of a fixed-seed 8-turn conversation on IVF,
IVF-PQ and HNSW so any such change fails loudly and must be justified in
review.

The pinned values also encode the PQ cost-model identity: TopLoc_IVFPQ
pays the same centroid work and the same |I0| refresh schedule as float
TopLoc_IVF, its ``code_dists`` equal the float backend's ``list_dists``
(same posting lists, scanned compressed), and its float ``list_dists``
collapse to the re-rank depth R.

Determinism scope: fixed seeds end-to-end (workload, k-means, PQ
codebooks, HNSW build) on the CPU backend CI runs — the same platform
the tier-1 suite targets.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import hnsw, ivf, pq, toploc
from repro.core.backend import HNSWBackend, IVFBackend, IVFPQBackend

H, NPROBE, K, ALPHA, RERANK, EF, UP = 16, 4, 10, 0.3, 32, 16, 2
IVF_BK = IVFBackend(h=H, nprobe=NPROBE, alpha=ALPHA)
PQ_BK = IVFPQBackend(h=H, nprobe=NPROBE, alpha=ALPHA, rerank=RERANK)
HNSW_BK = HNSWBackend(ef=EF, up=UP)

GOLD_IVF = {
    "centroid_dists": [32, 16, 16, 16, 16, 48, 16, 16],
    "list_dists": [161, 149, 149, 168, 184, 206, 220, 212],
    "graph_dists": [0, 0, 0, 0, 0, 0, 0, 0],
    "code_dists": [0, 0, 0, 0, 0, 0, 0, 0],
    "i0": [-1, 3, 3, 3, 3, 1, 3, 3],
    "refreshed": [1, 0, 0, 0, 0, 1, 0, 0],
}
GOLD_IVF_PQ = {
    "centroid_dists": [32, 16, 16, 16, 16, 48, 16, 16],
    "list_dists": [32, 32, 32, 32, 32, 32, 32, 32],      # = RERANK
    "graph_dists": [0, 0, 0, 0, 0, 0, 0, 0],
    "code_dists": [161, 149, 149, 168, 184, 206, 220, 212],
    "i0": [-1, 3, 3, 3, 3, 1, 3, 3],
    "refreshed": [1, 0, 0, 0, 0, 1, 0, 0],
}
GOLD_HNSW = {
    "centroid_dists": [0, 0, 0, 0, 0, 0, 0, 0],
    "list_dists": [0, 0, 0, 0, 0, 0, 0, 0],
    "graph_dists": [315, 186, 178, 164, 183, 173, 178, 169],
    "code_dists": [0, 0, 0, 0, 0, 0, 0, 0],
    "i0": [-1, -1, -1, -1, -1, -1, -1, -1],
    "refreshed": [1, 0, 0, 0, 0, 0, 0, 0],
}


@pytest.fixture(scope="module")
def golden_setup():
    from repro.data import synthetic as SY
    wl = SY.make_workload(SY.WorkloadConfig(
        n_docs=1500, d=32, n_topics=12, n_conversations=2,
        turns_per_conversation=8, query_drift=0.15, walk_step=0.05,
        shift_prob=0.15, seed=7))
    fidx = ivf.build(jnp.asarray(wl.doc_vecs), p=32, iters=5,
                     key=jax.random.PRNGKey(0))
    pqi = pq.build_ivf_pq(fidx, jnp.asarray(wl.doc_vecs), m=8, iters=6,
                          key=jax.random.PRNGKey(0))
    hidx = hnsw.build(wl.doc_vecs[:800], m=8, ef_construction=32, seed=0)
    return jnp.asarray(wl.conversations[0]), fidx, pqi, hidx


def _check(stats: toploc.TurnStats, gold: dict) -> None:
    for field, expect in gold.items():
        got = np.asarray(getattr(stats, field)).astype(int).tolist()
        assert got == expect, (field, got, expect)


def test_golden_ivf_counters(golden_setup):
    conv, fidx, _, _ = golden_setup
    _, _, st = toploc.conversation(IVF_BK, fidx, conv, k=K)
    _check(st, GOLD_IVF)


def test_golden_ivf_pq_counters(golden_setup):
    conv, _, pqi, _ = golden_setup
    _, _, st = toploc.conversation(PQ_BK, pqi, conv, k=K)
    _check(st, GOLD_IVF_PQ)


def test_golden_hnsw_counters(golden_setup):
    conv, _, _, hidx = golden_setup
    _, _, st = toploc.conversation(HNSW_BK, hidx, conv, k=K)
    _check(st, GOLD_HNSW)


@pytest.mark.parametrize("name,bk,gold", [("ivf", IVF_BK, GOLD_IVF),
                                          ("ivf_pq", PQ_BK, GOLD_IVF_PQ)])
def test_golden_fused_counters_equal_classic(golden_setup, name, bk,
                                             gold):
    """The fused megakernel path reports the SAME pinned work counters
    as the 3-dispatch turn it replaces — fusion changes dispatch
    structure, never the cost accounting the paper's claims rest on."""
    conv, fidx, pqi, _ = golden_setup
    index = fidx if name == "ivf" else pqi
    fbk = dataclasses.replace(bk, fused=toploc.FusedTurn())
    _, _, st = toploc.conversation(fbk, index, conv, k=K)
    _check(st, gold)


def test_golden_pq_cost_identity(golden_setup):
    """The structural identity behind the pinned numbers: PQ scans the
    SAME lists as float IVF (code_dists == float list_dists, same
    refresh schedule) while float work collapses to R per turn."""
    conv, fidx, pqi, _ = golden_setup
    _, _, st_f = toploc.conversation(IVF_BK, fidx, conv, k=K)
    _, _, st_q = toploc.conversation(PQ_BK, pqi, conv, k=K)
    np.testing.assert_array_equal(np.asarray(st_q.code_dists),
                                  np.asarray(st_f.list_dists))
    np.testing.assert_array_equal(np.asarray(st_q.centroid_dists),
                                  np.asarray(st_f.centroid_dists))
    np.testing.assert_array_equal(np.asarray(st_q.refreshed),
                                  np.asarray(st_f.refreshed))
    assert np.all(np.asarray(st_q.list_dists) == RERANK)
