"""IVF-PQ extension: codebook training, encoding, ADC search."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ivf, pq


@pytest.fixture(scope="module")
def pq_setup(small_corpus):
    wl = small_corpus
    book = pq.train(jnp.asarray(wl.doc_vecs), m=8, iters=6,
                    key=jax.random.PRNGKey(0))
    codes = pq.encode(book, jnp.asarray(wl.doc_vecs))
    return wl, book, codes


def test_codebook_shapes(pq_setup):
    wl, book, codes = pq_setup
    assert book.codewords.shape == (8, 256, 4)     # d=32, m=8
    assert codes.shape == (wl.doc_vecs.shape[0], 8)
    assert codes.dtype == jnp.uint8


def test_reconstruction_reduces_error(pq_setup):
    """Decoded vectors must be far closer than random codewords."""
    wl, book, codes = pq_setup
    recon = pq.decode(book, codes)
    err = float(jnp.mean(jnp.sum(
        (recon - jnp.asarray(wl.doc_vecs)) ** 2, -1)))
    rng = np.random.default_rng(0)
    rand_codes = jnp.asarray(
        rng.integers(0, 256, codes.shape).astype(np.uint8))
    rand_err = float(jnp.mean(jnp.sum(
        (pq.decode(book, rand_codes) - jnp.asarray(wl.doc_vecs)) ** 2,
        -1)))
    assert err < 0.35 * rand_err


def test_adc_approximates_exact_scores(pq_setup):
    wl, book, codes = pq_setup
    q = jnp.asarray(wl.conversations[0, 0])
    table = pq.adc_table(book, q)
    approx = np.asarray(pq.adc_scores(table, codes[:500]))
    exact = np.asarray(wl.doc_vecs[:500] @ np.asarray(q))
    # correlation is what ranking needs.  Expected bound, not a blind
    # tolerance: at the Lloyd fixed point PQ with m=8 reaches a
    # per-vector reconstruction MSE of E ≈ 0.11 on this unit-norm
    # corpus; quantisation error is near-isotropic, so the score-error
    # variance is ≈ E/d ≈ 3.4e-3 against a score variance of ≈ 3.5e-2,
    # giving corr ≈ sqrt(1 / (1 + 3.4e-3/3.5e-2)) ≈ 0.954 in
    # expectation, minus finite-sample noise over 500 docs → floor 0.92.
    corr = np.corrcoef(approx, exact)[0, 1]
    assert corr > 0.92, corr
    # ADC == dot with the DECODED vectors (exact identity)
    recon = np.asarray(pq.decode(book, codes[:500]))
    np.testing.assert_allclose(approx, recon @ np.asarray(q), rtol=1e-4,
                               atol=1e-4)


def test_toploc_pq_composition(pq_setup, ivf_index):
    """TopLoc prunes WHICH lists, PQ compresses HOW: composed search
    keeps most of the uncompressed recall at 8x smaller lists."""
    wl, book, codes = pq_setup
    # PQ-encode the bucketed posting lists
    gather = jnp.maximum(ivf_index.list_ids, 0)
    list_codes = codes[gather]                     # (p, Lmax, m)
    q = jnp.asarray(wl.conversations[1, 0])
    cache_ids, cache_vecs = ivf.make_cache(ivf_index, q, h=8)
    csims = cache_vecs @ q
    sel = cache_ids[jnp.argsort(-csims)[:4]]
    v_pq, i_pq = pq.adc_search_lists(book, q, list_codes,
                                     ivf_index.list_ids, sel, 10)
    # uncompressed reference over the same lists
    from repro.kernels import ref
    v_ref, i_ref = ref.ivf_scan(q, ivf_index.list_vecs,
                                ivf_index.list_ids, sel, 10)
    overlap = len(set(np.asarray(i_pq).tolist())
                  & set(np.asarray(i_ref).tolist()))
    assert overlap >= 5, overlap   # ≥50% top-10 agreement at 8 bytes/vec
    # compression ratio: 32 f32 dims -> 8 bytes
    assert (32 * 4) / 8 == 16.0
