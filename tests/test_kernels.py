"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref, sorting
from repro.kernels import flash_attention as fa


RNG = np.random.default_rng(42)


# ---------------------------------------------------------------- sorting

@pytest.mark.parametrize("shape", [(16,), (4, 32), (2, 2, 64), (256,)])
def test_bitonic_sort(shape):
    v = jnp.asarray(RNG.normal(size=shape).astype(np.float32))
    i = jnp.broadcast_to(jnp.arange(shape[-1], dtype=jnp.int32), shape)
    sv, si = sorting.bitonic_sort_desc(v, i)
    ref_v = -np.sort(-np.asarray(v), axis=-1)
    np.testing.assert_allclose(np.asarray(sv), ref_v)
    gathered = np.take_along_axis(np.asarray(v), np.asarray(si), axis=-1)
    np.testing.assert_allclose(gathered, ref_v)


@pytest.mark.parametrize("k", [4, 16, 64])
def test_bitonic_merge(k):
    a = np.sort(RNG.normal(size=(3, k)).astype(np.float32))[:, ::-1]
    b = np.sort(RNG.normal(size=(3, k)).astype(np.float32))[:, ::-1]
    mv, _ = sorting.merge_topk_desc(
        jnp.asarray(a.copy()), jnp.zeros((3, k), jnp.int32),
        jnp.asarray(b.copy()), jnp.ones((3, k), jnp.int32))
    expect = -np.sort(-np.concatenate([a, b], -1))[:, :k]
    np.testing.assert_allclose(np.asarray(mv), expect)


# ---------------------------------------------------------- centroid_topk

@pytest.mark.parametrize("b,d,p,k,blk", [
    (1, 32, 256, 8, 64), (8, 64, 512, 16, 128), (4, 128, 1024, 32, 256),
    (3, 48, 1000, 10, 512),      # non-pow2 p/k through ops padding
])
def test_centroid_topk_sweep(b, d, p, k, blk):
    q = jnp.asarray(RNG.normal(size=(b, d)).astype(np.float32))
    c = jnp.asarray(RNG.normal(size=(p, d)).astype(np.float32))
    v, i = ops.centroid_topk(q, c, k, mode="interpret", blk_p=blk)
    rv, ri = ref.centroid_topk(q, c, k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.tpu_only
def test_centroid_topk_kernel_mode_smoke():
    """Compile-and-run the real Pallas TPU kernel (mode='kernel', no
    interpreter).  Auto-skipped off-TPU (see conftest/pytest.ini)."""
    q = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32))
    c = jnp.asarray(RNG.normal(size=(512, 64)).astype(np.float32))
    v, i = ops.centroid_topk(q, c, 8, mode="kernel")
    rv, ri = ref.centroid_topk(q, c, 8)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_centroid_topk_dtypes(dtype):
    q = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32)).astype(dtype)
    c = jnp.asarray(RNG.normal(size=(256, 64)).astype(np.float32)).astype(dtype)
    v, i = ops.centroid_topk(q, c, 8, mode="interpret")
    rv, ri = ref.centroid_topk(q, c, 8)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


# -------------------------------------------------------------- ivf_scan

@pytest.mark.parametrize("b,d,p,lmax,npb,k", [
    (2, 32, 32, 64, 4, 8), (4, 64, 64, 128, 8, 16),
    (1, 32, 16, 100, 4, 10),     # non-pow2 lmax/k through ops padding
])
def test_ivf_scan_sweep(b, d, p, lmax, npb, k):
    lv = RNG.normal(size=(p, lmax, d)).astype(np.float32)
    li = RNG.integers(0, 100000, (p, lmax)).astype(np.int32)
    pad = RNG.uniform(size=(p, lmax)) < 0.25
    li[pad] = -1
    lv[pad] = 0
    q = jnp.asarray(RNG.normal(size=(b, d)).astype(np.float32))
    sel = jnp.asarray(np.stack(
        [RNG.permutation(p)[:npb] for _ in range(b)]).astype(np.int32))
    v, i = ops.ivf_scan(q, jnp.asarray(lv), jnp.asarray(li), sel, k,
                        mode="interpret")
    rv, ri = ref.ivf_scan_batch(q, jnp.asarray(lv), jnp.asarray(li), sel, k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-4,
                               atol=1e-4)


# -------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,h,hkv,s,d,causal", [
    (1, 4, 4, 128, 32, True), (2, 8, 2, 256, 64, True),
    (1, 4, 1, 128, 64, False), (1, 4, 4, 128, 32, False),
])
def test_flash_attention_sweep(b, h, hkv, s, d, causal):
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    o = fa.flash_attention(q, k, v, causal=causal, blk_q=64, blk_kv=64,
                           interpret=True)
    r = ref.mha_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_mla_dims():
    """MLA: value head dim != qk head dim."""
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 48)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, 4, 128, 48)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, 4, 128, 32)).astype(np.float32))
    o = fa.flash_attention(q, k, v, causal=True, blk_q=64, blk_kv=64,
                           interpret=True)
    r = ref.mha_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("b,h,hkv,s,d", [
    (2, 8, 2, 512, 64), (1, 4, 4, 256, 32),
])
def test_flash_decode_sweep(b, h, hkv, s, d):
    q = jnp.asarray(RNG.normal(size=(b, h, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    clen = jnp.asarray(RNG.integers(1, s, b).astype(np.int32))
    o = fa.flash_decode(q, k, v, clen, blk_kv=128, interpret=True)
    r = ref.decode_attention(q, k, v, clen)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_grad_matches_ref():
    q = jnp.asarray(RNG.normal(size=(1, 2, 64, 16)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, 2, 64, 16)).astype(np.float32))

    def f_op(q, k, v):
        return ops.flash_attention(q, k, v, causal=True, mode="ref").sum()

    def f_ref(q, k, v):
        return ref.mha_attention(q, k, v, causal=True).sum()

    g_op = jax.grad(f_op, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_op, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# -------------------------------------------------------- embedding_bag

@pytest.mark.parametrize("v,d,b,l,weighted,agg", [
    (100, 16, 8, 4, False, "sum"), (500, 32, 16, 10, True, "sum"),
    (100, 16, 8, 4, False, "mean"), (256, 64, 4, 20, True, "mean"),
])
def test_embedding_bag_sweep(v, d, b, l, weighted, agg):
    table = jnp.asarray(RNG.normal(size=(v, d)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(-1, v, (b, l)).astype(np.int32))
    w = (jnp.asarray(RNG.uniform(0.5, 2.0, (b, l)).astype(np.float32))
         if weighted else None)
    o = ops.embedding_bag(table, ids, w, agg=agg, mode="interpret")
    r = ref.embedding_bag(table, ids, w, mode=agg)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=1e-5,
                               atol=1e-5)


def test_embedding_bag_grad():
    table = jnp.asarray(RNG.normal(size=(50, 8)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, 50, (4, 3)).astype(np.int32))

    def f(t):
        return (ops.embedding_bag(t, ids, mode="ref") ** 2).sum()

    g = jax.grad(f)(table)
    # only looked-up rows should have gradient
    touched = np.zeros(50, bool)
    touched[np.asarray(ids).reshape(-1)] = True
    gn = np.linalg.norm(np.asarray(g), axis=-1)
    assert np.all(gn[~touched] == 0)
    assert np.all(gn[touched] > 0)
