"""Checkpoint/restart + failure injection: resumed run == continuous run."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as C
from repro.distributed import elastic, fault
from repro.optim import optimizers as O


def _make_step():
    opt = O.adamw(1e-2)

    @jax.jit
    def step(params, opt_state, x):
        def loss(p):
            return jnp.sum((x @ p["w"]) ** 2)
        grads = jax.grad(loss)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return O.apply_updates(params, updates), opt_state

    return opt, step


def _train(ckpt_dir, n_steps, resume, fail: fault.FailureInjector,
           every=2):
    opt, step = _make_step()
    params = {"w": jnp.ones((8, 8))}
    opt_state = opt.init(params)
    start = 0
    if resume:
        latest = C.latest_step(ckpt_dir)
        if latest is not None:
            state = C.restore(ckpt_dir, latest,
                              {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)
                                                    ).astype(np.float32))
    for s in range(start, n_steps):
        fail.check(s)
        params, opt_state = step(params, opt_state, x)
        if (s + 1) % every == 0 or s + 1 == n_steps:
            C.save(ckpt_dir, s + 1, {"params": params, "opt": opt_state})
    return params


def test_restart_bitexact(tmp_path):
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    # continuous run
    p_ref = _train(d1, 10, False, fault.FailureInjector([]))
    # crash at steps 3 and 7, restart from checkpoints
    inj = fault.FailureInjector([3, 7])
    p_rec = fault.run_with_restarts(
        lambda resume: _train(d2, 10, resume, inj))
    np.testing.assert_array_equal(np.asarray(p_ref["w"]),
                                  np.asarray(p_rec["w"]))


def test_injector_fires_once():
    inj = fault.FailureInjector([5])
    with pytest.raises(fault.SimulatedFailure):
        inj.check(5)
    inj.check(5)   # second pass: no raise


def test_supervisor_gives_up():
    inj = fault.FailureInjector(list(range(100)))

    calls = []

    def run(resume):
        calls.append(resume)
        inj.check(len(calls) - 1)
        return 0

    with pytest.raises(fault.SimulatedFailure):
        fault.run_with_restarts(run, max_restarts=3)
    assert len(calls) == 4   # initial + 3 restarts, 4th failure surfaces


def test_atomic_save_leaves_no_partial(tmp_path):
    d = str(tmp_path)
    C.save(d, 1, {"w": jnp.ones((4,))})
    files = os.listdir(d)
    assert files == ["ckpt_0000000001.npz"]
    assert not any(f.endswith(".tmp.npz") for f in files)


def test_restore_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    C.save(d, 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        C.restore(d, 1, {"w": jnp.ones((5,))})
    with pytest.raises(KeyError):
        C.restore(d, 1, {"other": jnp.ones((4,))})


def test_elastic_mesh_shapes():
    assert elastic.choose_mesh_shape(256)[0] == (16, 16)
    assert elastic.choose_mesh_shape(512, multi_pod=True)[0] == (2, 16, 16)
    assert elastic.choose_mesh_shape(24)[0] == (2, 12)
    assert elastic.choose_mesh_shape(7)[0] == (1, 7)
    shape, axes = elastic.choose_mesh_shape(1)
    assert shape == (1, 1)


def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Save from one layout, restore + re-place on another (1-device CPU
    host stands in; the specs path is identical)."""
    from jax.sharding import PartitionSpec as P
    d = str(tmp_path)
    params = {"w": jnp.arange(16.0).reshape(4, 4)}
    C.save(d, 1, params)
    mesh = elastic.make_elastic_mesh(1, preferred_model=1)
    restored = C.restore(d, 1, params)
    placed = elastic.replace_on_mesh(restored, {"w": P()}, mesh)
    np.testing.assert_array_equal(np.asarray(placed["w"]),
                                  np.asarray(params["w"]))
