"""Fused retrieval megakernel: equivalence with the 3-dispatch turn.

Three layers of contract, mirroring how the kernel is wired in:

* **op level** (``kernels.ops.fused_turn*`` / ``fused_scan*``):
  interpret-mode Pallas == jnp oracle at adversarial shapes —
  non-tile-multiple nlist/Lmax, k near nprobe*Lmax, empty probed
  lists — for every precision.  Float is exact (integer-valued inputs
  make dot products order-independent); bf16 compares values only.
* **backend level** (``FusedTurn`` plugin on IVF/IVF-PQ): the fused
  f32 path is bit-identical to the classic 3-dispatch ``plain_batch``
  and sessioned ``start``/``step`` — ids, scores and every TurnStats
  counter.  Quantised paths hold a recall floor against the float ids.
* **sharded level**: ``shard_backend`` propagates the plugin into the
  sharded scan and the result stays bit-identical to single-device.

CPU runs use mode="ref"/"interpret"; the kernel path itself is
TPU-target (tpu_only coverage lives in test_kernels.py).
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ivf, pq, toploc
from repro.core.backend import IVFBackend, IVFPQBackend
from repro.distributed import retrieval as R
from repro.kernels import ops

K = 10
PRECISIONS = ("f32", "int8", "bf16")


def _mk_lists(rng, p, lmax, d, n_docs):
    """Ragged integer-valued posting lists; list 0 is always empty."""
    lv = rng.integers(-4, 5, size=(p, lmax, d)).astype(np.float32)
    li = np.full((p, lmax), -1, np.int32)
    sizes = rng.integers(0, lmax + 1, size=p)
    sizes[0] = 0
    nid = 0
    for pi in range(p):
        for l in range(sizes[pi]):
            li[pi, l] = nid % n_docs
            nid += 1
        lv[pi, sizes[pi]:] = 0
    return jnp.asarray(lv), jnp.asarray(li)


def _check(a, b, exact_ids):
    va, ia = a[0], a[1]
    vb, ib = b[0], b[1]
    np.testing.assert_allclose(np.asarray(va), np.asarray(vb), rtol=1e-5)
    if exact_ids:
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))


# ---------------------------------------------------------------- op level

@pytest.mark.parametrize("p,lmax,d,b,nprobe,k",
                         [(6, 10, 16, 3, 3, 4),     # non-tile-multiple
                          (5, 7, 8, 1, 5, 8),       # k > real candidates
                          (9, 16, 32, 4, 2, 4)])
@pytest.mark.parametrize("precision", PRECISIONS)
def test_fused_turn_ivf_interpret_vs_ref(p, lmax, d, b, nprobe, k,
                                         precision):
    rng = np.random.default_rng(p * 100 + lmax)
    q = jnp.asarray(rng.integers(-4, 5, size=(b, d)).astype(np.float32))
    cents = jnp.asarray(rng.integers(-4, 5, size=(p, d))
                        .astype(np.float32))
    lv, li = _mk_lists(rng, p, lmax, d, n_docs=200)
    exact = precision != "bf16"
    rref = ops.fused_turn(q, cents, lv, li, nprobe=nprobe, k=k,
                          precision=precision, mode="ref")
    rint = ops.fused_turn(q, cents, lv, li, nprobe=nprobe, k=k,
                          precision=precision, mode="interpret")
    _check(rint, rref, exact_ids=exact)
    if exact:
        np.testing.assert_array_equal(np.asarray(rint[2]),
                                      np.asarray(rref[2]))
    # the standalone fused list scan agrees on the same probe set
    sref = ops.fused_scan(q, lv, li, rref[2], k, precision=precision,
                          mode="ref")
    sint = ops.fused_scan(q, lv, li, rref[2], k, precision=precision,
                          mode="interpret")
    _check(sint, sref, exact_ids=exact)
    if precision == "f32":
        fin = np.isfinite(np.asarray(sref[0]))
        np.testing.assert_array_equal(np.asarray(sint[2])[fin],
                                      np.asarray(sref[2])[fin])


@pytest.mark.parametrize("p,lmax,d,b,nprobe,k,m,C",
                         [(6, 10, 16, 3, 3, 4, 4, 16),
                          (5, 8, 8, 2, 4, 8, 2, 8)])
@pytest.mark.parametrize("precision", PRECISIONS)
def test_fused_turn_pq_interpret_vs_ref(p, lmax, d, b, nprobe, k, m, C,
                                        precision):
    rng = np.random.default_rng(p * 10 + m)
    n_docs = 64
    q = jnp.asarray(rng.integers(-4, 5, size=(b, d)).astype(np.float32))
    cents = jnp.asarray(rng.integers(-4, 5, size=(p, d))
                        .astype(np.float32))
    codes = jnp.asarray(rng.integers(0, C, size=(p, lmax, m))
                        .astype(np.uint8))
    li = np.full((p, lmax), -1, np.int32)
    sizes = rng.integers(0, lmax + 1, size=p)
    sizes[0] = 0
    nid = 0
    for pi in range(p):
        for l in range(sizes[pi]):
            li[pi, l] = nid % n_docs
            nid += 1
    li = jnp.asarray(li)
    tables = jnp.asarray(rng.integers(-4, 5, size=(b, m, C))
                         .astype(np.float32))
    corpus = jnp.asarray(rng.integers(-4, 5, size=(n_docs, d))
                         .astype(np.float32))
    exact = precision != "bf16"
    rref = ops.fused_turn_pq(q, cents, tables, codes, li, corpus,
                             nprobe=nprobe, k=k, rerank=2 * k,
                             precision=precision, mode="ref")
    rint = ops.fused_turn_pq(q, cents, tables, codes, li, corpus,
                             nprobe=nprobe, k=k, rerank=2 * k,
                             precision=precision, mode="interpret")
    _check(rint, rref, exact_ids=exact)
    for fuse_rerank in (True, False):
        sref = ops.fused_scan_pq(tables, q, codes, li, rref[2], corpus,
                                 k, rerank=2 * k, precision=precision,
                                 fuse_rerank=fuse_rerank, mode="ref")
        sint = ops.fused_scan_pq(tables, q, codes, li, rref[2], corpus,
                                 k, rerank=2 * k, precision=precision,
                                 fuse_rerank=fuse_rerank,
                                 mode="interpret")
        _check(sint, sref, exact_ids=exact)


def test_fused_turn_all_probed_lists_empty():
    """Every probed list empty -> all ids -1, scores -inf, no crash."""
    rng = np.random.default_rng(0)
    p, lmax, d, b = 4, 6, 8, 2
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    cents = jnp.asarray(rng.normal(size=(p, d)).astype(np.float32))
    lv = jnp.zeros((p, lmax, d), jnp.float32)
    li = jnp.full((p, lmax), -1, jnp.int32)
    for mode in ("ref", "interpret"):
        v, i, _ = ops.fused_turn(q, cents, lv, li, nprobe=2, k=4,
                                 mode=mode)
        assert np.all(np.asarray(i) == -1)
        assert np.all(np.isneginf(np.asarray(v)))


# ----------------------------------------------------------- backend level

@pytest.fixture(scope="module")
def fused_setup():
    from repro.data import synthetic as SY
    wl = SY.make_workload(SY.WorkloadConfig(
        n_docs=1200, d=32, n_topics=12, n_conversations=3,
        turns_per_conversation=5, seed=3))
    idx = ivf.build(jnp.asarray(wl.doc_vecs), p=24, iters=4,
                    key=jax.random.PRNGKey(0))
    pqi = pq.build_ivf_pq(idx, jnp.asarray(wl.doc_vecs), m=8, iters=4,
                          key=jax.random.PRNGKey(0))
    q = jnp.asarray(wl.conversations.reshape(-1, 32)[:7])
    return idx, pqi, q


BACKENDS = [("ivf", IVFBackend(h=16, nprobe=4)),
            ("ivf_pq", IVFPQBackend(h=16, nprobe=4, rerank=32))]


def _eq_stats(a, b, ctx):
    for f in toploc.TurnStats._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: TurnStats.{f}")


@pytest.mark.parametrize("name,base", BACKENDS)
def test_fused_plain_batch_f32_bit_identical(fused_setup, name, base):
    idx, pqi, q = fused_setup
    index = idx if name == "ivf" else pqi
    v0, i0, st0 = base.plain_batch(index, q, k=K)
    fb = dataclasses.replace(base, fused=toploc.FusedTurn())
    v1, i1, st1 = fb.plain_batch(index, q, k=K)
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    _eq_stats(st0, st1, name)


@pytest.mark.parametrize("name,base", BACKENDS)
@pytest.mark.parametrize("precision", ("int8", "bf16"))
def test_fused_plain_batch_quantized_recall(fused_setup, name, base,
                                            precision):
    idx, pqi, q = fused_setup
    index = idx if name == "ivf" else pqi
    _, ri, _ = base.plain_batch(index, q, k=K)
    fb = dataclasses.replace(base,
                             fused=toploc.FusedTurn(precision=precision))
    _, gi, _ = fb.plain_batch(index, q, k=K)
    ri, gi = np.asarray(ri), np.asarray(gi)
    rec = np.mean([len(set(ri[r]) & set(gi[r])) / K
                   for r in range(ri.shape[0])])
    assert rec >= 0.9, (name, precision, rec)


@pytest.mark.parametrize("name,base", BACKENDS)
def test_fused_sessioned_start_step_bit_identical(fused_setup, name,
                                                  base):
    idx, pqi, q = fused_setup
    index = idx if name == "ivf" else pqi
    fb = dataclasses.replace(base, fused=toploc.FusedTurn())
    v0a, i0a, sa, st0a = base.start(index, q[0], k=K)
    v0b, i0b, sb, st0b = fb.start(index, q[0], k=K)
    np.testing.assert_array_equal(np.asarray(v0a), np.asarray(v0b))
    np.testing.assert_array_equal(np.asarray(i0a), np.asarray(i0b))
    _eq_stats(st0a, st0b, name + " start")
    v1a, i1a, _, st1a = base.step(index, sa, q[1], k=K)
    v1b, i1b, _, st1b = fb.step(index, sb, q[1], k=K)
    np.testing.assert_array_equal(np.asarray(v1a), np.asarray(v1b))
    np.testing.assert_array_equal(np.asarray(i1a), np.asarray(i1b))
    _eq_stats(st1a, st1b, name + " step")


# ----------------------------------------------------------- sharded level

@pytest.mark.parametrize("shards",
                         [s for s in (1, 2, 4) if s <= jax.device_count()])
@pytest.mark.parametrize("name,base", BACKENDS)
def test_fused_sharded_bit_identical(fused_setup, name, base, shards):
    idx, pqi, q = fused_setup
    index = idx if name == "ivf" else pqi
    single = base.plain_batch(index, q, k=K)
    fb = dataclasses.replace(base, fused=toploc.FusedTurn())
    mesh = R.retrieval_mesh(shards)
    sh_b, sh_i = R.shard_backend(mesh, fb, index)
    assert sh_b.scan is not None and sh_b.scan.fused is not None, (
        "shard_backend must propagate the fused plugin into the scan")
    v, i, st = sh_b.plain_batch(sh_i, q, k=K)
    np.testing.assert_array_equal(np.asarray(single[0]), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(single[1]), np.asarray(i))
    _eq_stats(single[2], st, f"sharded {name} s={shards}")
