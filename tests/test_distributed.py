"""Distributed building blocks on a single host: shard_map collectives
run on a 1-device mesh (semantics identical; production meshes are
exercised by launch/dryrun.py)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import ivf

from repro.core.topk import distributed_topk
from repro.distributed import collectives as COL
from repro.distributed import sharding as SH


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("model",))


def test_sharded_corpus_topk_matches_exact(mesh1, rng):
    corpus = jnp.asarray(rng.normal(size=(256, 16)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    v, i = COL.sharded_corpus_topk(mesh1, corpus, q, 10)
    ev, ei = ivf.exact_search(corpus, q, 10)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))
    np.testing.assert_allclose(np.asarray(v), np.asarray(ev), rtol=1e-5)


def test_sharded_ivf_probe_matches_local(mesh1, rng):
    from repro.kernels import ref
    p, lmax, d, b, npb, k = 16, 32, 8, 3, 4, 5
    lv = jnp.asarray(rng.normal(size=(p, lmax, d)).astype(np.float32))
    li = jnp.asarray(rng.integers(0, 10_000, (p, lmax)).astype(np.int32))
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    sel = jnp.asarray(np.stack([rng.permutation(p)[:npb]
                                for _ in range(b)]).astype(np.int32))
    v, i = COL.sharded_ivf_probe(mesh1, lv, li, q, sel, k)
    rv, ri = ref.ivf_scan_batch(q, lv, li, sel, k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_distributed_topk_single_axis(mesh1, rng):
    v = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 100, (2, 8)).astype(np.int32))

    def f(v, ids):
        return distributed_topk(v, ids, 4, "model")

    out_v, out_i = compat.shard_map(f, mesh=mesh1,
                                 in_specs=(P(), P()),
                                 out_specs=(P(), P()),
                                 check_vma=False)(v, ids)
    ev, pos = jax.lax.top_k(v, 4)
    np.testing.assert_allclose(np.asarray(out_v), np.asarray(ev),
                               rtol=1e-6)


def test_lm_param_specs_match_param_tree():
    """Spec pytrees must mirror the param pytrees structurally for every
    LM arch (a mismatch kills the dry-run)."""
    from repro.configs import get
    from repro.models import transformer as TF
    ax = SH.Axes(data=("data",), model="model")
    for arch_id in ("grok-1-314b", "deepseek-v2-lite-16b", "qwen1.5-4b",
                    "qwen3-14b", "yi-9b"):
        cfg = get(arch_id).make_smoke_config()
        structs = jax.eval_shape(
            lambda: TF.init_params(cfg, jax.random.PRNGKey(0)))
        specs = SH.lm_param_specs(cfg, ax)
        # structural zip: raises on mismatch
        jax.tree.map(lambda sp, st: None, specs, structs,
                     is_leaf=lambda x: isinstance(x, P))


def test_opt_specs_match_opt_tree():
    from repro.configs import get
    from repro.models import transformer as TF
    from repro.optim import optimizers as O
    ax = SH.Axes(data=("data",), model="model")
    cfg = get("grok-1-314b").make_smoke_config()
    structs = jax.eval_shape(
        lambda: TF.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = SH.lm_param_specs(cfg, ax)
    for name, opt in (("adamw", O.adamw(1e-3)),
                      ("adafactor", O.adafactor(1e-3))):
        ostructs = jax.eval_shape(opt.init, structs)
        ospecs = SH.lm_opt_specs(name, pspecs, structs)
        jax.tree.map(lambda sp, st: None, ospecs, ostructs,
                     is_leaf=lambda x: isinstance(x, P))


def test_axes_from_mesh():
    m1 = jax.make_mesh((1, 1), ("data", "model"))
    ax = SH.from_mesh(m1)
    assert ax.data == ("data",) and ax.model == "model"


def test_compressed_psum_under_shard_map(mesh1, rng):
    """int8 error-feedback all-reduce compiles + matches fp32 mean on a
    1-shard mesh (numerics identical path to multi-shard)."""
    from repro.optim import grad as G
    g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
    e = {"w": jnp.zeros((32,))}

    def f(gw, ew):
        deq, new_e = G.compressed_mean({"w": gw}, {"w": ew},
                                       axis_name="model")
        return deq["w"], new_e["w"]

    deq, new_e = compat.shard_map(f, mesh=mesh1, in_specs=(P(), P()),
                               out_specs=(P(), P()),
                               check_vma=False)(g["w"], e["w"])
    # 1 shard: compressed mean == dequantised value; error bounded
    q_err = np.abs(np.asarray(deq) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).max() / 127
    assert q_err.max() <= scale * 0.51
    np.testing.assert_allclose(np.asarray(new_e),
                               np.asarray(g["w"]) - np.asarray(deq),
                               rtol=1e-5, atol=1e-7)


def test_adaptive_entry_point_mode(hnsw_index, small_corpus):
    from repro.core import toploc
    from repro.core.backend import HNSWBackend
    conv = jnp.asarray(small_corpus.conversations[0])
    v, i, st = toploc.conversation(HNSWBackend(ef=16, adaptive=True),
                                   hnsw_index, conv, k=5)
    assert bool(jnp.isfinite(v).all())
    assert np.asarray(st.graph_dists)[1:].min() > 0
