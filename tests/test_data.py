"""Data substrate: synthetic workload properties, tokenizer, pipeline."""
import numpy as np
import jax.numpy as jnp

from repro.data import pipeline as PL
from repro.data import synthetic as SY
from repro.data import tokenizer as TK


def test_workload_structure(small_corpus):
    wl = small_corpus
    n = wl.doc_vecs.shape[0]
    # unit norm corpus
    np.testing.assert_allclose(np.linalg.norm(wl.doc_vecs, axis=1), 1.0,
                               rtol=1e-5)
    # qrels exist for every (conv, turn) with grades in 1..3
    for (c, t), g in wl.qrels.items():
        assert len(g) == 20
        assert set(g.values()) <= {1, 2, 3}
    # conversations stay near their topic centre
    sims = np.einsum("ctd,ctd->ct", wl.conversations,
                     wl.topic_centers[wl.conv_topics])
    assert sims.mean() > 0.6


def test_exact_search_is_metric_upper_bound(small_corpus):
    """Exact search gets (near-)perfect metrics by construction of qrels."""
    wl = small_corpus
    scores = wl.conversations.reshape(-1, 32) @ wl.doc_vecs.T
    top10 = np.argsort(-scores, -1)[:, :10].reshape(
        wl.conversations.shape[0], -1, 10)
    m = SY.evaluate_run(top10, wl)
    assert m["mrr@10"] == 1.0
    assert m["ndcg@10"] > 0.95


def test_hard_set_is_harder():
    easy = SY.make_workload(SY.WorkloadConfig(
        n_docs=1000, d=32, n_topics=8, n_conversations=4,
        turns_per_conversation=6, shift_prob=0.0, seed=1))
    hard = SY.make_workload(SY.WorkloadConfig(
        n_docs=1000, d=32, n_topics=8, n_conversations=4,
        turns_per_conversation=6, shift_prob=0.4, seed=1))
    # topic shifts: in the hard set consecutive turns change topic more
    easy_changes = (np.diff(easy.conv_topics, axis=1) != 0).mean()
    hard_changes = (np.diff(hard.conv_topics, axis=1) != 0).mean()
    assert hard_changes > easy_changes


def test_tokenizer_deterministic_and_padded():
    ids1, m1 = TK.encode("hello world of retrieval", 1000, 12)
    ids2, m2 = TK.encode("hello world of retrieval", 1000, 12)
    np.testing.assert_array_equal(ids1, ids2)
    assert ids1[0] == TK.CLS
    assert m1[:5].all() and not m1[5:].any()
    assert (ids1[m1] >= 0).all() and (ids1 < 1000).all()
    batch, masks = TK.encode_batch(["a b", "c d e"], 1000, 8)
    assert batch.shape == (2, 8)


def test_text_corpus_topic_signal(small_corpus):
    docs, queries = SY.make_text_corpus(small_corpus, vocab=1024,
                                        doc_len=32, query_len=8)
    assert docs.shape == (small_corpus.doc_vecs.shape[0], 32)
    assert (docs[:, 0] == 1).all()          # CLS
    # same-topic docs share vocabulary band
    t0 = np.where(small_corpus.doc_topic == 0)[0][:2]
    t1 = np.where(small_corpus.doc_topic == 1)[0][:2]
    if len(t0) == 2 and len(t1) == 2:
        def band(x):
            toks = x[x >= 512]
            return set(toks.tolist())
        same = len(band(docs[t0[0]]) & band(docs[t0[1]]))
        diff = len(band(docs[t0[0]]) & band(docs[t1[0]]))
        assert same >= diff


def test_batch_iterator_epochs():
    data = {"x": np.arange(10), "y": np.arange(10) * 2}
    it = PL.batch_iterator(data, 4, shuffle=False)
    b1 = next(it)
    assert b1["x"].shape == (4,)
    np.testing.assert_array_equal(b1["y"], b1["x"] * 2)
    # drop_remainder: two batches per epoch, then wraps
    batches = [next(it) for _ in range(3)]
    assert all(b["x"].shape == (4,) for b in batches)


def test_prefetcher():
    it = iter(range(20))
    pf = PL.Prefetcher(it, depth=2)
    got = [next(pf) for _ in range(20)]
    assert got == list(range(20))
    pf.close()


def test_sample_trees_format():
    from repro.data import graph as GR
    src, dst, feats, labels = GR.sbm_graph(300, 2000, 4, d_feat=8, seed=0)
    csr = GR.edges_to_csr(src, dst, 300)
    samp = GR.NeighborSampler(csr, feats, labels, fanouts=(3, 2), seed=0)
    batch = samp.sample_trees(np.arange(8))
    tn = 1 + 3 + 6
    assert batch["x"].shape == (8, tn, 8)
    assert batch["edge_src"].shape == (8, tn - 1)
    # every valid edge points child -> ancestor (dst index < src index)
    em = batch["edge_mask"]
    assert (batch["edge_dst"][em] < batch["edge_src"][em]).all()
    # root features match the seeds
    np.testing.assert_allclose(batch["x"][:, 0], feats[np.arange(8)])
    # and it feeds the gin tree loss
    import jax, jax.numpy as jnp
    from repro.models import gnn
    cfg = gnn.GINConfig(n_layers=2, d_hidden=8, d_in=8, n_classes=4)
    params = gnn.init_params(cfg, jax.random.PRNGKey(0))
    def tree_logits(x, es, ed, em):
        return gnn.forward_node(params, cfg, x, es, ed, em)[0]
    logits = jax.vmap(tree_logits)(
        jnp.asarray(batch["x"]), jnp.asarray(batch["edge_src"]),
        jnp.asarray(batch["edge_dst"]), jnp.asarray(batch["edge_mask"]))
    assert logits.shape == (8, 4)
    assert bool(jnp.isfinite(logits).all())
