"""TopLoc_IVFPQ backend: PQ-compressed posting lists + ADC + re-rank.

Covers the PR-3 acceptance criteria at unit scale: the index container,
the ADC-scan + exact-re-rank turn functions (sequential, batched,
conversation scan), the Pallas kernel vs the reference path, the cost
accounting (``code_dists`` vs ``list_dists``), and the recall floor
against the float TopLoc_IVF backend.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ivf, pq, toploc
from repro.core.backend import IVFBackend, IVFPQBackend
from repro.kernels import ops, ref
from repro.serving import ConversationalSearchEngine, ServingConfig

K, H, NPROBE, RERANK = 10, 16, 4, 32
PQBK = IVFPQBackend(h=H, nprobe=NPROBE, rerank=RERANK)


# ------------------------------------------------------------- container

def test_ivf_pq_index_layout(small_corpus, ivf_index, ivf_pq_index):
    idx = ivf_pq_index
    assert idx.p == ivf_index.p and idx.d == ivf_index.d
    assert idx.m == 8 and idx.list_codes.dtype == jnp.uint8
    assert idx.list_codes.shape == (idx.p, idx.lmax, idx.m)
    assert idx.n_docs == ivf_index.n_docs
    # compression: m bytes/doc vs 4·d bytes/doc (d=32 → 16x)
    assert 4 * idx.d / idx.bytes_per_doc == 16.0
    # codes of real entries match encoding the doc vectors directly
    codes = pq.encode(idx.book, jnp.asarray(small_corpus.doc_vecs))
    gathered = codes[jnp.maximum(idx.list_ids, 0)]
    mask = (idx.list_ids >= 0)[..., None]
    assert bool(jnp.all(jnp.where(mask, gathered == idx.list_codes, True)))


# ----------------------------------------------------- ADC kernel vs ref

@pytest.mark.parametrize("b,m,ncodes,p,lmax,npb,k", [
    (2, 8, 256, 16, 64, 4, 8),
    (1, 4, 256, 8, 100, 4, 10),     # non-pow2 lmax/k through ops padding
    (3, 4, 64, 6, 33, 3, 5),        # small codebook, non-pow2 lmax
])
def test_pq_adc_kernel_matches_ref(b, m, ncodes, p, lmax, npb, k):
    # code spaces are large enough (64^4+) that duplicate code rows —
    # ADC score ties, where bitonic and lax.top_k order legally differ —
    # don't occur; the hypothesis test covers tiny codebooks tie-safely
    rng = np.random.default_rng(7)
    tables = jnp.asarray(rng.normal(size=(b, m, ncodes)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, ncodes, (p, lmax, m))
                        .astype(np.uint8))
    li = rng.integers(0, 10 ** 5, (p, lmax)).astype(np.int32)
    li[rng.uniform(size=(p, lmax)) < 0.3] = -1
    li = jnp.asarray(li)
    sel = jnp.asarray(np.stack(
        [rng.permutation(p)[:npb] for _ in range(b)]).astype(np.int32))
    v, i = ops.pq_adc_scan(tables, codes, li, sel, k, mode="interpret")
    rv, ri = ref.pq_adc_scan_batch(tables, codes, li, sel, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


@pytest.mark.tpu_only
def test_pq_adc_kernel_mode_smoke():
    """Compile-and-run the real Pallas TPU ADC kernel (no interpreter)."""
    rng = np.random.default_rng(0)
    tables = jnp.asarray(rng.normal(size=(4, 8, 256)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 256, (16, 128, 8)).astype(np.uint8))
    li = jnp.asarray(rng.integers(0, 10 ** 5, (16, 128)).astype(np.int32))
    sel = jnp.asarray(np.stack(
        [rng.permutation(16)[:4] for _ in range(4)]).astype(np.int32))
    v, i = ops.pq_adc_scan(tables, codes, li, sel, 8, mode="kernel")
    rv, ri = ref.pq_adc_scan_batch(tables, codes, li, sel, 8)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


# --------------------------------------------------------- turn functions

def test_ivf_pq_start_step_accounting(small_corpus, ivf_pq_index):
    """code_dists counts ADC evals (selected list sizes); list_dists
    counts only the R exact re-rank distances."""
    idx = ivf_pq_index
    conv = jnp.asarray(small_corpus.conversations[0])
    v, i, sess, st = toploc.start(PQBK, idx, conv[0], k=K)
    assert v.shape == (K,) and i.shape == (K,)
    assert int(st.centroid_dists) == idx.p
    assert int(st.list_dists) == RERANK          # lists are bigger than R
    assert int(st.code_dists) > RERANK           # ADC touched every entry
    assert bool(st.refreshed)
    import dataclasses
    v2, i2, sess2, st2 = toploc.step(
        dataclasses.replace(PQBK, alpha=0.3), idx, sess, conv[1], k=K)
    assert int(st2.centroid_dists) in (H, H + idx.p)
    assert int(sess2.turn) == 2
    # both turns return valid doc ids
    assert bool((i >= 0).all()) and bool((i2 >= 0).all())


def test_ivf_pq_rerank_orders_by_exact_scores(small_corpus, ivf_pq_index):
    """Returned scores are EXACT dot products (not ADC approximations),
    descending, and consistent with the returned ids."""
    idx = ivf_pq_index
    q = jnp.asarray(small_corpus.conversations[2, 0])
    v, i, _, _ = toploc.start(PQBK, idx, q, k=K)
    v, i = np.asarray(v), np.asarray(i)
    assert np.all(np.diff(v) <= 1e-6)
    exact = np.asarray(small_corpus.doc_vecs)[i] @ np.asarray(q)
    np.testing.assert_allclose(v, exact, rtol=1e-5, atol=1e-6)


def test_ivf_pq_topk_subset_of_adc_candidates(small_corpus, ivf_pq_index):
    """Re-ranking can only reorder/drop ADC candidates, never add."""
    idx = ivf_pq_index
    q = jnp.asarray(small_corpus.conversations[1, 0])
    cache_ids, cache_vecs = ivf.make_cache(idx, q, h=H)
    sel = cache_ids[:NPROBE]
    tables = toploc._adc_tables(idx, q[None])
    _, cand = ops.pq_adc_scan(tables, idx.list_codes, idx.list_ids,
                              sel[None], RERANK)
    v, i, _, _ = toploc.start(PQBK, idx, q, k=K)
    assert set(np.asarray(i).tolist()) <= set(np.asarray(cand[0]).tolist())


def test_ivf_pq_conversation_modes(small_corpus, ivf_pq_index):
    idx = ivf_pq_index
    conv = jnp.asarray(small_corpus.conversations[0])
    T = conv.shape[0]
    import dataclasses
    v, i, st = toploc.conversation(dataclasses.replace(PQBK, alpha=0.3),
                                   idx, conv, k=K)
    assert i.shape == (T, K)
    # turn 0 pays p, follow-ups pay h (+p on refresh)
    cd = np.asarray(st.centroid_dists)
    assert cd[0] == idx.p and np.all(cd[1:] >= H)
    pv, pi, pst = toploc.conversation(PQBK, idx, conv, k=K, mode="plain")
    assert np.all(np.asarray(pst.centroid_dists) == idx.p)
    assert np.all(np.asarray(pst.code_dists) > 0)


def test_ivf_pq_recall_floor_vs_float(small_corpus, ivf_index,
                                      ivf_pq_index):
    """Acceptance criterion: TopLoc_IVFPQ recall@10 >= 0.9 x float
    TopLoc_IVF recall@10 (both against exact search)."""
    wl = small_corpus
    convs = jnp.asarray(wl.conversations)
    d = convs.shape[-1]
    _, ei = ivf.exact_search(jnp.asarray(wl.doc_vecs),
                             convs.reshape(-1, d), K)
    ei = np.asarray(ei)

    def recall(ids):
        ids = np.asarray(ids).reshape(-1, K)
        return np.mean([len(set(ids[j]) & set(ei[j])) / K
                        for j in range(ei.shape[0])])

    fbk = IVFBackend(h=H, nprobe=NPROBE)
    _, fi, _ = jax.vmap(lambda c: toploc.conversation(
        fbk, ivf_index, c, k=K))(convs)
    _, qi, _ = jax.vmap(lambda c: toploc.conversation(
        PQBK, ivf_pq_index, c, k=K))(convs)
    r_float, r_pq = recall(fi), recall(qi)
    assert r_pq >= 0.9 * r_float, (r_pq, r_float)


# ------------------------------------------------------ sequential engine

def test_ivf_pq_engine_matches_library_path(small_corpus, ivf_pq_index):
    idx = ivf_pq_index
    conv = jnp.asarray(small_corpus.conversations[0])
    _, ids_lib, _ = toploc.conversation(PQBK, idx, conv, k=K)
    eng = ConversationalSearchEngine(
        ServingConfig(backend="ivf_pq", strategy="toploc", nprobe=NPROBE,
                      h=H, k=K, rerank=RERANK), ivf_pq_index=idx)
    for t in range(conv.shape[0]):
        _, ids_eng = eng.query("c", conv[t])
        np.testing.assert_array_equal(ids_eng, np.asarray(ids_lib[t]))
    assert eng.records[0].code_dists > 0
    assert eng.summary()["mean_code_dists"] > 0


def test_ivf_pq_engine_requires_index():
    with pytest.raises(ValueError, match="ivf_pq"):
        ConversationalSearchEngine(ServingConfig(backend="ivf_pq"))
